//! Ablation studies over OPIMA's design choices (DESIGN.md §7).
//!
//! Each ablation removes or varies one architectural mechanism and shows
//! its contribution on ResNet18/MobileNet (4-bit):
//!   A1 — in-waveguide optical accumulation (the PIM "accumulate")
//!   A2 — MDM degree (cross-bank parallelism)
//!   A3 — subarray grouping (vs. single-group COMET-style access)
//!   A4 — MLC write latency (the writeback wall)
//!   A5 — writeback lane budget
//!   A6 — the 1×1 serialization hazard (what if it didn't exist?)

use opima::analyzer::analyze_model;
use opima::cnn::{build_model, Model};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn total_ms(cfg: &OpimaConfig, m: Model) -> f64 {
    analyze_model(cfg, &build_model(m).unwrap(), 4)
        .unwrap()
        .total_ms()
        .raw()
}

fn main() {
    let base = OpimaConfig::paper();

    // A1: optical accumulation depth.
    table_header(
        "A1: in-waveguide optical accumulation (products per readout)",
        &["optical_accum", "resnet18 (ms)", "Δ vs paper"],
    );
    let paper_rn = total_ms(&base, Model::ResNet18);
    for accum in [1usize, 2, 4] {
        let mut cfg = base.clone();
        cfg.pim.optical_accum = accum;
        let t = total_ms(&cfg, Model::ResNet18);
        table_row(&[
            format!("{accum}"),
            format!("{t:.3}"),
            format!("{:+.1}%", 100.0 * (t - paper_rn) / paper_rn),
        ]);
    }

    // A2: MDM degree (banks bounded by modes).
    table_header(
        "A2: MDM degree → concurrent banks",
        &["modes/banks", "resnet18 (ms)", "peak TMAC/s"],
    );
    for banks in [1usize, 2, 4] {
        let mut cfg = base.clone();
        cfg.geometry.banks = banks;
        cfg.geometry.mdm_degree = banks.max(1);
        let t = total_ms(&cfg, Model::ResNet18);
        let p = opima::pim::group::evaluate(&cfg, cfg.geometry.subarray_groups).unwrap();
        table_row(&[
            format!("{banks}"),
            format!("{t:.3}"),
            format!("{:.2}", p.mac_throughput / 1e12),
        ]);
    }

    // A3: single group (COMET-style: no concurrent PIM/memory split).
    table_header(
        "A3: subarray grouping",
        &["groups", "resnet18 (ms)", "rows free for memory"],
    );
    for groups in [1usize, 16] {
        let mut cfg = base.clone();
        cfg.geometry.subarray_groups = groups;
        let t = total_ms(&cfg, Model::ResNet18);
        table_row(&[
            format!("{groups}"),
            format!("{t:.3}"),
            format!("{}", cfg.geometry.subarray_rows - groups),
        ]);
    }

    // A4: MLC write latency sweep — the writeback wall of Fig. 9.
    table_header(
        "A4: OPCM MLC write latency (the writeback wall)",
        &["write_ns", "resnet18 total (ms)", "writeback share"],
    );
    for wns in [100.0, 500.0, 1000.0, 2000.0] {
        let mut cfg = base.clone();
        cfg.timing.write_ns = wns;
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        table_row(&[
            format!("{wns}"),
            format!("{:.3}", a.total_ms().raw()),
            format!("{:.0}%", 100.0 * (a.writeback_ms / a.total_ms())),
        ]);
    }

    // A5: writeback lane budget.
    table_header(
        "A5: concurrent MLC write lanes",
        &["lanes", "vgg16 total (ms)"],
    );
    for lanes in [128usize, 512, 2048] {
        let mut cfg = base.clone();
        cfg.pim.writeback_lanes = lanes;
        table_row(&[format!("{lanes}"), format!("{:.1}", total_ms(&cfg, Model::Vgg16))]);
    }

    // A6: hypothetical fix of the 1×1 hazard (MobileNet's pain).
    table_header(
        "A6: 1×1-kernel serialization (guarded lanes per bank)",
        &["lanes/bank", "mobilenet proc (ms)", "mobilenet total (ms)"],
    );
    for lanes in [2usize, 8, 64, 256] {
        let mut cfg = base.clone();
        cfg.pim.one_by_one_lanes_per_bank = lanes;
        let a = analyze_model(&cfg, &build_model(Model::MobileNet).unwrap(), 4).unwrap();
        table_row(&[
            format!("{lanes}"),
            format!("{:.3}", a.processing_ms.raw()),
            format!("{:.3}", a.total_ms().raw()),
        ]);
    }

    // Sanity: the paper's mechanisms must each matter.
    {
        let mut no_accum = base.clone();
        no_accum.pim.optical_accum = 1;
        assert!(total_ms(&no_accum, Model::ResNet18) >= paper_rn);
        let mut one_bank = base.clone();
        one_bank.geometry.banks = 1;
        one_bank.geometry.mdm_degree = 1;
        assert!(total_ms(&one_bank, Model::ResNet18) > paper_rn);
        let mut fixed_1x1 = base.clone();
        fixed_1x1.pim.one_by_one_lanes_per_bank = 256;
        let mob_paper = total_ms(&base, Model::MobileNet);
        assert!(total_ms(&fixed_1x1, Model::MobileNet) < mob_paper / 1.5);
    }
    println!("\nablation sanity checks passed");

    measure("ablations/full_suite_one_point", 2, 20, || {
        black_box(total_ms(&base, Model::ResNet18));
    });
}
