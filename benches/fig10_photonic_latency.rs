//! Paper Fig. 10: inference latency across the photonic architectures
//! OPIMA (O), CrossLight (C) and PhPIM (P) for the four CNN workloads.
//!
//! Paper shapes: the OPCM architectures (OPIMA, PhPIM) beat CrossLight;
//! OPIMA and PhPIM are comparable with OPIMA lower on average (the
//! abstract's ~3× throughput advantage).

use opima::analyzer::metrics::geomean_ratio;
use opima::baselines::{crosslight::CrossLight, evaluate_opima, phpim::PhPim};
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let models: Vec<Model> = ALL_MODELS
        .iter()
        .copied()
        .filter(|m| *m != Model::Vgg16)
        .collect();
    table_header(
        "Fig. 10: latency (ms) across photonic architectures",
        &["model", "OPIMA (O)", "CrossLight (C)", "PhPIM (P)"],
    );
    let mut opima_l = Vec::new();
    let mut cl_l = Vec::new();
    let mut ph_l = Vec::new();
    for m in &models {
        let net = build_model(*m).unwrap();
        let o = evaluate_opima(&cfg, &net, 4).unwrap();
        let c = CrossLight::default().evaluate(&net, 4);
        let p = PhPim::new(&cfg).evaluate(&net, 4);
        table_row(&[
            m.name().to_string(),
            format!("{:.3}", o.latency_ms.raw()),
            format!("{:.3}", c.latency_ms.raw()),
            format!("{:.3}", p.latency_ms.raw()),
        ]);
        opima_l.push(o.latency_ms.raw());
        cl_l.push(c.latency_ms.raw());
        ph_l.push(p.latency_ms.raw());
    }
    let vs_cl = geomean_ratio(&cl_l, &opima_l);
    let vs_ph = geomean_ratio(&ph_l, &opima_l);
    println!("\ngeomean latency vs OPIMA: CrossLight {vs_cl:.2}×, PhPIM {vs_ph:.2}×");
    println!("(paper: OPCM architectures beat CrossLight; OPIMA ~3× PhPIM throughput)");
    assert!(vs_cl > 1.0, "CrossLight must be slower than OPIMA on average");
    assert!(vs_ph > 1.0, "OPIMA must have lower average latency than PhPIM");
    assert!(
        vs_cl > vs_ph,
        "CrossLight is the slowest photonic platform in Fig. 10"
    );

    let net = build_model(Model::ResNet18).unwrap();
    measure("fig10/three_platform_eval", 3, 50, || {
        black_box(evaluate_opima(&cfg, &net, 4).unwrap());
        black_box(CrossLight::default().evaluate(&net, 4));
        black_box(PhPim::new(&cfg).evaluate(&net, 4));
    });
}
