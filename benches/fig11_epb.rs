//! Paper Fig. 11: energy-per-bit comparison across the seven platforms.
//!
//! Paper geomeans (OPIMA advantage): NP100 78.3×, E7742 157.5×, ORIN
//! 1.7×, PRIME 4.4×, CrossLight 2.2×, PhPIM 137×. Accounting
//! conventions and the ORIN deviation are documented in EXPERIMENTS.md.

use opima::analyzer::metrics::{geomean_ratio, workload_bits};
use opima::baselines::evaluate_all;
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let models: Vec<Model> = ALL_MODELS
        .iter()
        .copied()
        .filter(|m| *m != Model::Vgg16)
        .collect();

    table_header(
        "Fig. 11: EPB (pJ/bit) per platform per model (4-bit workloads)",
        &["model", "OPIMA", "NP100", "E7742", "ORIN", "PRIME", "CrossLight", "PhPIM"],
    );
    let mut ratios = vec![Vec::new(); 6];
    for m in &models {
        let net = build_model(*m).unwrap();
        let bits = workload_bits(&net, 4);
        let rs = evaluate_all(&cfg, &net, 4).unwrap();
        table_row(
            &std::iter::once(m.name().to_string())
                .chain(rs.iter().map(|r| format!("{:.3}", r.epb_pj(bits))))
                .collect::<Vec<_>>(),
        );
        for (i, r) in rs.iter().enumerate().skip(1) {
            ratios[i - 1].push(r.epb_pj(bits) / rs[0].epb_pj(bits));
        }
    }

    let paper = [78.3, 157.5, 1.7, 4.4, 2.2, 137.0];
    let names = ["NP100", "E7742", "ORIN", "PRIME", "CrossLight", "PhPIM"];
    println!("\ngeomean OPIMA advantage (ours vs paper):");
    let ones = vec![1.0; models.len()];
    for i in 0..6 {
        let ours = geomean_ratio(&ratios[i], &ones);
        println!("  {:<11} {:8.1}×   (paper {:.1}×)", names[i], ours, paper[i]);
        // Ordering: OPIMA must win everywhere (ratio > 1).
        assert!(ours > 1.0, "{} must have worse EPB than OPIMA", names[i]);
    }
    // PIM-class platforms must land near the paper's ratios.
    let prime = geomean_ratio(&ratios[3], &ones);
    let cl = geomean_ratio(&ratios[4], &ones);
    let ph = geomean_ratio(&ratios[5], &ones);
    assert!((2.0..9.0).contains(&prime), "PRIME ratio {prime}");
    assert!((1.1..5.0).contains(&cl), "CrossLight ratio {cl}");
    assert!(ph > 50.0, "PhPIM must be in the 100×-class: {ph}");

    let net = build_model(Model::ResNet18).unwrap();
    measure("fig11/evaluate_all_platforms", 3, 50, || {
        black_box(evaluate_all(&cfg, &net, 4).unwrap());
    });
}
