//! Paper Fig. 12: throughput efficiency (FPS/W) across the seven
//! platforms. Paper geomeans (OPIMA advantage): NP100 6.7×, E7742
//! 15.2×, ORIN 8.2×, PRIME 5.7×, CrossLight 1.8×, PhPIM 11.9×.

use opima::analyzer::metrics::geomean_ratio;
use opima::baselines::evaluate_all;
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let models: Vec<Model> = ALL_MODELS
        .iter()
        .copied()
        .filter(|m| *m != Model::Vgg16)
        .collect();

    table_header(
        "Fig. 12: FPS/W per platform per model (4-bit workloads)",
        &["model", "OPIMA", "NP100", "E7742", "ORIN", "PRIME", "CrossLight", "PhPIM"],
    );
    let mut ratios = vec![Vec::new(); 6];
    for m in &models {
        let net = build_model(*m).unwrap();
        let rs = evaluate_all(&cfg, &net, 4).unwrap();
        table_row(
            &std::iter::once(m.name().to_string())
                .chain(rs.iter().map(|r| format!("{:.2}", r.fps_per_w())))
                .collect::<Vec<_>>(),
        );
        for (i, r) in rs.iter().enumerate().skip(1) {
            ratios[i - 1].push(rs[0].fps_per_w() / r.fps_per_w());
        }
    }

    let paper = [6.7, 15.2, 8.2, 5.7, 1.8, 11.9];
    let names = ["NP100", "E7742", "ORIN", "PRIME", "CrossLight", "PhPIM"];
    println!("\ngeomean OPIMA advantage (ours vs paper):");
    let ones = vec![1.0; models.len()];
    for i in 0..6 {
        let ours = geomean_ratio(&ratios[i], &ones);
        println!("  {:<11} {:6.2}×   (paper {:.1}×)", names[i], ours, paper[i]);
        assert!(ours > 1.0, "{} must have worse FPS/W than OPIMA", names[i]);
        // Factors within ~2.5× of the paper's reported values.
        assert!(
            ours / paper[i] < 2.5 && paper[i] / ours < 2.5,
            "{}: {ours:.2} vs paper {}",
            names[i],
            paper[i]
        );
    }
    // Ordering check: E7742 worst, CrossLight closest (as in the paper).
    let gm: Vec<f64> = (0..6).map(|i| geomean_ratio(&ratios[i], &ones)).collect();
    assert!(gm[1] > gm[0], "E7742 worse than NP100");
    assert!(gm[4] < gm[3] && gm[4] < gm[0], "CrossLight closest to OPIMA");

    let net = build_model(Model::MobileNet).unwrap();
    measure("fig12/evaluate_all_platforms", 3, 50, || {
        black_box(evaluate_all(&cfg, &net, 4).unwrap());
    });
}
