//! Paper Fig. 2: GST OPCM cell design-space exploration.
//!
//! Regenerates the three panels (ΔT_s crystalline, ΔT_s amorphous, ΔT
//! contrast) over the width × thickness grid and reports the selected
//! optimum against the paper's (0.48 µm, 20 nm, ΔT ≈ 96%).

use opima::phys::dse::{run, DseSweep};
use opima::util::bench::{black_box, measure, table_header, table_row};

fn main() {
    let sweep = DseSweep::default();
    let r = run(&sweep);

    table_header(
        "Fig. 2(a,b): ΔT_s (%) at selected widths (rows: thickness nm)",
        &["t (nm)", "w=0.40 cryst", "w=0.48 cryst", "w=0.56 cryst", "w=0.48 amorph"],
    );
    let wi = |w: f64| {
        r.widths_um
            .iter()
            .position(|x| (x - w).abs() < 1e-9)
            .unwrap()
    };
    let (w40, w48, w56) = (wi(0.40), wi(0.48), wi(0.56));
    for (ti, t) in r.thicknesses_nm.iter().enumerate() {
        table_row(&[
            format!("{t:.0}"),
            format!("{:.1}", 100.0 * r.grid[ti][w40].dts_crystalline),
            format!("{:.1}", 100.0 * r.grid[ti][w48].dts_crystalline),
            format!("{:.1}", 100.0 * r.grid[ti][w56].dts_crystalline),
            format!("{:.1}", 100.0 * r.grid[ti][w48].dts_amorphous),
        ]);
    }

    table_header(
        "Fig. 2(c): ΔT contrast (%) along w=0.48 µm",
        &["t (nm)", "ΔT (%)", "feasible (ΔT_s<5%)"],
    );
    for (ti, t) in r.thicknesses_nm.iter().enumerate() {
        let p = &r.grid[ti][w48];
        table_row(&[
            format!("{t:.0}"),
            format!("{:.1}", 100.0 * p.contrast),
            format!(
                "{}",
                p.dts_crystalline < 0.05 && p.dts_amorphous < 0.05
            ),
        ]);
    }

    println!(
        "\noptimum: w={:.2} µm t={:.0} nm ΔT={:.1}%  (paper: 0.48 µm, 20 nm, ~96%)",
        r.optimum.width_um,
        r.optimum.thickness_nm,
        100.0 * r.optimum.contrast
    );
    assert!((r.optimum.width_um - 0.48).abs() < 1e-9);
    assert!((r.optimum.thickness_nm - 20.0).abs() < 1e-9);

    measure("fig2/full_dse_sweep", 3, 30, || {
        black_box(run(&sweep));
    });
}
