//! Paper Fig. 6: inverse-designed waveguide crossing — C-band loss
//! profile and crosstalk. Paper: <0.001% insertion loss, ≤ −40 dB
//! crosstalk across the C-band.

use opima::phys::crossing::{c_band_profile, chain_loss_db, CENTER_NM};
use opima::util::bench::{black_box, measure, table_header, table_row};

fn main() {
    table_header(
        "Fig. 6: crossing response over the C-band",
        &["λ (nm)", "insertion loss (%)", "crosstalk (dB)"],
    );
    let profile = c_band_profile(15);
    for p in &profile {
        table_row(&[
            format!("{:.1}", p.wavelength_nm),
            format!("{:.6}", 100.0 * p.insertion_loss),
            format!("{:.1}", p.crosstalk_db),
        ]);
    }
    let worst_loss = profile
        .iter()
        .map(|p| p.insertion_loss)
        .fold(0.0f64, f64::max);
    let worst_xtalk = profile
        .iter()
        .map(|p| p.crosstalk_db)
        .fold(f64::NEG_INFINITY, f64::max);
    println!("\nworst insertion loss: {:.6}% (paper: <0.001%)", 100.0 * worst_loss);
    println!("worst crosstalk: {worst_xtalk:.1} dB (paper: ≤ -40 dB)");
    println!(
        "512-crossing chain loss at band center: {:.4} dB",
        chain_loss_db(512, CENTER_NM)
    );
    assert!(worst_loss < 1e-5);
    assert!(worst_xtalk <= -40.0);

    measure("fig6/c_band_profile_1024pts", 5, 50, || {
        black_box(c_band_profile(1024));
    });
}
