//! Paper Fig. 7: subarray-group selection — normalized power, MAC
//! throughput and rows available for memory vs. group count; the MAC/W
//! optimum must land on 16 groups.

use opima::pim::group::{select_optimal, sweep};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let choices = [1usize, 2, 4, 8, 16, 32, 64];
    let pts = sweep(&cfg, &choices).unwrap();
    let max_power = pts.iter().map(|p| p.power_w).fold(0.0f64, f64::max);
    let max_tp = pts.iter().map(|p| p.mac_throughput).fold(0.0f64, f64::max);

    table_header(
        "Fig. 7: subarray grouping sweep (normalized, as in the paper)",
        &[
            "groups",
            "norm. power",
            "norm. MAC throughput",
            "rows free",
            "GMAC/s/W",
        ],
    );
    for p in &pts {
        table_row(&[
            format!("{}", p.groups),
            format!("{:.2}", p.power_w / max_power),
            format!("{:.2}", p.mac_throughput / max_tp),
            format!("{}", p.rows_available),
            format!("{:.1}", p.macs_per_watt / 1e9),
        ]);
    }
    let best = select_optimal(&cfg).unwrap();
    println!(
        "\nMAC/W optimum: {} groups at {:.1} GMAC/s/W (paper: 16 groups)",
        best.groups,
        best.macs_per_watt / 1e9
    );
    assert_eq!(best.groups, 16);

    measure("fig7/grouping_sweep", 5, 100, || {
        black_box(sweep(&cfg, &choices).unwrap());
    });
}
