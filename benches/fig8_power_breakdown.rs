//! Paper Fig. 8: power breakdown for concurrent PIM + main-memory
//! operation. Paper: 55.9 W total, dominated by the MDL array and the
//! electrical-optical interface.

use opima::analyzer::power::power_breakdown;
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let b = power_breakdown(&cfg);
    table_header(
        "Fig. 8: OPIMA power breakdown",
        &["component", "watts", "share (%)"],
    );
    let total = b.total_w();
    for c in &b.components {
        table_row(&[
            c.name.to_string(),
            format!("{:.2}", c.watts),
            format!("{:.1}", 100.0 * c.watts / total),
        ]);
    }
    println!("\ntotal: {total:.1} W (paper: 55.9 W)");
    println!("dominant: {} ({:.1} W)", b.dominant().name, b.dominant().watts);
    assert!((total - 55.9).abs() / 55.9 < 0.15, "within 15% of paper");
    assert!(
        b.dominant().name == "mdl_array" || b.dominant().name == "eo_interface",
        "paper: MDL array / E-O interface dominate"
    );

    measure("fig8/power_breakdown", 10, 1000, || {
        black_box(power_breakdown(&cfg));
    });
}
