//! Paper Fig. 9: OPIMA latency breakdown (processing vs writeback) for
//! the 4-bit and 8-bit variants of each model.
//!
//! Paper shapes checked here:
//!   - writeback dominates ResNet18 / SqueezeNet / VGG16;
//!   - MobileNet's processing exceeds its writeback (1×1 serialization);
//!   - InceptionV2 and MobileNet have higher processing than ResNet18;
//!   - InceptionV2's total is below ResNet18's;
//!   - 8-bit variants cost ~4× processing and ~2× writeback.

use opima::analyzer::analyze_model;
use opima::cnn::{build_model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    table_header(
        "Fig. 9: latency breakdown (ms)",
        &["model", "processing", "writeback", "total"],
    );
    let mut by_name = std::collections::BTreeMap::new();
    for m in ALL_MODELS {
        let net = build_model(m).unwrap();
        for bits in [4u32, 8] {
            let a = analyze_model(&cfg, &net, bits).unwrap();
            table_row(&[
                a.name.clone(),
                format!("{:.3}", a.processing_ms.raw()),
                format!("{:.3}", a.writeback_ms.raw()),
                format!("{:.3}", a.total_ms().raw()),
            ]);
            by_name.insert(a.name.clone(), a);
        }
    }

    // Paper-shape assertions.
    let g = |n: &str| by_name.get(n).unwrap();
    assert!(g("resnet18_4b").writeback_ms > g("resnet18_4b").processing_ms);
    assert!(g("squeezenet_4b").writeback_ms.raw() > 0.0);
    assert!(g("vgg16_4b").writeback_ms > g("vgg16_4b").processing_ms);
    assert!(g("mobilenet_4b").processing_ms > g("mobilenet_4b").writeback_ms);
    assert!(g("inceptionv2_4b").processing_ms > g("resnet18_4b").processing_ms);
    assert!(g("mobilenet_4b").processing_ms > g("resnet18_4b").processing_ms);
    assert!(g("inceptionv2_4b").total_ms() < g("resnet18_4b").total_ms());
    let ratio = g("resnet18_8b").processing_ms / g("resnet18_4b").processing_ms;
    assert!((3.0..5.0).contains(&ratio), "8b/4b processing ratio {ratio}");
    println!("\nall Fig. 9 shape checks passed");

    // Writeback pricing models (`[memory] writeback_model`): the same
    // batch-8 stream priced under the flat scalar and the two command-
    // level controllers. The scheduled controller may only ever claw
    // time back from the naive reference, and at batch 1 the command
    // decomposition must collapse to the flat figure bit-exactly.
    use opima::analyzer::timeline::simulate_analysis_makespan;
    use opima::config::WritebackModel;
    table_header(
        "Writeback model comparison (batch 8, ms)",
        &["model", "flat", "naive", "scheduled"],
    );
    for m in ALL_MODELS {
        let a = analyze_model(&cfg, &build_model(m).unwrap(), 4).unwrap();
        let mut per = [0.0f64; 3];
        let mut per1 = [0.0f64; 3];
        for (i, wm) in WritebackModel::ALL.iter().enumerate() {
            let mut c = cfg.clone();
            c.memory.writeback_model = *wm;
            c.pipeline.writeback_channels = 2;
            per[i] = simulate_analysis_makespan(&c, &a, 8).makespan_ms().raw();
            c.pipeline.writeback_channels = cfg.pipeline.writeback_channels;
            per1[i] = simulate_analysis_makespan(&c, &a, 1).makespan_ns.raw();
        }
        table_row(&[
            a.name.clone(),
            format!("{:.3}", per[0]),
            format!("{:.3}", per[1]),
            format!("{:.3}", per[2]),
        ]);
        assert!(
            per[2] <= per[1] + 1e-9,
            "{}: scheduled {} above naive {}",
            a.name,
            per[2],
            per[1]
        );
        if m == opima::cnn::Model::ResNet18 {
            assert_eq!(per1[0], per1[1], "naive must recover flat at batch 1");
            assert_eq!(per1[0], per1[2], "scheduled must recover flat at batch 1");
        }
    }
    println!("\nwriteback model ordering checks passed");

    let net = build_model(opima::cnn::Model::ResNet18).unwrap();
    measure("fig9/analyze_resnet18_4b", 3, 50, || {
        black_box(analyze_model(&cfg, &net, 4).unwrap());
    });
}
