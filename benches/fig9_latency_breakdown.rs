//! Paper Fig. 9: OPIMA latency breakdown (processing vs writeback) for
//! the 4-bit and 8-bit variants of each model.
//!
//! Paper shapes checked here:
//!   - writeback dominates ResNet18 / SqueezeNet / VGG16;
//!   - MobileNet's processing exceeds its writeback (1×1 serialization);
//!   - InceptionV2 and MobileNet have higher processing than ResNet18;
//!   - InceptionV2's total is below ResNet18's;
//!   - 8-bit variants cost ~4× processing and ~2× writeback.

use opima::analyzer::analyze_model;
use opima::cnn::{build_model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    table_header(
        "Fig. 9: latency breakdown (ms)",
        &["model", "processing", "writeback", "total"],
    );
    let mut by_name = std::collections::BTreeMap::new();
    for m in ALL_MODELS {
        let net = build_model(m).unwrap();
        for bits in [4u32, 8] {
            let a = analyze_model(&cfg, &net, bits).unwrap();
            table_row(&[
                a.name.clone(),
                format!("{:.3}", a.processing_ms.raw()),
                format!("{:.3}", a.writeback_ms.raw()),
                format!("{:.3}", a.total_ms().raw()),
            ]);
            by_name.insert(a.name.clone(), a);
        }
    }

    // Paper-shape assertions.
    let g = |n: &str| by_name.get(n).unwrap();
    assert!(g("resnet18_4b").writeback_ms > g("resnet18_4b").processing_ms);
    assert!(g("squeezenet_4b").writeback_ms.raw() > 0.0);
    assert!(g("vgg16_4b").writeback_ms > g("vgg16_4b").processing_ms);
    assert!(g("mobilenet_4b").processing_ms > g("mobilenet_4b").writeback_ms);
    assert!(g("inceptionv2_4b").processing_ms > g("resnet18_4b").processing_ms);
    assert!(g("mobilenet_4b").processing_ms > g("resnet18_4b").processing_ms);
    assert!(g("inceptionv2_4b").total_ms() < g("resnet18_4b").total_ms());
    let ratio = g("resnet18_8b").processing_ms / g("resnet18_4b").processing_ms;
    assert!((3.0..5.0).contains(&ratio), "8b/4b processing ratio {ratio}");
    println!("\nall Fig. 9 shape checks passed");

    let net = build_model(opima::cnn::Model::ResNet18).unwrap();
    measure("fig9/analyze_resnet18_4b", 3, 50, || {
        black_box(analyze_model(&cfg, &net, 4).unwrap());
    });
}
