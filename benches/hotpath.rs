//! Hot-path micro/macro benchmarks (§Perf): the components on the
//! serving and analysis critical paths, plus the end-to-end PJRT
//! execution of the AOT artifacts.
//!
//! Results are printed as a table *and* written to `BENCH_hotpath.json`
//! (schema: `util::bench::JsonReport`). `OPIMA_BENCH_SMOKE=1` runs one
//! sample per measurement so CI can validate the JSON schema cheaply.

use std::sync::Arc;
use std::time::Instant;

use opima::analyzer::{analyze_model, simulate_analysis, simulate_analysis_makespan};
use opima::cnn::{build_model, Model};
use opima::coordinator::batcher::DynamicBatcher;
use opima::coordinator::request::{ImageBuf, InferenceRequest, LogitsPool, LogitsView, Variant};
use opima::coordinator::router::Router;
use opima::mapper::map_network;
use opima::memory::MemoryController;
use opima::pim::PimScheduler;
use opima::runtime::{Executor, Manifest};
use opima::util::bench::{black_box, measure, scaled, JsonReport};
use opima::util::prng::Rng;
use opima::util::units::{ms, Millis};
use opima::OpimaConfig;

fn main() {
    let cfg = OpimaConfig::paper();
    let mut report = JsonReport::new("hotpath");

    // --- analyzer path --------------------------------------------------
    let nets: Vec<_> = [Model::ResNet18, Model::Vgg16]
        .iter()
        .map(|&m| build_model(m).unwrap())
        .collect();
    for net in &nets {
        report.add_stats(&measure(&format!("analyze/{}_4b", net.name), 3, scaled(100), || {
            black_box(analyze_model(&cfg, net, 4).unwrap());
        }));
    }
    report.add_stats(&measure("mapper/map_resnet18", 3, scaled(200), || {
        black_box(map_network(&cfg, &nets[0], 4).unwrap());
    }));
    let mapped = map_network(&cfg, &nets[0], 4).unwrap();
    let sched = PimScheduler::new(&cfg).unwrap();
    report.add_stats(&measure("scheduler/cost_network_resnet18", 3, scaled(200), || {
        black_box(sched.cost_network(&mapped.works).unwrap());
    }));
    // The pipelined batch timeline (the registry caches these per
    // (model, variant, batch); this is the cold cost of one schedule).
    let analysis = analyze_model(&cfg, &nets[0], 4).unwrap();
    report.add_stats(&measure("timeline/resnet18_batch32", 3, scaled(200), || {
        black_box(simulate_analysis(&cfg, &analysis, 32));
    }));
    // The makespan-only fast path the registry/cost tables use — same
    // arithmetic, no `batch × layers × 3` event vec.
    report.add_stats(&measure("timeline/resnet18_batch32_makespan_only", 3, scaled(200), || {
        black_box(simulate_analysis_makespan(&cfg, &analysis, 32));
    }));

    // --- memory simulator hot loop ---------------------------------------
    let mut mem = MemoryController::new(&cfg).unwrap();
    let data = vec![0xA5u8; 128];
    let mut addr = 0u64;
    report.add_stats(&measure("memory/write128_read128", 10, scaled(2000), || {
        addr = (addr + 4096) % (1 << 28);
        mem.write(addr, &data).unwrap();
        black_box(mem.read(addr, 128).unwrap());
    }));

    // --- command-level writeback controllers -------------------------------
    // Admission throughput of the naive/scheduled pair on a contended
    // 1k-job stream (8 trains each, ready at half the previous drain so
    // jobs overlap). ci.sh pins both row names; the deterministic
    // makespan row below feeds scripts/bench_gate.py's scheduled ≤ naive
    // ordering check on non-smoke runs.
    {
        use opima::memory::{
            NaiveWritebackController, ScheduledWritebackController, WbJob, WritebackController,
        };
        use opima::util::json::Json;
        use opima::util::units::{ns, Nanos};
        let jobs: Vec<WbJob> = (0..1000u64)
            .map(|id| WbJob {
                id,
                row: id % 48,
                trains: 8,
                train_ns: ns(1000.0),
                settle_ns: ns(120.0),
                flat_ns: ns(8.0 * 1000.0 + 120.0),
            })
            .collect();
        report.add_stats(&measure("memory/writeback_naive_1k", 5, scaled(200), || {
            let mut c = NaiveWritebackController::new(4);
            let mut end = Nanos::ZERO;
            for j in &jobs {
                end = c.admit(Nanos::ZERO, end * 0.5, j).1;
            }
            black_box(end);
        }));
        report.add_stats(&measure("memory/writeback_scheduled_1k", 5, scaled(200), || {
            let mut c = ScheduledWritebackController::new(4, 2);
            let mut end = Nanos::ZERO;
            for j in &jobs {
                end = end.max(c.admit(Nanos::ZERO, end * 0.5, j).1);
            }
            black_box(end);
        }));
        // Value row (no mean_ns ⇒ the timing gate skips it): the two
        // controllers' simulated makespans over the same stream.
        let run = |naive: bool| -> f64 {
            let mut n = NaiveWritebackController::new(4);
            let mut s = ScheduledWritebackController::new(4, 2);
            let mut end = Nanos::ZERO;
            for j in &jobs {
                let c: &mut dyn WritebackController =
                    if naive { &mut n } else { &mut s };
                end = end.max(c.admit(Nanos::ZERO, end * 0.5, j).1);
            }
            end.raw()
        };
        report.add(
            "memory/writeback_model_makespan",
            &[
                ("naive_ns", Json::Num(run(true))),
                ("scheduled_ns", Json::Num(run(false))),
            ],
        );
    }

    // --- coordinator components ------------------------------------------
    let mut rng = Rng::new(1);
    report.add_stats(&measure("batcher/push_flush_batch8", 10, scaled(2000), || {
        let mut b = DynamicBatcher::new(8, std::time::Duration::from_millis(2));
        for id in 0..8u64 {
            let out = b.push(InferenceRequest {
                id,
                model: Model::LeNet,
                image: vec![rng.f64() as f32; 4].into(),
                variant: Variant::Int4,
                arrival: Instant::now(),
                deadline: None,
                reply: None,
            });
            if id == 7 {
                assert!(out.is_some());
                black_box(out);
            }
        }
    }));
    report.add_stats(&measure("router/dispatch_1k", 5, scaled(500), || {
        let mut r = Router::new(4);
        for i in 0..1000 {
            black_box(r.dispatch(ms(i as f64), ms(1.5)));
        }
    }));
    report.add_stats(&measure("router/dispatch_for_occupancy_1k", 5, scaled(500), || {
        let mut r = Router::with_capacity(4, 16_384);
        for i in 0..1000 {
            black_box(r.dispatch_for(Model::ResNet18, 400, ms(i as f64), ms(1.5)));
        }
    }));
    // The global-engine dispatch path: the same 1k-batch workload, but
    // every batch's priced event stream is admitted into the persistent
    // per-instance stage pools (the acceptance bar: within 2× of the
    // occupancy-only row above).
    {
        use opima::analyzer::contention::BatchStream;
        let stream = BatchStream {
            costs: &analysis.layer_costs,
            batch: 8,
            pipelined: analysis.occupancy.fits(),
        };
        let iso_ms = simulate_analysis_makespan(&cfg, &analysis, 8).makespan_ms();
        report.add_stats(&measure("router/dispatch_batch_contended_1k", 5, scaled(500), || {
            let mut r = Router::with_pools(4, 16_384, &cfg.pipeline);
            for i in 0..1000 {
                black_box(r.dispatch_batch(Model::ResNet18, 400, ms(i as f64), stream, iso_ms));
            }
        }));
        // Same admissions with the contention knob off — the optimistic
        // occupancy-only pricing through the dispatch_batch entry point.
        let mut optimistic = cfg.pipeline.clone();
        optimistic.cross_batch_contention = false;
        report.add_stats(&measure("router/dispatch_batch_optimistic_1k", 5, scaled(500), || {
            let mut r = Router::with_pools(4, 16_384, &optimistic);
            for i in 0..1000 {
                black_box(r.dispatch_batch(Model::ResNet18, 400, ms(i as f64), stream, iso_ms));
            }
        }));
    }

    // --- units layer overhead smoke ---------------------------------------
    // The `#[repr(transparent)]` newtypes must be free: the same 10k-step
    // accumulate loop over raw f64 vs `Millis` should optimize to identical
    // code. Two adjacent rows make any regression visible in the JSON.
    report.add_stats(&measure("units/overhead_smoke_raw_f64", 5, scaled(2000), || {
        let mut acc = 0.0f64;
        for i in 0..10_000u64 {
            acc += black_box(i as f64) * 0.001;
        }
        black_box(acc);
    }));
    report.add_stats(&measure("units/overhead_smoke_newtype", 5, scaled(2000), || {
        let mut acc = Millis::ZERO;
        for i in 0..10_000u64 {
            acc += black_box(ms(i as f64)) * 0.001;
        }
        black_box(acc.raw());
    }));

    // --- serving data plane: old copy path vs pooled zero-copy path -------
    // What a worker pays per batch to (a) pack 8 images into the fixed-
    // shape batch input and (b) publish per-request logits. The `_copy`
    // rows replicate the pre-zero-copy engine (fresh Vec per batch,
    // `row.to_vec()` per response); the `_pooled` rows are the shipping
    // path (reused input buffer, shared Arc logits + per-response views).
    let bsz = 8usize;
    let elems = 144usize;
    let classes = 4usize;
    let images: Vec<ImageBuf> = (0..bsz)
        .map(|b| (0..elems).map(|i| ((b * elems + i) % 7) as f32 * 0.1).collect())
        .collect();
    report.add_stats(&measure("serving/pack_batch8_copy", 10, scaled(2000), || {
        let mut input = vec![0f32; bsz * elems];
        for (i, img) in images.iter().enumerate() {
            input[i * elems..(i + 1) * elems].copy_from_slice(img);
        }
        black_box(&input);
    }));
    let mut pooled_input: Vec<f32> = Vec::new();
    report.add_stats(&measure("serving/pack_batch8_pooled", 10, scaled(2000), || {
        // The worker's path: size the reused buffer, overwrite the rows
        // in place — a full batch pays no memset (only a short batch
        // zeroes its padding tail).
        pooled_input.resize(bsz * elems, 0.0);
        for (i, img) in images.iter().enumerate() {
            pooled_input[i * elems..(i + 1) * elems].copy_from_slice(img);
        }
        black_box(&pooled_input);
    }));
    let batch_logits: Vec<f32> = (0..bsz * classes).map(|i| i as f32 * 0.25).collect();
    report.add_stats(&measure("serving/respond_batch8_copy", 10, scaled(2000), || {
        let rows: Vec<Vec<f32>> = (0..bsz)
            .map(|i| batch_logits[i * classes..(i + 1) * classes].to_vec())
            .collect();
        black_box(&rows);
    }));
    let mut pool = LogitsPool::new(4);
    report.add_stats(&measure("serving/respond_batch8_pooled", 10, scaled(2000), || {
        let mut buf = pool.take(bsz * classes);
        Arc::get_mut(&mut buf)
            .expect("freshly taken pool buffer is unique")
            .copy_from_slice(&batch_logits);
        let views: Vec<LogitsView> = (0..bsz)
            .map(|i| LogitsView::new(Arc::clone(&buf), i * classes, classes))
            .collect();
        black_box(&views);
        drop(views);
        pool.put(buf);
    }));

    // --- fault-injection plane probe cost ----------------------------------
    // The plane sits on the submit/execute hot path in every worker, so
    // its disarmed cost must stay at one predictable branch per probe.
    // Two adjacent rows (ci.sh pins both names): `_off` is the shipping
    // disarmed plane, `_armed` is armed with all probabilities zero —
    // the worst case that still injects nothing. Any spread between
    // them is the price of arming, and any growth in `_off` is a
    // regression on the production path.
    {
        use opima::config::FaultParams;
        use opima::util::fault::FaultPlane;
        let mut off = FaultPlane::disarmed();
        report.add_stats(&measure("serving/submit_fault_plane_off", 10, scaled(2000), || {
            for _ in 0..1000 {
                black_box(off.worker_panic());
                black_box(off.exec_transient());
                black_box(off.worker_stall());
            }
        }));
        let mut armed = FaultPlane::new(
            FaultParams {
                armed: true,
                ..FaultParams::default()
            },
            0,
        );
        report.add_stats(&measure("serving/submit_fault_plane_armed", 10, scaled(2000), || {
            for _ in 0..1000 {
                black_box(armed.worker_panic());
                black_box(armed.exec_transient());
                black_box(armed.worker_stall());
            }
        }));
    }

    // --- wire protocol frame codec ----------------------------------------
    // What one end of a connection pays per 1k-element frame: encoding a
    // header + f32 payload into the writer's reused scratch, and decoding
    // a SUBMIT frame into a pooled image buffer (the reader's path —
    // after the pool warms, neither direction allocates).
    {
        use opima::coordinator::net::frame::{
            decode_header, encode_header, extend_f32s, read_pooled_image,
        };
        use opima::coordinator::net::protocol::{FrameHeader, FrameKind, HEADER_LEN};
        use opima::coordinator::request::ImagePool;
        use std::io::{Cursor, Read};

        let wire_elems = 1024usize;
        let payload: Vec<f32> = (0..wire_elems).map(|i| (i % 97) as f32 * 0.5).collect();
        let header = FrameHeader {
            kind: FrameKind::Submit,
            model: 0,
            variant: 2,
            id: 7,
            payload_len: (wire_elems * 4) as u32,
            aux: 0,
        };
        let mut scratch: Vec<u8> = Vec::new();
        report.add_stats(&measure("net/encode_frame_1k", 10, scaled(2000), || {
            let mut head = [0u8; HEADER_LEN];
            encode_header(&header, &mut head);
            scratch.clear();
            extend_f32s(&mut scratch, &payload);
            black_box((&head, &scratch));
        }));
        let mut wire = Vec::with_capacity(HEADER_LEN + wire_elems * 4);
        {
            let mut head = [0u8; HEADER_LEN];
            encode_header(&header, &mut head);
            wire.extend_from_slice(&head);
            extend_f32s(&mut wire, &payload);
        }
        let mut pool = ImagePool::new(4);
        report.add_stats(&measure("net/decode_frame_pooled_1k", 10, scaled(2000), || {
            let mut r = Cursor::new(&wire[..]);
            let mut head = [0u8; HEADER_LEN];
            r.read_exact(&mut head).unwrap();
            let h = decode_header(&head).unwrap();
            let img = read_pooled_image(&mut r, &mut pool, h.payload_len as usize / 4).unwrap();
            black_box(&img);
        }));
    }

    // --- streaming stats (the engine's observe path) ----------------------
    use opima::util::histogram::Histogram;
    let lat_samples: Vec<f64> = {
        let mut r = Rng::new(99);
        (0..10_000).map(|_| (r.normal() * 1.2 + 1.0).exp()).collect()
    };
    report.add_stats(&measure("histogram/record_10k", 3, scaled(200), || {
        let mut h = Histogram::new();
        for &v in &lat_samples {
            h.record(v);
        }
        black_box(h.count());
    }));
    let mut shards = vec![Histogram::new(); 4];
    for (i, &v) in lat_samples.iter().enumerate() {
        shards[i % 4].record(v);
    }
    // What Engine::stats pays per snapshot: merge the worker shards and
    // extract the percentile summary — O(buckets), served-count-free.
    report.add_stats(&measure("histogram/merge_4_shards_summary", 3, scaled(500), || {
        let mut agg = Histogram::new();
        for s in &shards {
            agg.merge(s);
        }
        black_box(agg.summary());
    }));

    // --- PJRT end-to-end ---------------------------------------------------
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let mut ex = Executor::new(Manifest::load(&dir).unwrap()).unwrap();
        let info = ex.manifest().get("photonic_mac_4b").unwrap().clone();
        let a: Vec<f32> = (0..info.input_elems(0)).map(|i| (i % 16) as f32).collect();
        let w: Vec<f32> = (0..info.input_elems(1)).map(|i| (i % 16) as f32).collect();
        // Label rows with the actual backend: without --features pjrt the
        // executor silently resolves to the sim backend, and recording
        // those timings as "pjrt/..." would misattribute them.
        let plat = ex.platform();
        ex.run_f32("photonic_mac_4b", &[&a, &w]).unwrap(); // compile outside timing
        report.add_stats(&measure(
            &format!("{plat}/photonic_mac_4b_64x128x64"),
            5,
            scaled(200),
            || {
                black_box(ex.run_f32("photonic_mac_4b", &[&a, &w]).unwrap());
            },
        ));
        let cnn = ex.manifest().get("cnn_int4_b8").unwrap().clone();
        let x = vec![0.5f32; cnn.input_elems(0)];
        ex.run_f32("cnn_int4_b8", &[&x]).unwrap();
        report.add_stats(&measure(
            &format!("{plat}/cnn_int4_b8_batch8"),
            5,
            scaled(100),
            || {
                black_box(ex.run_f32("cnn_int4_b8", &[&x]).unwrap());
            },
        ));
    } else {
        println!("(skipping PJRT benches: run `make artifacts`)");
    }

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("WARNING: could not write bench JSON: {e}"),
    }
}
