//! Wire front-end throughput (§Perf): the zero-copy TCP path measured
//! over loopback with the open-loop load generator, across a grid of
//! client connections × engine workers.
//!
//! Each cell binds a fresh `NetServer` over a fresh sim-backed engine
//! on an ephemeral loopback port and drives it with `run_load` — the
//! same generator behind `serve --listen` self-drive — so the numbers
//! cover the full socket→engine→socket round trip: frame decode into
//! pooled image buffers, submission, batching, execution, reply-queue
//! handoff and the vectored response write.
//!
//! The sim work factor is kept tiny on purpose: the point is the wire
//! path's overhead and scaling, not the simulated model's compute.
//!
//! Run: cargo bench --bench net_throughput

use std::sync::Arc;
use std::time::Duration;

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::net::{run_load, LoadGenConfig, NetServer};
use opima::coordinator::request::Variant;
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::bench::{smoke, table_header, table_row, JsonReport};
use opima::util::json::Json;

const BATCH: usize = 8;
const IMAGE: usize = 12;

fn requests_per_conn() -> usize {
    if smoke() {
        32
    } else {
        512
    }
}

/// One grid cell: a fresh server, `conns` connections driving it open
/// loop, then a graceful drain. Returns the aggregated client report.
fn cell(conns: usize, workers: usize) -> opima::coordinator::net::LoadGenReport {
    let engine = Arc::new(
        Engine::new(
            EngineConfig {
                workers,
                queue_capacity: 1024,
                instances: workers,
                max_wait: Duration::from_millis(2),
                executor: ExecutorSpec::Sim { work_factor: 2 },
                ..EngineConfig::default()
            },
            Manifest::synthetic(BATCH, IMAGE),
        )
        .unwrap(),
    );
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let report = run_load(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections: conns,
        requests_per_conn: requests_per_conn(),
        rate_rps: 0.0,
        mix: vec![(Model::LeNet, 1)],
        variant: Variant::Int4,
        window: 32,
        seed: 4242,
    })
    .unwrap();
    server.shutdown().unwrap();
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown().unwrap();
    }
    report
}

fn main() {
    println!(
        "net throughput: loopback wire path, {} request(s)/connection, sim work factor 2{}",
        requests_per_conn(),
        if smoke() { " (smoke mode)" } else { "" }
    );

    // The acceptance grid: ≥2 connection counts × ≥2 worker counts.
    let grid: Vec<(usize, usize)> = vec![(1, 1), (1, 2), (4, 1), (4, 2)];
    let mut report = JsonReport::new("net_throughput");
    table_header(
        "Wire front-end throughput (loopback)",
        &["conns × workers", "req/s", "p50 ms", "p99 ms", "busy", "failed"],
    );
    for (conns, workers) in grid {
        let r = cell(conns, workers);
        assert_eq!(
            r.responses + r.busy + r.failed,
            r.sent,
            "every submitted request is answered (response, busy or error)"
        );
        assert_eq!(r.failed, 0, "no request fails on the healthy loopback path");
        table_row(&[
            format!("{conns} × {workers}"),
            format!("{:.0}", r.rps),
            format!("{:.2}", r.p50_ms.raw()),
            format!("{:.2}", r.p99_ms.raw()),
            format!("{}", r.busy),
            format!("{}", r.failed),
        ]);
        report.add(
            &format!("net/throughput_c{conns}_w{workers}"),
            &[
                ("req_per_s", Json::Num(r.rps)),
                ("p50_ms", Json::Num(r.p50_ms.raw())),
                ("p99_ms", Json::Num(r.p99_ms.raw())),
                ("requests", Json::Num(r.sent as f64)),
                ("responses", Json::Num(r.responses as f64)),
                ("busy", Json::Num(r.busy as f64)),
                ("connections", Json::Num(conns as f64)),
                ("workers", Json::Num(workers as f64)),
            ],
        );
    }
    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nWARNING: could not write bench JSON: {e}"),
    }
}
