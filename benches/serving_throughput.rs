//! Serving throughput scaling (§Perf): multi-producer closed-loop load
//! through the pipelined engine at 1/2/4 workers, against a faithful
//! replica of the seed's synchronous inline serving path.
//!
//! Uses the deterministic sim executor backend with a work factor that
//! emulates a multi-millisecond model, so the scheduling behaviour —
//! not PJRT kernel time on one particular host — dominates, and the
//! bench runs without artifacts or the XLA native library.
//!
//! Run: cargo bench --bench serving_throughput

use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::batcher::DynamicBatcher;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::request::{InferenceRequest, Variant};
use opima::runtime::{Executor, ExecutorSpec, Manifest};
use opima::util::bench::{smoke, table_header, table_row, JsonReport};
use opima::util::json::Json;
use opima::util::prng::Rng;
use opima::util::units::{ms, Millis};

/// Sim backend work factor: ~2 ms per batch on a laptop-class core, so
/// a 512-request run keeps the worker pool genuinely busy. Smoke mode
/// (`OPIMA_BENCH_SMOKE=1`) shrinks the run to a schema check.
fn work() -> u32 {
    if smoke() {
        2
    } else {
        400
    }
}

fn n_requests() -> usize {
    if smoke() {
        64
    } else {
        512
    }
}

const PRODUCERS: usize = 4;
const BATCH: usize = 8;
const IMAGE: usize = 12;

fn requests() -> Vec<InferenceRequest> {
    let mut rng = Rng::new(4242);
    (0..n_requests() as u64)
        .map(|id| {
            let variant = match id % 3 {
                0 => Variant::Fp32,
                1 => Variant::Int8,
                _ => Variant::Int4,
            };
            InferenceRequest {
                id,
                model: Model::LeNet,
                image: (0..IMAGE * IMAGE).map(|_| rng.f64() as f32).collect(),
                variant,
                arrival: Instant::now(),
                deadline: None,
                reply: None,
            }
        })
        .collect()
}

/// The seed's synchronous call-loop: one thread, batches executed inline
/// on the submitting thread, deadline flushes piggybacking on submits.
fn sync_seed_path(manifest: &Manifest) -> f64 {
    let mut ex =
        Executor::from_spec(ExecutorSpec::Sim { work_factor: work() }, manifest.clone()).unwrap();
    let mut batcher = DynamicBatcher::new(BATCH, Duration::from_millis(2));
    let elems = IMAGE * IMAGE;
    let mut served = 0usize;
    let run = |ex: &mut Executor, batch: opima::coordinator::batcher::Batch| -> usize {
        let mut input = vec![0f32; BATCH * elems];
        for (i, r) in batch.requests.iter().enumerate() {
            input[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
        }
        let n = batch.requests.len();
        ex.run_f32(&batch.variant.artifact_for(batch.model, BATCH), &[&input])
            .unwrap();
        n
    };
    let t0 = Instant::now();
    for mut req in requests() {
        req.arrival = Instant::now();
        if let Some(batch) = batcher.push(req) {
            served += run(&mut ex, batch);
        }
        for batch in batcher.poll(Instant::now()) {
            served += run(&mut ex, batch);
        }
    }
    for batch in batcher.drain() {
        served += run(&mut ex, batch);
    }
    assert_eq!(served, n_requests());
    served as f64 / t0.elapsed().as_secs_f64()
}

/// The pipelined engine under a multi-producer closed loop. Returns
/// `(req/s, p50 ms, p99 ms)` — the percentiles come from the engine's
/// streaming histograms, so collecting them costs O(buckets) regardless
/// of how many requests were served.
fn engine_path(manifest: &Manifest, workers: usize) -> (f64, Millis, Millis) {
    let mut engine = Engine::new(
        EngineConfig {
            workers,
            queue_capacity: 256,
            instances: workers,
            max_wait: Duration::from_millis(2),
            executor: ExecutorSpec::Sim { work_factor: work() },
            ..EngineConfig::default()
        },
        manifest.clone(),
    )
    .unwrap();
    let reqs = requests();
    let chunk = n_requests() / PRODUCERS;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for slice in reqs.chunks(chunk) {
            let eng = &engine;
            s.spawn(move || {
                for r in slice {
                    let mut r = r.clone();
                    r.arrival = Instant::now();
                    eng.submit_blocking(r).unwrap();
                }
            });
        }
    });
    engine.drain().unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    assert_eq!(stats.served as usize, n_requests());
    engine.shutdown().unwrap();
    (
        stats.served as f64 / elapsed,
        ms(stats.latency.total.p50),
        ms(stats.latency.total.p99),
    )
}

fn main() {
    let manifest = Manifest::synthetic(BATCH, IMAGE);
    println!(
        "serving throughput: {} mixed-variant requests, batch {BATCH}, \
         {PRODUCERS} producers, sim work factor {}{}",
        n_requests(),
        work(),
        if smoke() { " (smoke mode)" } else { "" }
    );

    let sync_rps = sync_seed_path(&manifest);
    // The sync replica has no latency accounting (the seed didn't
    // either), so its percentile cells are blank.
    let mut rows: Vec<(String, f64, Option<(Millis, Millis)>)> =
        vec![("sync seed path (inline)".into(), sync_rps, None)];
    for workers in [1usize, 2, 4] {
        let (rps, p50, p99) = engine_path(&manifest, workers);
        rows.push((format!("engine, {workers} worker(s)"), rps, Some((p50, p99))));
    }

    table_header(
        "Serving throughput scaling",
        &["path", "req/s", "vs sync", "p50 ms", "p99 ms"],
    );
    for (name, rps, pcts) in &rows {
        let (p50, p99) = match pcts {
            Some((a, b)) => (format!("{:.2}", a.raw()), format!("{:.2}", b.raw())),
            None => ("-".into(), "-".into()),
        };
        table_row(&[
            name.clone(),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / sync_rps),
            p50,
            p99,
        ]);
    }
    // Machine-readable summary alongside the table.
    let mut report = JsonReport::new("serving_throughput");
    for (name, rps, pcts) in &rows {
        let mut fields = vec![
            ("req_per_s", Json::Num(*rps)),
            ("vs_sync", Json::Num(rps / sync_rps)),
            ("requests", Json::Num(n_requests() as f64)),
        ];
        if let Some((p50, p99)) = pcts {
            fields.push(("p50_ms", Json::Num(p50.raw())));
            fields.push(("p99_ms", Json::Num(p99.raw())));
        }
        report.add(name, &fields);
    }
    match report.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nWARNING: could not write bench JSON: {e}"),
    }

    let best = rows[1..].iter().map(|(_, r, _)| *r).fold(0.0f64, f64::max);
    // Report, don't assert: on 1-2 vCPU machines the pool can legitimately
    // tie the zero-handoff inline loop, and a panic would eat the table.
    if best > sync_rps {
        println!("\nserving_throughput OK — pool peak {best:.0} req/s vs sync {sync_rps:.0} req/s");
    } else {
        println!(
            "\nWARNING: pool peak {best:.0} req/s did not beat sync {sync_rps:.0} req/s \
             (expected on boxes with too few cores for {PRODUCERS} producers + workers)"
        );
    }
}
