//! Paper Table II: model zoo parameter counts and the quantization
//! accuracy shape (fp32 ≥ int8 ≥ int4 with a modest int4 drop).
//!
//! Parameter counts come from our model definitions and are compared to
//! the paper's reported values. The accuracy evidence is the measured
//! sweep from the Python photonic pipeline (artifacts/table2_accuracy.json,
//! produced by `make artifacts`: a CNN trained on the synthetic dataset
//! and evaluated through the 5-bit-ADC photonic path).

use std::path::Path;

use opima::cnn::quant::MeasuredAccuracy;
use opima::cnn::{build_model, ALL_MODELS};
use opima::util::bench::{black_box, measure, table_header, table_row};

fn main() {
    table_header(
        "Table II: parameter counts (ours vs paper)",
        &["model", "dataset", "params (ours)", "params (paper)", "delta"],
    );
    for m in ALL_MODELS {
        let net = build_model(m).unwrap();
        let ours = net.params();
        let paper = m.paper_params();
        let delta = 100.0 * (ours as f64 - paper as f64) / paper as f64;
        table_row(&[
            m.name().to_string(),
            m.dataset().to_string(),
            format!("{ours}"),
            format!("{paper}"),
            format!("{delta:+.2}%"),
        ]);
        assert!(delta.abs() < 10.0, "{}: {delta:+.2}%", m.name());
    }

    table_header(
        "Table II: paper accuracies (%, for reference)",
        &["model", "fp32", "int8", "int4"],
    );
    for m in ALL_MODELS {
        let (a, b, c) = m.paper_accuracy();
        table_row(&[
            m.name().to_string(),
            format!("{a}"),
            format!("{b}"),
            format!("{c}"),
        ]);
        assert!(a >= b && b >= c, "paper rows are monotone");
    }

    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/table2_accuracy.json");
    if path.exists() {
        let acc = MeasuredAccuracy::load(&path).unwrap();
        println!(
            "\nmeasured sweep (small CNN through the photonic pipeline, 5-bit ADC):"
        );
        println!(
            "  fp32 {:.1}%   int8 {:.1}%   int4 {:.1}%   ({} params)",
            100.0 * acc.fp32,
            100.0 * acc.int8,
            100.0 * acc.int4,
            acc.parameter_count
        );
        assert!(acc.is_monotone(), "fp32 ≥ int8 ≥ int4 must hold");
        assert!(acc.int4 > 0.5, "int4 must stay usable");
        println!("Table II shape reproduced: fp32 ≥ int8 ≥ int4 with usable int4");
    } else {
        println!("\n(measured sweep missing — run `make artifacts`)");
    }

    measure("table2/build_all_models", 3, 50, || {
        for m in ALL_MODELS {
            black_box(build_model(m).unwrap());
        }
    });
}
