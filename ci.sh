#!/usr/bin/env bash
# Tier-1 verification + lint gate. Run from the repo root.
#
# The default build uses the deterministic sim executor backend and is
# dependency-free (works fully offline). The real PJRT backend needs an
# XLA-equipped host AND a manifest edit: add `xla = "0.1"` under
# [dependencies] in Cargo.toml (see the comment there), then run these
# same steps with `--features pjrt`. Plain `--features pjrt` without the
# dependency added will not compile — that is expected.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Benches and examples are plain binaries that `cargo build`/`test`
# don't touch — compile them too so drift can't break silently.
echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo bench --no-run =="
cargo bench --no-run

# The JSON-emitting benches run in smoke mode (1 sample, tiny load) so
# the BENCH_<name>.json schema cannot rot without CI noticing.
echo "== bench JSON emitters (smoke mode) =="
OPIMA_BENCH_SMOKE=1 cargo bench --bench hotpath
OPIMA_BENCH_SMOKE=1 cargo bench --bench serving_throughput
for f in BENCH_hotpath.json BENCH_serving_throughput.json; do
  test -s "$f" || { echo "missing bench summary $f"; exit 1; }
  grep -q '"results":\[' "$f" || { echo "bad schema in $f"; exit 1; }
done
# The zero-copy data-plane rows (copy vs pooled, ISSUE 5) must keep
# landing in the hotpath summary.
for row in 'serving/pack_batch8_copy' 'serving/pack_batch8_pooled' \
           'serving/respond_batch8_copy' 'serving/respond_batch8_pooled'; do
  grep -q "$row" BENCH_hotpath.json || { echo "missing $row row in BENCH_hotpath.json"; exit 1; }
done

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# Docs are part of the contract: broken intra-doc links (e.g. dangling
# references from the lib/module docs) fail the build here.
echo "== RUSTDOCFLAGS=-Dwarnings cargo doc --no-deps =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== cargo fmt --check =="
cargo fmt --check

echo "ci.sh OK"
