#!/usr/bin/env bash
# Tier-1 verification + lint gate. Run from the repo root.
#
# The default build uses the deterministic sim executor backend and is
# dependency-free (works fully offline). The real PJRT backend needs an
# XLA-equipped host AND a manifest edit: add `xla = "0.1"` under
# [dependencies] in Cargo.toml (see the comment there), then run these
# same steps with `--features pjrt`. Plain `--features pjrt` without the
# dependency added will not compile — that is expected.
set -euo pipefail
cd "$(dirname "$0")"

# The invariant linter runs FIRST — stdlib-python, no build needed, so
# unit-convention violations fail in seconds, before any compilation.
echo "== lint_invariants (self-test + tree) =="
python3 scripts/lint_invariants.py --self-test
python3 scripts/lint_invariants.py

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The chaos soak runs bounded (smoke) here: the seeded fault schedule,
# supervision/respawn, retry/deadline/limiter paths and exactly-once
# accounting all exercise end to end, just over a smaller request grid.
echo "== chaos soak (smoke) =="
OPIMA_CHAOS_SMOKE=1 cargo test -q --test chaos

# Benches and examples are plain binaries that `cargo build`/`test`
# don't touch — compile them too so drift can't break silently.
echo "== cargo build --release --examples =="
cargo build --release --examples

echo "== cargo bench --no-run =="
cargo bench --no-run

# The JSON-emitting benches run in smoke mode (1 sample, tiny load) so
# the BENCH_<name>.json schema cannot rot without CI noticing.
echo "== bench JSON emitters (smoke mode) =="
OPIMA_BENCH_SMOKE=1 cargo bench --bench hotpath
OPIMA_BENCH_SMOKE=1 cargo bench --bench serving_throughput
OPIMA_BENCH_SMOKE=1 cargo bench --bench net_throughput
for f in BENCH_hotpath.json BENCH_serving_throughput.json BENCH_net_throughput.json; do
  test -s "$f" || { echo "missing bench summary $f"; exit 1; }
  grep -q '"results":\[' "$f" || { echo "bad schema in $f"; exit 1; }
done
# The zero-copy data-plane rows (copy vs pooled, ISSUE 5), the router
# dispatch rows (occupancy-only vs global-engine, ISSUE 6), the
# command-level writeback controller rows (naive vs scheduled, ISSUE 8),
# the wire frame codec rows (ISSUE 9) and the fault-plane probe pair
# (disarmed vs armed-zero-probability, ISSUE 10) must keep landing in
# the hotpath summary.
for row in 'serving/pack_batch8_copy' 'serving/pack_batch8_pooled' \
           'serving/respond_batch8_copy' 'serving/respond_batch8_pooled' \
           'serving/submit_fault_plane_off' 'serving/submit_fault_plane_armed' \
           'router/dispatch_1k' 'router/dispatch_for_occupancy_1k' \
           'router/dispatch_batch_contended_1k' 'router/dispatch_batch_optimistic_1k' \
           'memory/writeback_naive_1k' 'memory/writeback_scheduled_1k' \
           'memory/writeback_model_makespan' \
           'net/encode_frame_1k' 'net/decode_frame_pooled_1k' \
           'units/overhead_smoke_raw_f64' 'units/overhead_smoke_newtype'; do
  grep -q "$row" BENCH_hotpath.json || { echo "missing $row row in BENCH_hotpath.json"; exit 1; }
done
# The wire throughput summary must cover the connection × worker grid
# (≥2 connection counts × ≥2 worker counts, ISSUE 9 acceptance).
for row in 'net/throughput_c1_w1' 'net/throughput_c1_w2' \
           'net/throughput_c4_w1' 'net/throughput_c4_w2'; do
  grep -q "$row" BENCH_net_throughput.json || { echo "missing $row row in BENCH_net_throughput.json"; exit 1; }
done

# Bench-regression gate: the smoke-run summaries above vs the committed
# baselines, with a generous tolerance (OPIMA_BENCH_TOL, default 5x) so
# only order-of-magnitude rot trips it. First run on a toolchain-
# equipped host seeds the baselines; commit them to arm the gate.
echo "== bench-regression gate =="
if ls benches/baseline/BENCH_*.json >/dev/null 2>&1; then
  if command -v python3 >/dev/null 2>&1; then
    python3 scripts/bench_gate.py benches/baseline .
  else
    echo "(python3 unavailable -- skipping bench-regression gate)"
  fi
else
  mkdir -p benches/baseline
  cp BENCH_hotpath.json BENCH_serving_throughput.json BENCH_net_throughput.json benches/baseline/
  echo "(no committed baselines -- seeded benches/baseline/ from this run;"
  echo " review and commit them to arm the regression gate)"
fi

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# Docs are part of the contract: broken intra-doc links (e.g. dangling
# references from the lib/module docs) fail the build here.
echo "== RUSTDOCFLAGS=-Dwarnings cargo doc --no-deps =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== cargo fmt --check =="
cargo fmt --check

echo "ci.sh OK"
