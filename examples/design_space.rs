//! Architecture design-space exploration beyond the paper's defaults.
//!
//! Sweeps the main architectural knobs (subarray groups, optical
//! accumulation depth, cell bit density, clock) and reports throughput /
//! power / latency trade-offs for ResNet18 — the kind of study a
//! downstream user runs before committing to a configuration.
//!
//! Run: cargo run --release --example design_space

use opima::analyzer::{analyze_model, power_breakdown};
use opima::cnn::{build_model, Model};
use opima::pim::group;
use opima::OpimaConfig;

fn main() -> opima::Result<()> {
    let net = build_model(Model::ResNet18)?;

    println!("## Subarray groups (Fig. 7 axis) — ResNet18 4-bit\n");
    println!("| groups | TMAC/s | power (W) | latency (ms) | GMAC/s/W |");
    println!("|---|---|---|---|---|");
    for groups in [2, 4, 8, 16, 32] {
        let mut cfg = OpimaConfig::paper();
        cfg.geometry.subarray_groups = groups;
        let p = group::evaluate(&cfg, groups)?;
        let a = analyze_model(&cfg, &net, 4)?;
        println!(
            "| {} | {:.2} | {:.1} | {:.3} | {:.1} |",
            groups,
            p.mac_throughput / 1e12,
            power_breakdown(&cfg).total_w(),
            a.total_ms().raw(),
            p.macs_per_watt / 1e9
        );
    }

    println!("\n## Optical accumulation depth (in-waveguide products per readout)\n");
    println!("| accum | lanes | latency (ms) | dynamic mJ |");
    println!("|---|---|---|---|");
    for accum in [1, 2, 4] {
        let mut cfg = OpimaConfig::paper();
        cfg.pim.optical_accum = accum;
        let a = analyze_model(&cfg, &net, 4)?;
        let p = group::evaluate(&cfg, cfg.geometry.subarray_groups)?;
        println!(
            "| {} | {} | {:.3} | {:.2} |",
            accum,
            p.macs_per_cycle,
            a.total_ms().raw(),
            a.dynamic_mj.raw()
        );
    }

    println!("\n## Cell bit density (TDM steps for 8-bit operands)\n");
    println!("| bits/cell | 8-bit latency (ms) | 8-bit dynamic mJ |");
    println!("|---|---|---|");
    for bpc in [2u32, 4, 8] {
        let mut cfg = OpimaConfig::paper();
        cfg.geometry.bits_per_cell = bpc;
        let a = analyze_model(&cfg, &net, 8)?;
        println!("| {} | {:.3} | {:.2} |", bpc, a.total_ms().raw(), a.dynamic_mj.raw());
    }

    println!("\n## Clock rate\n");
    println!("| GHz | processing (ms) | total (ms) |");
    println!("|---|---|---|");
    for ghz in [1.0, 2.5, 5.0, 10.0] {
        let mut cfg = OpimaConfig::paper();
        cfg.timing.clock_ghz = ghz;
        let a = analyze_model(&cfg, &net, 4)?;
        println!(
            "| {} | {:.4} | {:.3} |",
            ghz,
            a.processing_ms.raw(),
            a.total_ms().raw()
        );
    }

    println!("\ndesign_space OK");
    Ok(())
}
