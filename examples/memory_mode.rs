//! Main-memory mode under concurrent PIM (paper challenge 2): exercise
//! the OPCM memory with a mixed read/write workload while the PIM engine
//! holds its group reservations, and show that (a) memory traffic still
//! progresses on the free rows and (b) reserved rows are protected.
//!
//! Run: cargo run --release --example memory_mode

use opima::memory::MemoryController;
use opima::util::prng::Rng;
use opima::OpimaConfig;

fn main() -> opima::Result<()> {
    let cfg = OpimaConfig::paper();
    let mut mem = MemoryController::new(&cfg)?;
    let cap = mem.capacity_bytes();
    println!(
        "OPCM main memory: {} GiB, {} rows/bank available",
        cap >> 30,
        mem.rows_available()
    );

    // Lend one subarray row per group to the PIM engine.
    let reserved = mem.reserve_pim_rows()?;
    println!(
        "PIM reservations: {} subarray rows/bank lent ({} remain for memory)",
        reserved.len(),
        mem.rows_available()
    );

    // Mixed workload on the remaining rows. The address map interleaves
    // cell rows across (bank, subarray_col, subarray_row); subarray_row
    // advances every banks*subarray_cols = 256 rows, so we steer around
    // the reserved rows by address arithmetic.
    let bytes_per_row = 128u64; // 256 cells × 4 bits
    let rows_per_subarray_row = (cfg.geometry.banks * cfg.geometry.subarray_cols) as u64;
    let stride = bytes_per_row * rows_per_subarray_row; // one subarray_row band
    let mut rng = Rng::new(99);
    let mut verified = 0u64;
    let free_rows: Vec<u64> = (0..cfg.geometry.subarray_rows as u64)
        .filter(|r| !reserved.contains(&(*r as usize)))
        .collect();
    for i in 0..3000u64 {
        let band = free_rows[rng.index(free_rows.len())];
        let offset_in_band = rng.next_u64() % (stride - 256);
        let addr = (band * stride + offset_in_band) / 16 * 16;
        let len = 16 + (i % 5) * 32;
        let data: Vec<u8> = (0..len).map(|j| ((i * 31 + j) % 256) as u8).collect();
        mem.write(addr, &data)?;
        let back = mem.read(addr, len)?.data.unwrap();
        assert_eq!(back, data, "round-trip at {addr:#x}");
        verified += len;
    }
    let s = mem.stats().clone();
    println!("\nmixed workload: 3000 write/read pairs, {verified} B verified");
    println!(
        "  reads: {} ({} B, {:.1} µJ)   writes: {} ({} B, {:.1} µJ)",
        s.reads,
        s.bytes_read,
        s.read_energy_pj / 1e6,
        s.writes,
        s.bytes_written,
        s.write_energy_pj / 1e6
    );
    println!("  simulated busy time: {:.2} ms", s.busy_ns.to_millis().raw());

    // Reserved rows must reject memory traffic while PIM holds them.
    let reserved_band = reserved[0] as u64;
    let addr = reserved_band * stride;
    assert!(
        mem.read(addr, 16).is_err(),
        "reserved row must reject memory reads"
    );
    println!("\nreserved-row access correctly rejected during PIM");

    // Release and verify the rows come back.
    mem.release_pim_rows(&reserved)?;
    mem.write(addr, &[7u8; 16])?;
    let back = mem.read(addr, 16)?.data.unwrap();
    assert_eq!(back, vec![7u8; 16]);
    println!("released rows serve memory traffic again — memory_mode OK");
    Ok(())
}
