//! Wire front-end demo (ISSUE 9): serving over the TCP socket boundary
//! instead of the in-process `Engine::submit` call.
//!
//! The example binds a `NetServer` on an ephemeral loopback port over a
//! sim-backed engine, then exercises both client shapes:
//!
//!  1. a single `NetClient` doing explicit submit/recv round trips —
//!     showing the response carries the same logits, predicted class
//!     and per-request `SimMetering` the in-process path returns, plus
//!     a STATS request rendering the live `ServerStats` snapshot;
//!  2. the open-loop load generator (`run_load`) — the same driver the
//!     `serve --listen` CLI self-drive and the `net_throughput` bench
//!     use — over several connections.
//!
//! Everything runs in one process; the wire is real (loopback TCP),
//! the protocol is the length-prefixed binary framing of
//! `coordinator::net::protocol` (DESIGN.md §3.2).
//!
//! Run: cargo run --release --example net_inference

use std::sync::Arc;
use std::time::Duration;

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::net::{run_load, LoadGenConfig, NetClient, NetReply, NetServer};
use opima::coordinator::request::Variant;
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::prng::Rng;

/// Synthetic class-patterned image (same generator family as
/// serve_inference): stripes/checkerboard + noise, with its label.
fn make_image(rng: &mut Rng, size: usize) -> (Vec<f32>, usize) {
    let cls = rng.index(4);
    let phase = rng.index(6);
    let mut img = Vec::with_capacity(size * size);
    for r in 0..size {
        for c in 0..size {
            let v = match cls {
                0 => ((r + phase) / 2) % 2,
                1 => ((c + phase) / 2) % 2,
                2 => ((r + c + phase) / 3) % 2,
                _ => (((r + phase) / 3) + ((c + phase) / 3)) % 2,
            } as f64;
            img.push((v + 0.45 * rng.normal()) as f32);
        }
    }
    (img, cls)
}

fn main() -> opima::Result<()> {
    let manifest = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => m,
        Err(_) => {
            println!("(artifacts not found — synthetic manifest + sim backend)");
            Manifest::synthetic(8, 12)
        }
    };
    let image_size = manifest.image_size;
    let engine = Arc::new(Engine::new(
        EngineConfig {
            workers: 2,
            queue_capacity: 256,
            instances: 2,
            max_wait: Duration::from_millis(2),
            executor: ExecutorSpec::Sim { work_factor: 1 },
            ..EngineConfig::default()
        },
        manifest,
    )?);
    let server = NetServer::bind(Arc::clone(&engine), "127.0.0.1:0")?;
    let addr = server.local_addr().to_string();
    println!("serving on {addr} (loopback, ephemeral port)\n");

    // --- 1. explicit submit/recv round trips ------------------------------
    println!("=== single client, explicit round trips ===");
    let mut client = NetClient::connect(&addr)?;
    let mut rng = Rng::new(20260807);
    for id in 0..8u64 {
        let (image, label) = make_image(&mut rng, image_size);
        client.submit(id, Model::LeNet, Variant::Int4, &image)?;
        match client.recv()? {
            NetReply::Response(r) => {
                println!(
                    "  id {:>2}  model {:<8} predicted {} (label {})  logits {:>2} f32  \
                     hw latency {:.3} ms  energy {:.4} mJ",
                    r.id,
                    r.model.name(),
                    r.predicted,
                    label,
                    r.logits.len(),
                    r.sim.hw_latency_ms.raw(),
                    r.sim.hw_energy_mj.raw()
                );
            }
            other => println!("  id {id}: unexpected reply {other:?}"),
        }
    }
    client.request_stats()?;
    match client.recv()? {
        NetReply::Stats(json) => println!("\nserver stats: {json}"),
        other => println!("unexpected stats reply {other:?}"),
    }
    client.drain()?;
    loop {
        match client.recv()? {
            NetReply::Fin => break,
            other => println!("  (flushed during drain: {other:?})"),
        }
    }

    // --- 2. open-loop load generator --------------------------------------
    println!("\n=== load generator, 4 connections ===");
    let report = run_load(&LoadGenConfig {
        addr: addr.clone(),
        connections: 4,
        requests_per_conn: 64,
        rate_rps: 0.0,
        mix: vec![(Model::LeNet, 1)],
        variant: Variant::Int4,
        window: 32,
        seed: 11,
    })?;
    println!(
        "  sent {}  responses {}  busy {}  failed {}  wall {:.0} ms  \
         {:.0} req/s  p50 {:.2} ms  p99 {:.2} ms",
        report.sent,
        report.responses,
        report.busy,
        report.failed,
        report.wall_ms.raw(),
        report.rps,
        report.p50_ms.raw(),
        report.p99_ms.raw()
    );
    assert_eq!(report.responses + report.busy + report.failed, report.sent);

    server.shutdown()?;
    if let Ok(mut e) = Arc::try_unwrap(engine) {
        e.shutdown()?;
    }
    println!("\nnet_inference OK — socket path served both client shapes");
    Ok(())
}
