//! Quickstart: the OPIMA stack in one file.
//!
//! 1. Build the paper configuration and inspect the architecture.
//! 2. Use it as a main memory (write → read round-trip with timing and
//!    energy from Table I).
//! 3. Run a CNN through the PIM cost model.
//! 4. Execute the AOT-compiled photonic MAC kernel on PJRT — the same
//!    binary path the serving coordinator uses (requires
//!    `make artifacts` to have been run).
//!
//! Run: cargo run --release --example quickstart

use opima::analyzer::{analyze_model, power_breakdown};
use opima::cnn::{build_model, Model};
use opima::memory::MemoryController;
use opima::runtime::{Executor, Manifest};
use opima::OpimaConfig;

fn main() -> opima::Result<()> {
    // --- 1. the architecture ------------------------------------------
    let cfg = OpimaConfig::paper();
    let g = &cfg.geometry;
    println!(
        "OPIMA: {} banks, {}x{} subarrays, {} GiB, {} subarray groups",
        g.banks,
        g.subarray_rows,
        g.subarray_cols,
        g.capacity_bytes() >> 30,
        g.subarray_groups
    );
    println!(
        "power envelope: {:.1} W (paper: 55.9 W)\n",
        power_breakdown(&cfg).total_w()
    );

    // --- 2. main-memory mode -------------------------------------------
    let mut mem = MemoryController::new(&cfg)?;
    let payload: Vec<u8> = (0..256u32).map(|i| (i % 256) as u8).collect();
    let w = mem.write(0x1000, &payload)?;
    let r = mem.read(0x1000, payload.len() as u64)?;
    assert_eq!(r.data.as_deref(), Some(payload.as_slice()));
    println!("memory mode: 256 B round-trip OK");
    println!(
        "  write: {:.1} ns, {:.1} nJ   read: {:.1} ns, {:.2} nJ\n",
        w.latency_ns.raw(),
        w.energy_pj / 1e3,
        r.latency_ns.raw(),
        r.energy_pj / 1e3
    );

    // --- 3. PIM mode: a whole CNN through the cost model ----------------
    let net = build_model(Model::ResNet18)?;
    let a = analyze_model(&cfg, &net, 4)?;
    println!("ResNet18 (4-bit) on OPIMA:");
    println!(
        "  processing {:.3} ms + writeback {:.3} ms = {:.3} ms  ({:.0} FPS)",
        a.processing_ms.raw(),
        a.writeback_ms.raw(),
        a.total_ms().raw(),
        a.fps()
    );
    println!(
        "  dynamic energy {:.2} mJ over {} MACs\n",
        a.dynamic_mj.raw(),
        a.macs
    );

    // --- 4. the functional kernel on PJRT -------------------------------
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(skipping PJRT demo: run `make artifacts` first)");
        return Ok(());
    }
    let mut ex = Executor::new(Manifest::load(&dir)?)?;
    let info = ex.manifest().get("photonic_mac_4b")?.clone();
    let (m, k) = (info.input_shapes[0][0], info.input_shapes[0][1]);
    let n = info.input_shapes[1][1];
    let a_lv: Vec<f32> = (0..m * k).map(|i| ((i * 3) % 16) as f32).collect();
    let w_lv: Vec<f32> = (0..k * n).map(|i| ((i * 11) % 16) as f32).collect();
    let out = ex.run_f32("photonic_mac_4b", &[&a_lv, &w_lv])?;
    println!(
        "photonic MAC kernel on {}: {}x{}x{} -> out[0..4] = {:?}",
        ex.platform(),
        m,
        k,
        n,
        &out[..4]
    );
    println!("quickstart OK");
    Ok(())
}
