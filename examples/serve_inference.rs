//! End-to-end serving driver (deliverable E11, the headline workload):
//! load the trained small CNN's AOT artifacts, serve a Poisson stream of
//! classification requests through the coordinator (dynamic batching +
//! least-loaded routing), verify functional accuracy against the dataset
//! labels, and report latency/throughput plus the simulated OPIMA
//! hardware cost. The measured numbers are recorded in EXPERIMENTS.md.
//!
//! Run: make artifacts && cargo run --release --example serve_inference

use std::time::Instant;

use opima::coordinator::{InferenceRequest, Server, ServerConfig, Variant};
use opima::runtime::Manifest;
use opima::util::prng::Rng;

/// Synthetic dataset generator — mirrors python/compile/data.py so we can
/// check the served predictions against ground-truth labels.
/// (Class patterns: 0 horizontal stripes, 1 vertical, 2 diagonal,
/// 3 checkerboard; period-2/3 phases; additive Gaussian noise.)
fn make_image(rng: &mut Rng, size: usize) -> (Vec<f32>, usize) {
    let cls = rng.index(4);
    let phase = rng.index(6);
    let noise = 0.45;
    let mut img = Vec::with_capacity(size * size);
    for r in 0..size {
        for c in 0..size {
            let v = match cls {
                0 => ((r + phase) / 2) % 2,
                1 => ((c + phase) / 2) % 2,
                2 => ((r + c + phase) / 3) % 2,
                _ => (((r + phase) / 3) + ((c + phase) / 3)) % 2,
            } as f64;
            img.push((v + noise * rng.normal()) as f32);
        }
    }
    (img, cls)
}

fn main() -> opima::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let image_size = manifest.image_size;
    let n_requests = 512usize;
    let rate_per_s = 2000.0; // Poisson arrival rate

    for (variant, min_acc) in [
        (Variant::Fp32, 0.90),
        (Variant::Int8, 0.80),
        (Variant::Int4, 0.65),
    ] {
        let mut server = Server::new(
            ServerConfig::default(),
            Manifest::load(&Manifest::default_dir())?,
        )?;
        let mut rng = Rng::new(20240710);
        let mut labels = Vec::with_capacity(n_requests);
        let t0 = Instant::now();
        let mut next_arrival = 0.0f64;
        for id in 0..n_requests as u64 {
            let (image, label) = make_image(&mut rng, image_size);
            labels.push(label);
            // Poisson process: sleep until the scheduled arrival.
            next_arrival += rng.exponential(rate_per_s);
            let target = std::time::Duration::from_secs_f64(next_arrival);
            if let Some(wait) = target.checked_sub(t0.elapsed()) {
                std::thread::sleep(wait);
            }
            server.submit(InferenceRequest {
                id,
                image,
                variant,
                arrival: Instant::now(),
            })?;
        }
        server.flush()?;

        // Functional accuracy against ground truth.
        let mut correct = 0usize;
        for r in server.responses() {
            if r.predicted == labels[r.id as usize] {
                correct += 1;
            }
        }
        let acc = correct as f64 / n_requests as f64;
        let s = server.stats();
        println!("\n=== variant {variant:?} ===");
        println!(
            "served {} requests, {} batches, accuracy {:.1}% (threshold {:.0}%)",
            s.served,
            s.batches,
            100.0 * acc,
            100.0 * min_acc
        );
        println!(
            "  wall {:.0} ms  throughput {:.0} req/s  p50 {:.2} ms  p99 {:.2} ms  mean exec {:.3} ms",
            s.wall_ms, s.throughput_rps, s.p50_total_ms, s.p99_total_ms, s.mean_exec_ms
        );
        println!(
            "  simulated OPIMA hw: makespan {:.2} ms, dynamic energy {:.3} mJ",
            s.sim_makespan_ms, s.sim_energy_mj
        );
        assert!(
            acc >= min_acc,
            "accuracy {acc} below threshold {min_acc} for {variant:?}"
        );
    }
    println!("\nserve_inference OK — all variants above accuracy thresholds");
    Ok(())
}
