//! End-to-end serving driver (deliverable E11, the headline workload):
//! a multi-producer closed-loop load generator over the pipelined
//! engine. Several producer threads submit Poisson-paced classification
//! requests through the bounded ingress queue (blocking on backpressure
//! — the loop "closes" through queue capacity), the batcher thread forms
//! size/deadline batches, and the worker pool executes them on PJRT
//! while the router meters simulated OPIMA hardware cost per batch.
//!
//! With artifacts present and the `pjrt` feature enabled, functional
//! accuracy is verified against the dataset labels. Without them the
//! driver falls back to the deterministic sim executor backend, which
//! exercises the identical pipeline but serves pseudo-logits — so
//! accuracy thresholds are only asserted on the real path.
//!
//! With `--mix lenet:4,vgg16:1` the producers drive a weighted random
//! *multi-model* load instead: every request names a model, the batcher
//! keeps per-(model, variant) queues, and the engine's plan registry
//! compiles each pair exactly once on first use. Accuracy is only
//! checked for the LeNet share (the synthetic dataset is LeNet's).
//!
//! Run: make artifacts && cargo run --release --features pjrt --example serve_inference
//!  or: cargo run --release --example serve_inference   (sim fallback)
//!  or: cargo run --release --example serve_inference -- --mix lenet:4,vgg16:1

use std::time::{Duration, Instant};

use opima::cnn::Model;
use opima::coordinator::engine::{Engine, EngineConfig};
use opima::coordinator::{parse_mix, pick_weighted, InferenceRequest, Variant};
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::prng::Rng;

/// Synthetic dataset generator — mirrors python/compile/data.py so we can
/// check the served predictions against ground-truth labels.
/// (Class patterns: 0 horizontal stripes, 1 vertical, 2 diagonal,
/// 3 checkerboard; period-2/3 phases; additive Gaussian noise.)
fn make_image(rng: &mut Rng, size: usize) -> (Vec<f32>, usize) {
    let cls = rng.index(4);
    let phase = rng.index(6);
    let noise = 0.45;
    let mut img = Vec::with_capacity(size * size);
    for r in 0..size {
        for c in 0..size {
            let v = match cls {
                0 => ((r + phase) / 2) % 2,
                1 => ((c + phase) / 2) % 2,
                2 => ((r + c + phase) / 3) % 2,
                _ => (((r + phase) / 3) + ((c + phase) / 3)) % 2,
            } as f64;
            img.push((v + noise * rng.normal()) as f32);
        }
    }
    (img, cls)
}

/// The `--mix` spec from the process args, if given (the grammar lives
/// in `opima::coordinator::parse_mix`, shared with the CLI).
fn mix_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == "--mix").map(|i| {
        args.get(i + 1)
            .expect("--mix needs a value like lenet:4,vgg16:1")
            .clone()
    })
}

/// The multi-model load: producers submit a weighted random model mix,
/// the engine batches per (model, variant) and compiles each pair's
/// plan exactly once.
fn run_mix(
    manifest: Manifest,
    spec: ExecutorSpec,
    functional: bool,
    mix: Vec<(Model, u64)>,
) -> opima::Result<()> {
    let producers = 4usize;
    let per_producer = 64usize;
    let n_requests = producers * per_producer;
    let variant = Variant::Int4;
    let engine = Engine::new(
        EngineConfig {
            workers: 2,
            queue_capacity: 64,
            instances: 2,
            max_wait: Duration::from_millis(2),
            executor: spec,
            history: n_requests,
            ..EngineConfig::default()
        },
        manifest,
    )?;

    // Producers: weighted random model per request; LeNet requests use
    // the labeled synthetic dataset, other models random images.
    let label_chunks: Vec<Vec<Option<usize>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let eng = &engine;
                let mix = &mix;
                s.spawn(move || {
                    let mut rng = Rng::new(20260731 + p as u64);
                    let mut labels = Vec::with_capacity(per_producer);
                    for i in 0..per_producer {
                        let model = pick_weighted(&mut rng, mix);
                        let elems = eng.image_elems_for(model);
                        let (image, label) = if model == Model::LeNet {
                            let (img, l) = make_image(&mut rng, (elems as f64).sqrt() as usize);
                            (img, Some(l))
                        } else {
                            ((0..elems).map(|_| rng.f64() as f32).collect(), None)
                        };
                        labels.push(label);
                        eng.submit_blocking(InferenceRequest {
                            id: (p * per_producer + i) as u64,
                            model,
                            image: image.into(),
                            variant,
                            arrival: Instant::now(),
                            deadline: None,
                            reply: None,
                        })
                        .expect("submit");
                    }
                    labels
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut engine = engine;
    engine.drain()?;

    // LeNet-share accuracy (the only labeled traffic).
    let (mut lenet_total, mut lenet_correct) = (0usize, 0usize);
    for r in &engine.responses() {
        let (p, i) = (r.id as usize / per_producer, r.id as usize % per_producer);
        if let Some(label) = label_chunks[p][i] {
            lenet_total += 1;
            if r.predicted == label {
                lenet_correct += 1;
            }
        }
    }
    let s = engine.stats();
    let mix_desc: Vec<String> = mix.iter().map(|(m, w)| format!("{}:{w}", m.name())).collect();
    println!("\n=== mixed workload ({}) ===", mix_desc.join(","));
    println!(
        "served {} requests in {} batches; {} (model, variant) plan(s), each compiled once",
        s.served,
        s.batches,
        engine.registry().builds()
    );
    println!(
        "  wall {:.0} ms  throughput {:.0} req/s  p50 {:.2} ms  p99 {:.2} ms",
        s.wall_ms.raw(),
        s.throughput_rps,
        s.latency.total.p50,
        s.latency.total.p99
    );
    println!("  per-model: model served batches p50ms p99ms energy_mJ makespan_ms");
    for m in &s.per_model {
        println!(
            "    {:<12} {:>5} {:>6} {:>8.2} {:>8.2} {:>10.2} {:>10.2}",
            m.model.name(),
            m.served,
            m.batches,
            m.latency.total.p50,
            m.latency.total.p99,
            m.sim_energy_mj.raw(),
            m.sim_makespan_ms.raw()
        );
    }
    assert_eq!(s.served as usize, n_requests, "every request answered");
    let served_sum: u64 = s.per_model.iter().map(|m| m.served).sum();
    assert_eq!(served_sum, s.served, "per-model counts sum to the total");
    if functional && lenet_total > 0 {
        let acc = lenet_correct as f64 / lenet_total as f64;
        println!("  lenet accuracy: {:.1}% over {lenet_total}", 100.0 * acc);
        assert!(acc >= 0.65, "lenet int4 accuracy {acc} below threshold");
    }
    engine.shutdown()?;
    println!("\nserve_inference OK — mixed workload served");
    Ok(())
}

fn main() -> opima::Result<()> {
    let (manifest, spec, functional) = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) if cfg!(feature = "pjrt") => (m, ExecutorSpec::Native, true),
        Ok(m) => {
            println!("(built without --features pjrt — sim backend, accuracy not asserted)");
            (m, ExecutorSpec::Sim { work_factor: 1 }, false)
        }
        Err(_) => {
            println!("(artifacts not found — synthetic manifest + sim backend)");
            (
                Manifest::synthetic(8, 12),
                ExecutorSpec::Sim { work_factor: 1 },
                false,
            )
        }
    };
    if let Some(mix_spec) = mix_arg() {
        return run_mix(manifest, spec, functional, parse_mix(&mix_spec)?);
    }
    let image_size = manifest.image_size;
    let producers = 4usize;
    let per_producer = 128usize;
    let n_requests = producers * per_producer;
    let rate_per_s = 2000.0; // Poisson arrival rate per producer stream

    for (variant, min_acc) in [
        (Variant::Fp32, 0.90),
        (Variant::Int8, 0.80),
        (Variant::Int4, 0.65),
    ] {
        // queue_capacity well below the request count, so the closed loop
        // genuinely closes through ingress backpressure under burst.
        let engine = Engine::new(
            EngineConfig {
                workers: 2,
                queue_capacity: 64,
                instances: 2,
                max_wait: Duration::from_millis(2),
                executor: spec,
                // The accuracy check below reads every response back, so
                // size the bounded response ring to the full run (stats
                // would be complete either way; payloads would not).
                history: n_requests,
                ..EngineConfig::default()
            },
            manifest.clone(),
        )?;

        // Multi-producer closed loop: each producer owns a deterministic
        // PRNG stream and blocks on ingress backpressure.
        let label_chunks: Vec<Vec<usize>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..producers)
                .map(|p| {
                    let eng = &engine;
                    s.spawn(move || {
                        let mut rng = Rng::new(20240710 + p as u64);
                        let mut labels = Vec::with_capacity(per_producer);
                        let t0 = Instant::now();
                        let mut next_arrival = 0.0f64;
                        for i in 0..per_producer {
                            let (image, label) = make_image(&mut rng, image_size);
                            labels.push(label);
                            // Poisson pacing within this producer's stream.
                            next_arrival += rng.exponential(rate_per_s);
                            let target = Duration::from_secs_f64(next_arrival);
                            if let Some(wait) = target.checked_sub(t0.elapsed()) {
                                std::thread::sleep(wait);
                            }
                            eng.submit_blocking(InferenceRequest {
                                id: (p * per_producer + i) as u64,
                                model: Model::LeNet,
                                image: image.into(),
                                variant,
                                arrival: Instant::now(),
                                deadline: None,
                                reply: None,
                            })
                            .expect("submit");
                        }
                        labels
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut engine = engine;
        engine.drain()?;

        // Functional accuracy against ground truth (id → producer chunk).
        let mut correct = 0usize;
        let responses = engine.responses();
        for r in &responses {
            let (p, i) = (r.id as usize / per_producer, r.id as usize % per_producer);
            if r.predicted == label_chunks[p][i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / n_requests as f64;
        let s = engine.stats();
        println!("\n=== variant {variant:?} ===");
        println!(
            "served {} requests ({} producers), {} batches, accuracy {:.1}% (threshold {:.0}%)",
            s.served,
            producers,
            s.batches,
            100.0 * acc,
            100.0 * min_acc
        );
        println!(
            "  wall {:.0} ms  throughput {:.0} req/s  p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms  p99.9 {:.2} ms",
            s.wall_ms.raw(),
            s.throughput_rps,
            s.latency.total.p50,
            s.latency.total.p90,
            s.latency.total.p99,
            s.latency.total.p999
        );
        println!(
            "  latency split: mean form {:.3} ms  mean queue {:.3} ms  mean exec {:.3} ms",
            s.mean_form_ms.raw(),
            s.mean_queue_ms.raw(),
            s.mean_exec_ms.raw()
        );
        println!(
            "  simulated OPIMA hw: makespan {:.2} ms, dynamic energy {:.3} mJ ({} rejected)",
            s.sim_makespan_ms.raw(),
            s.sim_energy_mj.raw(),
            s.rejected
        );
        assert_eq!(s.served as usize, n_requests, "every request answered");
        if functional {
            assert!(
                acc >= min_acc,
                "accuracy {acc} below threshold {min_acc} for {variant:?}"
            );
        }
        engine.shutdown()?;
    }
    println!("\nserve_inference OK — pipelined engine served all variants");
    Ok(())
}
