"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts emitted (all lowered with return_tuple=True; the Rust side
unwraps with to_tuple1):
  photonic_mac_4b.hlo.txt  — standalone L1 kernel, 4-bit levels, (64,128)x(128,64)
  photonic_mac_8b.hlo.txt  — standalone L1 kernel, 8-bit levels
  cnn_fp32_b<batch>.hlo.txt — fp32 CNN forward, params baked as constants
  cnn_int8_b<batch>.hlo.txt — photonic-path CNN forward (8-bit, ADC on)
  cnn_int4_b<batch>.hlo.txt — photonic-path CNN forward (4-bit, ADC on)
  manifest.json            — shapes/dtypes per artifact for the Rust loader

Usage: python -m compile.aot --outdir ../artifacts [--steps 400] [--batch 8]
Training is cached: params.npz is reused if present.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.photonic_mac import PhotonicConfig, photonic_matmul
from .model import IMAGE_SIZE, forward_fp32, forward_photonic
from .train import load_params, quantization_sweep, save_params, train

MAC_M, MAC_K, MAC_N = 64, 128, 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    print_large_constants=True is essential: the default printer elides
    dense constants as ``{...}``, which the consuming parser silently
    reads back as ZEROS — baked model weights would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def emit(fn, example_args, path: str) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")
    return {
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in example_args
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest: dict = {"artifacts": {}}

    # --- L1 standalone kernel artifacts -----------------------------------
    spec_a = jax.ShapeDtypeStruct((MAC_M, MAC_K), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((MAC_K, MAC_N), jnp.float32)
    for bits in (4, 8):
        cfg = PhotonicConfig(bits_a=bits, bits_w=bits)

        def mac_fn(a, w, cfg=cfg):
            return (photonic_matmul(a, w, cfg),)

        name = f"photonic_mac_{bits}b"
        info = emit(mac_fn, (spec_a, spec_w), os.path.join(args.outdir, f"{name}.hlo.txt"))
        info["output_shape"] = [MAC_M, MAC_N]
        info["bits"] = bits
        manifest["artifacts"][name] = info

    # --- Train (or reuse) the small CNN ------------------------------------
    params_path = os.path.join(args.outdir, "params.npz")
    if os.path.exists(params_path):
        params = load_params(params_path)
        print(f"reusing {params_path}")
        test_x = test_y = None
    else:
        params, _, (test_x, test_y) = train(steps=args.steps)
        save_params(params, params_path)

    # Table II sweep (cached alongside params).
    acc_path = os.path.join(args.outdir, "table2_accuracy.json")
    if not os.path.exists(acc_path):
        if test_x is None:
            from .data import make_dataset

            test_x, test_y = make_dataset(jax.random.PRNGKey(7), 512)
        results = quantization_sweep(params, test_x, test_y)
        with open(acc_path, "w") as f:
            json.dump(results, f, indent=2)
        print("table2_accuracy:", results)

    # --- L2 CNN artifacts (params baked as constants) ----------------------
    spec_x = jax.ShapeDtypeStruct((args.batch, IMAGE_SIZE, IMAGE_SIZE, 1), jnp.float32)

    def cnn_fp32(x):
        return (forward_fp32(params, x),)

    name = f"cnn_fp32_b{args.batch}"
    info = emit(cnn_fp32, (spec_x,), os.path.join(args.outdir, f"{name}.hlo.txt"))
    info["output_shape"] = [args.batch, 4]
    manifest["artifacts"][name] = info

    for bits in (8, 4):
        cfg = PhotonicConfig(bits_a=bits, bits_w=bits)

        def cnn_q(x, bits=bits, cfg=cfg):
            return (forward_photonic(params, x, bits=bits, cfg=cfg, use_pallas=True),)

        name = f"cnn_int{bits}_b{args.batch}"
        info = emit(cnn_q, (spec_x,), os.path.join(args.outdir, f"{name}.hlo.txt"))
        info["output_shape"] = [args.batch, 4]
        info["bits"] = bits
        manifest["artifacts"][name] = info

    manifest["batch"] = args.batch
    manifest["image_size"] = IMAGE_SIZE
    manifest["mac_shape"] = [MAC_M, MAC_K, MAC_N]
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("manifest written")


if __name__ == "__main__":
    main()
