"""Synthetic image-classification dataset (Table II substitution).

The paper evaluates quantized-accuracy on CIFAR/SVHN/STL-10/Imagenette.
Those datasets (and TensorRT) are unavailable here, so we reproduce the
*shape* of Table II — fp32 >= int8 >= int4 accuracy with a modest int4
drop — on a deterministic synthetic task: 12x12 grayscale images of four
structured classes (horizontal stripes, vertical stripes, diagonal,
checkerboard) with additive noise. The task is non-trivial (noise sigma
tuned so fp32 accuracy is high but not saturated at 100%).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .model import IMAGE_SIZE, NUM_CLASSES


def _class_image(cls: int, phase: int, size: int) -> jnp.ndarray:
    r = jnp.arange(size)
    rr, cc = jnp.meshgrid(r, r, indexing="ij")
    if cls == 0:  # horizontal stripes
        img = ((rr + phase) // 2) % 2
    elif cls == 1:  # vertical stripes
        img = ((cc + phase) // 2) % 2
    elif cls == 2:  # diagonal stripes
        img = ((rr + cc + phase) // 3) % 2
    else:  # checkerboard
        img = (((rr + phase) // 3) + ((cc + phase) // 3)) % 2
    return img.astype(jnp.float32)


def make_dataset(key: jax.Array, n: int, noise: float = 0.45):
    """Returns (images (N, S, S, 1) float32 in ~[0,1]+noise, labels (N,))."""
    keys = jax.random.split(key, 3)
    labels = jax.random.randint(keys[0], (n,), 0, NUM_CLASSES)
    phases = jax.random.randint(keys[1], (n,), 0, 6)
    base = jnp.stack(
        [
            jnp.stack([_class_image(c, p, IMAGE_SIZE) for p in range(6)])
            for c in range(NUM_CLASSES)
        ]
    )  # (C, P, S, S)
    imgs = base[labels, phases]
    imgs = imgs + noise * jax.random.normal(keys[2], imgs.shape)
    return imgs[..., None], labels
