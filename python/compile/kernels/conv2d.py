"""Convolution on the photonic MAC: im2col lowering.

OPIMA maps convolutional layers to MVM with an input-stationary dataflow
(paper §IV.D): the feature map stays resident in the OPCM subarrays while
kernel rows are driven through as MDL wavelength vectors. Functionally the
computation is a matmul between im2col patches and the flattened kernels,
which is exactly what this module lowers to — the L3 mapper models the
*physical* dataflow (sharding across subarrays, stride walks, 1x1-kernel
serialization); this module models the *numerics*.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quant import quantized_matmul
from .photonic_mac import PhotonicConfig


def im2col(x: jnp.ndarray, kh: int, kw: int, stride: int = 1, padding: int = 0):
    """NHWC image -> (N*OH*OW, KH*KW*C) patch matrix.

    Returns (patches, (n, oh, ow)).
    """
    n, h, w, c = x.shape
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
        h, w = h + 2 * padding, w + 2 * padding
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    # Gather patches: (N, OH, OW, KH, KW, C)
    rows = []
    for i in range(kh):
        cols = []
        for j in range(kw):
            cols.append(x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :])
        rows.append(jnp.stack(cols, axis=3))  # (N, OH, OW, KW, C)
    patches = jnp.stack(rows, axis=3)  # (N, OH, OW, KH, KW, C)
    return patches.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d_fp32(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: int = 0):
    """Reference fp32 conv. x: NHWC, w: (KH, KW, C, F) -> NHWC."""
    kh, kw, _, f = w.shape
    patches, (n, oh, ow) = im2col(x, kh, kw, stride, padding)
    out = patches @ w.reshape(-1, f)
    return out.reshape(n, oh, ow, f)


def conv2d_photonic(
    x: jnp.ndarray,
    w: jnp.ndarray,
    bits: int,
    cfg: PhotonicConfig | None = None,
    stride: int = 1,
    padding: int = 0,
    use_pallas: bool = True,
):
    """Quantized conv through the photonic MAC pipeline."""
    kh, kw, _, f = w.shape
    patches, (n, oh, ow) = im2col(x, kh, kw, stride, padding)
    out = quantized_matmul(patches, w.reshape(-1, f), bits, cfg, use_pallas=use_pallas)
    return out.reshape(n, oh, ow, f)
