"""L1 Pallas kernel: OPIMA's photonic analog MAC pipeline.

This kernel is the functional model of OPIMA's in-memory compute primitive
(paper §IV.C-D). The physical pipeline it emulates:

  1. CNN parameters are stored as unsigned *levels* in 4-bit/cell OPCM
     multi-level cells (16 transmission levels per cell, paper Fig. 2).
  2. Wider operands (8-bit, ...) are decomposed into 4-bit nibbles and
     processed by time-division multiplexing (TDM, challenge (4) in §IV.C),
     recombined with shift-and-add in the aggregation unit.
  3. Each wavelength carries one activation x weight product; signals of the
     same wavelength from subarrays of the same *subarray group* interfere in
     the shared readout waveguide, summing `group_size` products optically
     (the in-waveguide accumulation of §IV.D).
  4. A photodetector + 5-bit ADC digitizes each accumulated analog value
     ("5-bit ADCs so that the data can be translated to the electrical domain
     with any carries", §IV.C.4). Further accumulation is digital (exact) in
     the aggregation unit's shift-add + SRAM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the WDM lane dimension
maps to the kernel's minor (lane) axis, the in-waveguide group accumulation
becomes an in-VMEM accumulator, the TDM nibble loop is a static loop inside
the block, and the K-reduction is a grid axis with revisiting-output
accumulation. interpret=True everywhere: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NIBBLE_BITS = 4
NIBBLE_BASE = 1 << NIBBLE_BITS  # 16 transmission levels per OPCM cell
MAX_NIBBLE_PRODUCT = (NIBBLE_BASE - 1) ** 2  # 225


@dataclasses.dataclass(frozen=True)
class PhotonicConfig:
    """Parameters of the analog MAC pipeline.

    Attributes:
      bits_a: activation bit-width (must be a multiple of 4).
      bits_w: weight bit-width (must be a multiple of 4).
      group_size: number of products summed optically in the shared readout
        waveguide before the ADC (subarrays per group row sharing a
        wavelength; 2 in the paper's worked example, §IV.D).
      adc_bits: ADC resolution at the aggregation unit (5 in the paper).
      enable_adc: model ADC quantization of the analog partial sums. When
        False the pipeline is exact and equals an integer matmul.
    """

    bits_a: int = 4
    bits_w: int = 4
    group_size: int = 2
    adc_bits: int = 5
    enable_adc: bool = True

    def __post_init__(self):
        if self.bits_a % NIBBLE_BITS or self.bits_a <= 0:
            raise ValueError(f"bits_a must be a positive multiple of 4, got {self.bits_a}")
        if self.bits_w % NIBBLE_BITS or self.bits_w <= 0:
            raise ValueError(f"bits_w must be a positive multiple of 4, got {self.bits_w}")
        if self.group_size <= 0:
            raise ValueError("group_size must be positive")
        if self.adc_bits <= 0:
            raise ValueError("adc_bits must be positive")

    @property
    def nibbles_a(self) -> int:
        return self.bits_a // NIBBLE_BITS

    @property
    def nibbles_w(self) -> int:
        return self.bits_w // NIBBLE_BITS

    @property
    def adc_step(self) -> float:
        """ADC LSB in nibble-product units: full scale is `group_size` maximal
        nibble products interfering in the waveguide."""
        return self.group_size * MAX_NIBBLE_PRODUCT / (1 << self.adc_bits)


def adc_quantize(x: jnp.ndarray, cfg: PhotonicConfig) -> jnp.ndarray:
    """Photodetector + ADC readout of an in-waveguide accumulated signal."""
    if not cfg.enable_adc:
        return x
    step = jnp.float32(cfg.adc_step)
    return jnp.round(x / step) * step


def extract_nibble(levels: jnp.ndarray, i: int) -> jnp.ndarray:
    """i-th 4-bit nibble (little-endian) of an unsigned level tensor."""
    return jnp.floor_divide(levels, NIBBLE_BASE**i) % NIBBLE_BASE


def _segment_mac(a_nib: jnp.ndarray, w_nib: jnp.ndarray, cfg: PhotonicConfig) -> jnp.ndarray:
    """One TDM step: nibble x nibble MAC with per-group ADC readout.

    a_nib: (bm, bk) float32 nibble levels; w_nib: (bk, bn). bk must be a
    multiple of cfg.group_size. Returns the (bm, bn) digital partial sum.
    """
    bm, bk = a_nib.shape
    bn = w_nib.shape[1]
    g = cfg.group_size
    s = bk // g
    # (S, bm, G) x (S, G, bn) batched matmul: each batch element is one
    # in-waveguide accumulation of G products (same wavelength, same group).
    a_seg = a_nib.reshape(bm, s, g).transpose(1, 0, 2)
    w_seg = w_nib.reshape(s, g, bn)
    seg = jax.lax.dot_general(
        a_seg,
        w_seg,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (S, bm, bn): analog accumulations
    seg = adc_quantize(seg, cfg)  # PD + 5-bit ADC per waveguide readout
    return seg.sum(axis=0)  # digital accumulation (aggregation-unit SRAM)


def _photonic_matmul_kernel(a_ref, w_ref, o_ref, *, cfg: PhotonicConfig):
    """Pallas kernel body. Grid = (M/bm, N/bn, K/bk); K is innermost so the
    output block is revisited and accumulated across K steps (the digital
    aggregation across subarray groups)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_lv = a_ref[...].astype(jnp.float32)
    w_lv = w_ref[...].astype(jnp.float32)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # TDM loop over nibble pairs, recombined via shift-and-add.
    for i in range(cfg.nibbles_a):
        a_nib = extract_nibble(a_lv, i)
        for j in range(cfg.nibbles_w):
            w_nib = extract_nibble(w_lv, j)
            shift = float(NIBBLE_BASE ** (i + j))
            acc = acc + shift * _segment_mac(a_nib, w_nib, cfg)
    o_ref[...] += acc


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("cfg", "block_m", "block_n", "block_k", "interpret")
)
def photonic_matmul(
    a_levels: jnp.ndarray,
    w_levels: jnp.ndarray,
    cfg: PhotonicConfig = PhotonicConfig(),
    *,
    # block_m=128 / block_k=32 measured fastest on the CPU-PJRT path
    # (EXPERIMENTS.md §Perf): tall im2col matmuls amortize grid overhead
    # at bm=128; small operands clamp to their own size anyway.
    block_m: int = 128,
    block_n: int = 64,
    block_k: int = 32,
    interpret: bool = True,
) -> jnp.ndarray:
    """OPIMA photonic MAC: (M,K) x (K,N) over unsigned quantization levels.

    Inputs are integer *levels* in [0, 2**bits) (any integer or float dtype
    holding integral values). Output is float32 holding the (possibly
    ADC-quantized) integer-valued result. With cfg.enable_adc=False this is
    exactly ``a_levels @ w_levels``.
    """
    if a_levels.ndim != 2 or w_levels.ndim != 2:
        raise ValueError("photonic_matmul expects 2-D operands")
    m, k = a_levels.shape
    k2, n = w_levels.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if block_k % cfg.group_size:
        raise ValueError("block_k must be a multiple of cfg.group_size")

    a_f = a_levels.astype(jnp.float32)
    w_f = w_levels.astype(jnp.float32)
    # Zero-pad to block multiples; zero levels contribute zero products and
    # ADC(0) == 0, so padding is exact.
    bm = min(block_m, _ceil_mult(m, 8))
    bn = min(block_n, _ceil_mult(n, 8))
    bk = min(block_k, _ceil_mult(k, cfg.group_size))
    a_f = _pad_to(_pad_to(a_f, 0, bm), 1, bk)
    w_f = _pad_to(_pad_to(w_f, 0, bk), 1, bn)
    mp, kp = a_f.shape
    np_ = w_f.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_photonic_matmul_kernel, cfg=cfg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_f, w_f)
    return out[:m, :n]


def _ceil_mult(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vmem_footprint_bytes(block_m: int, block_n: int, block_k: int) -> int:
    """Estimated VMEM bytes for one grid step (DESIGN.md §Perf): A block,
    W block, output accumulator, plus the transient segment tensor."""
    f32 = 4
    a = block_m * block_k * f32
    w = block_k * block_n * f32
    o = block_m * block_n * f32
    seg = block_k * block_m * block_n * f32  # worst case S*bm*bn with G=1
    return a + w + o + seg
