"""Pure-jnp correctness oracle for the photonic MAC kernel.

Implements the identical OPIMA analog pipeline (nibble TDM, per-group
in-waveguide accumulation, ADC readout, digital shift-and-add) without
Pallas, with the full K dimension handled in one shot. The Pallas kernel's
K blocks are multiples of the group size, so segment boundaries (and thus
every ADC readout) line up exactly; the only permitted difference is f32
summation order across K blocks, which matters one ulp (~1e-7 relative)
when ADC-quantized 8-bit totals exceed 2^24 step units. Tests therefore
compare bit-exact with ADC off and at rtol=1e-6 with ADC on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .photonic_mac import (
    NIBBLE_BASE,
    PhotonicConfig,
    adc_quantize,
    extract_nibble,
)


def photonic_matmul_ref(
    a_levels: jnp.ndarray,
    w_levels: jnp.ndarray,
    cfg: PhotonicConfig = PhotonicConfig(),
) -> jnp.ndarray:
    """Reference photonic MAC over unsigned levels. Returns float32."""
    a = a_levels.astype(jnp.float32)
    w = w_levels.astype(jnp.float32)
    m, k = a.shape
    _, n = w.shape
    g = cfg.group_size
    kp = ((k + g - 1) // g) * g
    a = jnp.pad(a, ((0, 0), (0, kp - k)))
    w = jnp.pad(w, ((0, kp - k), (0, 0)))
    s = kp // g

    out = jnp.zeros((m, n), jnp.float32)
    for i in range(cfg.nibbles_a):
        a_nib = extract_nibble(a, i)
        for j in range(cfg.nibbles_w):
            w_nib = extract_nibble(w, j)
            a_seg = a_nib.reshape(m, s, g).transpose(1, 0, 2)  # (S, m, G)
            w_seg = w_nib.reshape(s, g, n)  # (S, G, n)
            seg = jax.lax.dot_general(
                a_seg,
                w_seg,
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )
            seg = adc_quantize(seg, cfg)
            out = out + float(NIBBLE_BASE ** (i + j)) * seg.sum(axis=0)
    return out


def exact_matmul_ref(a_levels: jnp.ndarray, w_levels: jnp.ndarray) -> jnp.ndarray:
    """Ideal (no-ADC) integer matmul over levels, float32."""
    return a_levels.astype(jnp.float32) @ w_levels.astype(jnp.float32)


def adc_error_bound(k: int, cfg: PhotonicConfig) -> float:
    """Worst-case |photonic - exact| per output element: each of the
    ceil(K/G) segments contributes at most step/2 of rounding error,
    recombined with shift weights summed over nibble pairs."""
    segs = (k + cfg.group_size - 1) // cfg.group_size
    per_pair = segs * cfg.adc_step / 2.0
    shift_sum = sum(
        float(NIBBLE_BASE ** (i + j))
        for i in range(cfg.nibbles_a)
        for j in range(cfg.nibbles_w)
    )
    return per_pair * shift_sum
