"""L2: JAX model — a small CNN exercised through the photonic MAC pipeline.

This is the functional model used to (a) validate that OPIMA's analog
pipeline (4-bit cells + nibble TDM + 5-bit ADC) preserves classification
accuracy (paper Table II's fp32/int8/int4 sweep), and (b) produce the AOT
HLO artifacts the Rust coordinator executes on the request path.

Forward paths:
  forward_fp32      — float reference (also the training path).
  forward_photonic  — every conv/fc runs as quantized levels through the
                      L1 Pallas kernel (or its jnp oracle), with digital
                      zero-point correction, matching OPIMA end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.conv2d import conv2d_fp32, conv2d_photonic
from .kernels.photonic_mac import PhotonicConfig
from .quant import quantized_matmul

IMAGE_SIZE = 12
NUM_CLASSES = 4

# (name, kind, params) — kind: conv(kh, kw, cin, cout, stride, pad) | fc(i, o)
ARCH = [
    ("conv1", "conv", (3, 3, 1, 8, 1, 1)),
    ("conv2", "conv", (3, 3, 8, 16, 1, 1)),
    ("fc", "fc", (3 * 3 * 16, NUM_CLASSES)),
]


def init_params(key: jax.Array) -> dict:
    """He-initialized parameters for the small CNN."""
    params = {}
    for name, kind, spec in ARCH:
        key, sub = jax.random.split(key)
        if kind == "conv":
            kh, kw, cin, cout, _, _ = spec
            fan_in = kh * kw * cin
            params[name] = {
                "w": jax.random.normal(sub, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in),
                "b": jnp.zeros((cout,)),
            }
        else:
            i, o = spec
            params[name] = {
                "w": jax.random.normal(sub, (i, o)) * jnp.sqrt(2.0 / i),
                "b": jnp.zeros((o,)),
            }
    return params


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pooling, NHWC."""
    n, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def forward_fp32(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Float forward. x: (N, 12, 12, 1) -> logits (N, 4)."""
    h = conv2d_fp32(x, params["conv1"]["w"], padding=1) + params["conv1"]["b"]
    h = maxpool2(jax.nn.relu(h))
    h = conv2d_fp32(h, params["conv2"]["w"], padding=1) + params["conv2"]["b"]
    h = maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc"]["w"] + params["fc"]["b"]


def forward_photonic(
    params: dict,
    x: jnp.ndarray,
    bits: int = 4,
    cfg: PhotonicConfig | None = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """OPIMA-path forward: all MACs through the photonic pipeline.

    Non-linearities, pooling and bias adds are performed digitally at the
    E-O-E controller (paper Fig. 3) and are exact.
    """
    if cfg is None:
        cfg = PhotonicConfig(bits_a=bits, bits_w=bits)
    h = (
        conv2d_photonic(x, params["conv1"]["w"], bits, cfg, padding=1, use_pallas=use_pallas)
        + params["conv1"]["b"]
    )
    h = maxpool2(jax.nn.relu(h))
    h = (
        conv2d_photonic(h, params["conv2"]["w"], bits, cfg, padding=1, use_pallas=use_pallas)
        + params["conv2"]["b"]
    )
    h = maxpool2(jax.nn.relu(h))
    h = h.reshape(h.shape[0], -1)
    h = quantized_matmul(h, params["fc"]["w"], bits, cfg, use_pallas=use_pallas)
    return h + params["fc"]["b"]


def loss_fn(params: dict, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    logits = forward_fp32(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(logits: jnp.ndarray, y: jnp.ndarray) -> float:
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


def param_count(params: dict) -> int:
    return int(sum(p.size for layer in params.values() for p in layer.values()))
