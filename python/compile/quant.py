"""Post-training uniform quantization for the OPIMA photonic path.

OPCM cells hold unsigned transmission levels, so both activations and
weights are quantized to *asymmetric unsigned* levels (zero-point +
scale). The optical MAC computes sum(a_lv * w_lv); the zero-point
correction terms are digital and exact (performed in the aggregation
unit / E-O-E controller in the paper's architecture):

  sum (a-za)*sa * (w-zw)*sw
    = sa*sw * [ sum a*w  - zw * sum a  - za * sum w  + K*za*zw ]

Only the first term runs through the photonic (ADC-quantized) pipeline.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .kernels.photonic_mac import PhotonicConfig, photonic_matmul
from .kernels.ref import photonic_matmul_ref


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Affine quantization: real = scale * (level - zero_point).

    Fields hold jnp scalars so parameter selection stays traceable under
    jax.jit (activation ranges are data-dependent at AOT-lowering time).
    """

    scale: jnp.ndarray
    zero_point: jnp.ndarray
    bits: int


def choose_qparams(x: jnp.ndarray, bits: int) -> QuantParams:
    """Min/max asymmetric quantization parameters for a tensor (traceable)."""
    lo = jnp.min(x)
    hi = jnp.max(x)
    hi = jnp.where(hi <= lo, lo + 1e-8, hi)
    nlevels = (1 << bits) - 1
    scale = (hi - lo) / nlevels
    zero_point = jnp.clip(jnp.round(-lo / scale), 0, nlevels)
    return QuantParams(scale=scale, zero_point=zero_point, bits=bits)


def quantize(x: jnp.ndarray, qp: QuantParams) -> jnp.ndarray:
    """Real tensor -> unsigned integer levels (float32-held)."""
    nlevels = (1 << qp.bits) - 1
    lv = jnp.round(x / qp.scale + qp.zero_point)
    return jnp.clip(lv, 0, nlevels).astype(jnp.float32)


def dequantize(levels: jnp.ndarray, qp: QuantParams) -> jnp.ndarray:
    return (levels - qp.zero_point) * qp.scale


def quantized_matmul(
    a: jnp.ndarray,
    w: jnp.ndarray,
    bits: int,
    cfg: PhotonicConfig | None = None,
    *,
    use_pallas: bool = True,
    a_qp: QuantParams | None = None,
    w_qp: QuantParams | None = None,
) -> jnp.ndarray:
    """Approximate a @ w through the OPIMA photonic pipeline.

    a: (M, K) real activations; w: (K, N) real weights. Quantizes both to
    `bits` unsigned levels, performs the level-domain MAC photonic-style
    (nibble TDM + group accumulation + ADC), applies the exact digital
    zero-point corrections, and dequantizes.
    """
    if cfg is None:
        cfg = PhotonicConfig(bits_a=bits, bits_w=bits)
    a_qp = a_qp or choose_qparams(a, bits)
    w_qp = w_qp or choose_qparams(w, bits)
    a_lv = quantize(a, a_qp)
    w_lv = quantize(w, w_qp)

    if use_pallas:
        lvl_prod = photonic_matmul(a_lv, w_lv, cfg)
    else:
        lvl_prod = photonic_matmul_ref(a_lv, w_lv, cfg)

    k = a.shape[1]
    # Digital (exact) zero-point corrections — aggregation unit / controller.
    corr = (
        lvl_prod
        - w_qp.zero_point * jnp.sum(a_lv, axis=1, keepdims=True)
        - a_qp.zero_point * jnp.sum(w_lv, axis=0, keepdims=True)
        + k * a_qp.zero_point * w_qp.zero_point
    )
    return a_qp.scale * w_qp.scale * corr
