"""Train the small CNN (fp32) and run the Table II quantization sweep.

Usage:  python -m compile.train [--outdir ../artifacts] [--steps 400]

Writes:
  <outdir>/params.npz            — trained fp32 parameters
  <outdir>/table2_accuracy.json  — fp32 / int8 / int4 accuracy (photonic path)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .data import make_dataset
from .kernels.photonic_mac import PhotonicConfig
from .model import accuracy, forward_fp32, forward_photonic, init_params, loss_fn, param_count

SEED = 20240710


def train(steps: int = 400, batch: int = 64, lr: float = 0.05, momentum: float = 0.9):
    key = jax.random.PRNGKey(SEED)
    key, kp, kd, kt = jax.random.split(key, 4)
    params = init_params(kp)
    train_x, train_y = make_dataset(kd, 2048)
    test_x, test_y = make_dataset(kt, 512)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    vel = jax.tree.map(jnp.zeros_like, params)

    n = train_x.shape[0]
    rng = np.random.default_rng(SEED)
    for step in range(steps):
        idx = rng.choice(n, batch, replace=False)
        loss, grads = grad_fn(params, train_x[idx], train_y[idx])
        vel = jax.tree.map(lambda v, g: momentum * v - lr * g, vel, grads)
        params = jax.tree.map(lambda p, v: p + v, params, vel)
        if step % 100 == 0 or step == steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}")
    return params, (train_x, train_y), (test_x, test_y)


def quantization_sweep(params, test_x, test_y, n_eval: int = 256) -> dict:
    """fp32 / int8 / int4 accuracy through the photonic pipeline (ADC on)."""
    x, y = test_x[:n_eval], test_y[:n_eval]
    results = {"parameter_count": param_count(params)}
    results["fp32"] = accuracy(forward_fp32(params, x), y)
    for bits in (8, 4):
        cfg = PhotonicConfig(bits_a=bits, bits_w=bits)
        logits = forward_photonic(params, x, bits=bits, cfg=cfg, use_pallas=False)
        results[f"int{bits}"] = accuracy(logits, y)
    return results


def save_params(params: dict, path: str) -> None:
    flat = {f"{layer}/{name}": np.asarray(v) for layer, d in params.items() for name, v in d.items()}
    np.savez(path, **flat)


def load_params(path: str) -> dict:
    flat = np.load(path)
    params: dict = {}
    for key in flat.files:
        layer, name = key.split("/")
        params.setdefault(layer, {})[name] = jnp.asarray(flat[key])
    return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    params, _, (test_x, test_y) = train(steps=args.steps)
    save_params(params, os.path.join(args.outdir, "params.npz"))

    results = quantization_sweep(params, test_x, test_y)
    print("Table II sweep (photonic path):", json.dumps(results, indent=2))
    with open(os.path.join(args.outdir, "table2_accuracy.json"), "w") as f:
        json.dump(results, f, indent=2)

    # Shape check against the paper: fp32 >= int8 >= int4, modest int4 drop.
    assert results["fp32"] >= results["int8"] - 0.02, results
    assert results["int8"] >= results["int4"] - 0.05, results


if __name__ == "__main__":
    main()
