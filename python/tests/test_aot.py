"""AOT path: lowering to HLO text must succeed and contain entry params.

Full artifact generation (with training) is exercised by `make artifacts`;
here we check the lowering machinery on the standalone kernel quickly.
"""

import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.kernels.photonic_mac import PhotonicConfig, photonic_matmul


def test_kernel_lowers_to_hlo_text():
    cfg = PhotonicConfig()

    def fn(a, w):
        return (photonic_matmul(a, w, cfg),)

    spec_a = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    lowered = jax.jit(fn).lower(spec_a, spec_w)
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text and "parameter(1)" in text
    # interpret=True must have erased any Mosaic custom-call.
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_hlo_text_is_deterministic():
    def fn(a):
        return (a * 2.0,)

    spec = jax.ShapeDtypeStruct((4,), jnp.float32)
    t1 = to_hlo_text(jax.jit(fn).lower(spec))
    t2 = to_hlo_text(jax.jit(fn).lower(spec))
    assert t1 == t2
