"""L1 correctness: Pallas photonic_mac vs pure-jnp oracle.

This is the core correctness signal for the compute layer: the Pallas
kernel (blocked, grid-accumulated) must agree with the unblocked
reference — bit-for-bit when ADC is off (integer sums), within one ulp of
f32 summation-order freedom when ADC quantization is on — and with ADC
disabled both must equal the exact integer matmul.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.photonic_mac import (
    MAX_NIBBLE_PRODUCT,
    NIBBLE_BASE,
    PhotonicConfig,
    adc_quantize,
    extract_nibble,
    photonic_matmul,
    vmem_footprint_bytes,
)
from compile.kernels.ref import adc_error_bound, exact_matmul_ref, photonic_matmul_ref


def rand_levels(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 1 << bits, size=shape), jnp.float32)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("adc", [False, True])
def test_kernel_matches_ref_basic(bits, adc):
    rng = np.random.default_rng(0)
    cfg = PhotonicConfig(bits_a=bits, bits_w=bits, enable_adc=adc)
    a = rand_levels(rng, (32, 48), bits)
    w = rand_levels(rng, (48, 24), bits)
    got = photonic_matmul(a, w, cfg)
    want = photonic_matmul_ref(a, w, cfg)
    # ADC-on 8-bit totals exceed 2^24 ADC-step units, so f32 summation
    # order (which differs between the blocked kernel and the one-shot
    # reference) costs ~1e-7 relative; ADC-off sums are exact integers.
    np.testing.assert_allclose(got, want, rtol=1e-6 if adc else 0, atol=0)


@pytest.mark.parametrize("bits", [4, 8])
def test_adc_off_equals_exact_matmul(bits):
    rng = np.random.default_rng(1)
    cfg = PhotonicConfig(bits_a=bits, bits_w=bits, enable_adc=False)
    a = rand_levels(rng, (16, 40), bits)
    w = rand_levels(rng, (40, 12), bits)
    got = photonic_matmul(a, w, cfg)
    np.testing.assert_allclose(got, exact_matmul_ref(a, w), rtol=0, atol=0)


def test_adc_error_is_bounded():
    rng = np.random.default_rng(2)
    cfg = PhotonicConfig(bits_a=8, bits_w=8, enable_adc=True)
    a = rand_levels(rng, (8, 64), 8)
    w = rand_levels(rng, (64, 8), 8)
    got = photonic_matmul(a, w, cfg)
    exact = exact_matmul_ref(a, w)
    bound = adc_error_bound(64, cfg)
    assert float(jnp.max(jnp.abs(got - exact))) <= bound


def test_mixed_bitwidths():
    """8-bit activations against 4-bit weights (challenge (4), TDM)."""
    rng = np.random.default_rng(3)
    cfg = PhotonicConfig(bits_a=8, bits_w=4, enable_adc=False)
    a = rand_levels(rng, (8, 20), 8)
    w = rand_levels(rng, (20, 8), 4)
    got = photonic_matmul(a, w, cfg)
    np.testing.assert_allclose(got, exact_matmul_ref(a, w), rtol=0, atol=0)


def test_nibble_decomposition_roundtrip():
    lv = jnp.arange(256, dtype=jnp.float32)
    recomposed = sum(
        extract_nibble(lv, i) * float(NIBBLE_BASE**i) for i in range(2)
    )
    np.testing.assert_array_equal(recomposed, lv)


def test_adc_quantize_properties():
    cfg = PhotonicConfig()
    x = jnp.linspace(0.0, cfg.group_size * MAX_NIBBLE_PRODUCT, 97)
    q = adc_quantize(x, cfg)
    # Quantized to the step grid, error <= step/2, zero fixed point.
    assert float(jnp.max(jnp.abs(q - x))) <= cfg.adc_step / 2 + 1e-5
    steps = q / cfg.adc_step
    np.testing.assert_allclose(steps, jnp.round(steps), atol=1e-5)
    assert float(adc_quantize(jnp.zeros(()), cfg)) == 0.0


def test_block_shape_independence():
    """Result must not depend on the blocking (segment alignment holds)."""
    rng = np.random.default_rng(4)
    cfg = PhotonicConfig(bits_a=4, bits_w=4, enable_adc=True)
    a = rand_levels(rng, (24, 60), 4)
    w = rand_levels(rng, (60, 20), 4)
    ref = photonic_matmul_ref(a, w, cfg)
    for bm, bn, bk in [(8, 8, 8), (16, 32, 16), (64, 64, 64), (24, 20, 60)]:
        got = photonic_matmul(a, w, cfg, block_m=bm, block_n=bn, block_k=bk)
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=0)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    bits_a=st.sampled_from([4, 8]),
    bits_w=st.sampled_from([4, 8]),
    adc=st.booleans(),
    group=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_ref_hypothesis(m, k, n, bits_a, bits_w, adc, group, seed):
    """Property sweep: arbitrary shapes/bit-widths/groupings agree with ref."""
    rng = np.random.default_rng(seed)
    cfg = PhotonicConfig(bits_a=bits_a, bits_w=bits_w, enable_adc=adc, group_size=group)
    a = rand_levels(rng, (m, k), bits_a)
    w = rand_levels(rng, (k, n), bits_w)
    got = photonic_matmul(a, w, cfg)
    want = photonic_matmul_ref(a, w, cfg)
    np.testing.assert_allclose(got, want, rtol=1e-6 if adc else 0, atol=0)
    if not adc:
        np.testing.assert_allclose(got, exact_matmul_ref(a, w), rtol=0, atol=0)


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        PhotonicConfig(bits_a=3)
    with pytest.raises(ValueError):
        PhotonicConfig(bits_w=0)
    with pytest.raises(ValueError):
        PhotonicConfig(group_size=0)
    with pytest.raises(ValueError):
        photonic_matmul(jnp.zeros((2, 3)), jnp.zeros((4, 2)))


def test_vmem_footprint_estimate():
    # 64x64x64 f32 blocks must fit comfortably in a 16 MiB VMEM budget.
    assert vmem_footprint_bytes(64, 64, 64) < 4 * 1024 * 1024
