"""L2 model: shapes, conv lowering, photonic forward, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.data import make_dataset
from compile.kernels.conv2d import conv2d_fp32, conv2d_photonic, im2col
from compile.kernels.photonic_mac import PhotonicConfig
from compile.model import (
    IMAGE_SIZE,
    NUM_CLASSES,
    accuracy,
    forward_fp32,
    forward_photonic,
    init_params,
    loss_fn,
    maxpool2,
    param_count,
)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0))


def test_im2col_matches_lax_conv():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 5)), jnp.float32)
    got = conv2d_fp32(x, w, stride=1, padding=1)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(1, 1), (1, 1)], dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 10),
    kh=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    c=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_shapes_hypothesis(h, kh, stride, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, h, h, c)), jnp.float32)
    patches, (n, oh, ow) = im2col(x, kh, kh, stride=stride, padding=0)
    assert n == 1
    assert oh == (h - kh) // stride + 1
    assert patches.shape == (oh * ow, kh * kh * c)


def test_maxpool2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    out = maxpool2(x)
    np.testing.assert_array_equal(out[0, :, :, 0], [[5, 7], [13, 15]])


def test_forward_shapes(params):
    x = jnp.zeros((5, IMAGE_SIZE, IMAGE_SIZE, 1))
    assert forward_fp32(params, x).shape == (5, NUM_CLASSES)
    out = forward_photonic(params, x, bits=4, use_pallas=False)
    assert out.shape == (5, NUM_CLASSES)


def test_photonic_forward_close_to_fp32_at_8bit(params):
    x, _ = make_dataset(jax.random.PRNGKey(1), 16)
    ref = forward_fp32(params, x)
    q8 = forward_photonic(
        params, x, bits=8, cfg=PhotonicConfig(bits_a=8, bits_w=8, enable_adc=False),
        use_pallas=False,
    )
    # Logit agreement: argmax should mostly match at 8-bit.
    agree = float(jnp.mean(jnp.argmax(ref, 1) == jnp.argmax(q8, 1)))
    assert agree >= 0.75


def test_conv_photonic_matches_quantized_ref():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 2)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 2, 4)), jnp.float32)
    cfg = PhotonicConfig()
    via_pallas = conv2d_photonic(x, w, 4, cfg, padding=1, use_pallas=True)
    via_ref = conv2d_photonic(x, w, 4, cfg, padding=1, use_pallas=False)
    np.testing.assert_allclose(via_pallas, via_ref, rtol=0, atol=1e-4)


def test_loss_decreases_with_training(params):
    x, y = make_dataset(jax.random.PRNGKey(3), 128)
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    p = params
    l0, _ = grad_fn(p, x, y)
    for _ in range(30):
        _, g = grad_fn(p, x, y)
        p = jax.tree.map(lambda a, b: a - 0.1 * b, p, g)
    l1, _ = grad_fn(p, x, y)
    assert float(l1) < float(l0)


def test_dataset_determinism_and_balance():
    x1, y1 = make_dataset(jax.random.PRNGKey(5), 256)
    x2, y2 = make_dataset(jax.random.PRNGKey(5), 256)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2))
    counts = np.bincount(np.asarray(y1), minlength=NUM_CLASSES)
    assert counts.min() > 0.15 * 256


def test_param_count(params):
    # conv1: 3*3*1*8+8; conv2: 3*3*8*16+16; fc: 144*4+4
    assert param_count(params) == (72 + 8) + (1152 + 16) + (576 + 4)


def test_accuracy_fn():
    logits = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
    y = jnp.asarray([0, 0])
    assert accuracy(logits, y) == 0.5
