"""Quantization layer: qparams, round-trips, photonic quantized matmul."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.photonic_mac import PhotonicConfig
from compile.quant import choose_qparams, dequantize, quantize, quantized_matmul


def test_qparams_cover_range():
    x = jnp.asarray([-2.0, 0.0, 3.0])
    for bits in (4, 8):
        qp = choose_qparams(x, bits)
        lv = quantize(x, qp)
        assert float(lv.min()) >= 0
        assert float(lv.max()) <= (1 << bits) - 1
        back = dequantize(lv, qp)
        # Round-trip error bounded by one step.
        assert float(jnp.max(jnp.abs(back - x))) <= float(qp.scale) * 1.01


def test_constant_tensor_does_not_blow_up():
    x = jnp.full((4, 4), 3.25)
    qp = choose_qparams(x, 4)
    lv = quantize(x, qp)
    assert np.isfinite(np.asarray(dequantize(lv, qp))).all()


@pytest.mark.parametrize("bits", [4, 8])
def test_quantized_matmul_approximates_fp32(bits):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    exact = a @ w
    cfg = PhotonicConfig(bits_a=bits, bits_w=bits, enable_adc=False)
    approx = quantized_matmul(a, w, bits, cfg, use_pallas=False)
    # Relative Frobenius error shrinks with more bits.
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < (0.35 if bits == 4 else 0.05)


def test_adc_on_close_to_adc_off():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=(12, 24)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(24, 6)), jnp.float32)
    on = quantized_matmul(a, w, 4, PhotonicConfig(enable_adc=True), use_pallas=False)
    off = quantized_matmul(a, w, 4, PhotonicConfig(enable_adc=False), use_pallas=False)
    # ADC adds bounded analog readout error on top of quantization.
    denom = float(jnp.linalg.norm(off)) + 1e-9
    assert float(jnp.linalg.norm(on - off)) / denom < 0.5


def test_pallas_and_ref_paths_agree():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
    cfg = PhotonicConfig()
    via_pallas = quantized_matmul(a, w, 4, cfg, use_pallas=True)
    via_ref = quantized_matmul(a, w, 4, cfg, use_pallas=False)
    np.testing.assert_allclose(via_pallas, via_ref, rtol=0, atol=1e-4)


def test_traceable_under_jit():
    """choose_qparams/quantized_matmul must trace (needed for AOT)."""

    @jax.jit
    def f(a, w):
        return quantized_matmul(a, w, 4, PhotonicConfig(), use_pallas=False)

    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    out = f(a, w)
    assert out.shape == (4, 4)
    assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(2, 32),
    n=st.integers(1, 12),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantized_matmul_error_scales_with_bits(m, k, n, bits, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    exact = a @ w
    cfg = PhotonicConfig(bits_a=bits, bits_w=bits, enable_adc=False)
    approx = quantized_matmul(a, w, bits, cfg, use_pallas=False)
    scale_a = (float(a.max()) - float(a.min())) / ((1 << bits) - 1)
    scale_w = (float(w.max()) - float(w.min())) / ((1 << bits) - 1)
    # Generous analytic bound: per-element error can reach a full step of
    # each operand (0.5 from value rounding + 0.5 from the zero-point
    # rounding shifting the whole grid), propagated through the product.
    amax = float(jnp.max(jnp.abs(a))) + scale_a
    wmax = float(jnp.max(jnp.abs(w))) + scale_w
    bound = 2.0 * k * (scale_a * wmax + scale_w * amax + scale_a * scale_w) + 1e-5
    assert float(jnp.max(jnp.abs(approx - exact))) <= bound
