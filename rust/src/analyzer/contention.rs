//! The global contention timeline: one persistent event engine per
//! simulated OPIMA instance, into which in-flight batches are admitted
//! as event streams competing for the **shared** aggregation-unit and
//! writeback-channel pools as well as subarray occupancy.
//!
//! ## Why
//!
//! The per-batch timeline ([`crate::analyzer::timeline`]) prices a
//! batch assuming sole use of the stage pools, and the router's
//! co-residency (PR 4) charged subarray *occupancy* only — co-resident
//! batches optimistically shared the pools each timeline priced as
//! exclusive, so every fleet-scale makespan was optimistic by up to the
//! writeback-channel share. This engine closes that gap without
//! re-simulating: the pools persist *across* admissions, so a batch
//! admitted while another is draining sees the true residual capacity.
//!
//! ## How admission stays incremental
//!
//! - **Binary-heap slot pools.** Each instance owns one `PoolHeap`
//!   per shared stage (aggregation, writeback): a min-heap of slot free
//!   times, so acquiring the earliest-free slot is O(log capacity)
//!   instead of the O(capacity) scan the per-batch pool uses — and the
//!   heap *carries over* between admissions instead of resetting.
//! - **Relative-origin admission.** The scheduling arithmetic runs in
//!   the batch's own frame (t = 0 at admission) via the *same*
//!   `run_stream` pass the standalone timeline uses; shared slot free
//!   times are stored absolute and converted at acquire. A slot that
//!   drained at or before the admission origin grants exactly the
//!   requested ready time, so a batch admitted onto a drained instance
//!   reproduces [`simulate_analysis_makespan`](crate::analyzer::timeline::simulate_analysis_makespan)
//!   **bit-exactly** — the paper reproductions (Figs. 9/10) are priced
//!   by the identical arithmetic whenever one batch is in flight.
//! - **Per-batch cursors, not global replay.** The per-layer exclusive
//!   units and writeback-order cursors are batch-local (each admitted
//!   batch maps its own stationary operands), held in a reusable
//!   scratch, so one admission costs O(batch × layers × log pools) and
//!   allocates nothing in the steady state.
//! - **Retirement frontier.** [`GlobalTimeline::advance`] drops every
//!   occupancy reservation that ends at or before the latest observed
//!   dispatch clock — a prefix drain, because the ledger is kept sorted
//!   by end time. When simulated time outruns the wall clock nothing
//!   expires, so past [`MAX_RESERVATIONS_PER_INSTANCE`] the
//!   earliest-ending prefix folds into a per-instance start *floor*
//!   (conservative: placements only move later, never overbook). Pool
//!   heaps are fixed-size by construction; total memory is bounded
//!   regardless of how many batches were ever admitted.
//!
//! Retiring a reservation only frees occupancy for *future* placements;
//! it never rewrites pool state, so the makespans of already-admitted
//! (still-live) batches are unaffected — pinned by the property suite.
//!
//! ## Bounds
//!
//! For any admission: isolated makespan ≤ contended makespan (the pools
//! can only be busier than empty), and a set of batches admitted onto
//! one instance never exceeds the serialized sum of their isolated
//! makespans plus their queueing — both verified as property tests over
//! random CNN pairs (`tests/contention.rs`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::analyzer::timeline::{
    run_stream, CommandSink, Event, FlatSink, SlotPool, StreamScratch, WB_BATCH_ROW_STRIDE,
};
use crate::config::{PipelineParams, WritebackModel};
use crate::memory::writeback::{NaiveWritebackController, ScheduledWritebackController};
use crate::pim::scheduler::LayerCost;
use crate::util::units::{Millis, Nanos};

/// Ledger bound per instance; beyond this the earliest-ending half of
/// the occupancy reservations is folded into the instance's start
/// floor.
pub const MAX_RESERVATIONS_PER_INSTANCE: usize = 128;

/// Total-order wrapper so [`Nanos`] free times can live in a heap.
#[derive(Debug, Clone, Copy, PartialEq)]
struct FreeAt(Nanos);

impl Eq for FreeAt {}

impl PartialOrd for FreeAt {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FreeAt {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A persistent stage pool: a min-heap of absolute slot free times.
/// Acquire pops the earliest-free slot and pushes its new free
/// time back — O(log capacity), and the state survives across
/// admissions, which is exactly what makes co-resident batches contend.
#[derive(Debug, Clone)]
struct PoolHeap {
    free: BinaryHeap<Reverse<FreeAt>>,
}

impl PoolHeap {
    fn new(capacity: usize) -> Self {
        let mut free = BinaryHeap::with_capacity(capacity.max(1));
        for _ in 0..capacity.max(1) {
            free.push(Reverse(FreeAt(Nanos::ZERO)));
        }
        Self { free }
    }
}

/// Adapter presenting one instance's persistent heap to [`run_stream`]
/// in a batch's own time frame (t = 0 at the admission origin).
struct RelPool<'a> {
    heap: &'a mut PoolHeap,
    /// Absolute admission time of the batch being scheduled.
    origin: Nanos,
}

impl SlotPool for RelPool<'_> {
    fn acquire(&mut self, ready: Nanos, dur: Nanos) -> Nanos {
        let Reverse(FreeAt(free_abs)) =
            self.heap.free.pop().expect("pool has at least one slot");
        // A slot that drained at or before this batch's origin grants
        // exactly `ready` — bit-identical to the standalone per-batch
        // pass (whose slots start at 0), so a single batch in flight
        // reproduces the isolated timeline exactly, at any admission
        // time. A still-busy slot pushes the start out by its residual.
        let start = if free_abs <= self.origin {
            ready
        } else {
            ready.max(free_abs - self.origin)
        };
        self.heap.free.push(Reverse(FreeAt(self.origin + (start + dur))));
        start
    }
}

/// The writeback stage of one instance, per `[memory] writeback_model`:
/// the flat slot heap (default — byte-identical to the pre-command
/// engine) or one persistent command-level controller whose bank and
/// GST-route state carries across admissions, so co-resident batches
/// collide on real banks and row switches, not just on channel counts.
#[derive(Debug, Clone)]
enum WbSlots {
    Flat(PoolHeap),
    Naive(NaiveWritebackController),
    Scheduled(ScheduledWritebackController),
}

/// One committed slice of simulated subarray occupancy (absolute time).
#[derive(Debug, Clone, Copy)]
struct Reservation {
    start_ns: Nanos,
    end_ns: Nanos,
    subarrays: usize,
}

/// One simulated OPIMA instance: its occupancy ledger (sorted by end
/// time), its compaction floor, and its persistent stage pools.
#[derive(Debug, Clone)]
struct Instance {
    /// Live occupancy reservations, **sorted by `end_ns` ascending** —
    /// feasibility scans walk candidates in order without allocating,
    /// and retirement is a prefix drain.
    reservations: Vec<Reservation>,
    /// Simulated time before which no new reservation may start,
    /// raised when old reservations fold away to bound the ledger.
    floor_ns: Nanos,
    /// Latest reservation end ever committed here.
    horizon_ns: Nanos,
    /// Shared aggregation-unit pool (persists across admissions).
    agg: PoolHeap,
    /// Shared writeback stage (persists across admissions).
    wb: WbSlots,
    /// Monotone command-level job ids issued on this instance.
    wb_jobs: u64,
    /// Batches ever admitted here — the row-id tag that keeps
    /// co-resident batches on distinct subarray rows.
    wb_batches: u64,
}

impl Instance {
    fn new(pipe: &PipelineParams) -> Self {
        Self::with_memory(pipe, WritebackModel::Flat, 1)
    }

    fn with_memory(pipe: &PipelineParams, model: WritebackModel, banks: usize) -> Self {
        Self {
            reservations: Vec::new(),
            floor_ns: Nanos::ZERO,
            horizon_ns: Nanos::ZERO,
            agg: PoolHeap::new(pipe.aggregation_units),
            wb: match model {
                WritebackModel::Flat => WbSlots::Flat(PoolHeap::new(pipe.writeback_channels)),
                WritebackModel::Naive => WbSlots::Naive(NaiveWritebackController::new(banks)),
                WritebackModel::Scheduled => WbSlots::Scheduled(
                    ScheduledWritebackController::new(banks, pipe.writeback_channels),
                ),
            },
            wb_jobs: 0,
            wb_batches: 0,
        }
    }

    /// Insert a committed reservation keeping the ledger end-sorted,
    /// then compact **this instance only** if it outgrew the bound
    /// (the frontier prune in [`GlobalTimeline::advance`] handles the
    /// expiring case; this handles the oversubscribed one).
    fn commit(&mut self, fp: usize, start_ns: Nanos, end_ns: Nanos) {
        let at = self.reservations.partition_point(|r| r.end_ns <= end_ns);
        self.reservations.insert(
            at,
            Reservation {
                start_ns,
                end_ns,
                subarrays: fp,
            },
        );
        self.horizon_ns = self.horizon_ns.max(end_ns);
        if self.reservations.len() > MAX_RESERVATIONS_PER_INSTANCE {
            let cut = self.reservations.len() - MAX_RESERVATIONS_PER_INSTANCE / 2;
            // Already end-sorted: the fold point is the last dropped end.
            self.floor_ns = self.floor_ns.max(self.reservations[cut - 1].end_ns);
            self.reservations.drain(..cut);
        }
    }
}

/// What one batch brings to admission: its priced layer stream.
#[derive(Debug, Clone, Copy)]
pub struct BatchStream<'a> {
    /// Per-layer stage costs (the PIM scheduler's split).
    pub costs: &'a [LayerCost],
    /// Images in the batch.
    pub batch: usize,
    /// False when the mapping is over capacity — the stream runs
    /// strictly serialized, image by image.
    pub pipelined: bool,
}

/// The committed outcome of one admission (absolute time).
#[derive(Debug, Clone, Copy)]
pub struct Admission {
    /// When the batch entered the instance.
    pub start_ns: Nanos,
    /// When its last event drained.
    pub end_ns: Nanos,
    /// Contended whole-batch makespan, relative to the admission start
    /// (`end_ns − start_ns` up to rounding; this is the exact stream
    /// makespan the scheduling pass returned).
    pub makespan_ns: Nanos,
}

impl Admission {
    pub fn start_ms(&self) -> Millis {
        self.start_ns.to_millis()
    }

    pub fn end_ms(&self) -> Millis {
        self.end_ns.to_millis()
    }

    pub fn makespan_ms(&self) -> Millis {
        self.makespan_ns.to_millis()
    }
}

/// The persistent global engine: one [`Instance`] per simulated module.
/// All times are absolute [`Nanos`]; callers holding a millisecond
/// clock (the router) convert at the boundary.
#[derive(Debug, Clone)]
pub struct GlobalTimeline {
    /// Subarray capacity of each instance.
    capacity: usize,
    pipe: PipelineParams,
    instances: Vec<Instance>,
    /// Latest observed dispatch clock — the retirement frontier.
    frontier_ns: Nanos,
    /// Reusable per-admission scheduling state (no steady-state allocs).
    scratch: StreamScratch,
}

impl GlobalTimeline {
    pub fn new(instances: usize, subarray_capacity: usize, pipe: &PipelineParams) -> Self {
        Self::with_memory(instances, subarray_capacity, pipe, WritebackModel::Flat, 1)
    }

    /// Like [`Self::new`] but pricing writebacks with the configured
    /// command-level model (`[memory] writeback_model`); `banks` is the
    /// per-instance OPCM bank count the controllers stripe program
    /// trains over. `WritebackModel::Flat` matches [`Self::new`]
    /// bit-exactly regardless of `banks`.
    pub fn with_memory(
        instances: usize,
        subarray_capacity: usize,
        pipe: &PipelineParams,
        model: WritebackModel,
        banks: usize,
    ) -> Self {
        assert!(instances >= 1);
        Self {
            capacity: subarray_capacity.max(1),
            pipe: pipe.clone(),
            instances: (0..instances)
                .map(|_| Instance::with_memory(pipe, model, banks))
                .collect(),
            frontier_ns: Nanos::ZERO,
            scratch: StreamScratch::default(),
        }
    }

    pub fn instances(&self) -> usize {
        self.instances.len()
    }

    /// Subarray capacity of each instance.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The retirement frontier: the latest dispatch clock observed.
    pub fn frontier_ns(&self) -> Nanos {
        self.frontier_ns
    }

    /// Advance the retirement frontier to `now_ns` (monotone) and drop
    /// every reservation that ended at or before it. The ledgers are
    /// end-sorted, so retirement is a prefix drain per instance — and it
    /// runs only when the frontier **strictly advances**, not on every
    /// dispatch. Returns the (possibly clamped) frontier.
    pub fn advance(&mut self, now_ns: Nanos) -> Nanos {
        if now_ns > self.frontier_ns {
            self.frontier_ns = now_ns;
            for inst in &mut self.instances {
                let cut = inst.reservations.partition_point(|r| r.end_ns <= now_ns);
                if cut > 0 {
                    inst.reservations.drain(..cut);
                }
            }
        }
        self.frontier_ns
    }

    /// Earliest `t ≥ max(base, floor)` at which `fp` subarrays are free
    /// on instance `i` for the whole window `[t, t + dur)`, by the
    /// conservative overlap count (a window is charged every reservation
    /// it overlaps, so occupancy is never undercounted). Candidates are
    /// the base time and each reservation end, visited in order straight
    /// off the end-sorted ledger — no allocation, no sort.
    pub fn earliest_start(&self, i: usize, fp: usize, base_ns: Nanos, dur_ns: Nanos) -> Nanos {
        let inst = &self.instances[i];
        let fp = fp.clamp(1, self.capacity);
        let base = base_ns.max(inst.floor_ns);
        if self.feasible_at(&inst.reservations, fp, base, dur_ns) {
            return base;
        }
        for r in &inst.reservations {
            let t = r.end_ns;
            if t <= base {
                continue;
            }
            if self.feasible_at(&inst.reservations, fp, t, dur_ns) {
                return t;
            }
        }
        // Unreachable by construction: at the latest reservation end no
        // reservation overlaps the window and `fp ≤ capacity`. Kept as a
        // defensive fallback rather than a panic in the serving path.
        inst.horizon_ns.max(base)
    }

    /// Whether `fp` subarrays fit on top of the reservations overlapping
    /// `[t, t + dur)`. End-sorted ledger: everything ending at or before
    /// `t` is skipped in O(log n).
    fn feasible_at(&self, rs: &[Reservation], fp: usize, t: Nanos, dur_ns: Nanos) -> bool {
        let from = rs.partition_point(|r| r.end_ns <= t);
        let used: usize = rs[from..]
            .iter()
            .filter(|r| r.start_ns < t + dur_ns)
            .map(|r| r.subarrays)
            .sum();
        used + fp <= self.capacity
    }

    /// Occupancy-only admission (the optimistic pre-contention model):
    /// commit `[start, start + dur)` on instance `i` without touching
    /// the shared stage pools. Returns the end time.
    pub fn occupy(&mut self, i: usize, fp: usize, start_ns: Nanos, dur_ns: Nanos) -> Nanos {
        let fp = fp.clamp(1, self.capacity);
        let end_ns = start_ns + dur_ns;
        self.instances[i].commit(fp, start_ns, end_ns);
        end_ns
    }

    /// Admit a batch stream onto instance `i` at `start_ns`: run the
    /// shared per-batch scheduling pass against this instance's
    /// **persistent** stage pools (in the batch's own frame, t = 0 at
    /// `start_ns`), then commit the resulting contended window to the
    /// occupancy ledger. With `events`, the batch's schedule is appended
    /// in absolute time (co-residency audits). O(batch × layers ×
    /// log pools), allocation-free in the steady state.
    pub fn admit(
        &mut self,
        i: usize,
        fp: usize,
        start_ns: Nanos,
        stream: BatchStream<'_>,
        mut events: Option<&mut Vec<Event>>,
    ) -> Admission {
        let fp = fp.clamp(1, self.capacity);
        let GlobalTimeline {
            pipe,
            instances,
            scratch,
            ..
        } = self;
        scratch.reset(stream.costs.len(), stream.batch);
        let inst = &mut instances[i];
        let appended_from = events.as_deref().map_or(0, |ev| ev.len());
        // Row-id tag for this admission: co-resident batches write
        // distinct subarray rows, so their trains never coalesce on the
        // GST switches (flat model: unused).
        let row_base = inst.wb_batches * WB_BATCH_ROW_STRIDE;
        let makespan_ns = {
            let Instance {
                agg, wb, wb_jobs, ..
            } = inst;
            let mut agg = RelPool {
                heap: agg,
                origin: start_ns,
            };
            match wb {
                WbSlots::Flat(heap) => {
                    let mut pool = RelPool {
                        heap,
                        origin: start_ns,
                    };
                    let mut sink = FlatSink(&mut pool);
                    run_stream(
                        stream.costs,
                        stream.batch,
                        stream.pipelined,
                        pipe.max_in_flight_images,
                        &mut agg,
                        &mut sink,
                        scratch,
                        events.as_deref_mut(),
                    )
                }
                WbSlots::Naive(ctl) => {
                    let mut sink = CommandSink {
                        ctl,
                        origin: start_ns,
                        next_job: wb_jobs,
                        row_base,
                    };
                    run_stream(
                        stream.costs,
                        stream.batch,
                        stream.pipelined,
                        pipe.max_in_flight_images,
                        &mut agg,
                        &mut sink,
                        scratch,
                        events.as_deref_mut(),
                    )
                }
                WbSlots::Scheduled(ctl) => {
                    let mut sink = CommandSink {
                        ctl,
                        origin: start_ns,
                        next_job: wb_jobs,
                        row_base,
                    };
                    run_stream(
                        stream.costs,
                        stream.batch,
                        stream.pipelined,
                        pipe.max_in_flight_images,
                        &mut agg,
                        &mut sink,
                        scratch,
                        events.as_deref_mut(),
                    )
                }
            }
        };
        inst.wb_batches += 1;
        if let Some(ev) = events.as_deref_mut() {
            // run_stream emitted the batch frame; shift to absolute.
            for e in &mut ev[appended_from..] {
                e.start_ns += start_ns;
                e.end_ns += start_ns;
            }
        }
        let end_ns = start_ns + makespan_ns;
        inst.commit(fp, start_ns, end_ns);
        Admission {
            start_ns,
            end_ns,
            makespan_ns,
        }
    }

    /// Latest committed end across all instances — the global
    /// simulated makespan (monotone; retirement never lowers it).
    pub fn makespan_ns(&self) -> Nanos {
        self.instances
            .iter()
            .map(|i| i.horizon_ns)
            .fold(Nanos::ZERO, Nanos::max)
    }

    /// Latest committed end on instance `i`.
    pub fn horizon_ns(&self, i: usize) -> Nanos {
        self.instances[i].horizon_ns
    }

    /// Live (unretired, unfolded) reservations on instance `i`.
    pub fn live_reservations(&self, i: usize) -> usize {
        self.instances[i].reservations.len()
    }

    /// Compaction floor of instance `i`.
    pub fn floor_ns(&self, i: usize) -> Nanos {
        self.instances[i].floor_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::ns;

    fn lc(mac_ns: f64, aggregation_ns: f64, writeback_ns: f64) -> LayerCost {
        LayerCost {
            processing_ns: ns(mac_ns + aggregation_ns),
            mac_ns: ns(mac_ns),
            aggregation_ns: ns(aggregation_ns),
            writeback_ns: ns(writeback_ns),
            ..LayerCost::default()
        }
    }

    fn costs() -> Vec<LayerCost> {
        vec![lc(100.0, 40.0, 60.0), lc(80.0, 30.0, 50.0)]
    }

    fn stream(c: &[LayerCost], batch: usize) -> BatchStream<'_> {
        BatchStream {
            costs: c,
            batch,
            pipelined: true,
        }
    }

    #[test]
    fn single_admission_matches_standalone_timeline_bitwise() {
        let pipe = PipelineParams::default();
        let c = costs();
        // Reference: the standalone per-batch pass on fresh pools.
        let mut gt_fresh = GlobalTimeline::new(1, 64, &pipe);
        let iso = gt_fresh
            .admit(0, 8, Nanos::ZERO, stream(&c, 6), None)
            .makespan_ns;
        // Same batch admitted at an arbitrary origin onto drained pools.
        let mut gt = GlobalTimeline::new(1, 64, &pipe);
        let a = gt.admit(0, 8, ns(12_345.5), stream(&c, 6), None);
        assert_eq!(a.makespan_ns, iso, "drained-instance admission must be exact");
        assert_eq!(a.end_ns, ns(12_345.5) + iso);
    }

    #[test]
    fn coresident_admissions_contend_for_pools() {
        let pipe = PipelineParams {
            writeback_channels: 1,
            ..PipelineParams::default()
        };
        let c = costs();
        let mut gt = GlobalTimeline::new(1, 64, &pipe);
        let a0 = gt.admit(0, 8, Nanos::ZERO, stream(&c, 4), None);
        // Second batch co-admitted at t=0: the writeback channel is
        // busy, so its makespan must exceed its isolated one.
        let mut fresh = GlobalTimeline::new(1, 64, &pipe);
        let iso = fresh
            .admit(0, 8, Nanos::ZERO, stream(&c, 4), None)
            .makespan_ns;
        let a1 = gt.admit(0, 8, Nanos::ZERO, stream(&c, 4), None);
        assert!(a1.makespan_ns > iso, "co-resident batch saw no contention");
        // And bounded by full serialization behind the first batch.
        assert!(a1.end_ns <= a0.end_ns + iso + ns(1e-6));
    }

    #[test]
    fn advance_is_a_prefix_drain_and_monotone() {
        let pipe = PipelineParams::default();
        let mut gt = GlobalTimeline::new(1, 100, &pipe);
        gt.occupy(0, 10, Nanos::ZERO, ns(50.0));
        gt.occupy(0, 10, Nanos::ZERO, ns(100.0));
        gt.occupy(0, 10, Nanos::ZERO, ns(150.0));
        assert_eq!(gt.live_reservations(0), 3);
        gt.advance(ns(100.0));
        assert_eq!(gt.live_reservations(0), 1, "ends ≤ frontier retire");
        // A stale clock neither regresses the frontier nor re-prunes.
        assert_eq!(gt.advance(ns(10.0)), ns(100.0));
        assert_eq!(gt.live_reservations(0), 1);
        assert_eq!(gt.makespan_ns(), ns(150.0), "retirement keeps the horizon");
    }

    #[test]
    fn ledger_compacts_into_floor_when_nothing_expires() {
        let pipe = PipelineParams::default();
        let mut gt = GlobalTimeline::new(1, 100, &pipe);
        let mut t = Nanos::ZERO;
        for _ in 0..1000 {
            // Footprint 60: no two fit together, every window serializes.
            let s = gt.earliest_start(0, 60, Nanos::ZERO, ns(5.0));
            assert!(s >= t, "starts must not regress");
            t = gt.occupy(0, 60, s, ns(5.0));
        }
        assert!(gt.live_reservations(0) <= MAX_RESERVATIONS_PER_INSTANCE);
        assert!(gt.floor_ns(0) > Nanos::ZERO, "compaction must have folded");
        assert!((gt.makespan_ns() - ns(1000.0 * 5.0)).abs().raw() < 1e-6);
    }

    /// A drained command-model instance prices a batch identically at
    /// any admission origin — the same bit-exactness contract the flat
    /// heap pools honor ([`RelPool`]).
    #[test]
    fn command_model_admission_is_origin_invariant() {
        for model in [WritebackModel::Naive, WritebackModel::Scheduled] {
            let pipe = PipelineParams::default();
            let c = costs();
            let mut at_zero = GlobalTimeline::with_memory(1, 64, &pipe, model, 4);
            let iso = at_zero
                .admit(0, 8, Nanos::ZERO, stream(&c, 6), None)
                .makespan_ns;
            let mut shifted = GlobalTimeline::with_memory(1, 64, &pipe, model, 4);
            let a = shifted.admit(0, 8, ns(12_345.5), stream(&c, 6), None);
            assert_eq!(a.makespan_ns, iso, "{model:?} drifted under a shifted origin");
        }
    }

    /// Co-resident batches contend through the persistent bank/channel
    /// state of both command controllers, and the scheduled controller
    /// never prices the pair above the naive reference.
    #[test]
    fn command_model_coresidents_contend_and_stay_ordered() {
        let pipe = PipelineParams {
            writeback_channels: 1,
            ..PipelineParams::default()
        };
        let c = costs();
        let mut ends = Vec::new();
        for model in [WritebackModel::Naive, WritebackModel::Scheduled] {
            let mut gt = GlobalTimeline::with_memory(1, 64, &pipe, model, 4);
            gt.admit(0, 8, Nanos::ZERO, stream(&c, 4), None);
            let mut fresh = GlobalTimeline::with_memory(1, 64, &pipe, model, 4);
            let iso = fresh
                .admit(0, 8, Nanos::ZERO, stream(&c, 4), None)
                .makespan_ns;
            let a1 = gt.admit(0, 8, Nanos::ZERO, stream(&c, 4), None);
            assert!(
                a1.makespan_ns > iso,
                "{model:?} co-resident batch saw no contention"
            );
            ends.push(a1.end_ns);
        }
        assert!(
            ends[1] <= ends[0] + ns(1e-6),
            "scheduled {} must not trail naive {}",
            ends[1],
            ends[0]
        );
    }

    #[test]
    fn oversized_footprint_clamps_to_capacity() {
        let pipe = PipelineParams::default();
        let mut gt = GlobalTimeline::new(1, 100, &pipe);
        gt.occupy(0, 10_000, Nanos::ZERO, ns(10.0));
        let s = gt.earliest_start(0, 1, Nanos::ZERO, ns(1.0));
        assert_eq!(s, ns(10.0), "a clamped full-capacity window excludes others");
    }
}
