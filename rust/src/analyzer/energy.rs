//! Per-inference energy roll-up on OPIMA.

use crate::analyzer::latency::ModelAnalysis;
use crate::analyzer::power::power_breakdown;
use crate::config::OpimaConfig;
use crate::util::units::Millijoules;

/// Energy breakdown for one inference.
#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    /// OPCM cell reads (5 pJ × one per nibble MAC).
    pub reads_mj: Millijoules,
    /// MDL lasers (wall-plug while lit + drive DACs).
    pub mdl_mj: Millijoules,
    /// Aggregation unit (ADC + SRAM + shift-add + DAC/VCSEL regen).
    pub aggregation_mj: Millijoules,
    /// Output feature-map writeback (250 pJ OPCM writes).
    pub writeback_mj: Millijoules,
    /// Static envelope × latency (the full-power accounting used for
    /// cross-platform comparisons that meter at the wall).
    pub static_mj: Millijoules,
}

impl EnergyBreakdown {
    /// Dynamic (activity-proportional) energy.
    pub fn dynamic_mj(&self) -> Millijoules {
        self.reads_mj + self.mdl_mj + self.aggregation_mj + self.writeback_mj
    }

    /// Wall energy (dynamic + static envelope over the run).
    pub fn wall_mj(&self) -> Millijoules {
        self.dynamic_mj() + self.static_mj
    }
}

/// Compute the energy breakdown for an analyzed model.
pub fn energy_breakdown(cfg: &OpimaConfig, analysis: &ModelAnalysis) -> EnergyBreakdown {
    let reads_mj =
        Millijoules::from_picojoules(analysis.layer_costs.iter().map(|c| c.read_pj).sum::<f64>());
    let mdl_mj =
        Millijoules::from_picojoules(analysis.layer_costs.iter().map(|c| c.mdl_pj).sum::<f64>());
    let aggregation_mj = Millijoules::from_picojoules(
        analysis
            .layer_costs
            .iter()
            .map(|c| c.aggregation_pj)
            .sum::<f64>(),
    );
    let writeback_mj = Millijoules::from_picojoules(
        analysis
            .layer_costs
            .iter()
            .map(|c| c.writeback_pj)
            .sum::<f64>(),
    );
    // Cross-unit chain W × ms → mJ, priced with the explicit s↔ms factor
    // trail (1e-3 · 1e3 are power/energy scalings, not time conversions).
    let static_mj =
        Millijoules::new(power_breakdown(cfg).total_w() * analysis.total_ms().raw() * 1e-3 * 1e3);
    EnergyBreakdown {
        reads_mj,
        mdl_mj,
        aggregation_mj,
        writeback_mj,
        static_mj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::latency::analyze_model;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn read_energy_matches_table1_figure() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let a = analyze_model(&cfg, &net, 4).unwrap();
        let e = energy_breakdown(&cfg, &a);
        // 5 pJ per MAC at 4-bit (one TDM step).
        let expect = net.macs() as f64 * 5.0 / 1e9;
        assert!((e.reads_mj.raw() - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn components_positive_and_sum() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::InceptionV2).unwrap();
        let a = analyze_model(&cfg, &net, 4).unwrap();
        let e = energy_breakdown(&cfg, &a);
        assert!(e.reads_mj > Millijoules::ZERO && e.mdl_mj > Millijoules::ZERO);
        assert!(e.aggregation_mj > Millijoules::ZERO && e.writeback_mj > Millijoules::ZERO);
        assert!(e.wall_mj() > e.dynamic_mj());
    }

    #[test]
    fn eight_bit_costs_more_energy() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let e4 = energy_breakdown(&cfg, &analyze_model(&cfg, &net, 4).unwrap());
        let e8 = energy_breakdown(&cfg, &analyze_model(&cfg, &net, 8).unwrap());
        assert!(e8.dynamic_mj() > 2.0 * e4.dynamic_mj());
    }
}
