//! Per-model latency/energy analysis on OPIMA (Figs. 9 & 10 substrate).

use crate::cnn::graph::Network;
use crate::config::OpimaConfig;
use crate::error::Result;
use crate::mapper::plan::{map_network, MappedNetwork, Occupancy};
use crate::pim::scheduler::{LayerCost, PimScheduler};
use crate::util::units::{Millijoules, Millis, Nanos};

/// Full analysis of one (model, bit-width) pair on OPIMA.
#[derive(Debug, Clone)]
pub struct ModelAnalysis {
    pub name: String,
    pub bits: u32,
    pub layer_costs: Vec<LayerCost>,
    /// In-memory processing time (MACs + aggregation).
    pub processing_ms: Millis,
    /// Non-linearity + OPCM write-back time.
    pub writeback_ms: Millis,
    /// Dynamic energy per inference.
    pub dynamic_mj: Millijoules,
    /// Total MACs.
    pub macs: u64,
    /// Subarray occupancy of the mapping vs. the geometry's capacity —
    /// drives the timeline's pipelining decision and the serving-path
    /// capacity warnings.
    pub occupancy: Occupancy,
}

impl ModelAnalysis {
    pub fn total_ms(&self) -> Millis {
        self.processing_ms + self.writeback_ms
    }

    pub fn fps(&self) -> f64 {
        1e3 / self.total_ms().raw()
    }
}

/// Analyze a network at the given operand width on OPIMA.
pub fn analyze_model(cfg: &OpimaConfig, net: &Network, bits: u32) -> Result<ModelAnalysis> {
    analyze_mapped(cfg, &map_network(cfg, net, bits)?, bits)
}

/// Price an already-mapped network. For callers that need both the
/// mapper plan and its cost (the serving plan registry), so the mapping
/// pass runs once, not once per consumer. The MAC total comes from the
/// plan's work items — identical to `Network::macs()` by the mapper's
/// conservation invariant.
pub fn analyze_mapped(
    cfg: &OpimaConfig,
    mapped: &MappedNetwork,
    bits: u32,
) -> Result<ModelAnalysis> {
    let sched = PimScheduler::new(cfg)?;
    let layer_costs = sched.cost_network(&mapped.works)?;
    let processing_ms = layer_costs.iter().map(|c| c.processing_ns).sum::<Nanos>().to_millis();
    let writeback_ms = layer_costs.iter().map(|c| c.writeback_ns).sum::<Nanos>().to_millis();
    let dynamic_mj =
        Millijoules::from_picojoules(layer_costs.iter().map(|c| c.dynamic_pj()).sum::<f64>());
    Ok(ModelAnalysis {
        name: mapped.name.clone(),
        bits,
        layer_costs,
        processing_ms,
        writeback_ms,
        dynamic_mj,
        macs: mapped.works.iter().map(|w| w.macs).sum(),
        occupancy: mapped.occupancy(&cfg.geometry),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    fn analyze(m: Model, bits: u32) -> ModelAnalysis {
        let cfg = OpimaConfig::paper();
        analyze_model(&cfg, &build_model(m).unwrap(), bits).unwrap()
    }

    #[test]
    fn latencies_are_millisecond_class() {
        // Fig. 9's y-axis is milliseconds.
        for m in [Model::ResNet18, Model::InceptionV2, Model::MobileNet] {
            let a = analyze(m, 4);
            assert!(
                (0.05..50.0).contains(&a.total_ms().raw()),
                "{}: {} ms",
                a.name,
                a.total_ms()
            );
        }
    }

    #[test]
    fn writeback_dominates_resnet18() {
        // Fig. 9 discussion: "the latency for the OPCM write operations
        // ... far outweighs the latency savings from the PIM operations".
        let a = analyze(Model::ResNet18, 4);
        assert!(a.writeback_ms > 2.0 * a.processing_ms, "{a:?}");
    }

    #[test]
    fn mobilenet_processing_exceeds_writeback() {
        // Fig. 9 discussion: "MobileNet has lower writeback latency than
        // processing latency" (1×1 serialization).
        let a = analyze(Model::MobileNet, 4);
        assert!(a.processing_ms > a.writeback_ms, "{a:?}");
    }

    #[test]
    fn one_by_one_models_have_higher_processing_than_resnet() {
        // "Both models have higher processing latencies [than ResNet18]".
        let rn = analyze(Model::ResNet18, 4).processing_ms;
        assert!(analyze(Model::InceptionV2, 4).processing_ms > rn);
        assert!(analyze(Model::MobileNet, 4).processing_ms > rn);
    }

    #[test]
    fn inception_total_below_resnet_total() {
        // "why InceptionV2 has an overall lower latency than ResNet18".
        let rn = analyze(Model::ResNet18, 4);
        let inc = analyze(Model::InceptionV2, 4);
        assert!(inc.total_ms() < rn.total_ms());
    }

    #[test]
    fn eight_bit_slower_than_four_bit() {
        for m in [Model::ResNet18, Model::MobileNet] {
            let a4 = analyze(m, 4);
            let a8 = analyze(m, 8);
            assert!(a8.processing_ms > 3.0 * a4.processing_ms);
            assert!(a8.writeback_ms > 1.8 * a4.writeback_ms);
        }
    }

    #[test]
    fn vgg16_is_slowest() {
        let vgg = analyze(Model::Vgg16, 4).total_ms();
        for m in [Model::ResNet18, Model::InceptionV2, Model::MobileNet, Model::SqueezeNet] {
            assert!(vgg > analyze(m, 4).total_ms());
        }
    }

    #[test]
    fn dynamic_energy_millijoule_class() {
        let a = analyze(Model::ResNet18, 4);
        assert!((0.5..50.0).contains(&a.dynamic_mj.raw()), "{}", a.dynamic_mj);
    }
}
