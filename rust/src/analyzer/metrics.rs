//! Cross-platform comparison metrics: EPB and FPS/W (Figs. 11 & 12).
//!
//! Accounting conventions (see EXPERIMENTS.md §Figs 11–12 for the
//! rationale): *PIM/photonic* platforms (OPIMA, PhPIM, CrossLight, PRIME)
//! are metered by their modeled dynamic energy — matching how such
//! simulator-based papers report themselves — while *electronic*
//! platforms (GPU/CPU) are metered at the wall (power envelope ×
//! latency), matching how real systems are measured. Bits processed is a
//! workload property (2 operands × MACs × quantized width), identical
//! across platforms for a given model.

use crate::cnn::graph::Network;
use crate::util::histogram::{Histogram, Summary};
use crate::util::units::{Millijoules, Millis};

/// Result of running one model on one platform.
#[derive(Debug, Clone)]
pub struct PlatformResult {
    pub platform: String,
    pub model: String,
    pub latency_ms: Millis,
    pub power_w: f64,
    /// Energy per inference under the platform's accounting convention.
    pub energy_mj: Millijoules,
}

impl PlatformResult {
    pub fn fps(&self) -> f64 {
        1e3 / self.latency_ms.raw()
    }

    pub fn fps_per_w(&self) -> f64 {
        self.fps() / self.power_w
    }

    /// Energy per processed bit (pJ/bit) for a given workload bit count.
    pub fn epb_pj(&self, workload_bits: u64) -> f64 {
        self.energy_mj.raw() * 1e9 / workload_bits as f64
    }
}

/// Bits processed by one inference of a quantized model: two operands
/// per MAC at the quantized width.
pub fn workload_bits(net: &Network, bits: u32) -> u64 {
    2 * net.macs() * bits as u64
}

/// Summarize an offline latency sample set (ms) through the same
/// log-bucketed streaming histogram the serving engine uses — one
/// percentile implementation for online serving stats and offline
/// report tables, with the same nearest-rank definition and the same
/// bounded relative error.
pub fn latency_summary(samples_ms: &[f64]) -> Summary {
    let mut h = Histogram::new();
    for &v in samples_ms {
        h.record(v);
    }
    h.summary()
}

/// Geometric-mean ratio of `xs` over `ys` (how the paper reports "N×
/// better on average").
pub fn geomean_ratio(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (x / y).ln())
        .sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn derived_metrics() {
        use crate::util::units::{mj, ms};
        let r = PlatformResult {
            platform: "x".into(),
            model: "m".into(),
            latency_ms: ms(2.0),
            power_w: 100.0,
            energy_mj: mj(200.0),
        };
        assert!((r.fps() - 500.0).abs() < 1e-9);
        assert!((r.fps_per_w() - 5.0).abs() < 1e-9);
        assert!((r.epb_pj(1_000_000_000) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn workload_bits_scale() {
        let net = build_model(Model::ResNet18).unwrap();
        assert_eq!(workload_bits(&net, 8), 2 * workload_bits(&net, 4));
    }

    #[test]
    fn latency_summary_matches_exact_oracle() {
        use crate::util::histogram::nearest_rank;
        let samples: Vec<f64> = (1..=500).map(|i| (i as f64).sqrt() * 0.7).collect();
        let s = latency_summary(&samples);
        assert_eq!(s.count, 500);
        let mut sorted = samples.clone();
        sorted.sort_by(f64::total_cmp);
        for (p, est) in [(0.5, s.p50), (0.9, s.p90), (0.99, s.p99)] {
            let exact = nearest_rank(&sorted, p);
            assert!(
                (est - exact).abs() <= exact * Histogram::MAX_REL_ERROR,
                "p{p}: {est} vs {exact}"
            );
        }
        let mean = samples.iter().sum::<f64>() / 500.0;
        assert!((s.mean - mean).abs() < 1e-9, "streaming mean is exact");
    }

    #[test]
    fn geomean() {
        assert!((geomean_ratio(&[4.0, 16.0], &[1.0, 1.0]) - 8.0).abs() < 1e-9);
        assert!((geomean_ratio(&[2.0], &[4.0]) - 0.5).abs() < 1e-9);
    }
}
