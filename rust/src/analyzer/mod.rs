//! The performance analyzer — the paper's "Python-based performance
//! analyzer" substitute.
//!
//! Rolls the PIM scheduler's per-layer costs into the quantities the
//! paper reports: latency breakdowns (Fig. 9/10), the power envelope
//! (Fig. 8), energy-per-bit (Fig. 11) and FPS/W (Fig. 12) — and, since
//! the timeline refactor, schedules whole batches as discrete events
//! against resource pools ([`timeline`]) so batch latency reflects
//! pipelining instead of the old `batch ×` analytical scaling. The
//! [`contention`] engine extends that per-batch schedule across
//! batches: a persistent per-instance event engine into which in-flight
//! batches are admitted incrementally, competing for the shared
//! aggregation/writeback pools — the honest fleet-scale makespan.

pub mod contention;
pub mod energy;
pub mod latency;
pub mod metrics;
pub mod power;
pub mod report;
pub mod simcost;
pub mod timeline;

pub use contention::{Admission, BatchStream, GlobalTimeline};
pub use latency::{analyze_model, ModelAnalysis};
pub use metrics::PlatformResult;
pub use power::{power_breakdown, PowerBreakdown};
pub use simcost::{SimCost, SimCostTable};
pub use timeline::{
    simulate_analysis, simulate_analysis_makespan, BatchTimeline, TimelineSummary,
};
