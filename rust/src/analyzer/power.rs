//! Power-envelope breakdown (paper Fig. 8).
//!
//! Components for concurrent PIM + main-memory operation. The paper
//! reports a maximum of 55.9 W dominated by the MDL array and the
//! electrical-optical interface.

use crate::config::OpimaConfig;
use crate::pim::group::{active_mdls, ADC_ACTIVITY, DAC_ACTIVITY};

/// One Fig. 8 slice.
#[derive(Debug, Clone)]
pub struct PowerComponent {
    pub name: &'static str,
    pub watts: f64,
}

/// Full breakdown.
#[derive(Debug, Clone)]
pub struct PowerBreakdown {
    pub components: Vec<PowerComponent>,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f64 {
        self.components.iter().map(|c| c.watts).sum()
    }

    pub fn get(&self, name: &str) -> f64 {
        self.components
            .iter()
            .find(|c| c.name == name)
            .map(|c| c.watts)
            .unwrap_or(0.0)
    }

    /// The dominant component.
    pub fn dominant(&self) -> &PowerComponent {
        self.components
            .iter()
            .max_by(|a, b| a.watts.total_cmp(&b.watts))
            .expect("non-empty")
    }
}

/// Compute the Fig. 8 breakdown for a configuration (PIM + memory
/// concurrently active — the paper's "maximum power consumption" case).
pub fn power_breakdown(cfg: &OpimaConfig) -> PowerBreakdown {
    let g = &cfg.geometry;
    let f_hz = cfg.timing.clock_ghz * 1e9;
    let groups = g.subarray_groups;

    // MDL arrays: one active subarray row slice per group per bank.
    let mdl_w = active_mdls(g, groups, cfg.pim.optical_accum) as f64
        * cfg.power.mdl_wallplug_mw.raw()
        / 1e3;

    // E-O interface: ADC + DAC arrays at their duty factor, VCSEL
    // regeneration channels, and the E-O-E controller electronics.
    let channels = (g.banks * groups * g.cols_per_subarray) as f64;
    let adc_w = channels
        * cfg.energy.adc_conversion_pj(cfg.pim.adc_bits)
        * 1e-12
        * f_hz
        * ADC_ACTIVITY;
    // DAC regeneration fires per group output channel (16 per group),
    // not per λ lane.
    let dac_w = (g.banks * groups * 16) as f64
        * cfg.energy.dac_conversion_pj(g.bits_per_cell)
        * 1e-12
        * f_hz
        * DAC_ACTIVITY;
    let vcsel_w = (g.banks * groups) as f64 * 16.0 * cfg.power.vcsel_mw.raw() / 1e3;
    let eo_interface_w = adc_w + dac_w + vcsel_w + cfg.power.controller_w;

    // External laser driving concurrent main-memory traffic.
    let laser_w = cfg.power.external_laser_w;

    // SOA stages: per bank, amplification on the memory data paths (one
    // SOA per subarray column line) plus aggregation-path boosters.
    let soa_count = g.banks * (g.subarray_cols + groups);
    let soa_w = soa_count as f64 * cfg.power.soa_bias_mw.raw() / 1e3;

    // EO-tuned MR access rings on all PIM-active + memory-active rows.
    let active_rings = g.banks * (groups * cfg.pim.optical_accum + 1) * g.cols_per_subarray * 2;
    let mr_w = active_rings as f64 * cfg.power.mr_tuning_mw.raw() / 1e3;

    // Aggregation-unit digital logic (shift-add + SRAM) per bank.
    let agg_w = cfg.power.aggregation_logic_w * g.banks as f64 * (groups as f64 / 16.0).max(0.25);

    PowerBreakdown {
        components: vec![
            PowerComponent { name: "mdl_array", watts: mdl_w },
            PowerComponent { name: "eo_interface", watts: eo_interface_w },
            PowerComponent { name: "external_laser", watts: laser_w },
            PowerComponent { name: "soa", watts: soa_w },
            PowerComponent { name: "mr_tuning", watts: mr_w },
            PowerComponent { name: "aggregation_logic", watts: agg_w },
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_near_paper_55_9w() {
        let b = power_breakdown(&OpimaConfig::paper());
        let total = b.total_w();
        assert!(
            (47.5..64.3).contains(&total),
            "total {total} W vs paper 55.9 W ± 15%"
        );
    }

    #[test]
    fn mdl_and_eo_interface_dominate() {
        // Fig. 8: "maximum power consumption is contributed by the MDL
        // array and the electrical-optical interface".
        let b = power_breakdown(&OpimaConfig::paper());
        let mdl = b.get("mdl_array");
        let eo = b.get("eo_interface");
        for c in &b.components {
            if c.name != "mdl_array" && c.name != "eo_interface" {
                assert!(mdl > c.watts, "mdl {} vs {} {}", mdl, c.name, c.watts);
                assert!(eo > c.watts, "eo {} vs {} {}", eo, c.name, c.watts);
            }
        }
    }

    #[test]
    fn power_scales_with_groups() {
        let mut cfg = OpimaConfig::paper();
        let p16 = power_breakdown(&cfg).total_w();
        cfg.geometry.subarray_groups = 4;
        let p4 = power_breakdown(&cfg).total_w();
        assert!(p16 > p4);
    }
}
