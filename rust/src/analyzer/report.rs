//! Paper-style table/series printers (markdown) used by CLI and benches.

use crate::analyzer::latency::ModelAnalysis;
use crate::analyzer::metrics::PlatformResult;
use crate::analyzer::power::PowerBreakdown;
use crate::analyzer::timeline::BatchTimeline;
use crate::util::histogram::Summary;
use crate::util::units::Millis;

/// Fig. 9-style latency breakdown rows.
pub fn latency_table(analyses: &[ModelAnalysis]) -> String {
    let mut out = String::from(
        "| model | processing (ms) | writeback (ms) | total (ms) |\n|---|---|---|---|\n",
    );
    for a in analyses {
        // Column headers carry the unit; print the bare scalar.
        out.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} |\n",
            a.name,
            a.processing_ms.raw(),
            a.writeback_ms.raw(),
            a.total_ms().raw()
        ));
    }
    out
}

/// Fig. 8-style power breakdown.
pub fn power_table(b: &PowerBreakdown) -> String {
    let mut out = String::from("| component | watts | share |\n|---|---|---|\n");
    let total = b.total_w();
    for c in &b.components {
        out.push_str(&format!(
            "| {} | {:.2} | {:.1}% |\n",
            c.name,
            c.watts,
            100.0 * c.watts / total
        ));
    }
    out.push_str(&format!("| **total** | **{total:.1}** | 100% |\n"));
    out
}

/// Latency-percentile rows (ms) for streaming or offline summaries —
/// used by the CLI `serve` command and the serving example to render
/// the engine's per-stage breakdown.
pub fn latency_summary_table(rows: &[(&str, &Summary)]) -> String {
    let mut out = String::from(
        "| stage | n | mean (ms) | p50 | p90 | p99 | p99.9 | max |\n|---|---|---|---|---|---|---|---|\n",
    );
    for (name, s) in rows {
        out.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} | {:.3} |\n",
            name, s.count, s.mean, s.p50, s.p90, s.p99, s.p999, s.max
        ));
    }
    out
}

/// One model's row in the contended-vs-isolated report (`analyze
/// --streams S`): S identical batch streams admitted onto one
/// simulated instance, priced three ways.
pub struct ContentionRow {
    pub name: String,
    /// One stream's isolated (sole-tenant) makespan.
    pub isolated_ms: Millis,
    /// Fleet makespan under occupancy-only co-residency — the
    /// optimistic pre-contention model.
    pub optimistic_ms: Millis,
    /// Fleet makespan with the streams contending for the shared
    /// aggregation/writeback pools — the honest number.
    pub contended_ms: Millis,
    /// `S ×` the isolated makespan — the no-overlap upper bound.
    pub serialized_ms: Millis,
}

/// Contended-vs-isolated serving report: what sharing the stage pools
/// actually costs, bracketed by the co-residency bounds
/// (isolated ≤ contended ≤ serialized).
pub fn contention_table(streams: usize, rows: &[ContentionRow]) -> String {
    let mut out = format!(
        "| model | isolated (ms) | optimistic ×{streams} (ms) | contended ×{streams} (ms) | \
         serialized ×{streams} (ms) | contention cost |\n|---|---|---|---|---|---|\n"
    );
    for r in rows {
        let cost = if r.optimistic_ms > Millis::ZERO {
            r.contended_ms / r.optimistic_ms
        } else {
            1.0
        };
        out.push_str(&format!(
            "| {} | {:.3} | {:.3} | {:.3} | {:.3} | {:.2}× |\n",
            r.name,
            r.isolated_ms.raw(),
            r.optimistic_ms.raw(),
            r.contended_ms.raw(),
            r.serialized_ms.raw(),
            cost
        ));
    }
    out
}

/// One model's row in the writeback-model comparison (`analyze`): the
/// same batch priced under the flat scalar and the two command-level
/// controllers (`[memory] writeback_model`).
pub struct WritebackRow {
    pub name: String,
    pub batch: usize,
    /// Flat scalar writebacks — the historical default.
    pub flat_ms: Millis,
    /// Command-level, strictly serialized (the reference controller).
    pub naive_ms: Millis,
    /// Command-level, bank-parallel and row-switch-aware.
    pub scheduled_ms: Millis,
}

/// Flat-vs-naive-vs-scheduled writeback pricing report. The two ratio
/// columns bracket the command model: `naive/flat` is what honest
/// command serialization costs over the scalar, `scheduled/naive` is
/// what the optimized controller claws back (≤ 1 by construction).
pub fn writeback_table(rows: &[WritebackRow]) -> String {
    let mut out = String::from(
        "| model | batch | flat (ms) | naive (ms) | scheduled (ms) | \
         naive/flat | scheduled/naive |\n|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let vs_flat = if r.flat_ms > Millis::ZERO {
            r.naive_ms / r.flat_ms
        } else {
            1.0
        };
        let vs_naive = if r.naive_ms > Millis::ZERO {
            r.scheduled_ms / r.naive_ms
        } else {
            1.0
        };
        out.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.3} | {:.2}× | {:.2}× |\n",
            r.name,
            r.batch,
            r.flat_ms.raw(),
            r.naive_ms.raw(),
            r.scheduled_ms.raw(),
            vs_flat,
            vs_naive
        ));
    }
    out
}

/// Pipelined-vs-sequential batch report rows (the `analyze --batch`
/// command): one timeline per model, with the analytical `batch ×`
/// baseline, the pipelined makespan, and the bottleneck lower bound.
pub fn timeline_table(rows: &[(&str, &BatchTimeline)]) -> String {
    let mut out = String::from(
        "| model | batch | sequential (ms) | pipelined (ms) | speedup | \
         bottleneck (ms) | efficiency |\n|---|---|---|---|---|---|---|\n",
    );
    for (name, t) in rows {
        out.push_str(&format!(
            "| {} | {} | {:.3} | {:.3} | {:.2}× | {:.3} | {:.0}% |\n",
            name,
            t.batch,
            t.sequential_ms().raw(),
            t.makespan_ms().raw(),
            t.speedup(),
            t.bottleneck_ms().raw(),
            100.0 * t.efficiency()
        ));
    }
    out
}

/// Fig. 11/12-style cross-platform rows for one model.
pub fn comparison_table(results: &[PlatformResult], workload_bits: u64) -> String {
    let mut out = String::from(
        "| platform | latency (ms) | power (W) | energy (mJ) | EPB (pJ/b) | FPS | FPS/W |\n|---|---|---|---|---|---|---|\n",
    );
    for r in results {
        out.push_str(&format!(
            "| {} | {:.3} | {:.1} | {:.2} | {:.3} | {:.1} | {:.2} |\n",
            r.platform,
            r.latency_ms.raw(),
            r.power_w,
            r.energy_mj.raw(),
            r.epb_pj(workload_bits),
            r.fps(),
            r.fps_per_w()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::latency::analyze_model;
    use crate::analyzer::power::power_breakdown;
    use crate::cnn::models::{build_model, Model};
    use crate::config::OpimaConfig;

    #[test]
    fn tables_render() {
        let cfg = OpimaConfig::paper();
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        let t = latency_table(&[a]);
        assert!(t.contains("resnet18_4b"));
        let p = power_table(&power_breakdown(&cfg));
        assert!(p.contains("mdl_array") && p.contains("total"));
        let r = PlatformResult {
            platform: "OPIMA".into(),
            model: "resnet18".into(),
            latency_ms: crate::util::units::ms(1.0),
            power_w: 55.9,
            energy_mj: crate::util::units::mj(5.0),
        };
        let c = comparison_table(&[r], 1_000_000);
        assert!(c.contains("OPIMA"));
        let s = crate::analyzer::metrics::latency_summary(&[1.0, 2.0, 3.0]);
        let lt = latency_summary_table(&[("total", &s)]);
        assert!(lt.contains("total") && lt.contains("p99.9"));
    }

    #[test]
    fn contention_table_renders() {
        use crate::util::units::ms;
        let out = contention_table(
            4,
            &[ContentionRow {
                name: "resnet18".into(),
                isolated_ms: ms(2.0),
                optimistic_ms: ms(4.0),
                contended_ms: ms(6.0),
                serialized_ms: ms(8.0),
            }],
        );
        assert!(out.contains("resnet18") && out.contains("contended ×4"));
        assert!(out.contains("1.50×"), "{out}");
        // Degenerate rows never print inf/NaN.
        let z = contention_table(
            1,
            &[ContentionRow {
                name: "empty".into(),
                isolated_ms: Millis::ZERO,
                optimistic_ms: Millis::ZERO,
                contended_ms: Millis::ZERO,
                serialized_ms: Millis::ZERO,
            }],
        );
        assert!(z.contains("1.00×") && !z.contains("inf"), "{z}");
    }

    #[test]
    fn writeback_table_renders_and_guards_zero() {
        use crate::util::units::ms;
        let out = writeback_table(&[WritebackRow {
            name: "resnet18".into(),
            batch: 8,
            flat_ms: ms(2.0),
            naive_ms: ms(3.0),
            scheduled_ms: ms(2.4),
        }]);
        assert!(out.contains("resnet18") && out.contains("scheduled/naive"));
        assert!(out.contains("1.50×") && out.contains("0.80×"), "{out}");
        let z = writeback_table(&[WritebackRow {
            name: "empty".into(),
            batch: 1,
            flat_ms: Millis::ZERO,
            naive_ms: Millis::ZERO,
            scheduled_ms: Millis::ZERO,
        }]);
        assert!(z.contains("1.00×") && !z.contains("inf") && !z.contains("NaN"), "{z}");
    }

    #[test]
    fn timeline_table_renders() {
        let cfg = OpimaConfig::paper();
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        let t = crate::analyzer::timeline::simulate_analysis(&cfg, &a, 8);
        let out = timeline_table(&[("resnet18", &t)]);
        assert!(out.contains("resnet18") && out.contains("bottleneck"));
        assert!(out.contains("×"));
    }
}
