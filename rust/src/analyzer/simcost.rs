//! Shared immutable simulated-cost table, keyed by operand width **and
//! batch size**.
//!
//! The serving engine meters every executed batch with the OPIMA
//! simulator. Running `analyze_model` (let alone the batch timeline) on
//! the request path would dominate serving latency, so the engine
//! precomputes this table once per plan and shares it read-only across
//! all worker threads behind an `Arc` — no locking, no per-request
//! analyzer work.
//!
//! Batch latency is **no longer the `batch ×` analytical scaling**: each
//! entry's `latency_ms` is the pipelined makespan of the
//! [`timeline`](crate::analyzer::timeline) (sublinear in batch for
//! pipelinable mappings), while `energy_mj` stays linear — overlap moves
//! work in time, it does not remove any. The old scaling is preserved in
//! [`SimCost::sequential_ms`] so reports can show the gain.
//!
//! Entries inherit the configured `[memory] writeback_model`: under a
//! command-level model each entry's makespan prices writebacks through
//! the route/write/settle decomposition ([`crate::memory::writeback`])
//! instead of the flat scalar — identical at the uncontended batch-1
//! limit, honest once writebacks queue within the batch.

use crate::analyzer::latency::{analyze_model, ModelAnalysis};
use crate::analyzer::timeline::{simulate_analysis_makespan, TimelineSummary};
use crate::cnn::graph::Network;
use crate::config::OpimaConfig;
use crate::error::Result;
use crate::util::units::{Millijoules, Millis};

/// Simulated cost of serving one whole batch at a given operand width
/// and batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCost {
    /// Operand width on the PIM substrate (bits).
    pub bits: u32,
    /// Images per batch this entry is priced for.
    pub batch: usize,
    /// Pipelined OPIMA latency for the whole batch — the timeline
    /// makespan, sublinear in `batch` when the mapping pipelines.
    pub latency_ms: Millis,
    /// Simulated dynamic energy for the whole batch — linear in
    /// `batch`.
    pub energy_mj: Millijoules,
    /// The pre-timeline analytical cost (`batch ×` single inference).
    pub sequential_ms: Millis,
    /// False when the mapping was over capacity and the timeline ran
    /// strictly serialized (`latency_ms == sequential_ms`).
    pub pipelined: bool,
}

/// Immutable cost table, safe to share across threads
/// (`Arc<SimCostTable>`). Entries are keyed by `(bits, batch)`; every
/// build also inserts the `batch = 1` entry, which equals the analytical
/// single-inference totals by the timeline's fidelity invariant.
#[derive(Debug, Clone)]
pub struct SimCostTable {
    batch: usize,
    entries: Vec<SimCost>,
}

impl SimCostTable {
    /// Analyze `net` once per distinct bit-width and schedule each
    /// analysis at `batch` (and at 1) on the pipelined timeline.
    pub fn build(
        cfg: &OpimaConfig,
        net: &Network,
        batch: usize,
        bit_widths: &[u32],
    ) -> Result<Self> {
        let mut table = Self {
            batch,
            entries: Vec::new(),
        };
        for &bits in bit_widths {
            if table.entry(bits, batch).is_some() {
                continue;
            }
            let a = analyze_model(cfg, net, bits)?;
            table.insert(cfg, &a, batch);
        }
        Ok(table)
    }

    /// Single-width table from an existing analysis, scheduled at
    /// `batch` (and at 1) — the serving registry's path, which analyzes
    /// each `(model, width)` pair exactly once and reuses the same pass
    /// for the mapper plan, this cost table and the cached timelines.
    pub fn from_analysis(cfg: &OpimaConfig, analysis: &ModelAnalysis, batch: usize) -> Self {
        let mut table = Self {
            batch,
            entries: Vec::new(),
        };
        table.insert(cfg, analysis, batch);
        table
    }

    /// Schedule `analysis` at `batch` (and at 1, if absent) and record
    /// the entries. Idempotent per `(bits, batch)` key. Uses the
    /// makespan-only fast path — the table stores scalar bounds, so the
    /// event schedule is never materialized here.
    pub fn insert(&mut self, cfg: &OpimaConfig, analysis: &ModelAnalysis, batch: usize) {
        for b in [1usize, batch] {
            if self.entry(analysis.bits, b).is_some() {
                continue;
            }
            let t = simulate_analysis_makespan(cfg, analysis, b);
            self.entries.push(entry_from_timeline(analysis, &t));
        }
    }

    /// Serving batch size the default lookups are priced for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Whole-batch `(latency_ms, energy_mj)` at operand width `bits`
    /// and the table's serving batch size.
    pub fn get(&self, bits: u32) -> Option<(Millis, Millijoules)> {
        self.get_at(bits, self.batch)
    }

    /// Whole-batch `(latency_ms, energy_mj)` at `(bits, batch)`.
    pub fn get_at(&self, bits: u32, batch: usize) -> Option<(Millis, Millijoules)> {
        self.entry(bits, batch).map(|e| (e.latency_ms, e.energy_mj))
    }

    /// Full entry at `(bits, batch)`.
    pub fn entry(&self, bits: u32, batch: usize) -> Option<&SimCost> {
        self.entries
            .iter()
            .find(|e| e.bits == bits && e.batch == batch)
    }

    /// All entries, in insertion order.
    pub fn entries(&self) -> &[SimCost] {
        &self.entries
    }
}

/// Fold a scheduled timeline's scalar bounds into a cost-table entry
/// (a full [`BatchTimeline`](crate::analyzer::timeline::BatchTimeline)
/// converts via its `summary()`).
pub fn entry_from_timeline(analysis: &ModelAnalysis, t: &TimelineSummary) -> SimCost {
    SimCost {
        bits: analysis.bits,
        batch: t.batch,
        latency_ms: t.makespan_ms(),
        energy_mj: analysis.dynamic_mj * t.batch as f64,
        sequential_ms: t.sequential_ms(),
        pipelined: t.pipelined,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::graph::NetworkBuilder;
    use crate::cnn::layer::TensorShape;

    fn small_net() -> Network {
        let mut b = NetworkBuilder::new("t", TensorShape::new(12, 12, 1));
        b.conv(3, 3, 8, 1, 1)
            .unwrap()
            .pool(2, 2)
            .unwrap()
            .fc(4)
            .unwrap();
        b.build()
    }

    #[test]
    fn dedups_bit_widths_and_keys_by_batch() {
        let cfg = OpimaConfig::paper();
        let t = SimCostTable::build(&cfg, &small_net(), 8, &[8, 8, 4]).unwrap();
        // Two widths × two batch keys (1 and 8) each.
        assert_eq!(t.entries().len(), 4);
        assert_eq!(t.batch(), 8);
        assert!(t.get(8).is_some() && t.get(4).is_some());
        assert!(t.get_at(4, 1).is_some());
        assert!(t.get_at(4, 3).is_none(), "unscheduled batch sizes miss");
        assert!(t.get(2).is_none());
    }

    #[test]
    fn int4_cheaper_than_int8() {
        let cfg = OpimaConfig::paper();
        let t = SimCostTable::build(&cfg, &small_net(), 8, &[8, 4]).unwrap();
        let (l8, e8) = t.get(8).unwrap();
        let (l4, e4) = t.get(4).unwrap();
        assert!(l4 < l8, "TDM: 8-bit costs more time ({l4} vs {l8})");
        assert!(e4 < e8);
        assert!(l4.raw() > 0.0 && e4.raw() > 0.0);
    }

    #[test]
    fn from_analysis_matches_build() {
        let cfg = OpimaConfig::paper();
        let net = small_net();
        let mapped = crate::mapper::plan::map_network(&cfg, &net, 4).unwrap();
        let a = crate::analyzer::latency::analyze_mapped(&cfg, &mapped, 4).unwrap();
        let single = SimCostTable::from_analysis(&cfg, &a, 8);
        let full = SimCostTable::build(&cfg, &net, 8, &[4]).unwrap();
        assert_eq!(single.get(4), full.get(4));
        assert_eq!(single.batch(), 8);
    }

    #[test]
    fn batch_latency_sublinear_energy_linear() {
        // The old analytical core priced a batch as exactly `batch ×`
        // one inference; the timeline pipelines images, so batch latency
        // must now be *sublinear* while staying above the bottleneck
        // bound. Energy stays exactly linear.
        let cfg = OpimaConfig::paper();
        let t8 = SimCostTable::build(&cfg, &small_net(), 8, &[4]).unwrap();
        let (l1, e1) = t8.get_at(4, 1).unwrap();
        let (l8, e8) = t8.get(4).unwrap();
        assert!(l8 < 8.0 * l1, "pipelining must beat {} vs {}", l8, 8.0 * l1);
        assert!(l8 > l1, "more images cannot be faster");
        assert!(
            (e8 - 8.0 * e1).abs().raw() < 1e-9 * e8.raw().max(1.0),
            "energy is linear"
        );
        let entry = t8.entry(4, 8).unwrap();
        assert!(entry.pipelined);
        assert!(
            (entry.sequential_ms - 8.0 * l1).abs().raw() < 1e-9 * entry.sequential_ms.raw()
        );
    }

    #[test]
    fn batch_one_entry_matches_analytical_total() {
        let cfg = OpimaConfig::paper();
        let net = small_net();
        let a = analyze_model(&cfg, &net, 4).unwrap();
        let t = SimCostTable::build(&cfg, &net, 4, &[4]).unwrap();
        let (l1, e1) = t.get_at(4, 1).unwrap();
        assert!((l1 - a.total_ms()).abs().raw() <= 1e-9 * a.total_ms().raw());
        assert!((e1 - a.dynamic_mj).abs().raw() <= 1e-9 * a.dynamic_mj.raw());
    }
}
