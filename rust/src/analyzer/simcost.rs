//! Shared immutable per-batch simulated-cost table.
//!
//! The serving engine meters every executed batch with the OPIMA
//! simulator. Running `analyze_model` on the request path would dominate
//! serving latency, so the engine precomputes this table once at startup
//! (one entry per distinct operand width, scaled to the serving batch
//! size) and shares it read-only across all worker threads behind an
//! `Arc` — no locking, no per-request analyzer work.

use crate::analyzer::latency::{analyze_model, ModelAnalysis};
use crate::cnn::graph::Network;
use crate::config::OpimaConfig;
use crate::error::Result;

/// Simulated cost of serving one whole batch at a given operand width.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCost {
    /// Operand width on the PIM substrate (bits).
    pub bits: u32,
    /// Simulated OPIMA latency for the whole batch (ms).
    pub latency_ms: f64,
    /// Simulated dynamic energy for the whole batch (mJ).
    pub energy_mj: f64,
}

/// Immutable cost table, safe to share across threads (`Arc<SimCostTable>`).
#[derive(Debug, Clone)]
pub struct SimCostTable {
    batch: usize,
    entries: Vec<SimCost>,
}

impl SimCostTable {
    /// Analyze `net` once per distinct bit-width, scaled to `batch`
    /// inferences per served batch.
    pub fn build(
        cfg: &OpimaConfig,
        net: &Network,
        batch: usize,
        bit_widths: &[u32],
    ) -> Result<Self> {
        let mut entries: Vec<SimCost> = Vec::new();
        for &bits in bit_widths {
            if entries.iter().any(|e| e.bits == bits) {
                continue;
            }
            let a = analyze_model(cfg, net, bits)?;
            entries.push(SimCost {
                bits,
                latency_ms: a.total_ms() * batch as f64,
                energy_mj: a.dynamic_mj * batch as f64,
            });
        }
        Ok(Self { batch, entries })
    }

    /// Single-entry table from an existing analysis, scaled to `batch`
    /// inferences per served batch — the serving registry's path, which
    /// analyzes each `(model, width)` pair exactly once and reuses the
    /// same pass for both the mapper plan and this cost table.
    pub fn from_analysis(analysis: &ModelAnalysis, batch: usize) -> Self {
        Self {
            batch,
            entries: vec![SimCost {
                bits: analysis.bits,
                latency_ms: analysis.total_ms() * batch as f64,
                energy_mj: analysis.dynamic_mj * batch as f64,
            }],
        }
    }

    /// Batch size the costs are scaled to.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Whole-batch `(latency_ms, energy_mj)` at operand width `bits`.
    pub fn get(&self, bits: u32) -> Option<(f64, f64)> {
        self.entries
            .iter()
            .find(|e| e.bits == bits)
            .map(|e| (e.latency_ms, e.energy_mj))
    }

    /// All distinct entries.
    pub fn entries(&self) -> &[SimCost] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::graph::NetworkBuilder;
    use crate::cnn::layer::TensorShape;

    fn small_net() -> Network {
        let mut b = NetworkBuilder::new("t", TensorShape::new(12, 12, 1));
        b.conv(3, 3, 8, 1, 1)
            .unwrap()
            .pool(2, 2)
            .unwrap()
            .fc(4)
            .unwrap();
        b.build()
    }

    #[test]
    fn dedups_bit_widths() {
        let cfg = OpimaConfig::paper();
        let t = SimCostTable::build(&cfg, &small_net(), 8, &[8, 8, 4]).unwrap();
        assert_eq!(t.entries().len(), 2);
        assert_eq!(t.batch(), 8);
        assert!(t.get(8).is_some() && t.get(4).is_some());
        assert!(t.get(2).is_none());
    }

    #[test]
    fn int4_cheaper_than_int8() {
        let cfg = OpimaConfig::paper();
        let t = SimCostTable::build(&cfg, &small_net(), 8, &[8, 4]).unwrap();
        let (l8, e8) = t.get(8).unwrap();
        let (l4, e4) = t.get(4).unwrap();
        assert!(l4 < l8, "TDM: 8-bit costs more time ({l4} vs {l8})");
        assert!(e4 < e8);
        assert!(l4 > 0.0 && e4 > 0.0);
    }

    #[test]
    fn from_analysis_matches_build() {
        let cfg = OpimaConfig::paper();
        let net = small_net();
        let mapped = crate::mapper::plan::map_network(&cfg, &net, 4).unwrap();
        let a = crate::analyzer::latency::analyze_mapped(&cfg, &mapped, 4).unwrap();
        let single = SimCostTable::from_analysis(&a, 8);
        let full = SimCostTable::build(&cfg, &net, 8, &[4]).unwrap();
        assert_eq!(single.get(4), full.get(4));
        assert_eq!(single.batch(), 8);
    }

    #[test]
    fn scales_with_batch() {
        let cfg = OpimaConfig::paper();
        let t1 = SimCostTable::build(&cfg, &small_net(), 1, &[4]).unwrap();
        let t8 = SimCostTable::build(&cfg, &small_net(), 8, &[4]).unwrap();
        let (l1, e1) = t1.get(4).unwrap();
        let (l8, e8) = t8.get(4).unwrap();
        assert!((l8 - 8.0 * l1).abs() < 1e-9 * l8.max(1.0));
        assert!((e8 - 8.0 * e1).abs() < 1e-9 * e8.max(1.0));
    }
}
