//! The resource-aware pipelined simulation timeline.
//!
//! The analytical core prices one inference as the *sequential* sum of
//! its layer costs, and a batch as `batch ×` that sum. On the real
//! hardware a batch pipelines: while image `i`'s layer-`k` outputs are
//! being written back to OPCM, image `i+1` can already be processing in
//! layer `k`'s subarrays — the two touch disjoint footprints (layer `k`
//! reads its own input maps, the writeback targets layer `k+1`'s), so
//! nothing serializes except genuinely shared resources.
//!
//! This module schedules per-image, per-layer **events** against explicit
//! **resource pools** and reports the resulting makespan:
//!
//! - Every `(image, layer)` pair emits three chained events, priced by
//!   the PIM scheduler's stage split ([`LayerCost::mac_ns`],
//!   [`LayerCost::aggregation_ns`], [`LayerCost::writeback_ns`]):
//!   **Processing** (in-waveguide MACs), **Aggregation** (PD/ADC/
//!   shift-add drain) and **Writeback** (OPCM MLC program trains).
//! - Resource pools: each layer's subarray/MDL group is *exclusive*
//!   (one image in flight per layer — the mapper's input-stationary
//!   placement holds exactly one image's maps per layer); aggregation
//!   events draw from
//!   [`PipelineParams::aggregation_units`](crate::config::PipelineParams::aggregation_units);
//!   writeback events go through a [`WritebackSink`] selected by
//!   `[memory] writeback_model`: the default **flat** sink draws whole
//!   `writeback_ns` scalars from
//!   [`PipelineParams::writeback_channels`](crate::config::PipelineParams::writeback_channels)
//!   slots (the optical write-power budget already caps the lanes
//!   *inside* one train, this caps concurrent trains), while the
//!   **naive**/**scheduled** sinks replay each layer's route/write/
//!   settle command decomposition through the controllers in
//!   [`crate::memory::writeback`] (there, `writeback_channels` caps
//!   concurrent *trains* — a finer grain; see DESIGN.md §2.7).
//! - Hazards: layer `k` of image `i` cannot start before image `i`'s
//!   layer-`(k-1)` writeback lands (dataflow, RAW); the writeback of
//!   image `i`'s layer `k` cannot start before image `i-1` has finished
//!   *reading* layer `k+1`'s input maps (in-place overwrite, WAR); and
//!   writebacks into one layer issue in image order. Input-image loading
//!   is not priced — consistent with the analytical model, which also
//!   excludes it.
//!
//! Because the WAR hazard makes every in-place overwrite wait for its
//! reader, pipelining needs **no extra subarray capacity**: the resident
//! footprint is the mapper's single-image placement, whatever the batch.
//! When that placement itself exceeds the geometry
//! ([`Occupancy::fits`](crate::mapper::Occupancy::fits) is false) the
//! layers time-share the memory and
//! cross-image overlap is unsound, so the timeline falls back to strict
//! serial execution.
//!
//! **Fidelity invariant:** at `batch = 1` every event chains with zero
//! slack, so the makespan equals the analytical layer sum exactly — the
//! timeline widens the model without repricing the paper reproduction
//! (Figs. 9/10). For `batch ≥ 2` the makespan is bounded below by the
//! bottleneck resource ([`TimelineSummary::bottleneck_ns`]) and above by
//! the sequential sum, and is monotone in batch size.
//!
//! Two entry points share one scheduling pass: [`simulate`]/
//! [`simulate_analysis`] materialize the full [`Event`] schedule (the
//! `analyze` report and the property tests), while [`simulate_makespan`]/
//! [`simulate_analysis_makespan`] run the identical arithmetic without
//! allocating the `batch × layers × 3` event vec — the fast path the
//! serving registry and [`SimCostTable`](crate::analyzer::simcost::SimCostTable)
//! use, since they only consume the scalar [`TimelineSummary`] bounds.

use crate::analyzer::latency::ModelAnalysis;
use crate::config::{OpimaConfig, WritebackModel};
use crate::memory::writeback::{
    NaiveWritebackController, ScheduledWritebackController, WbJob, WritebackController,
};
use crate::pim::scheduler::LayerCost;
use crate::util::units::{Millis, Nanos};

/// Which hardware stage an event occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In-waveguide MACs on the layer's subarray group (exclusive).
    Processing,
    /// PD + ADC + shift-add drain on a shared aggregation unit.
    Aggregation,
    /// OPCM MLC program train on a shared writeback channel.
    Writeback,
}

/// One scheduled event on the timeline.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub image: usize,
    pub layer: usize,
    pub phase: Phase,
    pub start_ns: Nanos,
    pub end_ns: Nanos,
}

/// The scalar outcome of scheduling a batch: the makespan plus the
/// analytical bounds around it, without the event schedule.
///
/// This is what the serving-side consumers
/// ([`SimCostTable`](crate::analyzer::simcost::SimCostTable), the plan
/// registry's timeline cache) actually read — the makespan-only fast
/// path ([`simulate_makespan`]/[`simulate_analysis_makespan`]) produces
/// it without materializing the `batch × layers × 3` [`Event`] vec.
#[derive(Debug, Clone, Copy)]
pub struct TimelineSummary {
    /// Images scheduled.
    pub batch: usize,
    /// End of the last event — the simulated whole-batch latency.
    pub makespan_ns: Nanos,
    /// `batch ×` the analytical single-inference sum — the old
    /// cost model, and a hard upper bound on the makespan.
    pub sequential_ns: Nanos,
    /// Lower bound from the busiest resource: no feasible schedule
    /// can beat `max(single-image critical path, per-resource work)`.
    pub bottleneck_ns: Nanos,
    /// Analytical single-inference total.
    pub per_image_ns: Nanos,
    /// False when the mapping is over capacity and the schedule fell
    /// back to strict serial execution.
    pub pipelined: bool,
}

impl TimelineSummary {
    pub fn makespan_ms(&self) -> Millis {
        self.makespan_ns.to_millis()
    }

    pub fn sequential_ms(&self) -> Millis {
        self.sequential_ns.to_millis()
    }

    pub fn bottleneck_ms(&self) -> Millis {
        self.bottleneck_ns.to_millis()
    }

    /// Pipelining gain over the old `batch ×` analytical model (≥ 1).
    /// A degenerate schedule (empty cost slice or zero batch) has no
    /// work on either side of the ratio, so it reports a neutral 1.0
    /// instead of dividing toward `inf`.
    pub fn speedup(&self) -> f64 {
        if self.makespan_ns > Nanos::ZERO {
            self.sequential_ns / self.makespan_ns
        } else {
            1.0
        }
    }

    /// How close the schedule runs to the bottleneck lower bound (≤ 1);
    /// 1.0 for the degenerate zero-makespan schedule.
    pub fn efficiency(&self) -> f64 {
        if self.makespan_ns > Nanos::ZERO {
            self.bottleneck_ns / self.makespan_ns
        } else {
            1.0
        }
    }
}

/// The scheduled batch: the [`TimelineSummary`] bounds **and** the full
/// event schedule (reports and property tests; scalar consumers use the
/// summary via the makespan-only fast path). Derefs to the summary, so
/// `t.makespan_ns`, `t.speedup()`, … read through it unchanged — the
/// scalar fields and derived metrics live in exactly one place.
#[derive(Debug, Clone)]
pub struct BatchTimeline {
    summary: TimelineSummary,
    /// Every event, in issue order (image-major, layer-minor, M→A→W).
    pub events: Vec<Event>,
}

impl BatchTimeline {
    /// The scalar bounds without the event schedule.
    pub fn summary(&self) -> TimelineSummary {
        self.summary
    }
}

impl std::ops::Deref for BatchTimeline {
    type Target = TimelineSummary;

    fn deref(&self) -> &TimelineSummary {
        &self.summary
    }
}

/// A stage pool as seen by the scheduling pass: book `dur` of work
/// becoming ready at `ready`, returning the granted start time. The
/// per-batch timeline backs this with a private [`Pool`]; the global
/// contention engine ([`crate::analyzer::contention`]) backs it with
/// persistent binary-heap pools shared across in-flight batches — both
/// run the *same* [`run_stream`] pass, so their arithmetic can never
/// drift apart.
pub(crate) trait SlotPool {
    fn acquire(&mut self, ready: Nanos, dur: Nanos) -> Nanos;
}

/// A counting resource pool: `capacity` slots, each busy until its
/// recorded free time. Acquisition picks the earliest-free slot and
/// starts no earlier than `ready` — events on one slot never overlap.
#[derive(Debug)]
struct Pool {
    slots: Vec<Nanos>,
}

impl Pool {
    fn new(capacity: usize) -> Self {
        Self {
            slots: vec![Nanos::ZERO; capacity.max(1)],
        }
    }
}

impl SlotPool for Pool {
    /// Book `dur` of work becoming ready at `ready`; returns the start.
    fn acquire(&mut self, ready: Nanos, dur: Nanos) -> Nanos {
        let idx = self
            .slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("pool has at least one slot");
        let start = ready.max(self.slots[idx]);
        self.slots[idx] = start + dur;
        start
    }
}

/// The writeback stage as seen by the scheduling pass: issue one
/// layer's writeback becoming ready at `ready`, returning its
/// `(start, end)` window. Two implementations exist: [`FlatSink`]
/// preserves the historical flat-scalar arithmetic byte for byte
/// (one `SlotPool::acquire` of `writeback_ns`), and [`CommandSink`]
/// replays the layer's command decomposition through one of the
/// [`crate::memory::writeback`] controllers. `[memory] writeback_model`
/// picks the implementation; everything else in the pass is shared.
pub(crate) trait WritebackSink {
    fn issue(&mut self, ready: Nanos, cost: &LayerCost, layer: usize) -> (Nanos, Nanos);
}

/// The flat model: the whole `writeback_ns` scalar occupies one
/// writeback-channel slot. Default — bit-identical to the pre-command
/// timeline.
pub(crate) struct FlatSink<'a>(pub &'a mut dyn SlotPool);

impl WritebackSink for FlatSink<'_> {
    fn issue(&mut self, ready: Nanos, cost: &LayerCost, _layer: usize) -> (Nanos, Nanos) {
        let start = self.0.acquire(ready, cost.writeback_ns);
        (start, start + cost.writeback_ns)
    }
}

/// Row-id stride between co-resident batches: distinct batches write
/// distinct subarray rows, so their bursts never coalesce on the GST
/// switches. Comfortably above any real layer count.
pub(crate) const WB_BATCH_ROW_STRIDE: u64 = 1 << 20;

/// The command model: each writeback is decomposed into a [`WbJob`] and
/// admitted into a persistent controller in the caller's relative time
/// frame (the standalone timeline runs at `origin = 0`; the contention
/// engine at the batch's admission origin).
pub(crate) struct CommandSink<'a> {
    pub ctl: &'a mut dyn WritebackController,
    pub origin: Nanos,
    /// Monotone job ids across the controller's lifetime.
    pub next_job: &'a mut u64,
    /// Row-id base for this stream (`batch tag × WB_BATCH_ROW_STRIDE`).
    pub row_base: u64,
}

impl WritebackSink for CommandSink<'_> {
    fn issue(&mut self, ready: Nanos, cost: &LayerCost, layer: usize) -> (Nanos, Nanos) {
        let job = command_job(cost, *self.next_job, self.row_base + layer as u64);
        *self.next_job += 1;
        self.ctl.admit(self.origin, ready, &job)
    }
}

/// Decompose one layer cost into a command-level writeback job. Costs
/// priced by [`crate::pim::scheduler::PimScheduler`] carry the real
/// decomposition; hand-built costs (tests, fixtures) with `wb_trains =
/// 0` fall back to a single train of the whole flat figure, so the
/// uncontended-limit recovery holds for them too.
pub(crate) fn command_job(c: &LayerCost, id: u64, row: u64) -> WbJob {
    if c.wb_trains == 0 {
        WbJob {
            id,
            row,
            trains: if c.writeback_ns > Nanos::ZERO { 1 } else { 0 },
            train_ns: c.writeback_ns,
            settle_ns: Nanos::ZERO,
            flat_ns: c.writeback_ns,
        }
    } else {
        WbJob {
            id,
            row,
            trains: c.wb_trains,
            train_ns: c.wb_train_ns,
            settle_ns: c.wb_settle_ns,
            flat_ns: c.writeback_ns,
        }
    }
}

/// Reusable per-stream scheduling state: the per-layer exclusive-unit
/// cursors, the per-layer writeback-order cursors, and the image
/// retirement times. Owned by the caller so the global engine can admit
/// batches in a steady state without reallocating.
#[derive(Debug, Default, Clone)]
pub(crate) struct StreamScratch {
    /// Per-layer exclusive compute unit (subarray group + MDL array):
    /// free once the image's aggregation has drained into SRAM.
    layer_free: Vec<Nanos>,
    /// Writebacks into one layer's input maps issue in image order.
    wb_layer_free: Vec<Nanos>,
    /// Retirement time of each image (for the in-flight window knob and
    /// the serial fallback).
    retired: Vec<Nanos>,
}

impl StreamScratch {
    /// Reset for a fresh `layers × batch` stream, keeping allocations.
    pub(crate) fn reset(&mut self, layers: usize, batch: usize) {
        self.layer_free.clear();
        self.layer_free.resize(layers, Nanos::ZERO);
        self.wb_layer_free.clear();
        self.wb_layer_free.resize(layers, Nanos::ZERO);
        self.retired.clear();
        self.retired.reserve(batch);
    }
}

/// The per-batch scheduling pass, shared verbatim by the standalone
/// timeline ([`schedule`]) and the global contention engine's admission
/// ([`crate::analyzer::contention::GlobalTimeline`]). Chains every
/// `(image, layer)` triple (Processing → Aggregation → Writeback)
/// through the caller's stage pools and returns the stream's makespan
/// in the caller's time domain (the standalone timeline runs at t = 0;
/// the global engine runs relative to the batch's admission origin).
/// `scratch` must be [`StreamScratch::reset`] for `costs.len() × batch`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_stream(
    costs: &[LayerCost],
    batch: usize,
    pipelined: bool,
    window: usize,
    agg_pool: &mut dyn SlotPool,
    wb: &mut dyn WritebackSink,
    s: &mut StreamScratch,
    mut events: Option<&mut Vec<Event>>,
) -> Nanos {
    let nl = costs.len();
    debug_assert_eq!(s.layer_free.len(), nl);
    let mut makespan_ns = Nanos::ZERO;
    for image in 0..batch {
        // Dataflow cursor: when this image's input to the next layer is
        // available. The first layer's input load is not priced.
        let mut ready = if !pipelined {
            // Over-capacity: layers time-share the memory — image i may
            // not enter until image i-1 fully retires.
            s.retired.last().copied().unwrap_or(Nanos::ZERO)
        } else if window > 0 && image >= window {
            s.retired[image - window]
        } else {
            Nanos::ZERO
        };
        for (layer, c) in costs.iter().enumerate() {
            // Processing: the layer's exclusive unit, once the previous
            // image has drained out of it.
            let m_start = ready.max(s.layer_free[layer]);
            let m_end = m_start + c.mac_ns;
            // Aggregation: continues on the layer unit but also needs a
            // shared aggregation pipeline.
            let a_start = agg_pool.acquire(m_end, c.aggregation_ns);
            let a_end = a_start + c.aggregation_ns;
            s.layer_free[layer] = a_end;
            // Writeback targets layer k+1's input subarrays: wait until
            // the previous image has finished reading them (WAR), keep
            // per-layer image order, and take a writeback channel.
            let war = if layer + 1 < nl {
                s.layer_free[layer + 1]
            } else {
                Nanos::ZERO
            };
            let w_ready = a_end.max(war).max(s.wb_layer_free[layer]);
            let (w_start, w_end) = wb.issue(w_ready, c, layer);
            s.wb_layer_free[layer] = w_end;
            makespan_ns = makespan_ns.max(m_end).max(a_end).max(w_end);
            if let Some(ev) = events.as_deref_mut() {
                ev.push(Event {
                    image,
                    layer,
                    phase: Phase::Processing,
                    start_ns: m_start,
                    end_ns: m_end,
                });
                ev.push(Event {
                    image,
                    layer,
                    phase: Phase::Aggregation,
                    start_ns: a_start,
                    end_ns: a_end,
                });
                ev.push(Event {
                    image,
                    layer,
                    phase: Phase::Writeback,
                    start_ns: w_start,
                    end_ns: w_end,
                });
            }
            ready = w_end;
        }
        s.retired.push(ready);
    }
    makespan_ns
}

/// Schedule `batch` images through the priced layers, pipelined.
///
/// Callers that know the mapping's occupancy should prefer
/// [`simulate_analysis`], which falls back to serial execution when the
/// stationary operands don't fit in memory.
pub fn simulate(cfg: &OpimaConfig, costs: &[LayerCost], batch: usize) -> BatchTimeline {
    full_schedule(cfg, costs, batch, true)
}

/// Schedule a whole [`ModelAnalysis`] at `batch`, honouring its
/// occupancy: an over-capacity mapping runs strictly serialized.
pub fn simulate_analysis(cfg: &OpimaConfig, a: &ModelAnalysis, batch: usize) -> BatchTimeline {
    full_schedule(cfg, &a.layer_costs, batch, a.occupancy.fits())
}

/// Makespan-only counterpart of [`simulate`]: the identical scheduling
/// pass, but skipping the `batch × layers × 3` [`Event`] vec. The
/// serving-side consumers (plan registry, cost tables) only read the
/// scalar bounds, so they never pay for the schedule they discard.
pub fn simulate_makespan(cfg: &OpimaConfig, costs: &[LayerCost], batch: usize) -> TimelineSummary {
    schedule(cfg, costs, batch, true, None)
}

/// Makespan-only counterpart of [`simulate_analysis`].
pub fn simulate_analysis_makespan(
    cfg: &OpimaConfig,
    a: &ModelAnalysis,
    batch: usize,
) -> TimelineSummary {
    schedule(cfg, &a.layer_costs, batch, a.occupancy.fits(), None)
}

/// Run [`schedule`] with event materialization and package the full
/// timeline.
fn full_schedule(
    cfg: &OpimaConfig,
    costs: &[LayerCost],
    batch: usize,
    pipelined: bool,
) -> BatchTimeline {
    let mut events = Vec::with_capacity(batch * costs.len() * 3);
    let summary = schedule(cfg, costs, batch, pipelined, Some(&mut events));
    BatchTimeline { summary, events }
}

/// The scheduling pass. With `events: None` this is the makespan-only
/// fast path: identical arithmetic (the running makespan maximum visits
/// the same event end times in the same order), no event allocation.
/// `[memory] writeback_model` selects the writeback sink; the flat
/// default reproduces the historical arithmetic byte for byte.
fn schedule(
    cfg: &OpimaConfig,
    costs: &[LayerCost],
    batch: usize,
    pipelined: bool,
    events: Option<&mut Vec<Event>>,
) -> TimelineSummary {
    let pipe = &cfg.pipeline;
    let per_image_ns: Nanos = costs.iter().map(LayerCost::total_ns).sum();
    let sequential_ns = per_image_ns * batch as f64;
    let bottleneck_ns = bottleneck(cfg, costs, batch, per_image_ns);

    let mut agg_pool = Pool::new(pipe.aggregation_units);
    let mut scratch = StreamScratch::default();
    scratch.reset(costs.len(), batch);
    let window = pipe.max_in_flight_images;
    let makespan_ns = match cfg.memory.writeback_model {
        WritebackModel::Flat => {
            let mut wb_pool = Pool::new(pipe.writeback_channels);
            let mut sink = FlatSink(&mut wb_pool);
            run_stream(costs, batch, pipelined, window, &mut agg_pool, &mut sink, &mut scratch, events)
        }
        WritebackModel::Naive => {
            let mut ctl = NaiveWritebackController::new(cfg.geometry.banks);
            let mut next_job = 0u64;
            let mut sink = CommandSink {
                ctl: &mut ctl,
                origin: Nanos::ZERO,
                next_job: &mut next_job,
                row_base: 0,
            };
            run_stream(costs, batch, pipelined, window, &mut agg_pool, &mut sink, &mut scratch, events)
        }
        WritebackModel::Scheduled => {
            let mut ctl =
                ScheduledWritebackController::new(cfg.geometry.banks, pipe.writeback_channels);
            let mut next_job = 0u64;
            let mut sink = CommandSink {
                ctl: &mut ctl,
                origin: Nanos::ZERO,
                next_job: &mut next_job,
                row_base: 0,
            };
            run_stream(costs, batch, pipelined, window, &mut agg_pool, &mut sink, &mut scratch, events)
        }
    };
    TimelineSummary {
        batch,
        makespan_ns,
        sequential_ns,
        bottleneck_ns,
        per_image_ns,
        pipelined,
    }
}

/// Lower bound on any feasible schedule: the single-image critical path,
/// or the busiest resource's total work divided by its capacity.
///
/// The flat and naive models share one formula (naive only *adds*
/// serialization on top of flat, so flat's bound stays valid). The
/// scheduled controller can overlap a single job's trains across
/// channels and banks, so its per-layer and critical-path terms use the
/// per-job floor `ceil(trains / min(channels, banks)) × train + settle`
/// and its channel term counts train work only (settle drains
/// off-channel).
fn bottleneck(
    cfg: &OpimaConfig,
    costs: &[LayerCost],
    batch: usize,
    per_image_ns: Nanos,
) -> Nanos {
    let pipe = &cfg.pipeline;
    let b = batch as f64;
    // Each layer's exclusive unit holds one image for mac + aggregation.
    let max_unit = costs
        .iter()
        .map(|c| c.mac_ns + c.aggregation_ns)
        .fold(Nanos::ZERO, Nanos::max);
    let agg_total: Nanos = costs.iter().map(|c| c.aggregation_ns).sum();
    match cfg.memory.writeback_model {
        WritebackModel::Flat | WritebackModel::Naive => {
            // Writebacks into one layer are image-ordered.
            let max_wb =
                costs.iter().map(|c| c.writeback_ns).fold(Nanos::ZERO, Nanos::max);
            let wb_total: Nanos = costs.iter().map(|c| c.writeback_ns).sum();
            per_image_ns
                .max(b * max_unit)
                .max(b * max_wb)
                .max(b * agg_total / pipe.aggregation_units.max(1) as f64)
                .max(b * wb_total / pipe.writeback_channels.max(1) as f64)
        }
        WritebackModel::Scheduled => {
            let eff = pipe.writeback_channels.min(cfg.geometry.banks).max(1) as u64;
            let job_floor = |c: &LayerCost| -> Nanos {
                if c.wb_trains == 0 {
                    c.writeback_ns
                } else {
                    c.wb_trains.div_ceil(eff) as f64 * c.wb_train_ns + c.wb_settle_ns
                }
            };
            let critical: Nanos = costs
                .iter()
                .map(|c| c.mac_ns + c.aggregation_ns + job_floor(c))
                .sum();
            let max_wb = costs.iter().map(job_floor).fold(Nanos::ZERO, Nanos::max);
            let train_work: Nanos = costs
                .iter()
                .map(|c| {
                    if c.wb_trains == 0 {
                        c.writeback_ns
                    } else {
                        c.wb_trains as f64 * c.wb_train_ns
                    }
                })
                .sum();
            critical
                .max(b * max_unit)
                .max(b * max_wb)
                .max(b * agg_total / pipe.aggregation_units.max(1) as f64)
                .max(b * train_work / pipe.writeback_channels.max(1) as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::latency::analyze_model;
    use crate::cnn::graph::{Network, NetworkBuilder};
    use crate::cnn::layer::TensorShape;
    use crate::cnn::models::{build_model, Model};

    fn small_net() -> Network {
        let mut b = NetworkBuilder::new("t", TensorShape::new(12, 12, 1));
        b.conv(3, 3, 8, 1, 1)
            .unwrap()
            .pool(2, 2)
            .unwrap()
            .fc(4)
            .unwrap();
        b.build()
    }

    fn analysis(bits: u32) -> (OpimaConfig, ModelAnalysis) {
        let cfg = OpimaConfig::paper();
        let a = analyze_model(&cfg, &small_net(), bits).unwrap();
        (cfg, a)
    }

    #[test]
    fn batch_one_equals_analytical_sum() {
        let (cfg, a) = analysis(4);
        let t = simulate_analysis(&cfg, &a, 1);
        let total_ns = a.total_ms().to_nanos();
        assert!(
            (t.makespan_ns - total_ns).abs() <= 1e-9 * total_ns,
            "batch-1 makespan {} != analytical {}",
            t.makespan_ns,
            total_ns
        );
        assert!(t.pipelined);
        assert!((t.speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn batched_makespan_between_bounds_and_sublinear() {
        let (cfg, a) = analysis(4);
        for batch in [2usize, 8, 32] {
            let t = simulate_analysis(&cfg, &a, batch);
            assert!(
                t.makespan_ns < t.sequential_ns,
                "batch {batch}: no overlap ({} vs {})",
                t.makespan_ns,
                t.sequential_ns
            );
            assert!(
                t.makespan_ns + Nanos::new(1e-6) >= t.bottleneck_ns,
                "batch {batch}: beat the bottleneck bound"
            );
            assert!(t.speedup() > 1.0);
            assert!(t.efficiency() <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn makespan_monotone_in_batch() {
        let (cfg, a) = analysis(4);
        let mut prev = Nanos::ZERO;
        for batch in 1..=16 {
            let t = simulate_analysis(&cfg, &a, batch);
            assert!(t.makespan_ns >= prev, "batch {batch} shrank the makespan");
            prev = t.makespan_ns;
        }
    }

    #[test]
    fn resnet18_batch8_strictly_sublinear() {
        // The acceptance shape: a multi-row-kernel model at batch ≥ 8.
        let cfg = OpimaConfig::paper();
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        let t = simulate_analysis(&cfg, &a, 8);
        assert!(t.pipelined);
        assert!(t.makespan_ns < 8.0 * a.total_ms().to_nanos());
        assert!(t.makespan_ns + Nanos::new(1e-3) >= t.bottleneck_ns);
    }

    #[test]
    fn per_layer_unit_and_channels_never_oversubscribed() {
        let (cfg, a) = analysis(8);
        let t = simulate_analysis(&cfg, &a, 6);
        // Per (layer, phase=Processing∪Aggregation): one image at a time.
        let nl = a.layer_costs.len();
        for layer in 0..nl {
            let mut spans: Vec<(Nanos, Nanos)> = t
                .events
                .iter()
                .filter(|e| e.layer == layer && e.phase != Phase::Writeback)
                .map(|e| (e.start_ns, e.end_ns))
                .collect();
            spans.sort_by(|x, y| x.0.total_cmp(&y.0));
            // Group the M and A of one image as [M.start, A.end]; images
            // must not interleave on the layer unit.
            for pair in spans.chunks(2).collect::<Vec<_>>().windows(2) {
                assert!(
                    pair[0][1].1 <= pair[1][0].0 + Nanos::new(1e-9),
                    "layer {layer}: images overlap on the exclusive unit"
                );
            }
        }
        // Writeback channel pool: at no event boundary do more than
        // `writeback_channels` trains overlap.
        let wb: Vec<(Nanos, Nanos)> = t
            .events
            .iter()
            .filter(|e| e.phase == Phase::Writeback)
            .map(|e| (e.start_ns, e.end_ns))
            .collect();
        for &(s, _) in &wb {
            let live = wb.iter().filter(|&&(a_, b_)| a_ <= s && s < b_).count();
            assert!(live <= cfg.pipeline.writeback_channels);
        }
    }

    #[test]
    fn over_capacity_falls_back_to_serial() {
        let mut cfg = OpimaConfig::paper();
        cfg.geometry.banks = 1;
        cfg.geometry.subarray_rows = 2;
        cfg.geometry.subarray_cols = 2;
        cfg.geometry.subarray_groups = 2;
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        assert!(!a.occupancy.fits());
        let t = simulate_analysis(&cfg, &a, 4);
        assert!(!t.pipelined);
        assert!(
            (t.makespan_ns - t.sequential_ns).abs() <= 1e-9 * t.sequential_ns,
            "serial fallback must equal the sequential sum"
        );
    }

    #[test]
    fn wider_writeback_channel_pool_cannot_hurt() {
        let (cfg, a) = analysis(4);
        let base = simulate_analysis(&cfg, &a, 16);
        let mut wide = cfg.clone();
        wide.pipeline.writeback_channels = 4;
        let t = simulate_analysis(&wide, &a, 16);
        assert!(t.makespan_ns <= base.makespan_ns + Nanos::new(1e-6));
    }

    #[test]
    fn makespan_fast_path_matches_full_schedule() {
        let (cfg, a) = analysis(4);
        for batch in [1usize, 2, 8, 32] {
            let full = simulate_analysis(&cfg, &a, batch);
            let fast = simulate_analysis_makespan(&cfg, &a, batch);
            // Same pass, same arithmetic order → bit-identical scalars.
            assert_eq!(fast.batch, full.batch);
            assert_eq!(fast.makespan_ns, full.makespan_ns);
            assert_eq!(fast.sequential_ns, full.sequential_ns);
            assert_eq!(fast.bottleneck_ns, full.bottleneck_ns);
            assert_eq!(fast.per_image_ns, full.per_image_ns);
            assert_eq!(fast.pipelined, full.pipelined);
            assert_eq!(fast.makespan_ms(), full.summary().makespan_ms());
            assert_eq!(full.events.len(), batch * a.layer_costs.len() * 3);
        }
        // The serial (over-capacity) fallback agrees too.
        let raw = simulate_makespan(&cfg, &a.layer_costs, 4);
        assert_eq!(raw.makespan_ns, simulate(&cfg, &a.layer_costs, 4).makespan_ns);
    }

    #[test]
    fn degenerate_empty_schedule_reports_finite_ratios() {
        // Empty cost slice and zero batch both produce a zero makespan;
        // speedup/efficiency must report a neutral 1.0, never `inf` —
        // contended reports print these ratios directly.
        let cfg = OpimaConfig::paper();
        for t in [
            simulate_makespan(&cfg, &[], 4),
            simulate_makespan(&cfg, &[], 0),
        ] {
            assert_eq!(t.makespan_ns, Nanos::ZERO);
            assert_eq!(t.speedup(), 1.0);
            assert_eq!(t.efficiency(), 1.0);
            assert!(t.speedup().is_finite() && t.efficiency().is_finite());
        }
        let (cfg, a) = analysis(4);
        let t = simulate_analysis_makespan(&cfg, &a, 0);
        assert_eq!(t.makespan_ns, Nanos::ZERO);
        assert_eq!(t.speedup(), 1.0);
        assert_eq!(t.efficiency(), 1.0);
    }

    #[test]
    fn command_models_recover_flat_at_batch_one() {
        // The uncontended limit: at batch 1 with one writeback channel,
        // every writeback runs as a gapless serial chain from its ready
        // time, so both command controllers return exactly the flat
        // analytical window — bit-identical makespans. This needs a real
        // model: every inter-writeback gap must cover the GST row-switch
        // reconfiguration (true for all Table II CNNs; sub-10ns-gap toy
        // nets surface genuine route stalls — see DESIGN.md §2.7).
        let cfg = OpimaConfig::paper();
        let a = analyze_model(&cfg, &build_model(Model::ResNet18).unwrap(), 4).unwrap();
        let flat = simulate_analysis_makespan(&cfg, &a, 1);
        for model in [WritebackModel::Naive, WritebackModel::Scheduled] {
            let mut c = cfg.clone();
            c.memory.writeback_model = model;
            let t = simulate_analysis_makespan(&c, &a, 1);
            assert_eq!(
                t.makespan_ns, flat.makespan_ns,
                "{model} batch-1 makespan drifted from flat"
            );
        }
    }

    #[test]
    fn command_models_bounded_and_ordered_at_batch() {
        let (cfg, a) = analysis(4);
        for batch in [2usize, 8, 16] {
            let flat = simulate_analysis_makespan(&cfg, &a, batch);
            let mut nc = cfg.clone();
            nc.memory.writeback_model = WritebackModel::Naive;
            let naive = simulate_analysis_makespan(&nc, &a, batch);
            let mut sc = cfg.clone();
            sc.memory.writeback_model = WritebackModel::Scheduled;
            let sched = simulate_analysis_makespan(&sc, &a, batch);
            let eps = Nanos::new(1e-6);
            assert!(
                naive.makespan_ns + eps >= flat.makespan_ns,
                "batch {batch}: naive {} < flat {}",
                naive.makespan_ns,
                flat.makespan_ns
            );
            assert!(
                naive.makespan_ns + eps >= sched.makespan_ns,
                "batch {batch}: naive {} < scheduled {}",
                naive.makespan_ns,
                sched.makespan_ns
            );
            assert!(
                sched.makespan_ns + eps >= sched.bottleneck_ns,
                "batch {batch}: scheduled beat its own lower bound"
            );
        }
    }

    #[test]
    fn in_flight_window_of_one_serializes_images() {
        let (cfg, a) = analysis(4);
        let mut tight = cfg.clone();
        tight.pipeline.max_in_flight_images = 1;
        let t = simulate_analysis(&tight, &a, 4);
        // Window 1: image i may only enter once i-1 retired — the
        // schedule degenerates to the sequential sum.
        assert!((t.makespan_ns - t.sequential_ns).abs() <= 1e-9 * t.sequential_ns);
        let free = simulate_analysis(&cfg, &a, 4);
        assert!(free.makespan_ns < t.makespan_ns);
    }
}
