//! CrossLight (DAC'21): silicon-photonic CNN accelerator baseline.
//!
//! CrossLight computes MVMs in MR weight banks (broadcast-and-weight) but
//! is *not* a PIM: weights and activations live in external DDR5 DRAM and
//! every layer's operands cross the memory interface. Its energy story:
//! photonic MACs are cheap, but thermo-optic weight-bank (re)tuning and
//! DRAM traffic add up; its latency is capped by the MR bank count.

use crate::analyzer::metrics::PlatformResult;
use crate::cnn::graph::Network;
use crate::phys::params::EnergyParams;

#[derive(Debug, Clone)]
pub struct CrossLight {
    /// Sustained photonic MAC throughput (MAC/s): MR banks × WDM × rate.
    pub sustained_macs_per_s: f64,
    /// Photonic MAC energy (pJ/MAC): laser + modulation share.
    pub mac_energy_pj: f64,
    /// Thermo-optic retuning energy per weight programming event
    /// (pJ/weight): TO heaters hold mW-class power for µs-class lock
    /// times, so per-weight programming is ~0.5 nJ.
    pub tune_energy_pj: f64,
    /// DDR5 interface bandwidth (bits/s) — 4800 MT/s × 64 bit.
    pub dram_bits_per_s: f64,
    /// Accelerator power envelope (W).
    pub power_w: f64,
}

impl Default for CrossLight {
    fn default() -> Self {
        Self {
            sustained_macs_per_s: 0.023e12,
            mac_energy_pj: 1.7,
            tune_energy_pj: 500.0,
            dram_bits_per_s: 4800e6 * 64.0,
            power_w: 24.0,
        }
    }
}

impl CrossLight {
    pub fn evaluate(&self, net: &Network, bits: u32) -> PlatformResult {
        let e = EnergyParams::default();
        let macs = net.macs() as f64;
        let passes = (bits as f64 / 4.0).max(1.0).powi(2); // heterogeneous-quant TDM
        // All weights + activations cross the DRAM interface each
        // inference (no PIM): that traffic overlaps compute imperfectly.
        let moved_bits = ((net.params() + 2 * net.activation_elems()) * bits as u64) as f64;
        let dram_ms = moved_bits / self.dram_bits_per_s * 1e3;
        let compute_ms = macs * passes / self.sustained_macs_per_s * 1e3;
        let latency_ms = compute_ms + 0.6 * dram_ms + 0.05;
        let energy_mj = macs * passes * self.mac_energy_pj / 1e9
            + net.params() as f64 * self.tune_energy_pj / 1e9
            + moved_bits * e.dram_access_pj_per_bit / 1e9;
        PlatformResult {
            platform: "CrossLight".into(),
            model: net.name.clone(),
            latency_ms: crate::util::units::ms(latency_ms),
            power_w: self.power_w,
            energy_mj: crate::util::units::mj(energy_mj),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn dram_traffic_matters_for_big_models() {
        let cl = CrossLight::default();
        let vgg = build_model(Model::Vgg16).unwrap();
        let r = cl.evaluate(&vgg, 4);
        // VGG16 weights alone are 134M × 4 bits = 67 MB — a large DRAM
        // bill at 38.4 GB/s.
        assert!(r.latency_ms.raw() > 100.0, "{}", r.latency_ms);
    }

    #[test]
    fn small_model_sane() {
        let cl = CrossLight::default();
        let net = build_model(Model::ResNet18).unwrap();
        let r = cl.evaluate(&net, 4);
        assert!((10.0..60.0).contains(&r.latency_ms.raw()), "{}", r.latency_ms);
        assert!(r.energy_mj.raw() > 0.5);
    }
}
