//! Electronic platform rooflines: NVIDIA P100, AMD EPYC 7742, Jetson
//! AGX Orin (paper §V: NP100, E7742, ORIN).
//!
//! Model: latency = MACs / (peak × sustained-utilization) + fixed
//! per-inference overhead (launch, staging). Energy is metered at the
//! wall: board power × latency + DRAM traffic energy. Peaks and board
//! powers are datasheet values; utilizations are the small-batch CNN
//! inference figures these systems achieve in practice (batch-1 32×32
//! workloads leave big accelerators mostly idle), set so the relative
//! results land in the paper's reported bands.

use crate::analyzer::metrics::PlatformResult;
use crate::cnn::graph::Network;
use crate::phys::params::EnergyParams;
use crate::util::units::{ms, Millijoules, Millis};

/// An electronic platform model.
#[derive(Debug, Clone)]
pub struct ElectronicPlatform {
    pub name: &'static str,
    /// Peak MAC/s at the precision used for inference.
    pub peak_macs_per_s: f64,
    /// Sustained fraction of peak for batch-1 CNN inference.
    pub utilization: f64,
    /// Board/package power under load (W).
    pub power_w: f64,
    /// Fixed per-inference overhead: kernel launch, staging, sync.
    pub overhead_ms: Millis,
    /// Native operand width (bits) for the deployed precision.
    pub native_bits: u32,
}

impl ElectronicPlatform {
    pub fn evaluate(&self, net: &Network, _bits: u32) -> PlatformResult {
        let e = EnergyParams::default();
        let compute_ms = net.macs() as f64 / (self.peak_macs_per_s * self.utilization) * 1e3;
        let latency_ms = ms(compute_ms) + self.overhead_ms;
        // DRAM traffic: weights once + activations twice (write + read).
        let moved_bits = (net.params() + 2 * net.activation_elems()) * self.native_bits as u64;
        let dram_mj = moved_bits as f64 * e.dram_access_pj_per_bit / 1e9;
        let energy_mj = Millijoules::new(self.power_w * latency_ms.raw() + dram_mj); // W·ms = mJ
        PlatformResult {
            platform: self.name.into(),
            model: net.name.clone(),
            latency_ms,
            power_w: self.power_w,
            energy_mj,
        }
    }
}

/// NVIDIA P100: 9.3 TFLOPS fp32 (4.65 T MAC/s), 250 W board.
pub fn np100() -> ElectronicPlatform {
    ElectronicPlatform {
        name: "NP100",
        peak_macs_per_s: 4.65e12,
        utilization: 0.013,
        power_w: 250.0,
        overhead_ms: ms(0.10),
        native_bits: 32,
    }
}

/// AMD EPYC 7742: 64 cores × 2.25 GHz × 32 fp32 FLOPs ≈ 2.3 T MAC/s, 225 W.
pub fn e7742() -> ElectronicPlatform {
    ElectronicPlatform {
        name: "E7742",
        peak_macs_per_s: 2.3e12,
        utilization: 0.0105,
        power_w: 225.0,
        overhead_ms: ms(0.25),
        native_bits: 32,
    }
}

/// Jetson AGX Orin: 137 TOPS dense int8 (68.5 T MAC/s), 60 W MAXN.
/// Batch-1 tiny-image inference leaves the tensor cores almost idle —
/// sustained throughput is dominated by launch/DMA overheads.
pub fn orin() -> ElectronicPlatform {
    ElectronicPlatform {
        name: "ORIN",
        peak_macs_per_s: 68.5e12,
        utilization: 0.00022,
        power_w: 60.0,
        overhead_ms: ms(2.0),
        native_bits: 8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn gpu_faster_than_cpu() {
        let net = build_model(Model::ResNet18).unwrap();
        let g = np100().evaluate(&net, 4);
        let c = e7742().evaluate(&net, 4);
        assert!(g.latency_ms < c.latency_ms);
        assert!(g.fps() > c.fps());
    }

    #[test]
    fn electronic_latencies_plausible() {
        // ResNet18 batch-1: GPU ~2 ms, CPU ~5 ms, ORIN ~10 ms class.
        let net = build_model(Model::ResNet18).unwrap();
        for (p, lo, hi) in [
            (np100(), 2.0, 15.0),
            (e7742(), 8.0, 40.0),
            (orin(), 15.0, 60.0),
        ] {
            let r = p.evaluate(&net, 4);
            assert!(
                (lo..hi).contains(&r.latency_ms.raw()),
                "{}: {}",
                r.platform,
                r.latency_ms
            );
        }
    }

    #[test]
    fn energy_includes_dram_term() {
        let net = build_model(Model::Vgg16).unwrap();
        let p = np100();
        let r = p.evaluate(&net, 4);
        let compute_only = p.power_w * r.latency_ms.raw();
        assert!(r.energy_mj.raw() > compute_only);
    }

    #[test]
    fn vgg_scales_latency() {
        let rn = build_model(Model::ResNet18).unwrap();
        let vgg = build_model(Model::Vgg16).unwrap();
        let p = np100();
        assert!(p.evaluate(&vgg, 4).latency_ms > 10.0 * p.evaluate(&rn, 4).latency_ms);
    }
}
