//! Comparison platforms (paper §V): electronic rooflines (NVIDIA P100,
//! AMD EPYC 7742, Jetson ORIN), the ReRAM PIM PRIME, and the photonic
//! platforms CrossLight and PhPIM.
//!
//! The paper measured/modeled these systems directly; we cannot, so each
//! baseline is an analytical model with a mechanistic structure (peak
//! throughput × sustained utilization + memory-traffic terms + the
//! platform's characteristic energy story) whose constants are set from
//! datasheets and, where only relative results are published, calibrated
//! to the paper's reported ratios. DESIGN.md §2 records the argument;
//! EXPERIMENTS.md records paper-vs-measured for every ratio.

pub mod crosslight;
pub mod electronic;
pub mod phpim;
pub mod prime;

use crate::analyzer::energy::energy_breakdown;
use crate::analyzer::latency::analyze_model;
use crate::analyzer::metrics::PlatformResult;
use crate::analyzer::power::power_breakdown;
use crate::cnn::graph::Network;
use crate::config::OpimaConfig;
use crate::error::Result;

/// Evaluate OPIMA itself as a platform row (dynamic-energy accounting,
/// envelope power for FPS/W — see `analyzer::metrics`).
pub fn evaluate_opima(cfg: &OpimaConfig, net: &Network, bits: u32) -> Result<PlatformResult> {
    let a = analyze_model(cfg, net, bits)?;
    let e = energy_breakdown(cfg, &a);
    Ok(PlatformResult {
        platform: "OPIMA".into(),
        model: net.name.clone(),
        latency_ms: a.total_ms(),
        power_w: power_breakdown(cfg).total_w(),
        energy_mj: e.dynamic_mj(),
    })
}

/// All seven platforms of Figs. 11/12, OPIMA first.
pub fn evaluate_all(cfg: &OpimaConfig, net: &Network, bits: u32) -> Result<Vec<PlatformResult>> {
    Ok(vec![
        evaluate_opima(cfg, net, bits)?,
        electronic::np100().evaluate(net, bits),
        electronic::e7742().evaluate(net, bits),
        electronic::orin().evaluate(net, bits),
        prime::Prime::default().evaluate(net, bits),
        crosslight::CrossLight::default().evaluate(net, bits),
        phpim::PhPim::new(cfg).evaluate(net, bits),
    ])
}
