//! PhPIM (ISLPED'23 [32]): OPCM photonic tensor-core PIM baseline —
//! the paper's state-of-the-art comparator.
//!
//! PhPIM uses the Feldmann-style photonic tensor core cell (Fig. 1(b)):
//! optical MVM over OPCM-stored weights, but (a) an external DDR5 DRAM is
//! the actual main memory, (b) reprogramming uses *electrical* PCM
//! writes — fast (the paper: "reprogramming ... is significantly faster
//! for PhPIM") but at 860 nJ/cell (Table I) — and (c) without OPIMA's
//! bank/group/MDL machinery its MAC parallelism is a single tensor-core
//! array, far below a whole main memory's.
//!
//! These three structural facts produce the paper's two headline numbers:
//! OPIMA is ~3× faster (parallelism) and ~137× more energy-efficient
//! (pJ-class OPCM writes vs nJ-class EPCM writes).

use crate::analyzer::metrics::PlatformResult;
use crate::cnn::graph::Network;
use crate::config::OpimaConfig;
use crate::phys::params::EnergyParams;
use crate::util::units::{ns, Millijoules, Millis, Nanos};

#[derive(Debug, Clone)]
pub struct PhPim {
    /// Sustained tensor-core MAC throughput (MAC/s).
    pub sustained_macs_per_s: f64,
    /// Photonic MAC energy (pJ/MAC).
    pub mac_energy_pj: f64,
    /// EPCM write energy per cell (nJ) — Table I.
    pub epcm_write_nj: f64,
    /// EPCM write latency per cell batch: electrical, fast.
    pub epcm_write_ns: Nanos,
    /// Concurrent EPCM write lanes.
    pub write_lanes: usize,
    /// DDR5 bandwidth (bits/s).
    pub dram_bits_per_s: f64,
    /// Power envelope (W).
    pub power_w: f64,
    /// Cell bit density (4, like OPIMA).
    pub bits_per_cell: u32,
}

impl PhPim {
    pub fn new(cfg: &OpimaConfig) -> Self {
        Self {
            sustained_macs_per_s: 0.04e12,
            mac_energy_pj: 1.1,
            epcm_write_nj: cfg.energy.epcm_write_nj,
            epcm_write_ns: ns(100.0),
            write_lanes: 512,
            dram_bits_per_s: 4800e6 * 64.0,
            power_w: 31.0,
            bits_per_cell: cfg.geometry.bits_per_cell,
        }
    }

    pub fn evaluate(&self, net: &Network, bits: u32) -> PlatformResult {
        let e = EnergyParams::default();
        let macs = net.macs() as f64;
        let passes = (bits as f64 / self.bits_per_cell as f64).max(1.0).powi(2);
        let compute_ms = macs * passes / self.sustained_macs_per_s * 1e3;
        // Activations stream from/to the external DRAM (weights stay in
        // the OPCM tensor cores).
        let act_bits = (2 * net.activation_elems() * bits as u64) as f64;
        let dram_ms = act_bits / self.dram_bits_per_s * 1e3;
        // Intermediate feature maps are reprogrammed into PCM electrically:
        // fast (100 ns trains, wide lanes) but at 860 nJ per cell.
        let cells =
            (net.activation_elems() * bits as u64).div_ceil(self.bits_per_cell as u64) as f64;
        let write_ms = (cells / self.write_lanes as f64 * self.epcm_write_ns).to_millis();
        let latency_ms = compute_ms + 0.5 * dram_ms + write_ms.raw() + 0.05;
        let energy_mj = macs * passes * self.mac_energy_pj / 1e9
            + cells * self.epcm_write_nj * 1e3 / 1e9 // nJ → pJ → mJ
            + act_bits * e.dram_access_pj_per_bit / 1e9;
        // EPCM write power is a first-class contributor to PhPIM's
        // envelope: average power = base + dynamic energy over the run.
        let power_w = self.power_w + energy_mj / latency_ms;
        PlatformResult {
            platform: "PhPIM".into(),
            model: net.name.clone(),
            latency_ms: Millis::new(latency_ms),
            power_w,
            energy_mj: Millijoules::new(energy_mj),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn epcm_writes_dominate_energy() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let r = PhPim::new(&cfg).evaluate(&net, 4);
        // 614 k cells × 860 nJ ≈ 530 mJ — orders beyond the compute term.
        assert!(r.energy_mj.raw() > 100.0, "{}", r.energy_mj);
    }

    #[test]
    fn writeback_is_fast_but_compute_slow() {
        // The paper: PhPIM reprograms faster than OPIMA but processes
        // slower (less parallelism).
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let macs = net.macs() as f64;
        let p = PhPim::new(&cfg);
        let compute_ms = macs / p.sustained_macs_per_s * 1e3;
        let cells = (net.activation_elems() * 4).div_ceil(4) as f64;
        let write_ms = (cells / p.write_lanes as f64 * p.epcm_write_ns).to_millis();
        assert!(write_ms.raw() < 0.5 * compute_ms);
    }
}
