//! PRIME (ISCA'16): ReRAM crossbar processing-in-memory baseline.
//!
//! PRIME computes MVMs inside ReRAM crossbar arrays. Its energy story is
//! dominated by the ADC/DAC conversions around the analog crossbars and
//! the (electrical) writes of intermediate activations back into ReRAM;
//! its throughput by the crossbar bank parallelism. Dynamic-energy
//! accounting, like the other PIM platforms.

use crate::analyzer::metrics::PlatformResult;
use crate::cnn::graph::Network;

/// PRIME model constants.
#[derive(Debug, Clone)]
pub struct Prime {
    /// Aggregate sustained crossbar throughput (MAC/s).
    pub sustained_macs_per_s: f64,
    /// Per-MAC dynamic energy (pJ): analog MAC + amortized ADC/DAC.
    /// Literature-consistent figure for ISAAC/PRIME-class designs.
    pub mac_energy_pj: f64,
    /// ReRAM write energy per activation cell (pJ).
    pub write_energy_pj: f64,
    /// Chip power envelope (W).
    pub power_w: f64,
}

impl Default for Prime {
    fn default() -> Self {
        Self {
            sustained_macs_per_s: 0.011e12,
            mac_energy_pj: 24.0,
            write_energy_pj: 80.0,
            power_w: 38.0,
        }
    }
}

impl Prime {
    pub fn evaluate(&self, net: &Network, bits: u32) -> PlatformResult {
        let macs = net.macs() as f64;
        // 8-bit operands need two 4-bit crossbar passes in PRIME's MLC
        // scheme, mirroring OPIMA's TDM factor.
        let passes = (bits as f64 / 4.0).max(1.0).powi(2);
        let latency_ms = macs * passes / self.sustained_macs_per_s * 1e3 + 0.05;
        let write_mj =
            net.activation_elems() as f64 * (bits as f64 / 4.0) * self.write_energy_pj / 1e9;
        let energy_mj = macs * passes * self.mac_energy_pj / 1e9 + write_mj;
        PlatformResult {
            platform: "PRIME".into(),
            model: net.name.clone(),
            latency_ms: crate::util::units::ms(latency_ms),
            power_w: self.power_w,
            energy_mj: crate::util::units::mj(energy_mj),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model};

    #[test]
    fn prime_evaluates_sanely() {
        let net = build_model(Model::ResNet18).unwrap();
        let r = Prime::default().evaluate(&net, 4);
        assert!((20.0..100.0).contains(&r.latency_ms.raw()), "{}", r.latency_ms);
        assert!(r.energy_mj.raw() > 1.0, "ADC-heavy energy: {}", r.energy_mj);
    }

    #[test]
    fn eight_bit_quadruples_compute() {
        let net = build_model(Model::ResNet18).unwrap();
        let p = Prime::default();
        let r4 = p.evaluate(&net, 4);
        let r8 = p.evaluate(&net, 8);
        assert!(r8.latency_ms > 3.5 * r4.latency_ms);
    }
}
