//! Shape-tracking network builder and the finished [`Network`].
//!
//! The builder maintains the current activation shape and appends bound
//! [`LayerInstance`]s. Branch/concat (inception modules) and residual
//! blocks are expressed by building branches from the current shape and
//! merging: all compute layers land in one flat instance list — exactly
//! what the OPIMA mapper needs (layer execution is sequential because
//! each layer consumes its predecessor's written-back feature maps).

use crate::cnn::layer::{Layer, LayerInstance, TensorShape};
use crate::error::{Error, Result};

/// A finished network.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub input: TensorShape,
    pub layers: Vec<LayerInstance>,
    pub output: TensorShape,
}

impl Network {
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Compute layers only (conv/fc).
    pub fn compute_layers(&self) -> impl Iterator<Item = &LayerInstance> {
        self.layers.iter().filter(|l| l.layer.is_compute())
    }

    /// MACs carried by accumulation-free (1×1) kernels — the workloads
    /// that lose OPIMA's WDM parallelism (paper §V.C).
    pub fn one_by_one_macs(&self) -> u64 {
        self.compute_layers()
            .filter(|l| l.layer.spatial_accum() == 1)
            .map(|l| l.macs())
            .sum()
    }

    /// Total activation elements written back across layers.
    pub fn activation_elems(&self) -> u64 {
        self.compute_layers().map(|l| l.out_shape.elems()).sum()
    }
}

/// Incremental builder.
#[derive(Debug, Clone)]
pub struct NetworkBuilder {
    name: String,
    input: TensorShape,
    cur: TensorShape,
    layers: Vec<LayerInstance>,
    counter: usize,
}

impl NetworkBuilder {
    pub fn new(name: &str, input: TensorShape) -> Self {
        Self {
            name: name.to_string(),
            input,
            cur: input,
            layers: Vec::new(),
            counter: 0,
        }
    }

    pub fn current_shape(&self) -> TensorShape {
        self.cur
    }

    fn push(&mut self, tag: &str, layer: Layer) -> Result<&mut Self> {
        let out = layer.out_shape(self.cur)?;
        self.counter += 1;
        self.layers.push(LayerInstance {
            name: format!("{}{}_{}", tag, self.counter, self.name),
            layer,
            in_shape: self.cur,
            out_shape: out,
        });
        self.cur = out;
        Ok(self)
    }

    /// Standard convolution (+ bias), followed by an implicit ReLU.
    pub fn conv(
        &mut self,
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        pad: usize,
    ) -> Result<&mut Self> {
        self.push(
            "conv",
            Layer::Conv {
                kh,
                kw,
                cout,
                stride,
                pad,
                groups: 1,
                bias: true,
            },
        )
    }

    /// Depthwise convolution (groups = channels).
    pub fn dwconv(&mut self, k: usize, stride: usize) -> Result<&mut Self> {
        let c = self.cur.c;
        self.push(
            "dwconv",
            Layer::Conv {
                kh: k,
                kw: k,
                cout: c,
                stride,
                pad: k / 2,
                groups: c,
                bias: true,
            },
        )
    }

    /// Pointwise (1×1) convolution.
    pub fn pwconv(&mut self, cout: usize) -> Result<&mut Self> {
        self.conv(1, 1, cout, 1, 0)
    }

    pub fn pool(&mut self, k: usize, stride: usize) -> Result<&mut Self> {
        self.push("pool", Layer::Pool { k, stride })
    }

    pub fn global_pool(&mut self) -> Result<&mut Self> {
        self.push("gap", Layer::GlobalPool)
    }

    pub fn fc(&mut self, out: usize) -> Result<&mut Self> {
        self.push("fc", Layer::Fc { out, bias: true })
    }

    /// Inception-style module: every branch starts from the current
    /// shape; outputs must agree spatially and concatenate channel-wise.
    /// Each branch is a list of (kh, kw, cout, stride, pad) convs; an
    /// empty branch is a channel passthrough (pool-projection branches
    /// should include their 1×1 projection conv).
    pub fn inception(&mut self, branches: &[Vec<(usize, usize, usize, usize, usize)>]) -> Result<&mut Self> {
        if branches.is_empty() {
            return Err(Error::Model("inception needs branches".into()));
        }
        let entry = self.cur;
        let mut spatial: Option<(usize, usize)> = None;
        let mut channels = 0usize;
        for branch in branches {
            self.cur = entry;
            if branch.is_empty() {
                channels += entry.c;
                spatial.get_or_insert((entry.h, entry.w));
                continue;
            }
            for &(kh, kw, cout, stride, pad) in branch {
                self.conv(kh, kw, cout, stride, pad)?;
            }
            let out = self.cur;
            match spatial {
                None => spatial = Some((out.h, out.w)),
                Some(s) if s == (out.h, out.w) => {}
                Some(s) => {
                    return Err(Error::Model(format!(
                        "inception branch spatial mismatch: {:?} vs {:?}",
                        s,
                        (out.h, out.w)
                    )))
                }
            }
            channels += out.c;
        }
        let (h, w) = spatial.unwrap();
        self.cur = TensorShape::new(h, w, channels);
        Ok(self)
    }

    /// Residual basic block (ResNet-18 style): two 3×3 convs; a 1×1
    /// projection shortcut when stride ≠ 1 or channels change (the
    /// projection is itself a 1×1 conv and is priced as such).
    pub fn basic_block(&mut self, cout: usize, stride: usize) -> Result<&mut Self> {
        let entry = self.cur;
        self.conv(3, 3, cout, stride, 1)?;
        self.conv(3, 3, cout, 1, 1)?;
        if stride != 1 || entry.c != cout {
            let exit = self.cur;
            self.cur = entry;
            self.conv(1, 1, cout, stride, 0)?; // projection shortcut
            if self.cur != exit {
                return Err(Error::Model("projection shape mismatch".into()));
            }
        }
        Ok(self)
    }

    pub fn build(self) -> Network {
        Network {
            name: self.name,
            input: self.input,
            output: self.cur,
            layers: self.layers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_shapes_track() {
        let mut b = NetworkBuilder::new("t", TensorShape::new(32, 32, 3));
        b.conv(3, 3, 16, 1, 1)
            .unwrap()
            .pool(2, 2)
            .unwrap()
            .conv(3, 3, 32, 1, 1)
            .unwrap()
            .global_pool()
            .unwrap()
            .fc(10)
            .unwrap();
        let n = b.build();
        assert_eq!(n.output, TensorShape::new(1, 1, 10));
        // conv1: 3*3*3*16+16; conv2: 3*3*16*32+32; fc: 32*10+10
        assert_eq!(n.params(), (432 + 16) + (4608 + 32) + (320 + 10));
    }

    #[test]
    fn inception_concatenates() {
        let mut b = NetworkBuilder::new("t", TensorShape::new(16, 16, 32));
        b.inception(&[
            vec![(1, 1, 8, 1, 0)],
            vec![(1, 1, 4, 1, 0), (3, 3, 16, 1, 1)],
            vec![(1, 1, 4, 1, 0)],
        ])
        .unwrap();
        assert_eq!(b.current_shape(), TensorShape::new(16, 16, 28));
    }

    #[test]
    fn inception_rejects_spatial_mismatch() {
        let mut b = NetworkBuilder::new("t", TensorShape::new(16, 16, 32));
        let r = b.inception(&[
            vec![(1, 1, 8, 1, 0)],
            vec![(3, 3, 8, 2, 1)], // stride 2 shrinks
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn basic_block_with_projection() {
        let mut b = NetworkBuilder::new("t", TensorShape::new(32, 32, 64));
        b.basic_block(128, 2).unwrap();
        let n = b.build();
        assert_eq!(n.output, TensorShape::new(16, 16, 128));
        // Projection shortcut is a 1×1 layer.
        assert_eq!(n.one_by_one_macs(), 16 * 16 * 128 * 64);
    }

    #[test]
    fn one_by_one_macs_counted() {
        let mut b = NetworkBuilder::new("t", TensorShape::new(8, 8, 16));
        b.pwconv(32).unwrap().conv(3, 3, 32, 1, 1).unwrap();
        let n = b.build();
        assert_eq!(n.one_by_one_macs(), 8 * 8 * 32 * 16);
        assert!(n.macs() > n.one_by_one_macs());
    }
}
