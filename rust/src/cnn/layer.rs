//! Layer descriptors: shape, parameter and MAC arithmetic.

use crate::error::{Error, Result};

/// A (height, width, channels) activation shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TensorShape {
    pub fn new(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c }
    }

    pub fn elems(&self) -> u64 {
        (self.h * self.w * self.c) as u64
    }
}

/// A layer kind.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// 2-D convolution. `groups` > 1 models grouped/depthwise convs
    /// (depthwise: groups == cin == cout).
    Conv {
        kh: usize,
        kw: usize,
        cout: usize,
        stride: usize,
        pad: usize,
        groups: usize,
        bias: bool,
    },
    /// Fully connected over the flattened input.
    Fc { out: usize, bias: bool },
    /// Max/avg pooling (no params, no MACs in our accounting).
    Pool { k: usize, stride: usize },
    /// Global average pooling to 1×1.
    GlobalPool,
    /// Element-wise activation (applied at the E-O-E controller; free).
    Relu,
}

impl Layer {
    /// Output shape given the input shape.
    pub fn out_shape(&self, input: TensorShape) -> Result<TensorShape> {
        match *self {
            Layer::Conv {
                kh,
                kw,
                cout,
                stride,
                pad,
                groups,
                ..
            } => {
                if stride == 0 || groups == 0 {
                    return Err(Error::Model("stride/groups must be positive".into()));
                }
                if input.c % groups != 0 || cout % groups != 0 {
                    return Err(Error::Model(format!(
                        "channels {} / cout {} not divisible by groups {}",
                        input.c, cout, groups
                    )));
                }
                if input.h + 2 * pad < kh || input.w + 2 * pad < kw {
                    return Err(Error::Model("kernel larger than padded input".into()));
                }
                Ok(TensorShape::new(
                    (input.h + 2 * pad - kh) / stride + 1,
                    (input.w + 2 * pad - kw) / stride + 1,
                    cout,
                ))
            }
            Layer::Fc { out, .. } => Ok(TensorShape::new(1, 1, out)),
            Layer::Pool { k, stride } => {
                if stride == 0 || input.h < k || input.w < k {
                    return Err(Error::Model("bad pool geometry".into()));
                }
                Ok(TensorShape::new(
                    (input.h - k) / stride + 1,
                    (input.w - k) / stride + 1,
                    input.c,
                ))
            }
            Layer::GlobalPool => Ok(TensorShape::new(1, 1, input.c)),
            Layer::Relu => Ok(input),
        }
    }

    /// Trainable parameter count.
    pub fn params(&self, input: TensorShape) -> u64 {
        match *self {
            Layer::Conv {
                kh,
                kw,
                cout,
                groups,
                bias,
                ..
            } => {
                let weights = (kh * kw * (input.c / groups) * cout) as u64;
                weights + if bias { cout as u64 } else { 0 }
            }
            Layer::Fc { out, bias } => {
                input.elems() * out as u64 + if bias { out as u64 } else { 0 }
            }
            _ => 0,
        }
    }

    /// Multiply-accumulate operations for one inference.
    pub fn macs(&self, input: TensorShape) -> Result<u64> {
        match *self {
            Layer::Conv {
                kh, kw, groups, ..
            } => {
                let out = self.out_shape(input)?;
                Ok(out.elems() * (kh * kw * (input.c / groups)) as u64)
            }
            Layer::Fc { out, .. } => Ok(input.elems() * out as u64),
            _ => Ok(0),
        }
    }

    /// Spatial accumulation depth available to OPIMA's in-waveguide sum:
    /// kernel rows pair across subarrays (paper §IV.D). 1×1 kernels have
    /// no partner (the serialization hazard); FC layers chunk their long
    /// reductions into pairable row-vectors.
    pub fn spatial_accum(&self) -> usize {
        match *self {
            Layer::Conv { kh, .. } => kh,
            Layer::Fc { .. } => 2,
            _ => 0,
        }
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, Layer::Conv { .. } | Layer::Fc { .. })
    }
}

/// A layer bound to concrete input/output shapes inside a network.
#[derive(Debug, Clone)]
pub struct LayerInstance {
    pub name: String,
    pub layer: Layer,
    pub in_shape: TensorShape,
    pub out_shape: TensorShape,
}

impl LayerInstance {
    pub fn params(&self) -> u64 {
        self.layer.params(self.in_shape)
    }

    pub fn macs(&self) -> u64 {
        self.layer.macs(self.in_shape).expect("validated at build")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_and_params() {
        let l = Layer::Conv {
            kh: 3,
            kw: 3,
            cout: 64,
            stride: 1,
            pad: 1,
            groups: 1,
            bias: true,
        };
        let inp = TensorShape::new(32, 32, 3);
        assert_eq!(l.out_shape(inp).unwrap(), TensorShape::new(32, 32, 64));
        assert_eq!(l.params(inp), 3 * 3 * 3 * 64 + 64);
        assert_eq!(l.macs(inp).unwrap(), 32 * 32 * 64 * 27);
        assert_eq!(l.spatial_accum(), 3);
    }

    #[test]
    fn strided_conv_shape() {
        let l = Layer::Conv {
            kh: 3,
            kw: 3,
            cout: 128,
            stride: 2,
            pad: 1,
            groups: 1,
            bias: false,
        };
        let out = l.out_shape(TensorShape::new(32, 32, 64)).unwrap();
        assert_eq!(out, TensorShape::new(16, 16, 128));
    }

    #[test]
    fn depthwise_conv() {
        let l = Layer::Conv {
            kh: 3,
            kw: 3,
            cout: 64,
            stride: 1,
            pad: 1,
            groups: 64,
            bias: false,
        };
        let inp = TensorShape::new(16, 16, 64);
        assert_eq!(l.params(inp), 3 * 3 * 64);
        assert_eq!(l.macs(inp).unwrap(), 16 * 16 * 64 * 9);
    }

    #[test]
    fn fc_counts() {
        let l = Layer::Fc {
            out: 100,
            bias: true,
        };
        let inp = TensorShape::new(1, 1, 512);
        assert_eq!(l.params(inp), 512 * 100 + 100);
        assert_eq!(l.macs(inp).unwrap(), 51_200);
        assert_eq!(l.spatial_accum(), 2);
    }

    #[test]
    fn pool_and_global_pool() {
        let p = Layer::Pool { k: 2, stride: 2 };
        assert_eq!(
            p.out_shape(TensorShape::new(32, 32, 64)).unwrap(),
            TensorShape::new(16, 16, 64)
        );
        assert_eq!(p.params(TensorShape::new(32, 32, 64)), 0);
        let g = Layer::GlobalPool;
        assert_eq!(
            g.out_shape(TensorShape::new(7, 7, 512)).unwrap(),
            TensorShape::new(1, 1, 512)
        );
    }

    #[test]
    fn invalid_geometry_rejected() {
        let l = Layer::Conv {
            kh: 5,
            kw: 5,
            cout: 8,
            stride: 1,
            pad: 0,
            groups: 1,
            bias: false,
        };
        assert!(l.out_shape(TensorShape::new(3, 3, 1)).is_err());
        let l = Layer::Conv {
            kh: 1,
            kw: 1,
            cout: 7,
            stride: 1,
            pad: 0,
            groups: 2,
            bias: false,
        };
        assert!(l.out_shape(TensorShape::new(8, 8, 4)).is_err());
    }
}
