//! CNN graph IR and the paper's evaluation model zoo (Table II).
//!
//! - [`layer`] — conv/fc/pool layer descriptors with exact shape, MAC and
//!   parameter arithmetic.
//! - [`graph`] — a shape-tracking network builder (sequential spine with
//!   inception-style branch/concat and residual blocks) producing the
//!   per-layer workload stream the mapper consumes.
//! - [`models`] — ResNet18, InceptionV2(-S), MobileNet, SqueezeNet and
//!   VGG16 as evaluated in the paper, with parameter counts checked
//!   against Table II, plus the tiny served LeNet and the static
//!   input/classifier metadata the multi-model coordinator validates
//!   requests against.
//! - [`quant`] — model bit-width variants (fp32/int8/int4) and the
//!   accuracy table loaded from the Python training artifact.

pub mod graph;
pub mod layer;
pub mod models;
pub mod quant;

pub use graph::{Network, NetworkBuilder};
pub use layer::{Layer, LayerInstance, TensorShape};
pub use models::{build_model, Model, ALL_MODELS, SERVABLE_MODELS};
