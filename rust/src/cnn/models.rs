//! The paper's evaluation model zoo (Table II) plus the serving demo CNN.
//!
//! Five CNNs, each built for the dataset the paper pairs it with. The
//! definitions follow the standard architectures; parameter counts are
//! checked against Table II (tests assert within 10%; exact deltas are
//! recorded in EXPERIMENTS.md §Table II). Where the paper's count
//! evidently corresponds to the 1000-class ImageNet head (MobileNet,
//! SqueezeNet), we keep that head and note it.
//!
//! A sixth [`Model::LeNet`] variant names the tiny LeNet-style CNN the
//! serving path has always executed (python/compile/model.py's ARCH —
//! the only model with real AOT HLO artifacts). It is *not* a Table II
//! row: [`ALL_MODELS`] still enumerates exactly the paper's five, while
//! [`SERVABLE_MODELS`] adds LeNet for the multi-model coordinator.

use crate::cnn::graph::{Network, NetworkBuilder};
use crate::cnn::layer::TensorShape;
use crate::error::Result;

/// The evaluated models (Table II rows) plus the serving demo CNN.
///
/// `Ord` follows declaration order (= [`SERVABLE_MODELS`] order), so
/// sorted per-model reports are stable across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Model {
    /// The tiny served CNN (python/compile/model.py); not in Table II.
    #[default]
    LeNet,
    ResNet18,
    InceptionV2,
    MobileNet,
    SqueezeNet,
    Vgg16,
}

/// All Table II rows in paper order (LeNet is serving-only).
pub const ALL_MODELS: [Model; 5] = [
    Model::ResNet18,
    Model::InceptionV2,
    Model::MobileNet,
    Model::SqueezeNet,
    Model::Vgg16,
];

/// Every model the multi-model coordinator can serve: the demo LeNet
/// plus the five Table II CNNs.
pub const SERVABLE_MODELS: [Model; 6] = [
    Model::LeNet,
    Model::ResNet18,
    Model::InceptionV2,
    Model::MobileNet,
    Model::SqueezeNet,
    Model::Vgg16,
];

impl Model {
    pub fn name(&self) -> &'static str {
        match self {
            Model::LeNet => "lenet",
            Model::ResNet18 => "resnet18",
            Model::InceptionV2 => "inceptionv2",
            Model::MobileNet => "mobilenet",
            Model::SqueezeNet => "squeezenet",
            Model::Vgg16 => "vgg16",
        }
    }

    /// Dataset pairing from Table II (LeNet serves the synthetic
    /// 4-pattern dataset of python/compile/data.py).
    pub fn dataset(&self) -> &'static str {
        match self {
            Model::LeNet => "synthetic-4",
            Model::ResNet18 => "CIFAR100",
            Model::InceptionV2 => "SVHN",
            Model::MobileNet => "CIFAR10",
            Model::SqueezeNet => "STL-10",
            Model::Vgg16 => "Imagenette",
        }
    }

    /// Parameter count reported in Table II. LeNet is not a Table II
    /// row; its entry is the exact count of the built network (asserted
    /// by `lenet_metadata_matches_built_network`).
    pub fn paper_params(&self) -> u64 {
        match self {
            Model::LeNet => 1_828,
            Model::ResNet18 => 11_584_865,
            Model::InceptionV2 => 2_661_960,
            Model::MobileNet => 4_209_088,
            Model::SqueezeNet => 1_159_848,
            Model::Vgg16 => 134_268_738,
        }
    }

    /// Table II accuracies: (fp32, int8, int4) in percent. LeNet has no
    /// Table II row and reports zeros.
    pub fn paper_accuracy(&self) -> (f64, f64, f64) {
        match self {
            Model::LeNet => (0.0, 0.0, 0.0),
            Model::ResNet18 => (75.3, 74.2, 72.6),
            Model::InceptionV2 => (81.5, 80.8, 75.9),
            Model::MobileNet => (88.2, 87.5, 83.5),
            Model::SqueezeNet => (92.5, 90.3, 86.5),
            Model::Vgg16 => (98.96, 96.25, 93.7),
        }
    }

    /// Input spatial size (square side) of the model's serving tensor.
    pub fn input_size(&self) -> usize {
        match self {
            Model::LeNet => 12,
            Model::ResNet18 | Model::InceptionV2 | Model::MobileNet => 32,
            Model::SqueezeNet => 96,
            Model::Vgg16 => 224,
        }
    }

    /// Input channel count of the model's serving tensor.
    pub fn input_channels(&self) -> usize {
        match self {
            Model::LeNet => 1,
            _ => 3,
        }
    }

    /// Classifier width (logits per inference).
    pub fn classes(&self) -> usize {
        match self {
            Model::LeNet => 4,
            Model::ResNet18 => 100,
            Model::InceptionV2 | Model::Vgg16 => 10,
            Model::MobileNet | Model::SqueezeNet => 1000,
        }
    }

    /// Flattened per-image element count (`size² × channels`, NHWC) a
    /// serving request for this model must carry.
    pub fn input_elems(&self) -> usize {
        self.input_size() * self.input_size() * self.input_channels()
    }

    pub fn from_name(name: &str) -> Option<Model> {
        SERVABLE_MODELS.iter().copied().find(|m| m.name() == name)
    }
}

/// Build a model's network graph.
pub fn build_model(model: Model) -> Result<Network> {
    match model {
        Model::LeNet => lenet(4),
        Model::ResNet18 => resnet18(100),
        Model::InceptionV2 => inception_v2s(10),
        Model::MobileNet => mobilenet(1000),
        Model::SqueezeNet => squeezenet(1000),
        Model::Vgg16 => vgg16(10),
    }
}

/// The tiny LeNet-style served CNN — must match python/compile/model.py's
/// ARCH (the architecture behind the `cnn_*` AOT HLO artifacts).
pub fn lenet(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("lenet", TensorShape::new(12, 12, 1));
    b.conv(3, 3, 8, 1, 1)?
        .pool(2, 2)?
        .conv(3, 3, 16, 1, 1)?
        .pool(2, 2)?
        .fc(classes)?;
    Ok(b.build())
}

/// CIFAR-style ResNet-18: 3×3 stem, four stages of two basic blocks.
pub fn resnet18(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("resnet18", TensorShape::new(32, 32, 3));
    b.conv(3, 3, 64, 1, 1)?;
    b.basic_block(64, 1)?.basic_block(64, 1)?;
    b.basic_block(128, 2)?.basic_block(128, 1)?;
    b.basic_block(256, 2)?.basic_block(256, 1)?;
    b.basic_block(512, 2)?.basic_block(512, 1)?;
    b.global_pool()?.fc(classes)?;
    Ok(b.build())
}

/// Reduced InceptionV2 for 32×32 inputs (the paper's SVHN variant is a
/// ~2.66M-parameter reduction of InceptionV2; channel widths here are
/// chosen to land on that budget with the canonical module mix).
pub fn inception_v2s(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("inceptionv2", TensorShape::new(32, 32, 3));
    b.conv(3, 3, 32, 1, 1)?.conv(3, 3, 64, 2, 1)?; // 16×16×64
    // Inception-A ×2.
    let module_a = |cin_proj: usize| {
        vec![
            vec![(1, 1, 32, 1, 0)],
            vec![(1, 1, 24, 1, 0), (3, 3, 48, 1, 1)],
            vec![(1, 1, 8, 1, 0), (3, 3, 16, 1, 1), (3, 3, 16, 1, 1)],
            vec![(1, 1, cin_proj, 1, 0)],
        ]
    };
    b.inception(&module_a(16))?; // → 112 ch
    b.inception(&module_a(16))?;
    b.conv(3, 3, 160, 2, 1)?; // reduction → 8×8×160
    // Inception-B ×2.
    let module_b = || {
        vec![
            vec![(1, 1, 64, 1, 0)],
            vec![(1, 1, 48, 1, 0), (3, 3, 96, 1, 1)],
            vec![(1, 1, 16, 1, 0), (3, 3, 32, 1, 1), (3, 3, 32, 1, 1)],
            vec![(1, 1, 32, 1, 0)],
        ]
    };
    b.inception(&module_b())?; // → 224 ch
    b.inception(&module_b())?;
    b.conv(3, 3, 320, 2, 1)?; // reduction → 4×4×320
    // Inception-C.
    b.inception(&[
        vec![(1, 1, 128, 1, 0)],
        vec![(1, 1, 96, 1, 0), (3, 3, 160, 1, 1)],
        vec![(1, 1, 32, 1, 0), (3, 3, 64, 1, 1), (3, 3, 64, 1, 1)],
        vec![(1, 1, 64, 1, 0)],
    ])?; // → 416 ch
    b.conv(3, 3, 336, 1, 1)?;
    b.global_pool()?.fc(classes)?;
    Ok(b.build())
}

/// MobileNet v1 (width 1.0) with a CIFAR-friendly stride-1 stem. The
/// classifier keeps the 1000-way head Table II's count corresponds to.
pub fn mobilenet(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("mobilenet", TensorShape::new(32, 32, 3));
    b.conv(3, 3, 32, 1, 1)?;
    let blocks = [
        (64usize, 1usize),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for &(cout, stride) in &blocks {
        b.dwconv(3, stride)?.pwconv(cout)?;
    }
    b.global_pool()?.fc(classes)?;
    Ok(b.build())
}

/// SqueezeNet 1.0 (fire modules); final 1×1 conv classifier head.
pub fn squeezenet(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("squeezenet", TensorShape::new(96, 96, 3));
    b.conv(7, 7, 96, 2, 3)?.pool(3, 2)?;
    fire(&mut b, 16, 64, 64)?;
    fire(&mut b, 16, 64, 64)?;
    fire(&mut b, 32, 128, 128)?;
    b.pool(3, 2)?;
    fire(&mut b, 32, 128, 128)?;
    fire(&mut b, 48, 192, 192)?;
    fire(&mut b, 48, 192, 192)?;
    fire(&mut b, 64, 256, 256)?;
    b.pool(3, 2)?;
    fire(&mut b, 64, 256, 256)?;
    b.pwconv(classes)?; // conv10
    b.global_pool()?;
    Ok(b.build())
}

/// Fire module: 1×1 squeeze then concat(1×1 expand, 3×3 expand).
fn fire(b: &mut NetworkBuilder, squeeze: usize, e1: usize, e3: usize) -> Result<()> {
    b.pwconv(squeeze)?;
    b.inception(&[vec![(1, 1, e1, 1, 0)], vec![(3, 3, e3, 1, 1)]])?;
    Ok(())
}

/// VGG-16 for 224×224 inputs with a 10-way (Imagenette) classifier.
pub fn vgg16(classes: usize) -> Result<Network> {
    let mut b = NetworkBuilder::new("vgg16", TensorShape::new(224, 224, 3));
    for &(reps, c) in &[(2usize, 64usize), (2, 128), (3, 256), (3, 512), (3, 512)] {
        for _ in 0..reps {
            b.conv(3, 3, c, 1, 1)?;
        }
        b.pool(2, 2)?;
    }
    b.fc(4096)?.fc(4096)?.fc(classes)?;
    Ok(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_params(model: Model, tolerance: f64) {
        let net = build_model(model).unwrap();
        let got = net.params() as f64;
        let want = model.paper_params() as f64;
        let rel = (got - want).abs() / want;
        assert!(
            rel < tolerance,
            "{}: {} params vs paper {} ({:+.1}%)",
            model.name(),
            got,
            want,
            100.0 * (got - want) / want
        );
    }

    #[test]
    fn resnet18_params_near_paper() {
        check_params(Model::ResNet18, 0.10);
    }

    #[test]
    fn inceptionv2_params_near_paper() {
        check_params(Model::InceptionV2, 0.10);
    }

    #[test]
    fn mobilenet_params_near_paper() {
        check_params(Model::MobileNet, 0.10);
    }

    #[test]
    fn squeezenet_params_near_paper() {
        check_params(Model::SqueezeNet, 0.10);
    }

    #[test]
    fn vgg16_params_near_paper() {
        check_params(Model::Vgg16, 0.01);
    }

    #[test]
    fn vgg16_is_the_giant() {
        let sizes: Vec<u64> = ALL_MODELS
            .iter()
            .map(|&m| build_model(m).unwrap().params())
            .collect();
        assert!(sizes[4] > 10 * sizes.iter().take(4).max().unwrap());
    }

    #[test]
    fn one_by_one_heavy_models() {
        // The paper's §V.C anomaly: InceptionV2 and MobileNet carry a
        // large share of accumulation-free 1×1 MACs; ResNet18 does not.
        let frac = |m: Model| {
            let n = build_model(m).unwrap();
            n.one_by_one_macs() as f64 / n.macs() as f64
        };
        assert!(frac(Model::ResNet18) < 0.10, "resnet {}", frac(Model::ResNet18));
        assert!(frac(Model::InceptionV2) > 0.10);
        assert!(frac(Model::MobileNet) > 0.60);
        assert!(frac(Model::Vgg16) < 0.01);
    }

    #[test]
    fn mac_counts_sane() {
        // VGG16@224 ≈ 15.3 GMACs (the classic figure).
        let vgg = build_model(Model::Vgg16).unwrap();
        let g = vgg.macs() as f64 / 1e9;
        assert!((15.0..16.0).contains(&g), "VGG16 GMACs = {g}");
        // CIFAR ResNet18 ≈ 0.55 GMACs.
        let rn = build_model(Model::ResNet18).unwrap();
        let g = rn.macs() as f64 / 1e9;
        assert!((0.4..0.7).contains(&g), "ResNet18 GMACs = {g}");
    }

    #[test]
    fn model_name_roundtrip() {
        for m in SERVABLE_MODELS {
            assert_eq!(Model::from_name(m.name()), Some(m));
        }
        assert_eq!(Model::from_name("nope"), None);
    }

    #[test]
    fn serving_metadata_matches_built_networks() {
        // The coordinator validates request images and synthesizes
        // executor programs from this static metadata — it must agree
        // exactly with the graphs the analyzer maps.
        for m in SERVABLE_MODELS {
            let net = build_model(m).unwrap();
            assert_eq!(net.input.elems() as usize, m.input_elems(), "{}", m.name());
            assert_eq!(net.output.elems() as usize, m.classes(), "{}", m.name());
        }
    }

    #[test]
    fn lenet_metadata_matches_built_network() {
        let net = build_model(Model::LeNet).unwrap();
        assert_eq!(net.params(), Model::LeNet.paper_params());
        assert_eq!(Model::LeNet.input_elems(), 144);
        assert_eq!(Model::LeNet.classes(), 4);
        // LeNet is serving-only: not a Table II row.
        assert!(!ALL_MODELS.contains(&Model::LeNet));
        assert!(SERVABLE_MODELS.contains(&Model::LeNet));
    }
}
