//! Model quantization variants and the measured accuracy artifact.
//!
//! The paper evaluates 4-bit (the cell-native width) and 8-bit variants
//! of each model (Fig. 9). Our functional accuracy evidence comes from
//! the Python layer: `make artifacts` trains a small CNN and sweeps
//! fp32/int8/int4 through the photonic pipeline, writing
//! `artifacts/table2_accuracy.json`, which this module loads.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// A bit-width variant of a model (paper Fig. 9's "4b"/"8b").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BitVariant {
    Int4,
    Int8,
}

impl BitVariant {
    pub fn bits(&self) -> u32 {
        match self {
            BitVariant::Int4 => 4,
            BitVariant::Int8 => 8,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BitVariant::Int4 => "4b",
            BitVariant::Int8 => "8b",
        }
    }
}

pub const BIT_VARIANTS: [BitVariant; 2] = [BitVariant::Int4, BitVariant::Int8];

/// Measured quantization sweep from the Python artifact (our Table II
/// substitution: a small CNN trained on the synthetic dataset, executed
/// through the photonic pipeline with the 5-bit ADC model).
#[derive(Debug, Clone, PartialEq)]
pub struct MeasuredAccuracy {
    pub parameter_count: u64,
    pub fp32: f64,
    pub int8: f64,
    pub int4: f64,
}

impl MeasuredAccuracy {
    /// Load from `artifacts/table2_accuracy.json`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)?;
        let f = |k: &str| {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Json(format!("missing field {k}")))
        };
        Ok(Self {
            parameter_count: f("parameter_count")? as u64,
            fp32: f("fp32")?,
            int8: f("int8")?,
            int4: f("int4")?,
        })
    }

    /// The Table II shape: fp32 ≥ int8 ≥ int4.
    pub fn is_monotone(&self) -> bool {
        self.fp32 >= self.int8 - 1e-9 && self.int8 >= self.int4 - 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants() {
        assert_eq!(BitVariant::Int4.bits(), 4);
        assert_eq!(BitVariant::Int8.bits(), 8);
        assert_eq!(BitVariant::Int4.label(), "4b");
    }

    #[test]
    fn load_accuracy_artifact() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/table2_accuracy.json");
        if !path.exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let acc = MeasuredAccuracy::load(&path).unwrap();
        assert!(acc.is_monotone(), "fp32 ≥ int8 ≥ int4 must hold: {acc:?}");
        assert!(acc.fp32 > 0.9, "trained model should classify well");
        assert!(acc.int4 > 0.5, "int4 must stay usable");
    }

    #[test]
    fn malformed_artifact_rejected() {
        let dir = std::env::temp_dir().join("opima_quant_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, "{\"fp32\": 1.0}").unwrap();
        assert!(MeasuredAccuracy::load(&p).is_err());
    }
}
