//! Central configuration for the OPIMA architecture.
//!
//! Geometry defaults follow the paper's evaluation configuration (§V):
//! 4 banks, 64×64 subarrays per bank, 256 MDLs per subarray, 256×512 OPCM
//! elements per subarray, 4 bits/cell, 16 subarray groups. Device loss and
//! energy parameters are the paper's Table I. Everything is `serde`-
//! (de)serializable so experiments can be driven from TOML files.



use crate::error::{Error, Result};
use crate::phys::params::{EnergyParams, LossParams};
use crate::util::units::{Millis, Milliwatts, Nanos};

/// Memory/PIM geometry (paper §V first paragraph).
#[derive(Debug, Clone, PartialEq)]

pub struct Geometry {
    /// Number of banks. Bounded by the MDM degree (4 modes → 4 banks,
    /// paper §IV.C.1).
    pub banks: usize,
    /// Subarray grid: rows of subarrays per bank.
    pub subarray_rows: usize,
    /// Subarray grid: columns of subarrays per bank.
    pub subarray_cols: usize,
    /// OPCM cell rows per subarray.
    pub rows_per_subarray: usize,
    /// OPCM cell columns per subarray (= WDM degree = MDL count; the paper
    /// gives 256 MDLs per subarray, "reflecting the column number").
    pub cols_per_subarray: usize,
    /// Bits stored per OPCM multi-level cell (16 transmission levels → 4).
    pub bits_per_cell: u32,
    /// Number of subarray groups for PIM (16 chosen in Fig. 7).
    pub subarray_groups: usize,
    /// MDM degree: concurrently excited waveguide modes (max 4, §IV.C.1).
    pub mdm_degree: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        Self {
            banks: 4,
            subarray_rows: 64,
            subarray_cols: 64,
            rows_per_subarray: 512,
            cols_per_subarray: 256,
            bits_per_cell: 4,
            subarray_groups: 16,
            mdm_degree: 4,
        }
    }
}

impl Geometry {
    /// Total OPCM cells in the memory.
    pub fn total_cells(&self) -> u64 {
        self.total_subarrays() as u64 * self.cells_per_subarray() as u64
    }

    pub fn subarrays_per_bank(&self) -> usize {
        self.subarray_rows * self.subarray_cols
    }

    /// Total subarrays across all banks — the one capacity figure the
    /// mapper occupancy check, the FC placement validator and the
    /// router's co-residency accounting all share.
    pub fn total_subarrays(&self) -> usize {
        self.banks * self.subarrays_per_bank()
    }

    pub fn cells_per_subarray(&self) -> usize {
        self.rows_per_subarray * self.cols_per_subarray
    }

    /// Memory capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.total_cells() * self.bits_per_cell as u64 / 8
    }

    /// Subarray rows per group (64 subarray rows / 16 groups = 4).
    pub fn subarray_rows_per_group(&self) -> usize {
        self.subarray_rows / self.subarray_groups
    }

    /// Peak MAC lanes per cycle: per bank, one subarray row per group is
    /// PIM-active; each active subarray contributes `cols_per_subarray`
    /// wavelength lanes (paper §IV.C.2).
    pub fn peak_mac_lanes(&self) -> u64 {
        (self.banks * self.subarray_groups * self.subarray_cols * self.cols_per_subarray)
            as u64
    }

    pub fn validate(&self) -> Result<()> {
        if self.banks == 0 || self.banks > self.mdm_degree {
            return Err(Error::Config(format!(
                "banks ({}) must be in 1..=mdm_degree ({}): each bank needs a \
                 dedicated waveguide mode (paper §IV.C.1)",
                self.banks, self.mdm_degree
            )));
        }
        if self.mdm_degree == 0 || self.mdm_degree > 4 {
            return Err(Error::Config(
                "mdm_degree must be 1..=4: >4 modes need impractically wide \
                 waveguides and suffer intermodal crosstalk (paper §IV.C.1)"
                    .into(),
            ));
        }
        if self.subarray_groups == 0 || self.subarray_groups > self.subarray_rows {
            return Err(Error::Config(format!(
                "subarray_groups ({}) must be in 1..=subarray_rows ({})",
                self.subarray_groups, self.subarray_rows
            )));
        }
        if self.subarray_rows % self.subarray_groups != 0 {
            return Err(Error::Config(format!(
                "subarray_rows ({}) must be divisible by subarray_groups ({})",
                self.subarray_rows, self.subarray_groups
            )));
        }
        if self.bits_per_cell == 0 || self.bits_per_cell > 8 {
            return Err(Error::Config(format!(
                "bits_per_cell ({}) out of the physically plausible 1..=8",
                self.bits_per_cell
            )));
        }
        if self.rows_per_subarray == 0
            || self.cols_per_subarray == 0
            || self.subarray_rows == 0
            || self.subarray_cols == 0
        {
            return Err(Error::Config("geometry dimensions must be nonzero".into()));
        }
        Ok(())
    }
}

/// Timing parameters (clock + OPCM access latencies).
#[derive(Debug, Clone, PartialEq)]

pub struct Timing {
    /// Photonic MAC/memory clock in GHz (MDL modulation rate; COMET-class
    /// OPCM memories run a 5 GHz optical clock).
    pub clock_ghz: f64,
    /// OPCM read latency (laser settle + propagation + PD/ADC).
    pub read_ns: Nanos,
    /// OPCM MLC write latency. Multi-level programming is an
    /// iterative pulse-and-verify train (partial crystallization must hit
    /// one of 16 transmission targets), putting MLC writes in the µs
    /// class — this is what makes writeback dominate CNN inference
    /// latency in the paper's Fig. 9.
    pub write_ns: Nanos,
    /// Aggregation-unit pipeline latency (PD + ADC + shift-add).
    pub aggregation_ns: Nanos,
    /// E-O-E controller round-trip for writeback staging, per tile.
    pub writeback_overhead_ns: Nanos,
}

impl Default for Timing {
    fn default() -> Self {
        Self {
            clock_ghz: 5.0,
            read_ns: Nanos::new(0.8),
            write_ns: Nanos::new(1000.0),
            aggregation_ns: Nanos::new(1.2),
            writeback_overhead_ns: Nanos::new(4.0),
        }
    }
}

impl Timing {
    pub fn cycle_ns(&self) -> Nanos {
        Nanos::new(1.0 / self.clock_ghz)
    }
}

/// Power-model parameters not covered by Table I.
#[derive(Debug, Clone, PartialEq)]

pub struct PowerModel {
    /// Wall-plug power per active microdisk laser.
    pub mdl_wallplug_mw: Milliwatts,
    /// External (main-memory) laser wall-plug power, in W.
    pub external_laser_w: f64,
    /// Per-SOA bias power.
    pub soa_bias_mw: Milliwatts,
    /// EO MR tuning power per active ring (free-carrier injection).
    pub mr_tuning_mw: Milliwatts,
    /// VCSEL regeneration power per active channel.
    pub vcsel_mw: Milliwatts,
    /// Aggregation-unit SRAM + shift-add logic per bank, in W.
    pub aggregation_logic_w: f64,
    /// E-O-E controller (serdes, caching, command decode), in W.
    pub controller_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            mdl_wallplug_mw: Milliwatts::new(0.6),
            external_laser_w: 4.0,
            soa_bias_mw: Milliwatts::new(12.0),
            mr_tuning_mw: Milliwatts::new(0.04),
            vcsel_mw: Milliwatts::new(2.5),
            aggregation_logic_w: 0.45,
            controller_w: 5.2,
        }
    }
}

/// PIM datapath parameters.
#[derive(Debug, Clone, PartialEq)]

pub struct PimParams {
    /// ADC resolution at the aggregation unit (5 bits, paper §IV.C.4).
    pub adc_bits: u32,
    /// Products optically summed per readout (in-waveguide accumulation
    /// group; 2 in the paper's worked example).
    pub optical_accum: usize,
    /// Clean λ lanes per bank for accumulation-free (1×1-kernel) layers:
    /// lone products cannot share a readout bus with anything (§V.C), so
    /// parallelism collapses to a couple of guarded lanes per bank.
    pub one_by_one_lanes_per_bank: usize,
    /// Concurrent MLC write lanes for activation writeback across the
    /// whole memory (optical write power budget bounds how many µs-class
    /// program-and-verify trains can run at once).
    pub writeback_lanes: usize,
}

impl Default for PimParams {
    fn default() -> Self {
        Self {
            adc_bits: 5,
            optical_accum: 2,
            one_by_one_lanes_per_bank: 2,
            writeback_lanes: 512,
        }
    }
}

/// Batch-pipelining parameters for the simulation timeline
/// ([`crate::analyzer::timeline`]).
///
/// The paper evaluates single-inference latency; these knobs govern how
/// a *batch* of images pipelines through the layer stages, and default
/// to what the paper's hardware actually provides — they widen the
/// model without repricing the single-image reproduction (at batch 1
/// the timeline collapses to the analytical layer sum regardless of
/// these values).
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineParams {
    /// Concurrent whole-layer OPCM writeback trains. The optical write
    /// power budget already bounds the *lanes* inside one train
    /// ([`PimParams::writeback_lanes`]); this bounds how many layers'
    /// trains can be in flight at once. Paper-faithful default: 1 — the
    /// lane budget is a single shared channel.
    pub writeback_channels: usize,
    /// Aggregation-unit pipelines usable concurrently by in-flight
    /// layers. Default: 4, one per bank (each bank owns its PD/ADC/
    /// shift-add stack, see [`PowerModel::aggregation_logic_w`]).
    pub aggregation_units: usize,
    /// Upper bound on images concurrently in flight in the layer
    /// pipeline (aggregation-SRAM staging depth). 0 means no explicit
    /// bound — in-flight depth is limited only by the resource pools.
    pub max_in_flight_images: usize,
    /// Whether co-resident batches on one simulated instance contend
    /// for the shared aggregation/writeback pools (the global
    /// contention timeline, honest) or only for subarray occupancy
    /// (the pre-contention optimistic model). Default: true.
    pub cross_batch_contention: bool,
}

impl Default for PipelineParams {
    fn default() -> Self {
        Self {
            writeback_channels: 1,
            aggregation_units: 4,
            max_in_flight_images: 0,
            cross_batch_contention: true,
        }
    }
}

/// How the simulation timeline prices the writeback stage.
///
/// `Flat` is the historical model: each layer's whole
/// `LayerCost::writeback_ns` scalar occupies one writeback-channel slot.
/// The command-level models decompose every writeback into
/// route/write/settle command sequences against per-bank busy windows
/// and GST row-switch penalties ([`crate::memory::writeback`]); they
/// recover the flat figure bit-exactly at the uncontended batch-1 limit
/// and diverge from it only under contention (DESIGN.md §2.7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WritebackModel {
    /// Flat per-layer scalar through the channel slot pool — the
    /// default, keeping every existing scalar bit-identical.
    #[default]
    Flat,
    /// Command-level reference controller: every writeback's command
    /// sequence strictly serialized behind the previous one
    /// ([`crate::memory::writeback::NaiveWritebackController`]).
    Naive,
    /// Command-level scheduled controller: bank-parallel,
    /// burst-coalescing, row-switch-aware
    /// ([`crate::memory::writeback::ScheduledWritebackController`]).
    Scheduled,
}

impl WritebackModel {
    /// Every model, in reporting order (flat, naive, scheduled).
    pub const ALL: [WritebackModel; 3] = [
        WritebackModel::Flat,
        WritebackModel::Naive,
        WritebackModel::Scheduled,
    ];

    /// The TOML spelling of this variant.
    pub fn as_str(self) -> &'static str {
        match self {
            WritebackModel::Flat => "flat",
            WritebackModel::Naive => "naive",
            WritebackModel::Scheduled => "scheduled",
        }
    }

    /// Parse the TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "flat" => Ok(WritebackModel::Flat),
            "naive" => Ok(WritebackModel::Naive),
            "scheduled" => Ok(WritebackModel::Scheduled),
            other => Err(Error::Config(format!(
                "memory.writeback_model must be \"flat\", \"naive\" or \
                 \"scheduled\", got \"{other}\""
            ))),
        }
    }
}

impl std::fmt::Display for WritebackModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Memory-controller modeling knobs (TOML `[memory]`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryParams {
    /// Which writeback pricing model the timeline uses.
    pub writeback_model: WritebackModel,
}

/// The deterministic fault-injection plane and its chaos-facing serving
/// defenses (TOML `[fault]`, DESIGN.md §3.3).
///
/// Probabilities are per-decision Bernoulli rates in `[0, 1]`; every
/// injection site derives its schedule from `seed` plus a site salt
/// ([`crate::util::fault::FaultPlane`]), so a failing chaos run replays
/// from its seed. `armed = false` (the default) turns every injection
/// probe into a single branch and leaves serving behavior bit-identical
/// to a build without the plane.
///
/// The token-bucket limiter knobs (`conn_rate_rps`, `conn_burst`) are
/// *defenses*, not injections: they stay active regardless of `armed`
/// so one adversarial connection cannot starve the rest in production
/// either. `conn_rate_rps = 0` disables the limiter.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultParams {
    /// Master switch for fault *injection* (never for the limiter).
    pub armed: bool,
    /// Base seed every injection site's schedule derives from.
    pub seed: u64,
    /// P(worker panics mid-batch) per executed batch.
    pub worker_panic: f64,
    /// P(worker stalls before executing) per batch.
    pub worker_stall: f64,
    /// Injected stall duration.
    pub stall_ms: Millis,
    /// P(executor reports an injected transient error) per batch — the
    /// non-panic failure path.
    pub exec_transient: f64,
    /// P(a reply frame goes out as a delayed two-part short write) per
    /// frame.
    pub writer_delay: f64,
    /// Gap between the two halves of an injected short write.
    pub writer_delay_ms: Millis,
    /// Per-connection token-bucket refill rate (submits/s); 0 = off.
    pub conn_rate_rps: f64,
    /// Token-bucket capacity (max burst admitted at line rate).
    pub conn_burst: usize,
}

impl Default for FaultParams {
    fn default() -> Self {
        Self {
            armed: false,
            seed: 0,
            worker_panic: 0.0,
            worker_stall: 0.0,
            stall_ms: Millis::new(2.0),
            exec_transient: 0.0,
            writer_delay: 0.0,
            writer_delay_ms: Millis::new(1.0),
            conn_rate_rps: 0.0,
            conn_burst: 32,
        }
    }
}

impl FaultParams {
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("worker_panic", self.worker_panic),
            ("worker_stall", self.worker_stall),
            ("exec_transient", self.exec_transient),
            ("writer_delay", self.writer_delay),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "fault.{name} ({p}) must be a probability in [0, 1]"
                )));
            }
        }
        if !self.stall_ms.is_finite()
            || self.stall_ms < Millis::ZERO
            || !self.writer_delay_ms.is_finite()
            || self.writer_delay_ms < Millis::ZERO
        {
            return Err(Error::Config(
                "fault.stall_ms and fault.writer_delay_ms must be finite and \
                 non-negative"
                    .into(),
            ));
        }
        if !self.conn_rate_rps.is_finite() || self.conn_rate_rps < 0.0 {
            return Err(Error::Config(
                "fault.conn_rate_rps must be finite and non-negative (0 = limiter off)".into(),
            ));
        }
        if self.conn_rate_rps > 0.0 && self.conn_burst == 0 {
            return Err(Error::Config(
                "fault.conn_burst must be ≥ 1 when the rate limiter is on".into(),
            ));
        }
        Ok(())
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]

pub struct OpimaConfig {
    pub geometry: Geometry,
    pub timing: Timing,
    pub power: PowerModel,
    pub pim: PimParams,
    pub pipeline: PipelineParams,
    pub memory: MemoryParams,
    pub fault: FaultParams,
    pub losses: LossParams,
    pub energy: EnergyParams,
}

impl OpimaConfig {
    /// The paper's evaluation configuration.
    pub fn paper() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<()> {
        self.geometry.validate()?;
        if self.timing.clock_ghz <= 0.0 {
            return Err(Error::Config("clock_ghz must be positive".into()));
        }
        if self.timing.write_ns < self.timing.read_ns {
            return Err(Error::Config(
                "OPCM writes are multi-pulse phase transitions and cannot be \
                 faster than reads"
                    .into(),
            ));
        }
        if self.pim.adc_bits == 0 || self.pim.adc_bits > 16 {
            return Err(Error::Config("adc_bits must be 1..=16".into()));
        }
        if self.pim.optical_accum == 0 {
            return Err(Error::Config("optical_accum must be positive".into()));
        }
        if self.pim.one_by_one_lanes_per_bank == 0 || self.pim.writeback_lanes == 0 {
            return Err(Error::Config(
                "one_by_one_lanes_per_bank and writeback_lanes must be positive".into(),
            ));
        }
        if self.pipeline.writeback_channels == 0 || self.pipeline.aggregation_units == 0 {
            return Err(Error::Config(
                "pipeline.writeback_channels and pipeline.aggregation_units must be \
                 positive (max_in_flight_images may be 0 = unbounded)"
                    .into(),
            ));
        }
        self.fault.validate()?;
        self.losses.validate()?;
        self.energy.validate()?;
        Ok(())
    }

    /// Load from a TOML(-subset) file; unspecified keys keep paper defaults.
    pub fn from_toml_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML(-subset) text over paper defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = crate::util::tomlite::Doc::parse(text)?;
        let mut cfg = Self::default();
        {
            let g = &mut cfg.geometry;
            g.banks = doc.usize_or("geometry.banks", g.banks);
            g.subarray_rows = doc.usize_or("geometry.subarray_rows", g.subarray_rows);
            g.subarray_cols = doc.usize_or("geometry.subarray_cols", g.subarray_cols);
            g.rows_per_subarray = doc.usize_or("geometry.rows_per_subarray", g.rows_per_subarray);
            g.cols_per_subarray = doc.usize_or("geometry.cols_per_subarray", g.cols_per_subarray);
            g.bits_per_cell = doc.usize_or("geometry.bits_per_cell", g.bits_per_cell as usize) as u32;
            g.subarray_groups = doc.usize_or("geometry.subarray_groups", g.subarray_groups);
            g.mdm_degree = doc.usize_or("geometry.mdm_degree", g.mdm_degree);
        }
        {
            let t = &mut cfg.timing;
            t.clock_ghz = doc.f64_or("timing.clock_ghz", t.clock_ghz);
            t.read_ns = Nanos::new(doc.f64_or("timing.read_ns", t.read_ns.raw()));
            t.write_ns = Nanos::new(doc.f64_or("timing.write_ns", t.write_ns.raw()));
            t.aggregation_ns =
                Nanos::new(doc.f64_or("timing.aggregation_ns", t.aggregation_ns.raw()));
            t.writeback_overhead_ns = Nanos::new(
                doc.f64_or("timing.writeback_overhead_ns", t.writeback_overhead_ns.raw()),
            );
        }
        {
            let p = &mut cfg.power;
            p.mdl_wallplug_mw =
                Milliwatts::new(doc.f64_or("power.mdl_wallplug_mw", p.mdl_wallplug_mw.raw()));
            p.external_laser_w = doc.f64_or("power.external_laser_w", p.external_laser_w);
            p.soa_bias_mw = Milliwatts::new(doc.f64_or("power.soa_bias_mw", p.soa_bias_mw.raw()));
            p.mr_tuning_mw =
                Milliwatts::new(doc.f64_or("power.mr_tuning_mw", p.mr_tuning_mw.raw()));
            p.vcsel_mw = Milliwatts::new(doc.f64_or("power.vcsel_mw", p.vcsel_mw.raw()));
            p.aggregation_logic_w = doc.f64_or("power.aggregation_logic_w", p.aggregation_logic_w);
            p.controller_w = doc.f64_or("power.controller_w", p.controller_w);
        }
        {
            let p = &mut cfg.pim;
            p.adc_bits = doc.usize_or("pim.adc_bits", p.adc_bits as usize) as u32;
            p.optical_accum = doc.usize_or("pim.optical_accum", p.optical_accum);
            p.one_by_one_lanes_per_bank =
                doc.usize_or("pim.one_by_one_lanes_per_bank", p.one_by_one_lanes_per_bank);
            p.writeback_lanes = doc.usize_or("pim.writeback_lanes", p.writeback_lanes);
        }
        {
            let p = &mut cfg.pipeline;
            p.writeback_channels =
                doc.usize_or("pipeline.writeback_channels", p.writeback_channels);
            p.aggregation_units =
                doc.usize_or("pipeline.aggregation_units", p.aggregation_units);
            p.max_in_flight_images =
                doc.usize_or("pipeline.max_in_flight_images", p.max_in_flight_images);
            p.cross_batch_contention = doc
                .get("pipeline.cross_batch_contention")
                .and_then(|v| v.as_bool())
                .unwrap_or(p.cross_batch_contention);
        }
        {
            let m = &mut cfg.memory;
            if let Some(s) = doc.get("memory.writeback_model").and_then(|v| v.as_str()) {
                m.writeback_model = WritebackModel::parse(s)?;
            }
        }
        {
            let f = &mut cfg.fault;
            f.armed = doc
                .get("fault.armed")
                .and_then(|v| v.as_bool())
                .unwrap_or(f.armed);
            f.seed = doc.usize_or("fault.seed", f.seed as usize) as u64;
            f.worker_panic = doc.f64_or("fault.worker_panic", f.worker_panic);
            f.worker_stall = doc.f64_or("fault.worker_stall", f.worker_stall);
            f.stall_ms = Millis::new(doc.f64_or("fault.stall_ms", f.stall_ms.raw()));
            f.exec_transient = doc.f64_or("fault.exec_transient", f.exec_transient);
            f.writer_delay = doc.f64_or("fault.writer_delay", f.writer_delay);
            f.writer_delay_ms =
                Millis::new(doc.f64_or("fault.writer_delay_ms", f.writer_delay_ms.raw()));
            f.conn_rate_rps = doc.f64_or("fault.conn_rate_rps", f.conn_rate_rps);
            f.conn_burst = doc.usize_or("fault.conn_burst", f.conn_burst);
        }
        {
            let l = &mut cfg.losses;
            l.directional_coupler_db =
                doc.f64_or("losses.directional_coupler_db", l.directional_coupler_db);
            l.mr_drop_db = doc.f64_or("losses.mr_drop_db", l.mr_drop_db);
            l.mr_through_db = doc.f64_or("losses.mr_through_db", l.mr_through_db);
            l.propagation_db_per_cm =
                doc.f64_or("losses.propagation_db_per_cm", l.propagation_db_per_cm);
            l.bend_db_per_90 = doc.f64_or("losses.bend_db_per_90", l.bend_db_per_90);
            l.eo_mr_drop_db = doc.f64_or("losses.eo_mr_drop_db", l.eo_mr_drop_db);
            l.eo_mr_through_db = doc.f64_or("losses.eo_mr_through_db", l.eo_mr_through_db);
            l.soa_gain_db = doc.f64_or("losses.soa_gain_db", l.soa_gain_db);
            l.gst_switch_db = doc.f64_or("losses.gst_switch_db", l.gst_switch_db);
            l.mode_converter_db = doc.f64_or("losses.mode_converter_db", l.mode_converter_db);
            l.crossing_db = doc.f64_or("losses.crossing_db", l.crossing_db);
            l.crossing_crosstalk_db =
                doc.f64_or("losses.crossing_crosstalk_db", l.crossing_crosstalk_db);
        }
        {
            let e = &mut cfg.energy;
            e.opcm_read_pj = doc.f64_or("energy.opcm_read_pj", e.opcm_read_pj);
            e.opcm_write_pj = doc.f64_or("energy.opcm_write_pj", e.opcm_write_pj);
            e.epcm_write_nj = doc.f64_or("energy.epcm_write_nj", e.epcm_write_nj);
            e.dram_access_pj_per_bit =
                doc.f64_or("energy.dram_access_pj_per_bit", e.dram_access_pj_per_bit);
            e.adc_fj_per_step = doc.f64_or("energy.adc_fj_per_step", e.adc_fj_per_step);
            e.dac_pj_per_bit = doc.f64_or("energy.dac_pj_per_bit", e.dac_pj_per_bit);
            e.sram_pj_per_bit = doc.f64_or("energy.sram_pj_per_bit", e.sram_pj_per_bit);
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to TOML(-subset) text.
    pub fn to_toml(&self) -> String {
        use crate::util::tomlite::Value as V;
        use std::collections::BTreeMap;
        let mut sections: BTreeMap<String, BTreeMap<String, V>> = BTreeMap::new();
        let g = &self.geometry;
        sections.insert(
            "geometry".into(),
            BTreeMap::from([
                ("banks".into(), V::Int(g.banks as i64)),
                ("subarray_rows".into(), V::Int(g.subarray_rows as i64)),
                ("subarray_cols".into(), V::Int(g.subarray_cols as i64)),
                ("rows_per_subarray".into(), V::Int(g.rows_per_subarray as i64)),
                ("cols_per_subarray".into(), V::Int(g.cols_per_subarray as i64)),
                ("bits_per_cell".into(), V::Int(g.bits_per_cell as i64)),
                ("subarray_groups".into(), V::Int(g.subarray_groups as i64)),
                ("mdm_degree".into(), V::Int(g.mdm_degree as i64)),
            ]),
        );
        let t = &self.timing;
        sections.insert(
            "timing".into(),
            BTreeMap::from([
                ("clock_ghz".into(), V::Float(t.clock_ghz)),
                ("read_ns".into(), V::Float(t.read_ns.raw())),
                ("write_ns".into(), V::Float(t.write_ns.raw())),
                ("aggregation_ns".into(), V::Float(t.aggregation_ns.raw())),
                ("writeback_overhead_ns".into(), V::Float(t.writeback_overhead_ns.raw())),
            ]),
        );
        let p = &self.power;
        sections.insert(
            "power".into(),
            BTreeMap::from([
                ("mdl_wallplug_mw".into(), V::Float(p.mdl_wallplug_mw.raw())),
                ("external_laser_w".into(), V::Float(p.external_laser_w)),
                ("soa_bias_mw".into(), V::Float(p.soa_bias_mw.raw())),
                ("mr_tuning_mw".into(), V::Float(p.mr_tuning_mw.raw())),
                ("vcsel_mw".into(), V::Float(p.vcsel_mw.raw())),
                ("aggregation_logic_w".into(), V::Float(p.aggregation_logic_w)),
                ("controller_w".into(), V::Float(p.controller_w)),
            ]),
        );
        let pi = &self.pim;
        sections.insert(
            "pim".into(),
            BTreeMap::from([
                ("adc_bits".into(), V::Int(pi.adc_bits as i64)),
                ("optical_accum".into(), V::Int(pi.optical_accum as i64)),
                ("one_by_one_lanes_per_bank".into(), V::Int(pi.one_by_one_lanes_per_bank as i64)),
                ("writeback_lanes".into(), V::Int(pi.writeback_lanes as i64)),
            ]),
        );
        let pl = &self.pipeline;
        sections.insert(
            "pipeline".into(),
            BTreeMap::from([
                ("writeback_channels".into(), V::Int(pl.writeback_channels as i64)),
                ("aggregation_units".into(), V::Int(pl.aggregation_units as i64)),
                ("max_in_flight_images".into(), V::Int(pl.max_in_flight_images as i64)),
                ("cross_batch_contention".into(), V::Bool(pl.cross_batch_contention)),
            ]),
        );
        let m = &self.memory;
        sections.insert(
            "memory".into(),
            BTreeMap::from([(
                "writeback_model".into(),
                V::Str(m.writeback_model.as_str().into()),
            )]),
        );
        let f = &self.fault;
        sections.insert(
            "fault".into(),
            BTreeMap::from([
                ("armed".into(), V::Bool(f.armed)),
                ("seed".into(), V::Int(f.seed as i64)),
                ("worker_panic".into(), V::Float(f.worker_panic)),
                ("worker_stall".into(), V::Float(f.worker_stall)),
                ("stall_ms".into(), V::Float(f.stall_ms.raw())),
                ("exec_transient".into(), V::Float(f.exec_transient)),
                ("writer_delay".into(), V::Float(f.writer_delay)),
                ("writer_delay_ms".into(), V::Float(f.writer_delay_ms.raw())),
                ("conn_rate_rps".into(), V::Float(f.conn_rate_rps)),
                ("conn_burst".into(), V::Int(f.conn_burst as i64)),
            ]),
        );
        let l = &self.losses;
        sections.insert(
            "losses".into(),
            BTreeMap::from([
                ("directional_coupler_db".into(), V::Float(l.directional_coupler_db)),
                ("mr_drop_db".into(), V::Float(l.mr_drop_db)),
                ("mr_through_db".into(), V::Float(l.mr_through_db)),
                ("propagation_db_per_cm".into(), V::Float(l.propagation_db_per_cm)),
                ("bend_db_per_90".into(), V::Float(l.bend_db_per_90)),
                ("eo_mr_drop_db".into(), V::Float(l.eo_mr_drop_db)),
                ("eo_mr_through_db".into(), V::Float(l.eo_mr_through_db)),
                ("soa_gain_db".into(), V::Float(l.soa_gain_db)),
                ("gst_switch_db".into(), V::Float(l.gst_switch_db)),
                ("mode_converter_db".into(), V::Float(l.mode_converter_db)),
                ("crossing_db".into(), V::Float(l.crossing_db)),
                ("crossing_crosstalk_db".into(), V::Float(l.crossing_crosstalk_db)),
            ]),
        );
        let e = &self.energy;
        sections.insert(
            "energy".into(),
            BTreeMap::from([
                ("opcm_read_pj".into(), V::Float(e.opcm_read_pj)),
                ("opcm_write_pj".into(), V::Float(e.opcm_write_pj)),
                ("epcm_write_nj".into(), V::Float(e.epcm_write_nj)),
                ("dram_access_pj_per_bit".into(), V::Float(e.dram_access_pj_per_bit)),
                ("adc_fj_per_step".into(), V::Float(e.adc_fj_per_step)),
                ("dac_pj_per_bit".into(), V::Float(e.dac_pj_per_bit)),
                ("sram_pj_per_bit".into(), V::Float(e.sram_pj_per_bit)),
            ]),
        );
        crate::util::tomlite::to_string(&sections)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        OpimaConfig::paper().validate().unwrap();
    }

    #[test]
    fn paper_geometry_capacity() {
        let g = Geometry::default();
        // 4 banks × 4096 subarrays × 131072 cells × 4 bits = 1 GiB.
        assert_eq!(g.capacity_bytes(), 1 << 30);
        assert_eq!(g.subarrays_per_bank(), 4096);
        assert_eq!(g.subarray_rows_per_group(), 4);
    }

    #[test]
    fn invalid_geometries_rejected() {
        let mut g = Geometry {
            banks: 5,
            ..Default::default()
        };
        assert!(g.validate().is_err(), "banks > mdm_degree");
        g.banks = 4;
        g.subarray_groups = 60; // not a divisor of 64
        assert!(g.validate().is_err());
        g.subarray_groups = 16;
        g.bits_per_cell = 0;
        assert!(g.validate().is_err());
    }

    #[test]
    fn write_slower_than_read_enforced() {
        let mut c = OpimaConfig::paper();
        c.timing.write_ns = Nanos::new(0.1);
        assert!(c.validate().is_err());
    }

    #[test]
    fn pipeline_knobs_validated_and_parse() {
        let mut c = OpimaConfig::paper();
        c.pipeline.writeback_channels = 0;
        assert!(c.validate().is_err());
        c.pipeline.writeback_channels = 1;
        c.pipeline.aggregation_units = 0;
        assert!(c.validate().is_err());
        // max_in_flight_images = 0 is the "unbounded" sentinel, valid.
        c.pipeline.aggregation_units = 4;
        c.pipeline.max_in_flight_images = 0;
        c.validate().unwrap();
        let parsed = OpimaConfig::from_toml(
            "[pipeline]\nwriteback_channels = 2\nmax_in_flight_images = 3\n",
        )
        .unwrap();
        assert_eq!(parsed.pipeline.writeback_channels, 2);
        assert_eq!(parsed.pipeline.aggregation_units, 4, "default kept");
        assert_eq!(parsed.pipeline.max_in_flight_images, 3);
        assert!(parsed.pipeline.cross_batch_contention, "default kept");
        let parsed = OpimaConfig::from_toml(
            "[pipeline]\ncross_batch_contention = false\n",
        )
        .unwrap();
        assert!(!parsed.pipeline.cross_batch_contention);
    }

    #[test]
    fn writeback_model_knob_parses() {
        assert_eq!(
            OpimaConfig::paper().memory.writeback_model,
            WritebackModel::Flat,
            "default must stay flat so existing scalars are bit-identical"
        );
        for (text, want) in [
            ("flat", WritebackModel::Flat),
            ("naive", WritebackModel::Naive),
            ("scheduled", WritebackModel::Scheduled),
        ] {
            let toml = format!("[memory]\nwriteback_model = \"{text}\"\n");
            let parsed = OpimaConfig::from_toml(&toml).unwrap();
            assert_eq!(parsed.memory.writeback_model, want);
            assert_eq!(want.as_str(), text);
        }
        assert!(
            OpimaConfig::from_toml("[memory]\nwriteback_model = \"dram\"\n").is_err(),
            "unknown model names must be rejected, not defaulted"
        );
    }

    #[test]
    fn fault_knobs_parse_validate_and_stay_disarmed_by_default() {
        let cfg = OpimaConfig::paper();
        assert!(!cfg.fault.armed, "the paper config must not inject faults");
        assert_eq!(cfg.fault.conn_rate_rps, 0.0, "limiter off by default");
        let parsed = OpimaConfig::from_toml(
            "[fault]\narmed = true\nseed = 99\nworker_panic = 0.25\n\
             stall_ms = 7.5\nconn_rate_rps = 500.0\nconn_burst = 8\n",
        )
        .unwrap();
        assert!(parsed.fault.armed);
        assert_eq!(parsed.fault.seed, 99);
        assert_eq!(parsed.fault.worker_panic, 0.25);
        assert_eq!(parsed.fault.stall_ms, Millis::new(7.5));
        assert_eq!(parsed.fault.conn_rate_rps, 500.0);
        assert_eq!(parsed.fault.conn_burst, 8);
        assert_eq!(parsed.fault.worker_stall, 0.0, "default kept");
        assert!(
            OpimaConfig::from_toml("[fault]\nworker_panic = 1.5\n").is_err(),
            "out-of-range probabilities must be rejected"
        );
        assert!(
            OpimaConfig::from_toml("[fault]\nconn_rate_rps = 10.0\nconn_burst = 0\n").is_err(),
            "a rate-limited connection needs a non-empty bucket"
        );
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = OpimaConfig::paper();
        let text = cfg.to_toml();
        let back = OpimaConfig::from_toml(&text).unwrap();
        assert_eq!(cfg, back);
    }
}
