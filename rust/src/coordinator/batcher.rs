//! Dynamic batching: group same-`(model, variant)` requests up to the
//! artifact batch size, flushing on size or deadline (vLLM-router-style
//! policy, specialized to fixed-shape AOT artifacts).
//!
//! Batches are never formed across models or variants — a batch executes
//! one artifact, and an artifact is one `(model, variant)` pair. Queues
//! are created on demand as new pairs arrive (at most
//! `SERVABLE_MODELS × 3` of them) and deadline/drain flushes walk the
//! queues round-robin starting at a rotating cursor, so under sustained
//! multi-model load every model periodically gets the head-of-line slot
//! instead of the first-registered model always flushing first.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::cnn::models::Model;
use crate::coordinator::request::{InferenceRequest, Variant};

/// A flushed batch (one `(model, variant)`, ≤ `max_batch` requests).
#[derive(Debug)]
pub struct Batch {
    pub model: Model,
    pub variant: Variant,
    pub requests: Vec<InferenceRequest>,
    pub formed_at: Instant,
    /// Formation sequence number (0, 1, 2, … per batcher).
    pub seq: u64,
}

/// Size/deadline-triggered batcher with per-`(model, variant)` queues.
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    max_wait: Duration,
    /// Insertion-ordered queues, one per `(model, variant)` seen so far.
    queues: Vec<((Model, Variant), VecDeque<InferenceRequest>)>,
    /// Round-robin cursor: where the next deadline/drain sweep starts.
    rr: usize,
    /// Batches formed so far (the next batch's sequence number).
    formed: u64,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self {
            max_batch,
            max_wait,
            queues: Vec::new(),
            rr: 0,
            formed: 0,
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Enqueue a request; returns a batch if the size trigger fired.
    pub fn push(&mut self, req: InferenceRequest) -> Option<Batch> {
        let key = (req.model, req.variant);
        let q = self.queue_mut(key);
        q.push_back(req);
        if q.len() >= self.max_batch {
            return self.take(key);
        }
        None
    }

    /// Flush every queue whose oldest request has exceeded the deadline,
    /// sweeping round-robin from the rotating cursor.
    ///
    /// Early-returns when nothing is pending: the batcher thread calls
    /// this on every timer tick, so the idle path must do no queue scan
    /// and no key-vec building (the cursor also stays put — an idle tick
    /// is not a flush).
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        if self.pending() == 0 {
            return Vec::new();
        }
        let expired: Vec<(Model, Variant)> = self
            .rotation()
            .filter(|key| {
                self.queue(*key)
                    .and_then(VecDeque::front)
                    .is_some_and(|r| now.duration_since(r.arrival) >= self.max_wait)
            })
            .collect();
        self.advance_rr(!expired.is_empty());
        expired.into_iter().filter_map(|k| self.take(k)).collect()
    }

    /// Drain everything (shutdown path), in round-robin order.
    /// Early-returns when nothing is pending, like [`Self::poll`] — the
    /// engine's `drain` re-arms a flush pass every waiter lap, which
    /// lands here with empty queues almost every time.
    pub fn drain(&mut self) -> Vec<Batch> {
        if self.pending() == 0 {
            return Vec::new();
        }
        let keys: Vec<(Model, Variant)> = self
            .rotation()
            .filter(|key| self.queue(*key).is_some_and(|q| !q.is_empty()))
            .collect();
        self.advance_rr(!keys.is_empty());
        keys.into_iter().filter_map(|k| self.take(k)).collect()
    }

    /// Sweep every request whose deadline has passed out of the queues
    /// and return them (the caller owes each a terminal
    /// [`Reply::Expired`](crate::coordinator::request::Reply::Expired)
    /// — an expired request must never occupy a batch slot, and must
    /// never be dropped without an outcome).
    ///
    /// Early-returns when nothing is pending, like [`Self::poll`]; the
    /// sweep itself is a full-queue scan (deadlines are per-request, so
    /// a later request can expire before an earlier one).
    pub fn expire(&mut self, now: Instant) -> Vec<InferenceRequest> {
        if self.pending() == 0 {
            return Vec::new();
        }
        let mut expired = Vec::new();
        for (_, q) in &mut self.queues {
            let mut i = 0;
            while i < q.len() {
                if matches!(q[i].deadline, Some(d) if d <= now) {
                    expired.extend(q.remove(i));
                } else {
                    i += 1;
                }
            }
        }
        expired
    }

    /// Outstanding (unbatched) requests.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Earliest instant at which a deadline flush becomes due, if any
    /// request is pending — the batcher thread sizes its timer tick on
    /// this so idle queues still flush on time.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|(_, q)| q.front().map(|r| r.arrival + self.max_wait))
            .min()
    }

    /// Queue keys starting at the round-robin cursor.
    fn rotation(&self) -> impl Iterator<Item = (Model, Variant)> + '_ {
        let n = self.queues.len();
        let start = if n == 0 { 0 } else { self.rr % n };
        (0..n).map(move |i| self.queues[(start + i) % n].0)
    }

    fn advance_rr(&mut self, flushed: bool) {
        if flushed && !self.queues.is_empty() {
            self.rr = (self.rr + 1) % self.queues.len();
        }
    }

    fn queue(&self, key: (Model, Variant)) -> Option<&VecDeque<InferenceRequest>> {
        self.queues.iter().find(|(k, _)| *k == key).map(|(_, q)| q)
    }

    fn queue_mut(&mut self, key: (Model, Variant)) -> &mut VecDeque<InferenceRequest> {
        if let Some(i) = self.queues.iter().position(|(k, _)| *k == key) {
            return &mut self.queues[i].1;
        }
        self.queues.push((key, VecDeque::new()));
        &mut self.queues.last_mut().expect("just pushed").1
    }

    fn take(&mut self, key: (Model, Variant)) -> Option<Batch> {
        let max = self.max_batch;
        let q = self.queue_mut(key);
        if q.is_empty() {
            return None;
        }
        let n = q.len().min(max);
        let requests: Vec<InferenceRequest> = q.drain(..n).collect();
        let seq = self.formed;
        self.formed += 1;
        Some(Batch {
            model: key.0,
            variant: key.1,
            requests,
            formed_at: Instant::now(),
            seq,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, v: Variant) -> InferenceRequest {
        req_for(id, Model::LeNet, v)
    }

    fn req_for(id: u64, m: Model, v: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            model: m,
            image: vec![0.0; 4].into(),
            variant: v,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        }
    }

    #[test]
    fn idle_poll_and_drain_are_noops_that_keep_the_cursor() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(0));
        // Idle ticks: nothing pending, nothing returned, no rotation.
        for _ in 0..100 {
            assert!(b.poll(Instant::now()).is_empty());
            assert!(b.drain().is_empty());
        }
        // The cursor did not move: the first real flush still starts at
        // the first-registered queue.
        b.push(req_for(0, Model::LeNet, Variant::Int4));
        b.push(req_for(1, Model::Vgg16, Variant::Int4));
        let flushed = b.poll(Instant::now() + Duration::from_millis(1));
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed[0].model, Model::LeNet, "idle ticks never rotate");
    }

    #[test]
    fn size_trigger() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(0, Variant::Int4)).is_none());
        assert!(b.push(req(1, Variant::Int4)).is_none());
        let batch = b.push(req(2, Variant::Int4)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.variant, Variant::Int4);
        assert_eq!(batch.model, Model::LeNet);
        assert_eq!(batch.seq, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn variants_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        assert!(b.push(req(0, Variant::Int4)).is_none());
        assert!(b.push(req(1, Variant::Int8)).is_none());
        assert_eq!(b.pending(), 2);
        let batch = b.push(req(2, Variant::Int4)).unwrap();
        assert!(batch.requests.iter().all(|r| r.variant == Variant::Int4));
    }

    #[test]
    fn models_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        assert!(b.push(req_for(0, Model::LeNet, Variant::Int4)).is_none());
        assert!(b.push(req_for(1, Model::Vgg16, Variant::Int4)).is_none());
        assert_eq!(b.pending(), 2, "same variant, different model: no mix");
        let batch = b.push(req_for(2, Model::Vgg16, Variant::Int4)).unwrap();
        assert_eq!(batch.model, Model::Vgg16);
        assert!(batch.requests.iter().all(|r| r.model == Model::Vgg16));
        assert_eq!(b.pending(), 1, "the LeNet request is still queued");
    }

    #[test]
    fn batch_seq_is_monotonic() {
        let mut b = DynamicBatcher::new(1, Duration::from_secs(10));
        let s0 = b.push(req_for(0, Model::LeNet, Variant::Int4)).unwrap().seq;
        let s1 = b.push(req_for(1, Model::Vgg16, Variant::Int8)).unwrap().seq;
        assert_eq!((s0, s1), (0, 1));
    }

    #[test]
    fn deadline_trigger() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(0));
        b.push(req(0, Variant::Fp32));
        let batches = b.poll(Instant::now() + Duration::from_millis(1));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
    }

    #[test]
    fn deadline_flush_rotates_across_models() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(0));
        b.push(req_for(0, Model::LeNet, Variant::Int4));
        b.push(req_for(1, Model::Vgg16, Variant::Int4));
        let later = Instant::now() + Duration::from_millis(1);
        let first = b.poll(later);
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].model, Model::LeNet, "cursor starts at 0");
        // Refill both; the cursor has advanced, so the other model now
        // gets the head-of-line slot.
        b.push(req_for(2, Model::LeNet, Variant::Int4));
        b.push(req_for(3, Model::Vgg16, Variant::Int4));
        let second = b.poll(later + Duration::from_millis(1));
        assert_eq!(second.len(), 2);
        assert_eq!(second[0].model, Model::Vgg16, "round-robin fairness");
    }

    #[test]
    fn next_deadline_tracks_oldest_request() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(InferenceRequest {
            id: 0,
            model: Model::LeNet,
            image: vec![].into(),
            variant: Variant::Int8,
            arrival: t0,
            deadline: None,
            reply: None,
        });
        b.push(InferenceRequest {
            id: 1,
            model: Model::LeNet,
            image: vec![].into(),
            variant: Variant::Fp32,
            arrival: t0 + Duration::from_millis(5),
            deadline: None,
            reply: None,
        });
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        let _ = b.drain();
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn deadline_not_yet() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        b.push(req(0, Variant::Fp32));
        assert!(b.poll(Instant::now()).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn expire_sweeps_only_past_deadline_requests() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        let t0 = Instant::now();
        let mut with_deadline = |id, offset_ms| {
            let mut r = req(id, Variant::Int4);
            r.deadline = Some(t0 + Duration::from_millis(offset_ms));
            r
        };
        b.push(with_deadline(0, 5));
        b.push(req(1, Variant::Int4)); // no deadline: never expires
        b.push(with_deadline(2, 50));
        // A *later* arrival with an *earlier* deadline must still be
        // swept — expiry is per-request, not head-of-queue.
        b.push(with_deadline(3, 5));
        assert!(b.expire(t0).is_empty(), "nothing due yet");
        let expired = b.expire(t0 + Duration::from_millis(10));
        let mut ids: Vec<u64> = expired.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 3]);
        assert_eq!(b.pending(), 2, "survivors keep their slots");
        let batch = b.drain().pop().unwrap();
        let mut left: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        left.sort_unstable();
        assert_eq!(left, vec![1, 2]);
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        b.push(req(0, Variant::Fp32));
        b.push(req(1, Variant::Int4));
        b.push(req_for(2, Model::MobileNet, Variant::Int4));
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(b.pending(), 0);
    }
}
