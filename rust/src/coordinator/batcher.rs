//! Dynamic batching: group same-variant requests up to the artifact
//! batch size, flushing on size or deadline (vLLM-router-style policy,
//! specialized to fixed-shape AOT artifacts).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::{InferenceRequest, Variant};

/// A flushed batch (all one variant, ≤ `max_batch` requests).
#[derive(Debug)]
pub struct Batch {
    pub variant: Variant,
    pub requests: Vec<InferenceRequest>,
    pub formed_at: Instant,
}

/// Size/deadline-triggered batcher with per-variant queues.
#[derive(Debug)]
pub struct DynamicBatcher {
    max_batch: usize,
    max_wait: Duration,
    queues: Vec<(Variant, VecDeque<InferenceRequest>)>,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Self {
            max_batch,
            max_wait,
            queues: vec![
                (Variant::Fp32, VecDeque::new()),
                (Variant::Int8, VecDeque::new()),
                (Variant::Int4, VecDeque::new()),
            ],
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Enqueue a request; returns a batch if the size trigger fired.
    pub fn push(&mut self, req: InferenceRequest) -> Option<Batch> {
        let variant = req.variant;
        let q = self.queue_mut(variant);
        q.push_back(req);
        if q.len() >= self.max_batch {
            return self.take(variant);
        }
        None
    }

    /// Flush any queue whose oldest request has exceeded the deadline.
    pub fn poll(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<Variant> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                q.front()
                    .is_some_and(|r| now.duration_since(r.arrival) >= self.max_wait)
            })
            .map(|(v, _)| *v)
            .collect();
        expired.into_iter().filter_map(|v| self.take(v)).collect()
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<Batch> {
        let variants: Vec<Variant> = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(v, _)| *v)
            .collect();
        variants.into_iter().filter_map(|v| self.take(v)).collect()
    }

    /// Outstanding (unbatched) requests.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Earliest instant at which a deadline flush becomes due, if any
    /// request is pending — the batcher thread sizes its timer tick on
    /// this so idle queues still flush on time.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .iter()
            .filter_map(|(_, q)| q.front().map(|r| r.arrival + self.max_wait))
            .min()
    }

    fn queue_mut(&mut self, v: Variant) -> &mut VecDeque<InferenceRequest> {
        &mut self
            .queues
            .iter_mut()
            .find(|(qv, _)| *qv == v)
            .expect("all variants present")
            .1
    }

    fn take(&mut self, v: Variant) -> Option<Batch> {
        let max = self.max_batch;
        let q = self.queue_mut(v);
        if q.is_empty() {
            return None;
        }
        let n = q.len().min(max);
        let requests: Vec<InferenceRequest> = q.drain(..n).collect();
        Some(Batch {
            variant: v,
            requests,
            formed_at: Instant::now(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, v: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            image: vec![0.0; 4],
            variant: v,
            arrival: Instant::now(),
        }
    }

    #[test]
    fn size_trigger() {
        let mut b = DynamicBatcher::new(3, Duration::from_secs(10));
        assert!(b.push(req(0, Variant::Int4)).is_none());
        assert!(b.push(req(1, Variant::Int4)).is_none());
        let batch = b.push(req(2, Variant::Int4)).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.variant, Variant::Int4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn variants_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        assert!(b.push(req(0, Variant::Int4)).is_none());
        assert!(b.push(req(1, Variant::Int8)).is_none());
        assert_eq!(b.pending(), 2);
        let batch = b.push(req(2, Variant::Int4)).unwrap();
        assert!(batch.requests.iter().all(|r| r.variant == Variant::Int4));
    }

    #[test]
    fn deadline_trigger() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(0));
        b.push(req(0, Variant::Fp32));
        let batches = b.poll(Instant::now() + Duration::from_millis(1));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
    }

    #[test]
    fn next_deadline_tracks_oldest_request() {
        let mut b = DynamicBatcher::new(100, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        let t0 = Instant::now();
        b.push(InferenceRequest {
            id: 0,
            image: vec![],
            variant: Variant::Int8,
            arrival: t0,
        });
        b.push(InferenceRequest {
            id: 1,
            image: vec![],
            variant: Variant::Fp32,
            arrival: t0 + Duration::from_millis(5),
        });
        assert_eq!(b.next_deadline(), Some(t0 + Duration::from_millis(10)));
        let _ = b.drain();
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn deadline_not_yet() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        b.push(req(0, Variant::Fp32));
        assert!(b.poll(Instant::now()).is_empty());
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = DynamicBatcher::new(100, Duration::from_secs(60));
        b.push(req(0, Variant::Fp32));
        b.push(req(1, Variant::Int4));
        let batches = b.drain();
        assert_eq!(batches.len(), 2);
        assert_eq!(b.pending(), 0);
    }
}
