//! The pipelined serving engine: bounded ingress queue → batcher thread →
//! worker pool → results collector.
//!
//! The seed coordinator was synchronous — `submit` executed batches
//! inline on the caller's thread and deadline flushes only fired when the
//! *next* request happened to arrive. This engine makes the serving path
//! genuinely concurrent — and, since the registry refactor, genuinely
//! multi-model: one engine serves every
//! [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS) entry from
//! shared capacity instead of one process per model.
//!
//! - **Ingress**: a bounded queue. [`Engine::submit`] is non-blocking and
//!   returns [`Error::Backpressure`] when the queue is full;
//!   [`Engine::submit_blocking`] waits for space (closed-loop producers
//!   and the synchronous `Server` facade). Backpressure propagates from
//!   the workers: when the pool is saturated the bounded batch channel
//!   fills, the batcher blocks handing off its next batch and stops
//!   pulling ingress, and the ingress queue fills up to `queue_capacity`.
//! - **Batcher thread**: owns the [`DynamicBatcher`] and is the only
//!   place batches form. Batches are strictly per-`(model, variant)` —
//!   never mixed — with round-robin fairness across the pending queues,
//!   and flush on size *or* deadline via a timer tick sized by
//!   [`DynamicBatcher::next_deadline`], so an idle queue still flushes
//!   on time (the seed's structural bug).
//! - **Worker pool**: `workers` threads, each owning its own PJRT
//!   [`Executor`] (the on-disk LeNet serving artifacts are pre-compiled
//!   at startup; other models compile on first batch). Workers pull
//!   formed batches from a shared channel, resolve each batch through
//!   the shared [`PlanRegistry`] — the lazily-built, `Arc`-shared cache
//!   of per-`(model, variant)` mapper plans, sim-cost tables and
//!   executor programs, built exactly once under a per-key lock — and
//!   place each real batch at the earliest *simulated* time its mapper
//!   footprint fits on an OPIMA instance via the shared,
//!   contention-aware [`Router`] (models whose footprints fit together
//!   co-reside; co-resident batches contend for the instance's shared
//!   aggregation/writeback pools through the global contention
//!   timeline; reservations are tagged by model).
//! - **Streaming stats**: each worker folds its batches' latencies into
//!   its own per-model shard of log-bucketed histograms
//!   ([`util::histogram`](crate::util::histogram)) — an uncontended
//!   per-worker lock on the record path. [`Engine::stats`] merges the
//!   shards in O(models × buckets), independent of how long the engine
//!   has been serving: no response-history sort, no history clone — and
//!   reports both the global breakdown and a per-model one (served,
//!   batches, latency, sim energy, sim makespan).
//! - **Stats sink**: completed batch outcomes flow over a results
//!   channel into a collector thread that maintains the shared sink
//!   (a *bounded* ring of the last [`EngineConfig::history`] responses,
//!   per-*batch* and per-model simulated energy, failure accounting) and
//!   wakes [`Engine::drain`] waiters. The seed retained the full
//!   response history forever; the ring caps retention so the sink is
//!   safe for unbounded request streams.
//!
//! Per-batch simulated costs come from the immutable
//! [`SimCostTable`](crate::analyzer::simcost::SimCostTable) inside each
//! registry plan — the analyzer never runs on the request path.
//!
//! The data plane is zero-copy in steady state (see `DESIGN.md` §3.1):
//! request images are shared
//! [`ImageBuf`](crate::coordinator::request::ImageBuf)s, workers pack
//! batches into pooled input buffers and execute prepared programs that
//! write logits into pooled shared buffers, and responses carry
//! [`LogitsView`](crate::coordinator::request::LogitsView)s into those
//! buffers instead of per-response copies — after warmup, a served
//! request allocates nothing for its pixels or logits.
//!
//! **Shutdown** is graceful: [`Engine::drain`] flushes and waits until
//! every accepted request has an outcome; [`Engine::shutdown`] (also run
//! on drop) disconnects the ingress queue — the batcher drains every
//! queued request (the last partial batch included) and exits, workers
//! finish every formed batch, the collector records every outcome — and
//! settles its result only after all joins, so the final stats snapshot
//! is complete by construction. Stats stay readable afterwards.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cnn::models::{Model, SERVABLE_MODELS};
use crate::config::OpimaConfig;
use crate::coordinator::batcher::{Batch, DynamicBatcher};
use crate::coordinator::registry::{augment_manifest, PlanRegistry};
use crate::coordinator::request::{InferenceRequest, InferenceResponse, LogitsPool, Reply, Variant};
use crate::coordinator::router::Router;
use crate::coordinator::server::{LatencyBreakdown, ModelServingStats, ServerStats};
use crate::coordinator::worker::{worker_loop, BatchOutcome, WorkerCtx};
use crate::error::{Error, Result};
use crate::runtime::{Executor, ExecutorSpec, Manifest};
use crate::util::fault::FaultPlane;
use crate::util::histogram::Histogram;
use crate::util::ring::Ring;
use crate::util::units::{Millijoules, Millis};

/// Longest the batcher sleeps while requests are pending; deadline and
/// flush handling are late by at most this much.
const MAX_TICK: Duration = Duration::from_millis(1);

/// Sleep while the batcher is completely idle (nothing pending). New
/// arrivals and ingress disconnection wake the receive immediately, and
/// an empty batcher has no deadline or flush work to do, so the long
/// tick costs no latency — it just stops a 1 kHz idle wakeup loop.
const IDLE_TICK: Duration = Duration::from_secs(1);

/// Fallback re-check period for [`Engine::drain`] waiters. The collector
/// notifies the drain condvar on every outcome, so a normal drain wakes
/// in notify time — the fallback only bounds how long a waiter can sit
/// on a *dead* pipeline (which will never produce the waking outcome)
/// and re-arms the flush flag for late-trickling submissions. Pinned
/// `pub(crate)` so the drain-latency test can assert a drain completes
/// well inside one tick — i.e. by notification, not by polling.
pub(crate) const DRAIN_FALLBACK_TICK: Duration = Duration::from_millis(200);

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads; each owns an executor with its own compile cache.
    pub workers: usize,
    /// Bounded ingress capacity: once the worker pool is saturated and
    /// this many requests are waiting in the ingress queue, `submit`
    /// returns `Error::Backpressure`.
    pub queue_capacity: usize,
    /// Simulated OPIMA instances behind the dispatch policy.
    pub instances: usize,
    /// Batch deadline for the dynamic batcher.
    pub max_wait: Duration,
    /// OPIMA hardware configuration for the metering simulator.
    pub hw: OpimaConfig,
    /// Worker executor backend.
    pub executor: ExecutorSpec,
    /// Bounded response history: the sink retains only the last
    /// `history` responses for [`Engine::responses`] /
    /// [`Engine::responses_since`] tailing. Aggregate statistics
    /// (served counts, means, percentiles, energy) always cover *every*
    /// response regardless of this capacity.
    pub history: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            queue_capacity: 1024,
            instances: 1,
            max_wait: Duration::from_millis(2),
            hw: OpimaConfig::paper(),
            executor: ExecutorSpec::Native,
            history: 1024,
        }
    }
}

/// Lock a mutex, recovering from poisoning (a panicked worker must not
/// wedge the whole pipeline — the sink data is append-only aggregates).
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Per-model aggregates the collector maintains alongside the global
/// counters.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct ModelSink {
    pub batches: u64,
    pub failed: u64,
    /// Requests swept out of this model's pending queues past their
    /// deadline (terminal `Expired` replies; never batched or executed).
    pub expired: u64,
    pub energy_mj: Millijoules,
}

/// Aggregates written by the collector thread, read by `stats()`/waiters.
#[derive(Debug)]
pub(crate) struct SinkState {
    /// Bounded response history: only the last `history` responses are
    /// retained (completion order, monotonic sequence numbers). The
    /// latency aggregates live in the per-worker shards, so eviction
    /// here loses payloads (logits), never statistics.
    pub recent: Ring<InferenceResponse>,
    /// Successfully executed batches.
    pub batches: u64,
    /// Requests lost to failed batches.
    pub failed: u64,
    /// Requests expired past their deadline before batch formation —
    /// terminal outcomes, counted into `completed` like responses and
    /// failures (the exactly-once invariant sums all three).
    pub expired: u64,
    /// Simulated energy summed once per *executed batch* (zero-padded
    /// partial batches pay full-batch energy, responses are not
    /// double-counted).
    pub batch_energy_mj: Millijoules,
    /// Per-model batch/failure/energy aggregates.
    pub models: HashMap<Model, ModelSink>,
    /// Requests with an outcome (responses + failed).
    pub completed: u64,
    /// When the most recent batch outcome landed — the wall-clock end of
    /// serving once the pipeline is idle.
    pub last_done: Option<Instant>,
    pub first_error: Option<String>,
}

#[derive(Debug)]
pub(crate) struct StatsSink {
    pub state: Mutex<SinkState>,
    pub done: Condvar,
}

impl StatsSink {
    fn new(history: usize) -> Self {
        Self {
            state: Mutex::new(SinkState {
                recent: Ring::new(history),
                batches: 0,
                failed: 0,
                expired: 0,
                batch_energy_mj: Millijoules::ZERO,
                models: HashMap::new(),
                completed: 0,
                last_done: None,
                first_error: None,
            }),
            done: Condvar::new(),
        }
    }
}

/// One latency accumulator: four log-bucketed histograms (total, queue,
/// exec, form), fixed memory.
#[derive(Debug, Default)]
pub(crate) struct LatencyShard {
    pub total: Histogram,
    pub queue: Histogram,
    pub exec: Histogram,
    pub form: Histogram,
}

impl LatencyShard {
    /// Fold one response's latency sample into the shard.
    pub fn record(&mut self, r: &InferenceResponse) {
        let (total, queue, exec, form) = r.latency_sample();
        self.total.record(total);
        self.queue.record(queue);
        self.exec.record(exec);
        self.form.record(form);
    }

    /// Fold another shard into this one. O(buckets).
    pub fn merge(&mut self, other: &LatencyShard) {
        self.total.merge(&other.total);
        self.queue.merge(&other.queue);
        self.exec.merge(&other.exec);
        self.form.merge(&other.form);
    }

    /// Snapshot the shard's summaries.
    pub fn breakdown(&self) -> LatencyBreakdown {
        LatencyBreakdown {
            total: self.total.summary(),
            queue: self.queue.summary(),
            exec: self.exec.summary(),
            form: self.form.summary(),
        }
    }
}

/// One worker's streaming latency accumulators, sharded per model —
/// recorded under the worker's own lock (only `stats()` ever contends
/// it, briefly, to merge). Sharding per worker keeps the record path
/// off any shared hot lock; keying per model keeps the per-model
/// breakdown exact without a second pass over responses.
#[derive(Debug, Default)]
pub(crate) struct WorkerShard {
    pub models: HashMap<Model, LatencyShard>,
}

impl WorkerShard {
    /// Fold one response into the model's latency shard.
    pub fn record(&mut self, model: Model, r: &InferenceResponse) {
        self.models.entry(model).or_default().record(r);
    }
}

/// Control flags shared with the batcher thread. Shutdown needs no
/// flag: dropping the ingress sender disconnects the batcher's receive,
/// which is its (single) exit signal.
#[derive(Debug, Default)]
struct Ctrl {
    flush: AtomicBool,
}

/// The multi-threaded pipelined serving engine.
pub struct Engine {
    cfg: EngineConfig,
    ingress: Option<SyncSender<InferenceRequest>>,
    ctrl: Arc<Ctrl>,
    sink: Arc<StatsSink>,
    /// Per-worker, per-model streaming latency histograms, merged by
    /// `stats()`.
    shards: Vec<Arc<Mutex<WorkerShard>>>,
    router: Arc<Mutex<Router>>,
    registry: Arc<PlanRegistry>,
    /// Serving epoch (post-warmup), shared with the workers.
    epoch: Arc<Mutex<Instant>>,
    batch_size: usize,
    image_elems: usize,
    accepted: AtomicU64,
    rejected: AtomicU64,
    /// Requests shed before submission by a front-end defense (the wire
    /// server's per-connection rate limiter) — they never reached the
    /// ingress queue, so they are neither `accepted` nor `rejected`.
    shed: AtomicU64,
    /// Worker executor respawns after mid-batch panics (shared with the
    /// pool; see `WorkerCtx::respawns`).
    respawns: Arc<AtomicU64>,
    batcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    collector: Option<JoinHandle<()>>,
}

impl Engine {
    /// Build and start the pipeline: spawns `cfg.workers` workers — each
    /// constructs and warms its own executor on its own thread, and a
    /// readiness barrier surfaces any startup failure here — then the
    /// batcher and the collector.
    pub fn new(cfg: EngineConfig, manifest: Manifest) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::Config("engine needs at least 1 worker".into()));
        }
        if cfg.queue_capacity == 0 {
            return Err(Error::Config("queue_capacity must be at least 1".into()));
        }
        if cfg.instances == 0 {
            return Err(Error::Config("engine needs at least 1 instance".into()));
        }
        if cfg.history == 0 {
            return Err(Error::Config("history capacity must be at least 1".into()));
        }
        cfg.hw.validate()?;
        // Synthesize artifact entries for the non-LeNet servable models
        // the manifest doesn't define (the sim backend needs only the
        // shapes; the PJRT backend will still fail loudly on a missing
        // HLO file). LeNet's on-disk `cnn_*` family is never touched.
        let mut manifest = manifest;
        augment_manifest(&mut manifest);
        let batch_size = manifest.batch;
        let image_elems = manifest.image_size * manifest.image_size;
        let variants = [Variant::Fp32, Variant::Int8, Variant::Int4];
        let registry = Arc::new(PlanRegistry::new(cfg.hw.clone(), manifest.clone()));
        // Each simulated instance is a whole OPIMA module: batches
        // co-reside when their mapper footprints fit in its subarrays,
        // and co-resident batches contend for the module's shared
        // aggregation/writeback pools (sized by the pipeline config).
        // The writeback stage is priced per `[memory] writeback_model`:
        // flat scalars by default, or command-level naive/scheduled
        // controllers against the geometry's banks.
        let router = Arc::new(Mutex::new(Router::with_hw(cfg.instances, &cfg.hw)));
        let sink = Arc::new(StatsSink::new(cfg.history));
        let shards: Vec<Arc<Mutex<WorkerShard>>> = (0..cfg.workers)
            .map(|_| Arc::new(Mutex::new(WorkerShard::default())))
            .collect();
        let ctrl = Arc::new(Ctrl::default());

        // Warm the LeNet serving artifacts (the only family with real
        // AOT HLO on disk); other models compile on first batch.
        let warm: Vec<String> = variants.iter().map(|v| v.artifact(batch_size)).collect();

        let (ingress_tx, ingress_rx) = mpsc::sync_channel::<InferenceRequest>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::sync_channel::<Batch>(cfg.workers * 2);
        let (res_tx, res_rx) = mpsc::channel::<BatchOutcome>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        // Shared serving epoch: one origin for the workers' simulated-
        // hardware clock *and* wall_ms/throughput. Provisionally set now,
        // finalized after warmup (workers can't execute batches until the
        // batcher — spawned after the readiness barrier — forms one).
        let epoch = Arc::new(Mutex::new(Instant::now()));

        // Each worker constructs and warms its own executor on its own
        // thread: the PJRT client never crosses a thread boundary (no
        // `Send` bound on the xla types) and per-worker warmup compiles
        // overlap. Startup failures are reported over the ready channel
        // so `new` still fails fast.
        let spawn_err = |e: std::io::Error| Error::Serving(format!("spawn pipeline thread: {e}"));
        let respawns = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::with_capacity(cfg.workers);
        for id in 0..cfg.workers {
            let manifest = manifest.clone();
            let spec = cfg.executor;
            let warm = warm.clone();
            let router = Arc::clone(&router);
            let registry = Arc::clone(&registry);
            let rx = Arc::clone(&batch_rx);
            let tx = res_tx.clone();
            let ready = ready_tx.clone();
            let w_epoch = Arc::clone(&epoch);
            let shard = Arc::clone(&shards[id]);
            let w_respawns = Arc::clone(&respawns);
            // Per-worker salt: workers sharing one seed still draw
            // decorrelated fault schedules.
            let fault = FaultPlane::new(cfg.hw.fault.clone(), id as u64);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("opima-worker-{id}"))
                    .spawn(move || {
                        let executor = match Executor::from_spec(spec, manifest.clone()) {
                            Ok(mut ex) => {
                                ex.warmup(&warm);
                                let _ = ready.send(Ok(()));
                                ex
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        worker_loop(WorkerCtx {
                            id,
                            executor,
                            batch_size,
                            registry,
                            router,
                            epoch: w_epoch,
                            shard,
                            rx,
                            tx,
                            plans: HashMap::new(),
                            input: Vec::new(),
                            // A handful of in-flight batch buffers per
                            // worker: enough that the ring's eviction
                            // cadence keeps recycling them under load.
                            logits_pool: LogitsPool::new(8),
                            spec,
                            manifest,
                            warm,
                            respawns: w_respawns,
                            fault,
                        });
                    })
                    .map_err(spawn_err)?,
            );
        }
        // The batcher reports deadline-expiry sweeps straight to the
        // collector over the same outcome channel the workers use — the
        // clone must be taken before the engine's copy drops.
        let expiry_tx = res_tx.clone();
        // Collector exits once the last worker (and the batcher, which
        // joins first at shutdown) hangs up its sender.
        drop(res_tx);
        drop(ready_tx);

        // Fail fast: every worker must bring up (and warm) its executor.
        for _ in 0..cfg.workers {
            let status = ready_rx.recv().unwrap_or_else(|_| {
                Err(Error::Serving("worker thread died during startup".into()))
            });
            if let Err(e) = status {
                // Closing the batch channel makes the live workers exit.
                drop(batch_tx);
                for h in workers {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
        // Finalize the serving epoch now that warmup is done: startup
        // compile time is billed to neither wall_ms/throughput_rps nor
        // the simulated-hardware horizons.
        *lock(&epoch) = Instant::now();

        let b_ctrl = Arc::clone(&ctrl);
        let max_wait = cfg.max_wait;
        let batcher = std::thread::Builder::new()
            .name("opima-batcher".into())
            .spawn(move || {
                batcher_loop(ingress_rx, batch_tx, expiry_tx, b_ctrl, batch_size, max_wait)
            })
            .map_err(spawn_err)?;

        let c_sink = Arc::clone(&sink);
        let collector = std::thread::Builder::new()
            .name("opima-collector".into())
            .spawn(move || collector_loop(res_rx, c_sink))
            .map_err(spawn_err)?;

        Ok(Self {
            cfg,
            ingress: Some(ingress_tx),
            ctrl,
            sink,
            shards,
            router,
            registry,
            epoch,
            batch_size,
            image_elems,
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            respawns,
            batcher: Some(batcher),
            workers,
            collector: Some(collector),
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Per-image element count of the legacy (LeNet) serving artifacts,
    /// from the manifest. See [`Engine::image_elems_for`] for the
    /// model-aware count.
    pub fn image_elems(&self) -> usize {
        self.image_elems
    }

    /// Flattened per-image element count a request for `model` must
    /// carry: LeNet follows the loaded manifest; the paper models follow
    /// their static metadata.
    pub fn image_elems_for(&self, model: Model) -> usize {
        match model {
            Model::LeNet => self.image_elems,
            m => m.input_elems(),
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// The shared plan/cost registry (lazily-built per-`(model,
    /// variant)` compiled artifacts).
    pub fn registry(&self) -> &PlanRegistry {
        &self.registry
    }

    /// Non-blocking submit. Returns [`Error::Backpressure`] when the
    /// bounded ingress queue is full, [`Error::Serving`] when the image
    /// is malformed or the engine has shut down.
    pub fn submit(&self, req: InferenceRequest) -> Result<()> {
        self.validate(&req)?;
        let tx = self
            .ingress
            .as_ref()
            .ok_or_else(|| Error::Serving("engine is shut down".into()))?;
        // Count the request *before* it becomes visible to the pipeline,
        // so a concurrent `drain` never snapshots a target that misses an
        // already-sent request; undo on failure.
        self.accepted.fetch_add(1, Ordering::AcqRel);
        match tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => {
                self.accepted.fetch_sub(1, Ordering::AcqRel);
                self.rejected.fetch_add(1, Ordering::AcqRel);
                Err(Error::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.accepted.fetch_sub(1, Ordering::AcqRel);
                Err(Error::Serving("engine is shut down".into()))
            }
        }
    }

    /// Blocking submit: waits for queue space instead of failing — for
    /// closed-loop producers and the synchronous `Server` facade.
    pub fn submit_blocking(&self, req: InferenceRequest) -> Result<()> {
        self.validate(&req)?;
        let tx = self
            .ingress
            .as_ref()
            .ok_or_else(|| Error::Serving("engine is shut down".into()))?;
        self.accepted.fetch_add(1, Ordering::AcqRel);
        tx.send(req).map_err(|_| {
            self.accepted.fetch_sub(1, Ordering::AcqRel);
            Error::Serving("engine is shut down".into())
        })?;
        Ok(())
    }

    fn validate(&self, req: &InferenceRequest) -> Result<()> {
        let want = self.image_elems_for(req.model);
        if req.image.len() != want {
            return Err(Error::Serving(format!(
                "image for {} has {} elems, artifact wants {want}",
                req.model.name(),
                req.image.len()
            )));
        }
        Ok(())
    }

    /// Flush pending batches and block until every accepted request has
    /// an outcome. Returns the first batch-execution error, if any, or
    /// an error when a pipeline thread died with work outstanding.
    pub fn drain(&self) -> Result<()> {
        let mut st = lock(&self.sink.state);
        // Re-read the accepted counter every lap: submissions may still
        // be racing in (and failed sends roll the counter back).
        while st.completed < self.accepted.load(Ordering::Acquire) {
            // A dead pipeline thread can never complete the remainder;
            // error out instead of waiting forever (this also keeps
            // Drop → shutdown → drain from hanging the process).
            if self.pipeline_dead() {
                let missing = self.accepted.load(Ordering::Acquire) - st.completed;
                return Err(Error::Serving(format!(
                    "pipeline thread exited with {missing} requests outstanding"
                )));
            }
            // Re-arm every lap: the batcher clears the flag after each
            // drain pass, and requests may still be trickling in.
            self.ctrl.flush.store(true, Ordering::Release);
            // The collector notifies per outcome, so completion wakes
            // this wait immediately; the timeout is only the fallback
            // lap for the dead-pipeline check and flush re-arm above.
            let (guard, _timeout) = self
                .sink
                .done
                .wait_timeout(st, DRAIN_FALLBACK_TICK)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
        // Don't leave a lingering flush armed: it would prematurely
        // flush the first undersized batch of the next submission burst.
        // (A batcher flush pass already in flight can still catch the
        // first post-drain submissions — a benign, µs-scale race whose
        // worst case is one undersized batch, not lost work.)
        self.ctrl.flush.store(false, Ordering::Release);
        // Report-and-clear: the error belongs to the work drained here;
        // a later, fully successful drain must not keep failing.
        match st.first_error.take() {
            Some(e) => Err(Error::Serving(format!("batch execution failed: {e}"))),
            None => Ok(()),
        }
    }

    /// True when any pipeline thread has exited. During normal serving
    /// all three stages run until `shutdown`; an early exit means a
    /// panic took a stage down and in-flight work may be lost.
    fn pipeline_dead(&self) -> bool {
        self.workers.iter().any(|w| w.is_finished())
            || match &self.batcher {
                Some(b) => b.is_finished(),
                None => true,
            }
            || match &self.collector {
                Some(c) => c.is_finished(),
                None => true,
            }
    }

    /// Requests accepted into the ingress queue so far.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Acquire)
    }

    /// Requests rejected with backpressure so far.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Acquire)
    }

    /// Requests shed by front-end defenses (rate limiting) before they
    /// reached `submit`.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Acquire)
    }

    /// Record one front-end shed (the wire server's per-connection rate
    /// limiter calls this when it answers `BUSY` without submitting).
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::AcqRel);
    }

    /// Worker executor respawns after mid-batch panics so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Acquire)
    }

    /// Requests with an outcome (response or recorded failure) so far.
    pub fn completed(&self) -> u64 {
        lock(&self.sink.state).completed
    }

    /// Snapshot of the *retained* responses (completion order): the last
    /// [`EngineConfig::history`] at most — older responses are evicted
    /// from the bounded ring, so the copy made here (and the memory
    /// behind it) is O(history), not O(everything ever served).
    /// Aggregate statistics are unaffected by eviction; callers that
    /// tail the stream should use [`Engine::responses_since`].
    pub fn responses(&self) -> Vec<InferenceResponse> {
        lock(&self.sink.state).recent.to_vec()
    }

    /// Retained responses with completion sequence ≥ `from` (completion
    /// order), plus the next cursor value (= total responses completed
    /// so far). A caller that polls with its last returned cursor sees
    /// each response exactly once — unless it falls more than the ring
    /// capacity behind, in which case the evicted gap is lost (the
    /// returned cursor still advances past it, so the caller does not
    /// stall; compare `vec.len()` against the cursor delta to detect
    /// the gap).
    pub fn responses_since(&self, from: u64) -> (Vec<InferenceResponse>, u64) {
        let st = lock(&self.sink.state);
        (st.recent.since(from), st.recent.pushed())
    }

    /// Per-batch simulated `(latency, energy)` for a `(model, variant)`
    /// pair, resolving (and, on first use, building) its registry plan.
    pub fn sim_cost(&self, model: Model, variant: Variant) -> Result<(Millis, Millijoules)> {
        Ok(self.registry.resolve(model, variant)?.sim_cost())
    }

    /// Structured over-capacity warnings for every model resolved so
    /// far whose mapping exceeds the simulated memory's subarray
    /// capacity (such models still serve, but time-share the memory).
    pub fn capacity_warnings(&self) -> Vec<crate::mapper::CapacityWarning> {
        self.registry.capacity_warnings()
    }

    /// Aggregate statistics over everything served so far.
    ///
    /// O(models × buckets): merges the per-worker streaming histogram
    /// shards — no response-history sort, no history clone, and the cost
    /// does not grow with how long the engine has been serving. Each
    /// shard lock is held only for its merge, so the observation path
    /// barely contends with the workers. (A worker records its batch
    /// into its shard just before the outcome reaches the collector, so
    /// a stats snapshot taken mid-flight may momentarily count a
    /// response in the latency aggregates that the sink counters haven't
    /// absorbed yet — after `drain` the two views always agree.)
    pub fn stats(&self) -> ServerStats {
        let (sim_makespan_ms, model_spans) = {
            let r = lock(&self.router);
            // Already model-sorted, so per-model rows are stable.
            (r.makespan_ms(), r.model_makespans())
        };
        let epoch = *lock(&self.epoch);
        let accepted = self.accepted.load(Ordering::Acquire);
        // Merge the per-worker shards into one shard per model, then
        // fold those into the global aggregate.
        let mut merged: HashMap<Model, LatencyShard> = HashMap::new();
        for shard in &self.shards {
            let s = lock(shard);
            for (m, sh) in &s.models {
                merged.entry(*m).or_default().merge(sh);
            }
        }
        let mut agg = LatencyShard::default();
        for sh in merged.values() {
            agg.merge(sh);
        }
        let (batches, failed, expired, sim_energy_mj, model_sinks, end) = {
            let st = lock(&self.sink.state);
            // While work is in flight the wall clock runs to "now"; once
            // the pipeline is idle it stops at the last completion, so
            // throughput_rps doesn't decay while the engine sits idle.
            let end = if st.completed >= accepted {
                st.last_done.unwrap_or(epoch)
            } else {
                Instant::now()
            };
            (
                st.batches,
                st.failed,
                st.expired,
                st.batch_energy_mj,
                st.models.clone(),
                end,
            )
        };
        let wall_ms = Millis::from_duration(end.saturating_duration_since(epoch));
        let latency = agg.breakdown();
        let n = latency.total.count;
        // Per-model breakdown in `SERVABLE_MODELS` order, covering every
        // model that served, failed, or was metered.
        let mut per_model = Vec::new();
        for m in SERVABLE_MODELS {
            let lat = merged.get(&m);
            let sunk = model_sinks.get(&m);
            if lat.is_none() && sunk.is_none() {
                continue;
            }
            let latb = lat.map(LatencyShard::breakdown).unwrap_or_default();
            let s = sunk.copied().unwrap_or_default();
            per_model.push(ModelServingStats {
                model: m,
                served: latb.total.count,
                batches: s.batches,
                failed: s.failed,
                expired: s.expired,
                sim_energy_mj: s.energy_mj,
                sim_makespan_ms: model_spans
                    .iter()
                    .find(|(sm, _)| *sm == m)
                    .map(|(_, e)| *e)
                    .unwrap_or(Millis::ZERO),
                latency: latb,
            });
        }
        ServerStats {
            served: n,
            batches,
            failed,
            expired,
            rejected: self.rejected.load(Ordering::Acquire),
            shed: self.shed.load(Ordering::Acquire),
            respawns: self.respawns.load(Ordering::Acquire),
            wall_ms,
            mean_queue_ms: Millis::new(latency.queue.mean),
            mean_exec_ms: Millis::new(latency.exec.mean),
            mean_form_ms: Millis::new(latency.form.mean),
            p50_total_ms: Millis::new(latency.total.p50),
            p99_total_ms: Millis::new(latency.total.p99),
            throughput_rps: if n == 0 {
                0.0
            } else {
                n as f64 / (wall_ms.raw() / 1e3).max(1e-9)
            },
            sim_energy_mj,
            sim_makespan_ms,
            latency,
            per_model,
        }
    }

    /// Graceful shutdown: disconnect the ingress queue, join every
    /// pipeline thread, and only then settle the final outcome.
    /// Idempotent; also run on drop. Stats and responses remain
    /// readable afterwards.
    ///
    /// The ordering is the drain barrier: dropping the ingress sender
    /// wakes the batcher, which drains every queued request — the last
    /// partial batch included — into the batch channel and exits;
    /// workers finish every formed batch and exit; the collector
    /// records every outcome and exits. A `stats()` snapshot taken
    /// after `shutdown()` therefore always counts the final partial
    /// batch. (The previous ordering polled `drain()` first, *before*
    /// tearing the pipeline down: a submission racing with shutdown
    /// could land after the drain target was sampled, and a single dead
    /// worker made `drain()` report outstanding work as lost even while
    /// the surviving workers were still completing it. Joining first
    /// makes the final snapshot a deterministic fact, not a poll.)
    pub fn shutdown(&mut self) -> Result<()> {
        self.ingress = None;
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
        // Settle only after the joins: the sink now holds the complete,
        // final accounting.
        let mut st = lock(&self.sink.state);
        let accepted = self.accepted.load(Ordering::Acquire);
        match st.first_error.take() {
            // Report-and-clear, like `drain`: the error belongs to the
            // work settled here.
            Some(e) => Err(Error::Serving(format!("batch execution failed: {e}"))),
            None if st.completed < accepted => Err(Error::Serving(format!(
                "pipeline exited with {} requests outstanding",
                accepted - st.completed
            ))),
            None => Ok(()),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// The batcher thread: the only place batches form.
///
/// Unbatched pending is structurally bounded (each `(model, variant)`
/// queue flushes at `max_batch`), and handing a formed batch to a
/// saturated worker pool blocks on the bounded batch channel — which
/// stops the ingress pull and lets the bounded ingress queue exert
/// backpressure.
fn batcher_loop(
    rx: Receiver<InferenceRequest>,
    tx: SyncSender<Batch>,
    expiry_tx: mpsc::Sender<BatchOutcome>,
    ctrl: Arc<Ctrl>,
    max_batch: usize,
    max_wait: Duration,
) {
    let mut batcher = DynamicBatcher::new(max_batch, max_wait);
    loop {
        let mut disconnected = false;
        let wait = if batcher.pending() == 0 {
            IDLE_TICK
        } else {
            batcher.next_deadline().map_or(MAX_TICK, |d| {
                d.saturating_duration_since(Instant::now()).min(MAX_TICK)
            })
        };
        match rx.recv_timeout(wait) {
            Ok(req) => {
                if let Some(b) = batcher.push(req) {
                    if tx.send(b).is_err() {
                        return;
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }
        // Deadline-expired requests are swept *before* batch formation:
        // a request in a formed batch always executes, so expiry and
        // execution are mutually exclusive terminal outcomes. While
        // requests are pending the loop ticks at least every MAX_TICK,
        // bounding expiry lateness the same way flush lateness is.
        sweep_expired(&mut batcher, &expiry_tx);
        // Deadline flushes fire here on the timer tick — even if no
        // request ever arrives again (the seed's idle-flush bug).
        for b in batcher.poll(Instant::now()) {
            if tx.send(b).is_err() {
                return;
            }
        }
        if ctrl.flush.swap(false, Ordering::AcqRel) || disconnected {
            while let Ok(req) = rx.try_recv() {
                if let Some(b) = batcher.push(req) {
                    if tx.send(b).is_err() {
                        return;
                    }
                }
            }
            // Flush-path sweep: a drain must settle expired stragglers
            // too, or `drain` would wait on requests no batch will ever
            // carry.
            sweep_expired(&mut batcher, &expiry_tx);
            for b in batcher.drain() {
                if tx.send(b).is_err() {
                    return;
                }
            }
        }
        if disconnected {
            return;
        }
    }
}

/// Sweep past-deadline requests out of the batcher: each gets a terminal
/// `Reply::Expired` on its connection queue (wire requests) and a
/// per-model expiry outcome to the collector, so `drain`'s exactly-once
/// accounting counts it — expired work completes, it is never dropped.
fn sweep_expired(batcher: &mut DynamicBatcher, expiry_tx: &mpsc::Sender<BatchOutcome>) {
    let swept = batcher.expire(Instant::now());
    if swept.is_empty() {
        return;
    }
    let mut per_model: HashMap<Model, u64> = HashMap::new();
    for r in swept {
        if let Some(q) = &r.reply {
            q.push(Reply::Expired { id: r.id });
        }
        *per_model.entry(r.model).or_default() += 1;
    }
    for (model, expired) in per_model {
        // A send can only fail once the collector is gone (dead
        // pipeline); drain's liveness check owns that case.
        let _ = expiry_tx.send(BatchOutcome {
            model,
            responses: Vec::new(),
            failed: 0,
            expired,
            error: None,
            sim_energy_mj: Millijoules::ZERO,
        });
    }
}

/// The collector thread: folds batch outcomes into the shared sink
/// (global and per-model) and wakes `drain` waiters.
///
/// Outcomes are disjoint by construction: an executed batch carries
/// responses, a failed batch carries `failed`, an expiry sweep carries
/// `expired` — never a mix. The three-way split below keeps the batch
/// and energy counters meaning "executed batches" only (an expiry
/// outcome is not a batch and must not phantom-increment `batches`).
fn collector_loop(rx: Receiver<BatchOutcome>, sink: Arc<StatsSink>) {
    while let Ok(out) = rx.recv() {
        let mut st = lock(&sink.state);
        st.completed += out.responses.len() as u64 + out.failed + out.expired;
        st.last_done = Some(Instant::now());
        {
            let m = st.models.entry(out.model).or_default();
            if out.failed > 0 {
                m.failed += out.failed;
            } else if out.expired > 0 {
                m.expired += out.expired;
            } else {
                m.batches += 1;
                m.energy_mj += out.sim_energy_mj;
            }
        }
        if out.failed > 0 {
            st.failed += out.failed;
            if st.first_error.is_none() {
                st.first_error = out.error;
            }
        } else if out.expired > 0 {
            st.expired += out.expired;
        } else {
            st.batches += 1;
            st.batch_energy_mj += out.sim_energy_mj;
        }
        for r in out.responses {
            st.recent.push(r);
        }
        drop(st);
        sink.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn sim_engine(workers: usize, queue: usize, max_wait: Duration) -> Engine {
        Engine::new(
            EngineConfig {
                workers,
                queue_capacity: queue,
                instances: 2,
                max_wait,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                ..EngineConfig::default()
            },
            Manifest::synthetic(8, 12),
        )
        .unwrap()
    }

    fn req(id: u64, variant: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            model: Model::LeNet,
            image: (0..144).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect(),
            variant,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        }
    }

    #[test]
    fn drain_wakes_on_notify_not_fallback_tick() {
        // A batch deadline far beyond the fallback tick: the partial
        // batch only ever forms through drain's flush. If the drain
        // waiter were tick-bound (the old 5 ms poll generalized to the
        // 200 ms fallback), this drain would take at least one full
        // DRAIN_FALLBACK_TICK — the collector's per-outcome notify must
        // wake it well inside one tick instead.
        let mut e = sim_engine(1, 64, Duration::from_secs(3600));
        for id in 0..5 {
            e.submit(req(id, Variant::Int8)).unwrap();
        }
        let t0 = Instant::now();
        e.drain().unwrap();
        let waited = t0.elapsed();
        assert_eq!(e.completed(), 5);
        assert!(
            waited < DRAIN_FALLBACK_TICK,
            "drain took {waited:?} — waiter woke by fallback tick, not notify"
        );
        e.shutdown().unwrap();
    }

    #[test]
    fn past_deadline_requests_expire_with_exact_accounting() {
        // Deadlines already past at submission and a batch deadline an
        // hour out: no batch will ever carry these requests, so the
        // batcher's sweep must settle them (terminal expired outcomes)
        // or drain would wait forever.
        let mut e = sim_engine(1, 64, Duration::from_secs(3600));
        for id in 0..3 {
            let mut r = req(id, Variant::Int8);
            r.deadline = Some(Instant::now());
            e.submit(r).unwrap();
        }
        e.drain().unwrap(); // expiry is a terminal outcome, not an engine error
        assert_eq!(e.completed(), 3);
        let s = e.stats();
        assert_eq!(s.expired, 3);
        assert_eq!(s.served, 0);
        assert_eq!(s.failed, 0);
        assert_eq!(s.batches, 0, "an expiry sweep is not an executed batch");
        assert_eq!(s.per_model.len(), 1);
        assert_eq!(s.per_model[0].expired, 3);
        e.shutdown().unwrap();
    }

    #[test]
    fn panicked_worker_respawns_and_accounting_holds() {
        crate::util::fault::silence_injected_panics();
        let mut hw = OpimaConfig::paper();
        hw.fault.armed = true;
        hw.fault.seed = 42;
        hw.fault.worker_panic = 1.0;
        let mut e = Engine::new(
            EngineConfig {
                workers: 1,
                queue_capacity: 64,
                max_wait: Duration::from_millis(1),
                executor: ExecutorSpec::Sim { work_factor: 1 },
                hw,
                ..EngineConfig::default()
            },
            Manifest::synthetic(8, 12),
        )
        .unwrap();
        for id in 0..8 {
            e.submit(req(id, Variant::Int8)).unwrap();
        }
        // Every batch panics (p = 1): the batch fails loudly and exactly
        // once...
        let err = e.drain().unwrap_err().to_string();
        assert!(err.contains("panicked mid-batch"), "unexpected drain error: {err}");
        assert_eq!(e.completed(), 8);
        assert!(e.respawns() >= 1);
        // ...and the worker thread survived: a second wave settles (to a
        // failure again at p = 1) instead of tripping the dead-pipeline
        // check.
        for id in 8..16 {
            e.submit(req(id, Variant::Int8)).unwrap();
        }
        let err2 = e.drain().unwrap_err().to_string();
        assert!(
            !err2.contains("pipeline thread exited"),
            "worker thread died instead of respawning: {err2}"
        );
        assert_eq!(e.completed(), 16);
        let s = e.stats();
        assert_eq!(s.failed, 16);
        assert!(s.respawns >= 2);
        e.shutdown().unwrap();
    }

    #[test]
    fn shutdown_counts_the_last_partial_batch() {
        // 13 requests at batch 8 with an hour-scale deadline: the last 5
        // only ever flush through the shutdown path itself. The final
        // stats snapshot must count them — shutdown's join sequence (not
        // a poll) is the drain barrier (ISSUE 9 satellite).
        let mut e = sim_engine(2, 64, Duration::from_secs(3600));
        const N: u64 = 13;
        for id in 0..N {
            e.submit(req(id, Variant::Int4)).unwrap();
        }
        e.shutdown().unwrap(); // no drain() first — on purpose
        assert_eq!(e.completed(), N);
        let s = e.stats();
        assert_eq!(s.served, N, "last partial batch missing from final stats");
        assert_eq!(s.failed, 0);
        assert_eq!(s.batches, 2, "8 + 5 → one full and one partial batch");
        let per_model: u64 = s.per_model.iter().map(|m| m.served).sum();
        assert_eq!(per_model, N);
    }

    #[test]
    fn pipeline_serves_and_drains() {
        let mut e = sim_engine(1, 64, Duration::from_secs(5));
        for id in 0..16 {
            e.submit(req(id, Variant::Int4)).unwrap();
        }
        e.drain().unwrap();
        assert_eq!(e.completed(), 16);
        let rs = e.responses();
        assert_eq!(rs.len(), 16);
        assert!(rs.iter().all(|r| r.logits.len() == 4));
        assert!(rs.iter().all(|r| r.model == Model::LeNet));
        let s = e.stats();
        assert_eq!(s.served, 16);
        assert_eq!(s.batches, 2, "16 requests at batch 8 → 2 full batches");
        assert!(s.sim_energy_mj > Millijoules::ZERO);
        // Streaming percentiles come from the merged worker shards and
        // cover every response.
        assert_eq!(s.latency.total.count, 16);
        assert!(s.latency.total.p50 <= s.latency.total.p99 + 1e-12);
        assert!(s.latency.total.p999 <= s.latency.total.max + 1e-12);
        assert!((s.latency.queue.mean - s.mean_queue_ms.raw()).abs() < 1e-12);
        // Single-model run: the per-model breakdown is that one model
        // and it carries the global totals.
        assert_eq!(s.per_model.len(), 1);
        let m = &s.per_model[0];
        assert_eq!(m.model, Model::LeNet);
        assert_eq!(m.served, 16);
        assert_eq!(m.batches, 2);
        assert!((m.sim_energy_mj - s.sim_energy_mj).abs().raw() < 1e-12);
        assert!(m.sim_makespan_ms.raw() > 0.0 && m.sim_makespan_ms <= s.sim_makespan_ms);
        // The LeNet plan was compiled exactly once for the whole run.
        assert_eq!(e.registry().builds(), 1);
        e.shutdown().unwrap();
    }

    #[test]
    fn rejects_bad_config() {
        let m = Manifest::synthetic(8, 12);
        assert!(Engine::new(
            EngineConfig {
                workers: 0,
                ..EngineConfig::default()
            },
            m.clone()
        )
        .is_err());
        assert!(Engine::new(
            EngineConfig {
                queue_capacity: 0,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                ..EngineConfig::default()
            },
            m.clone()
        )
        .is_err());
        assert!(Engine::new(
            EngineConfig {
                instances: 0,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                ..EngineConfig::default()
            },
            m.clone()
        )
        .is_err());
        assert!(Engine::new(
            EngineConfig {
                history: 0,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                ..EngineConfig::default()
            },
            m
        )
        .is_err());
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let mut e = sim_engine(1, 16, Duration::from_millis(1));
        e.submit(req(0, Variant::Int8)).unwrap();
        e.shutdown().unwrap();
        assert_eq!(e.completed(), 1, "shutdown drains in-flight work");
        assert!(matches!(
            e.submit(req(1, Variant::Int8)),
            Err(Error::Serving(_))
        ));
    }

    #[test]
    fn drop_without_shutdown_does_not_hang() {
        let e = sim_engine(2, 16, Duration::from_millis(1));
        e.submit(req(0, Variant::Fp32)).unwrap();
        drop(e); // Drop runs shutdown → drain → join
    }

    #[test]
    fn rejects_wrong_image_size_per_model() {
        let e = sim_engine(1, 16, Duration::from_secs(5));
        // A LeNet-sized image is not a valid ResNet18 request.
        let mut r = req(0, Variant::Int4);
        r.model = Model::ResNet18;
        assert!(e.submit(r).is_err());
        assert_eq!(e.image_elems_for(Model::LeNet), 144);
        assert_eq!(e.image_elems_for(Model::ResNet18), 32 * 32 * 3);
    }

    #[test]
    fn failed_batch_is_accounted_not_lost() {
        let mut manifest = Manifest::synthetic(8, 12);
        manifest.artifacts.remove("cnn_int4_b8");
        let mut e = Engine::new(
            EngineConfig {
                workers: 1,
                queue_capacity: 16,
                executor: ExecutorSpec::Sim { work_factor: 1 },
                ..EngineConfig::default()
            },
            manifest,
        )
        .unwrap();
        for id in 0..3 {
            e.submit(req(id, Variant::Int4)).unwrap();
        }
        assert!(e.drain().is_err(), "missing artifact surfaces on drain");
        assert_eq!(e.completed(), 3);
        let s = e.stats();
        assert_eq!(s.failed, 3);
        assert_eq!(s.served, 0);
        // The failure is attributed to the model that owned the batch.
        assert_eq!(s.per_model.len(), 1);
        assert_eq!(s.per_model[0].model, Model::LeNet);
        assert_eq!(s.per_model[0].failed, 3);
        assert_eq!(s.per_model[0].batches, 0);
        // The error was reported by that drain and cleared: a later
        // drain (here via shutdown) of an otherwise-clean engine is Ok.
        e.shutdown().unwrap();
    }
}
