//! The serving coordinator: OPIMA as an inference appliance.
//!
//! A multi-threaded pipelined engine serves CNN classification requests:
//! a bounded ingress queue (non-blocking `submit` returns
//! [`Backpressure`](crate::error::Error::Backpressure) when full), a
//! dedicated batcher thread that owns the dynamic batcher and flushes on
//! size **or** deadline via a timer tick (an idle queue still flushes on
//! time), and a worker pool where each worker owns its own PJRT executor
//! (compile caches warmed at startup) and pulls formed batches from a
//! channel. Completed responses flow over a results channel into a
//! shared stats sink; `shutdown` drains in-flight work before joining
//! the pipeline threads.
//!
//! Observability is *streaming and bounded*: each worker folds its
//! batches' latencies into a per-worker shard of log-bucketed histograms
//! ([`util::histogram`](crate::util::histogram)), `Engine::stats` merges
//! the shards in O(buckets) (no history sort or clone), and the sink
//! retains only a fixed-capacity ring of the most recent responses
//! ([`util::ring`](crate::util::ring)) — so memory and stats cost stay
//! constant over unbounded request streams. The `Server` facade exposes
//! responses by value (`recent`/`drain_responses`) rather than keeping
//! its own copy.
//!
//! The functional result comes from executing the AOT HLO artifacts
//! through PJRT (or the sim backend); the *architectural* cost of each
//! batch (what the OPIMA hardware would have spent) is metered once per
//! executed batch from a precomputed immutable cost table and reported
//! with every response.
//!
//! - [`request`] — request/response types and the model-variant registry.
//! - [`batcher`] — dynamic batching: size- and deadline-triggered.
//! - [`engine`] — the pipelined engine: queue → batcher → worker pool →
//!   stats sink; backpressure, drain and graceful shutdown; streaming
//!   per-worker latency histograms + bounded response ring.
//! - [`worker`] — worker loop: execute a batch, meter it, fold it into
//!   the worker's latency shard, report it.
//! - [`router`] — least-outstanding-work dispatch of *real* worker
//!   batches onto simulated OPIMA instance busy horizons.
//! - [`server`] — the synchronous facade preserving the seed call-loop
//!   API on top of the engine.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use engine::{Engine, EngineConfig};
pub use request::{InferenceRequest, InferenceResponse, Variant};
pub use server::{LatencyBreakdown, Server, ServerConfig, ServerStats};
