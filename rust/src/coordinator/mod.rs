//! The serving coordinator: OPIMA as an inference appliance.
//!
//! A thread-based event loop (request queue → dynamic batcher → router →
//! PJRT-backed workers) that serves CNN classification requests. The
//! functional result comes from executing the AOT HLO artifacts through
//! PJRT; the *architectural* cost of each batch (what the OPIMA hardware
//! would have spent) is metered by the simulator stack and reported with
//! every response.
//!
//! - [`request`] — request/response types and the model-variant registry.
//! - [`batcher`] — dynamic batching: size- and deadline-triggered.
//! - [`router`] — least-outstanding-work routing across PIM instances.
//! - [`server`] — the serving loop, workers and aggregate statistics.

pub mod batcher;
pub mod request;
pub mod router;
pub mod server;

pub use request::{InferenceRequest, InferenceResponse, Variant};
pub use server::{Server, ServerConfig, ServerStats};
