//! The serving coordinator: OPIMA as a multi-model inference appliance.
//!
//! A multi-threaded pipelined engine serves CNN classification requests
//! for any of the [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS)
//! from shared capacity: a bounded ingress queue (non-blocking `submit`
//! returns [`Backpressure`](crate::error::Error::Backpressure) when
//! full), a dedicated batcher thread that owns the dynamic batcher —
//! one pending queue per `(model, variant)` pair, flushed on size **or**
//! deadline via a timer tick (an idle queue still flushes on time) with
//! round-robin fairness across models, and never mixing models in one
//! batch — and a worker pool where each worker owns its own PJRT
//! executor (LeNet compile caches warmed at startup) and pulls formed
//! batches from a channel.
//!
//! Per-model compiled state lives in the shared [`registry`]: a
//! lazily-built, `Arc`-shared [`PlanRegistry`] caching each `(model,
//! variant)` pair's network graph, mapper plan,
//! [`SimCostTable`](crate::analyzer::simcost::SimCostTable) and
//! executor program. Plans build exactly once under a per-key lock —
//! concurrent first requests for the same pair share one build; the
//! registry additionally caches pipelined batch timelines per
//! `(model, variant, batch)`; the analyzer never runs on the request
//! path.
//!
//! Completed responses flow over a results channel into a shared stats
//! sink; `shutdown` drains in-flight work before joining the pipeline
//! threads.
//!
//! Observability is *streaming, bounded, and per-model*: each worker
//! folds its batches' latencies into a per-worker, per-model shard of
//! log-bucketed histograms ([`util::histogram`](crate::util::histogram)),
//! `Engine::stats` merges the shards in O(models × buckets), and the
//! sink retains only a fixed-capacity ring of the most recent responses
//! ([`util::ring`](crate::util::ring)) — so memory and stats cost stay
//! constant over unbounded request streams. [`ServerStats`] reports the
//! global breakdown plus a [`ModelServingStats`] row per active model
//! (served, batches, latency, sim energy, tagged sim makespan); the
//! `Server` facade exposes responses by value (`recent`/
//! `drain_responses`) rather than keeping its own copy.
//!
//! The functional result comes from executing the AOT HLO artifacts
//! through PJRT (or the sim backend); the *architectural* cost of each
//! batch (what the OPIMA hardware would have spent) is metered once per
//! executed batch from the plan's precomputed cost table and reported
//! with every response.
//!
//! See `DESIGN.md` §3 for the end-to-end dataflow picture (ingress →
//! per-model batch queues → registry → worker pool → router → stats).
//!
//! - [`request`] — request/response types, the model field and the
//!   quantization variants, per-`(model, variant)` artifact naming, and
//!   the zero-copy buffer types: shared [`ImageBuf`] images, per-batch
//!   shared logits published once and viewed per response via
//!   [`LogitsView`], recycled through the per-worker [`LogitsPool`]
//!   (see `DESIGN.md` §3.1).
//! - [`batcher`] — dynamic batching: size- and deadline-triggered,
//!   per-`(model, variant)` queues, round-robin fairness.
//! - [`registry`] — the shared plan/cost registry: per-`(model,
//!   variant)` compiled artifacts, built lazily and exactly once.
//! - [`engine`] — the pipelined engine: queue → batcher → worker pool →
//!   stats sink; backpressure, drain and graceful shutdown; streaming
//!   per-worker per-model latency histograms + bounded response ring.
//! - [`worker`] — worker loop: resolve a batch's plan, execute it,
//!   meter it, fold it into the worker's latency shard, report it.
//! - [`router`] — occupancy-aware dispatch of *real* worker batches
//!   onto simulated OPIMA instances: each batch is placed at the
//!   earliest simulated time its mapper footprint fits, so models
//!   whose footprints fit together co-reside; reservations are tagged
//!   per model.
//! - [`server`] — the synchronous facade preserving the seed call-loop
//!   API on top of the engine.
//! - [`net`] — the zero-copy TCP wire front end: length-prefixed binary
//!   frames over `std::net`, pooled image ingest straight off the
//!   socket, vectored response writes, explicit `BUSY` backpressure and
//!   a graceful `DRAIN` → flush → `FIN` state machine (DESIGN.md §3.2).

pub mod batcher;
pub mod engine;
pub mod net;
pub mod registry;
pub mod request;
pub mod router;
pub mod server;
pub mod worker;

pub use engine::{Engine, EngineConfig};
pub use net::{LoadGenConfig, LoadGenReport, NetClient, NetReply, NetServer};
pub use registry::{ModelPlan, PlanRegistry};
pub use request::{
    parse_mix, pick_weighted, ImageBuf, ImagePool, InferenceRequest, InferenceResponse,
    LogitsPool, LogitsView, Reply, ReplyQueue, Variant,
};
pub use router::Router;
pub use server::{LatencyBreakdown, ModelServingStats, Server, ServerConfig, ServerStats};
