//! Wire-protocol client: a reusable per-connection codec plus the
//! multi-connection open-loop load generator behind `serve --listen`
//! self-drive, the `net_inference` example and
//! `benches/net_throughput.rs`.
//!
//! [`NetClient`] owns one TCP stream and three reused scratch buffers
//! (encode bytes, decoded logits, decoded text); after warmup, a
//! submit/recv cycle performs no allocation — the loopback alloc test
//! counts the client's side of the wire too, so this matters for the
//! <1-alloc-per-request proof, not just throughput.

use std::io::Read;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cnn::models::Model;
use crate::coordinator::engine::lock;
use crate::coordinator::net::frame::{
    decode_header, encode_header, extend_f32s, read_f32_payload, read_full_or_eof, write_frame,
};
use crate::coordinator::net::protocol::{
    model_from_wire, model_to_wire, variant_to_wire, FrameHeader, FrameKind, HEADER_LEN,
    METERING_LEN,
};
use crate::coordinator::request::{pick_weighted, SimMetering, Variant};
use crate::error::{Error, Result};
use crate::util::prng::Rng;
use crate::util::units::{ms, Millijoules, Millis};

/// One reply frame as decoded by [`NetClient::recv`]. Payload-bearing
/// variants borrow the client's reused scratch buffers — copy out only
/// what you keep.
#[derive(Debug)]
pub enum NetReply<'a> {
    Response(NetResponse<'a>),
    /// The server shed the request under backpressure; retry later.
    Busy { id: u64 },
    /// A per-request or connection-level failure.
    Failed { id: u64, message: &'a str },
    /// A stats snapshot (JSON text).
    Stats(&'a str),
    /// End of stream (explicit FIN frame, or a clean close).
    Fin,
}

/// One served response, logits borrowed from the client's scratch.
#[derive(Debug)]
pub struct NetResponse<'a> {
    pub id: u64,
    pub model: Model,
    pub predicted: usize,
    /// The batch's simulated hardware metering, bit-exact through the
    /// wire (f64 LE roundtrip).
    pub sim: SimMetering,
    pub logits: &'a [f32],
}

/// A connected wire-protocol client.
pub struct NetClient {
    stream: TcpStream,
    /// Reused encode scratch for submit payloads.
    encode: Vec<u8>,
    /// Reused decode scratch for response logits.
    logits: Vec<f32>,
    /// Reused decode scratch for text payloads (error/stats).
    text: Vec<u8>,
}

impl NetClient {
    /// Connect to a server (e.g. `"127.0.0.1:7878"`).
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient {
            stream,
            encode: Vec::new(),
            logits: Vec::new(),
            text: Vec::new(),
        })
    }

    /// A second handle over the same connection with its own scratch
    /// buffers — one half submits while the other receives.
    pub fn try_clone(&self) -> Result<NetClient> {
        Ok(NetClient {
            stream: self.stream.try_clone()?,
            encode: Vec::new(),
            logits: Vec::new(),
            text: Vec::new(),
        })
    }

    /// Submit one inference request (`pixels` must carry the model's
    /// `input_elems()` values). One vectored write, no allocation after
    /// the scratch has warmed to the largest submitted image.
    pub fn submit(&mut self, id: u64, model: Model, variant: Variant, pixels: &[f32]) -> Result<()> {
        let mut hdr = [0u8; HEADER_LEN];
        encode_header(
            &FrameHeader {
                kind: FrameKind::Submit,
                model: model_to_wire(model),
                variant: variant_to_wire(variant),
                id,
                payload_len: (pixels.len() * 4) as u32,
                aux: 0,
            },
            &mut hdr,
        );
        self.encode.clear();
        extend_f32s(&mut self.encode, pixels);
        write_frame(&mut self.stream, &hdr, &self.encode)?;
        Ok(())
    }

    /// Ask for a stats snapshot (answered as [`NetReply::Stats`], in
    /// stream order relative to in-flight responses).
    pub fn request_stats(&mut self) -> Result<()> {
        self.control(FrameKind::StatsReq)
    }

    /// Ask the server to drain: every in-flight request completes, its
    /// response is flushed, then the stream ends with [`NetReply::Fin`].
    pub fn drain(&mut self) -> Result<()> {
        self.control(FrameKind::Drain)
    }

    fn control(&mut self, kind: FrameKind) -> Result<()> {
        let mut hdr = [0u8; HEADER_LEN];
        encode_header(&FrameHeader::control(kind), &mut hdr);
        write_frame(&mut self.stream, &hdr, &[])?;
        Ok(())
    }

    /// Block for the next reply frame. A clean close at a frame boundary
    /// decodes as [`NetReply::Fin`].
    pub fn recv(&mut self) -> Result<NetReply<'_>> {
        let mut hdr = [0u8; HEADER_LEN];
        if !read_full_or_eof(&mut self.stream, &mut hdr)? {
            return Ok(NetReply::Fin);
        }
        let h = decode_header(&hdr)?;
        match h.kind {
            FrameKind::Response => {
                if (h.payload_len as usize) < METERING_LEN || h.payload_len as usize % 4 != 0 {
                    return Err(Error::Serving(format!(
                        "response payload_len {} cannot carry metering + logits",
                        h.payload_len
                    )));
                }
                let mut metering = [0u8; METERING_LEN];
                self.stream.read_exact(&mut metering)?;
                let sim = SimMetering {
                    hw_latency_ms: Millis::new(f64::from_le_bytes(
                        metering[0..8].try_into().expect("metering field size"),
                    )),
                    hw_contended_ms: Millis::new(f64::from_le_bytes(
                        metering[8..16].try_into().expect("metering field size"),
                    )),
                    hw_energy_mj: Millijoules::new(f64::from_le_bytes(
                        metering[16..24].try_into().expect("metering field size"),
                    )),
                };
                let n = (h.payload_len as usize - METERING_LEN) / 4;
                self.logits.resize(n, 0.0);
                read_f32_payload(&mut self.stream, &mut self.logits)?;
                let model = model_from_wire(h.model).ok_or_else(|| {
                    Error::Serving(format!("response names unknown model byte {}", h.model))
                })?;
                Ok(NetReply::Response(NetResponse {
                    id: h.id,
                    model,
                    predicted: h.aux as usize,
                    sim,
                    logits: &self.logits,
                }))
            }
            FrameKind::Busy => Ok(NetReply::Busy { id: h.id }),
            FrameKind::Error | FrameKind::Stats => {
                self.text.resize(h.payload_len as usize, 0);
                self.stream.read_exact(&mut self.text)?;
                let text = std::str::from_utf8(&self.text)
                    .map_err(|_| Error::Serving("non-UTF-8 text payload".into()))?;
                Ok(if h.kind == FrameKind::Error {
                    NetReply::Failed {
                        id: h.id,
                        message: text,
                    }
                } else {
                    NetReply::Stats(text)
                })
            }
            FrameKind::Fin => Ok(NetReply::Fin),
            k => Err(Error::Serving(format!(
                "unexpected server frame kind {k:?}"
            ))),
        }
    }

    /// Close the submit direction (the server keeps flushing replies
    /// until its side finishes).
    pub fn close_write(&mut self) -> Result<()> {
        self.stream.shutdown(Shutdown::Write)?;
        Ok(())
    }
}

/// Open-loop load-generator configuration (shared by the CLI's
/// `serve --listen` self-drive, the `net_inference` example and the
/// `net_throughput` bench).
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Server address, e.g. `"127.0.0.1:7878"`.
    pub addr: String,
    /// Concurrent client connections.
    pub connections: usize,
    /// Requests each connection submits.
    pub requests_per_conn: usize,
    /// Aggregate arrival rate in requests/s across all connections;
    /// `0.0` submits as fast as the window allows.
    pub rate_rps: f64,
    /// Weighted model mix (`parse_mix` grammar).
    pub mix: Vec<(Model, u64)>,
    pub variant: Variant,
    /// Max in-flight requests per connection (submission waits above
    /// it, bounding client-side memory and pool pressure).
    pub window: usize,
    pub seed: u64,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            connections: 1,
            requests_per_conn: 256,
            rate_rps: 0.0,
            mix: vec![(Model::LeNet, 1)],
            variant: Variant::Int8,
            window: 32,
            seed: 7,
        }
    }
}

/// What one load-generator run measured, aggregated over connections.
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    pub sent: u64,
    pub responses: u64,
    pub busy: u64,
    pub failed: u64,
    pub wall_ms: Millis,
    /// Responses per second of wall time.
    pub rps: f64,
    /// Client-observed round-trip percentiles over responses.
    pub p50_ms: Millis,
    pub p99_ms: Millis,
}

/// In-flight window: submission blocks while `window` requests await
/// replies, so an open-loop burst cannot balloon client memory.
#[derive(Default)]
struct Window {
    in_flight: Mutex<usize>,
    freed: Condvar,
}

impl Window {
    fn acquire(&self, cap: usize) {
        let mut n = lock(&self.in_flight);
        while *n >= cap {
            n = self.freed.wait(n).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        *n += 1;
    }

    fn release(&self) {
        let mut n = lock(&self.in_flight);
        *n = n.saturating_sub(1);
        self.freed.notify_one();
    }
}

/// Run the open-loop load: `connections` parallel client connections,
/// each submitting `requests_per_conn` requests (windowed, optionally
/// paced), then draining. Returns the aggregated report.
pub fn run_load(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    if cfg.connections == 0 || cfg.requests_per_conn == 0 {
        return Err(Error::Config("load generator needs ≥1 connection and ≥1 request".into()));
    }
    if cfg.mix.is_empty() {
        return Err(Error::Config("load generator mix lists no models".into()));
    }
    let started = Instant::now();
    let pace = if cfg.rate_rps > 0.0 {
        Some(Duration::from_secs_f64(cfg.connections as f64 / cfg.rate_rps))
    } else {
        None
    };
    let mut totals = LoadGenReport::default();
    let mut rtts_ms: Vec<f64> = Vec::new();
    let conn_results: Result<Vec<ConnReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|c| s.spawn(move || run_conn(cfg, c, pace)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| Error::Serving("load connection panicked".into()))?)
            .collect()
    });
    for conn in conn_results? {
        totals.sent += conn.sent;
        totals.responses += conn.responses;
        totals.busy += conn.busy;
        totals.failed += conn.failed;
        rtts_ms.extend(conn.rtts_ms);
    }
    let wall_s = started.elapsed().as_secs_f64();
    totals.wall_ms = ms(wall_s * 1e3);
    totals.rps = totals.responses as f64 / wall_s.max(1e-9);
    rtts_ms.sort_by(f64::total_cmp);
    totals.p50_ms = ms(percentile(&rtts_ms, 0.50));
    totals.p99_ms = ms(percentile(&rtts_ms, 0.99));
    Ok(totals)
}

struct ConnReport {
    sent: u64,
    responses: u64,
    busy: u64,
    failed: u64,
    rtts_ms: Vec<f64>,
}

fn run_conn(cfg: &LoadGenConfig, conn_idx: usize, pace: Option<Duration>) -> Result<ConnReport> {
    let mut tx = NetClient::connect(&cfg.addr)?;
    let mut rx = tx.try_clone()?;
    let window = Arc::new(Window::default());
    let cap = cfg.window.max(1);
    // Request k on this connection gets id (conn << 32) | k; the start
    // slab is indexed by k for RTT measurement on the receive side.
    let starts: Arc<Mutex<Vec<Option<Instant>>>> =
        Arc::new(Mutex::new(vec![None; cfg.requests_per_conn]));
    let mut rng = Rng::new(cfg.seed.wrapping_add(conn_idx as u64 * 0x9E37_79B9));
    // One pre-generated image per mixed model, reused across requests
    // (the server decodes into pooled buffers either way).
    let models: Vec<Model> = cfg.mix.iter().map(|(m, _)| *m).collect();
    let images: Vec<(Model, Vec<f32>)> = models
        .iter()
        .map(|m| {
            let px = (0..m.input_elems()).map(|_| rng.f64() as f32).collect();
            (*m, px)
        })
        .collect();

    std::thread::scope(|s| {
        let recv_window = Arc::clone(&window);
        let recv_starts = Arc::clone(&starts);
        let receiver = s.spawn(move || -> Result<ConnReport> {
            let mut rep = ConnReport {
                sent: 0,
                responses: 0,
                busy: 0,
                failed: 0,
                rtts_ms: Vec::new(),
            };
            loop {
                match rx.recv()? {
                    NetReply::Response(r) => {
                        rep.responses += 1;
                        let k = (r.id & 0xFFFF_FFFF) as usize;
                        if let Some(t0) = lock(&recv_starts).get(k).copied().flatten() {
                            rep.rtts_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        recv_window.release();
                    }
                    NetReply::Busy { .. } => {
                        rep.busy += 1;
                        recv_window.release();
                    }
                    NetReply::Failed { .. } => {
                        rep.failed += 1;
                        recv_window.release();
                    }
                    NetReply::Stats(_) => {}
                    NetReply::Fin => return Ok(rep),
                }
            }
        });

        let mut sent = 0u64;
        let mut send_err = None;
        let anchor = Instant::now();
        for k in 0..cfg.requests_per_conn {
            if let Some(interval) = pace {
                // Open-loop schedule: request k is due at anchor + k·Δ,
                // independent of how fast the server responds.
                let due = anchor + interval * k as u32;
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
            }
            window.acquire(cap);
            let (model, pixels) = {
                let pick = pick_weighted(&mut rng, &cfg.mix);
                let (m, px) = images
                    .iter()
                    .find(|(m, _)| *m == pick)
                    .expect("every mixed model has a pre-generated image");
                (*m, px.as_slice())
            };
            let id = ((conn_idx as u64) << 32) | k as u64;
            lock(&starts)[k] = Some(Instant::now());
            if let Err(e) = tx.submit(id, model, cfg.variant, pixels) {
                send_err = Some(e);
                break;
            }
            sent += 1;
        }
        // End of quota: ask for a drain so every in-flight response is
        // flushed, then the receiver sees Fin and returns.
        if send_err.is_none() {
            if let Err(e) = tx.drain() {
                send_err = Some(e);
            }
        }
        if send_err.is_some() {
            // Can't drain cleanly — close our write half so the server
            // EOFs, flushes, and Fins (the receiver must not hang).
            let _ = tx.close_write();
        }
        let mut rep = receiver
            .join()
            .map_err(|_| Error::Serving("load receiver panicked".into()))??;
        rep.sent = sent;
        if let Some(e) = send_err {
            return Err(e);
        }
        Ok(rep)
    })
}

/// Nearest-rank percentile over an ascending-sorted sample (0 when
/// empty).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.5), 3.0);
        assert_eq!(percentile(&s, 0.99), 5.0);
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn window_blocks_at_capacity_and_releases() {
        let w = Arc::new(Window::default());
        w.acquire(2);
        w.acquire(2);
        let blocked = Arc::clone(&w);
        let t = std::thread::spawn(move || {
            blocked.acquire(2); // parks until a release
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished(), "third acquire waits at window 2");
        w.release();
        t.join().unwrap();
    }
}
