//! Wire-protocol client: a reusable per-connection codec plus the
//! multi-connection open-loop load generator behind `serve --listen`
//! self-drive, the `net_inference` example and
//! `benches/net_throughput.rs`.
//!
//! [`NetClient`] owns one TCP stream and three reused scratch buffers
//! (encode bytes, decoded logits, decoded text); after warmup, a
//! submit/recv cycle performs no allocation — the loopback alloc test
//! counts the client's side of the wire too, so this matters for the
//! <1-alloc-per-request proof, not just throughput.

use std::io::Read;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::cnn::models::Model;
use crate::coordinator::engine::lock;
use crate::coordinator::net::frame::{
    decode_header, encode_header, extend_f32s, read_f32_payload, read_full_or_eof, write_frame,
};
use crate::coordinator::net::protocol::{
    model_from_wire, model_to_wire, variant_to_wire, FrameHeader, FrameKind, HEADER_LEN,
    METERING_LEN,
};
use crate::coordinator::request::{pick_weighted, SimMetering, Variant};
use crate::error::{Error, Result};
use crate::util::prng::Rng;
use crate::util::units::{ms, Millijoules, Millis};

/// One reply frame as decoded by [`NetClient::recv`]. Payload-bearing
/// variants borrow the client's reused scratch buffers — copy out only
/// what you keep.
#[derive(Debug)]
pub enum NetReply<'a> {
    Response(NetResponse<'a>),
    /// The server shed the request under backpressure; retry later.
    Busy { id: u64 },
    /// The request's deadline expired before batch formation — a
    /// terminal outcome (retrying needs a fresh deadline budget).
    DeadlineExceeded { id: u64 },
    /// A per-request or connection-level failure.
    Failed { id: u64, message: &'a str },
    /// A stats snapshot (JSON text).
    Stats(&'a str),
    /// End of stream (explicit FIN frame, or a clean close).
    Fin,
}

/// One served response, logits borrowed from the client's scratch.
#[derive(Debug)]
pub struct NetResponse<'a> {
    pub id: u64,
    pub model: Model,
    pub predicted: usize,
    /// The batch's simulated hardware metering, bit-exact through the
    /// wire (f64 LE roundtrip).
    pub sim: SimMetering,
    pub logits: &'a [f32],
}

/// A connected wire-protocol client.
pub struct NetClient {
    stream: TcpStream,
    /// Reused encode scratch for submit payloads.
    encode: Vec<u8>,
    /// Reused decode scratch for response logits.
    logits: Vec<f32>,
    /// Reused decode scratch for text payloads (error/stats).
    text: Vec<u8>,
}

impl NetClient {
    /// Connect to a server (e.g. `"127.0.0.1:7878"`).
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient {
            stream,
            encode: Vec::new(),
            logits: Vec::new(),
            text: Vec::new(),
        })
    }

    /// A second handle over the same connection with its own scratch
    /// buffers — one half submits while the other receives.
    pub fn try_clone(&self) -> Result<NetClient> {
        Ok(NetClient {
            stream: self.stream.try_clone()?,
            encode: Vec::new(),
            logits: Vec::new(),
            text: Vec::new(),
        })
    }

    /// Submit one inference request (`pixels` must carry the model's
    /// `input_elems()` values). One vectored write, no allocation after
    /// the scratch has warmed to the largest submitted image.
    pub fn submit(&mut self, id: u64, model: Model, variant: Variant, pixels: &[f32]) -> Result<()> {
        self.submit_with_deadline(id, model, variant, pixels, 0)
    }

    /// [`NetClient::submit`] with a per-request deadline budget in whole
    /// milliseconds, carried in the header's `aux` slot (0 = none). The
    /// budget is measured by the *server* from receipt — client and
    /// server clocks never meet — and a request still queued past it is
    /// answered with a terminal `DEADLINE_EXCEEDED` frame instead of a
    /// response.
    pub fn submit_with_deadline(
        &mut self,
        id: u64,
        model: Model,
        variant: Variant,
        pixels: &[f32],
        deadline_ms: u32,
    ) -> Result<()> {
        let mut hdr = [0u8; HEADER_LEN];
        encode_header(
            &FrameHeader {
                kind: FrameKind::Submit,
                model: model_to_wire(model),
                variant: variant_to_wire(variant),
                id,
                payload_len: (pixels.len() * 4) as u32,
                aux: deadline_ms,
            },
            &mut hdr,
        );
        self.encode.clear();
        extend_f32s(&mut self.encode, pixels);
        write_frame(&mut self.stream, &hdr, &self.encode)?;
        Ok(())
    }

    /// Ask for a stats snapshot (answered as [`NetReply::Stats`], in
    /// stream order relative to in-flight responses).
    pub fn request_stats(&mut self) -> Result<()> {
        self.control(FrameKind::StatsReq)
    }

    /// Ask the server to drain: every in-flight request completes, its
    /// response is flushed, then the stream ends with [`NetReply::Fin`].
    pub fn drain(&mut self) -> Result<()> {
        self.control(FrameKind::Drain)
    }

    fn control(&mut self, kind: FrameKind) -> Result<()> {
        let mut hdr = [0u8; HEADER_LEN];
        encode_header(&FrameHeader::control(kind), &mut hdr);
        write_frame(&mut self.stream, &hdr, &[])?;
        Ok(())
    }

    /// Block for the next reply frame. A clean close at a frame boundary
    /// decodes as [`NetReply::Fin`].
    pub fn recv(&mut self) -> Result<NetReply<'_>> {
        let mut hdr = [0u8; HEADER_LEN];
        if !read_full_or_eof(&mut self.stream, &mut hdr)? {
            return Ok(NetReply::Fin);
        }
        let h = decode_header(&hdr)?;
        match h.kind {
            FrameKind::Response => {
                if (h.payload_len as usize) < METERING_LEN || h.payload_len as usize % 4 != 0 {
                    return Err(Error::Serving(format!(
                        "response payload_len {} cannot carry metering + logits",
                        h.payload_len
                    )));
                }
                let mut metering = [0u8; METERING_LEN];
                self.stream.read_exact(&mut metering)?;
                let sim = SimMetering {
                    hw_latency_ms: Millis::new(f64::from_le_bytes(
                        metering[0..8].try_into().expect("metering field size"),
                    )),
                    hw_contended_ms: Millis::new(f64::from_le_bytes(
                        metering[8..16].try_into().expect("metering field size"),
                    )),
                    hw_energy_mj: Millijoules::new(f64::from_le_bytes(
                        metering[16..24].try_into().expect("metering field size"),
                    )),
                };
                let n = (h.payload_len as usize - METERING_LEN) / 4;
                self.logits.resize(n, 0.0);
                read_f32_payload(&mut self.stream, &mut self.logits)?;
                let model = model_from_wire(h.model).ok_or_else(|| {
                    Error::Serving(format!("response names unknown model byte {}", h.model))
                })?;
                Ok(NetReply::Response(NetResponse {
                    id: h.id,
                    model,
                    predicted: h.aux as usize,
                    sim,
                    logits: &self.logits,
                }))
            }
            FrameKind::Busy => Ok(NetReply::Busy { id: h.id }),
            FrameKind::DeadlineExceeded => Ok(NetReply::DeadlineExceeded { id: h.id }),
            FrameKind::Error | FrameKind::Stats => {
                self.text.resize(h.payload_len as usize, 0);
                self.stream.read_exact(&mut self.text)?;
                let text = std::str::from_utf8(&self.text)
                    .map_err(|_| Error::Serving("non-UTF-8 text payload".into()))?;
                Ok(if h.kind == FrameKind::Error {
                    NetReply::Failed {
                        id: h.id,
                        message: text,
                    }
                } else {
                    NetReply::Stats(text)
                })
            }
            FrameKind::Fin => Ok(NetReply::Fin),
            k => Err(Error::Serving(format!(
                "unexpected server frame kind {k:?}"
            ))),
        }
    }

    /// Close the submit direction (the server keeps flushing replies
    /// until its side finishes).
    pub fn close_write(&mut self) -> Result<()> {
        self.stream.shutdown(Shutdown::Write)?;
        Ok(())
    }
}

/// Open-loop load-generator configuration (shared by the CLI's
/// `serve --listen` self-drive, the `net_inference` example and the
/// `net_throughput` bench).
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Server address, e.g. `"127.0.0.1:7878"`.
    pub addr: String,
    /// Concurrent client connections.
    pub connections: usize,
    /// Requests each connection submits.
    pub requests_per_conn: usize,
    /// Aggregate arrival rate in requests/s across all connections;
    /// `0.0` submits as fast as the window allows.
    pub rate_rps: f64,
    /// Weighted model mix (`parse_mix` grammar).
    pub mix: Vec<(Model, u64)>,
    pub variant: Variant,
    /// Max in-flight requests per connection (submission waits above
    /// it, bounding client-side memory and pool pressure).
    pub window: usize,
    pub seed: u64,
    /// Max automatic re-submissions after a `BUSY` shed (0 — the
    /// default — reports the shed and moves on, keeping the
    /// no-retry benches byte-identical in behavior).
    pub retry_max: u32,
    /// Base backoff before the first retry; doubles per attempt with
    /// 50–100% jitter from the connection's seeded RNG.
    pub retry_backoff: Millis,
    /// Ceiling on the (pre-jitter, post-doubling) retry backoff.
    pub retry_backoff_cap: Millis,
    /// Per-request deadline budget in whole milliseconds carried in the
    /// SUBMIT header's `aux` slot (0 = no deadline).
    pub deadline_ms: u32,
}

impl Default for LoadGenConfig {
    fn default() -> Self {
        Self {
            addr: String::new(),
            connections: 1,
            requests_per_conn: 256,
            rate_rps: 0.0,
            mix: vec![(Model::LeNet, 1)],
            variant: Variant::Int8,
            window: 32,
            seed: 7,
            retry_max: 0,
            retry_backoff: ms(1.0),
            retry_backoff_cap: ms(50.0),
            deadline_ms: 0,
        }
    }
}

/// What one load-generator run measured, aggregated over connections.
#[derive(Debug, Clone, Default)]
pub struct LoadGenReport {
    pub sent: u64,
    pub responses: u64,
    /// Terminal `BUSY` sheds — rate-limit or backpressure rejections
    /// that exhausted the retry budget (or had none). A separate bucket
    /// from `failed`: a shed request was never accepted, a failed one
    /// was accepted and lost.
    pub busy: u64,
    pub failed: u64,
    /// Terminal `DEADLINE_EXCEEDED` outcomes.
    pub expired: u64,
    /// `BUSY` sheds that were re-submitted. Retries are *attempts*, not
    /// outcomes — each retried request still lands in exactly one of
    /// `responses`/`busy`/`failed`/`expired`, so
    /// `sent = responses + busy + failed + expired` holds with or
    /// without retries.
    pub retries: u64,
    pub wall_ms: Millis,
    /// Responses per second of wall time.
    pub rps: f64,
    /// Client-observed round-trip percentiles over responses.
    pub p50_ms: Millis,
    pub p99_ms: Millis,
}

/// In-flight window: submission blocks while `window` requests await
/// replies, so an open-loop burst cannot balloon client memory.
#[derive(Default)]
struct Window {
    in_flight: Mutex<usize>,
    freed: Condvar,
}

impl Window {
    fn acquire(&self, cap: usize) {
        let mut n = lock(&self.in_flight);
        while *n >= cap {
            n = self.freed.wait(n).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        *n += 1;
    }

    fn release(&self) {
        let mut n = lock(&self.in_flight);
        *n = n.saturating_sub(1);
        self.freed.notify_one();
    }

    /// Block until the window is empty — every request has a terminal
    /// reply — or `deadline` passes or `abort` turns true (a finished
    /// receiver will never release another slot, so waiting on is
    /// pointless). Short waits, not notify-dependent: a missed wakeup
    /// costs at most one poll period.
    fn wait_idle(&self, deadline: Instant, abort: impl Fn() -> bool) -> bool {
        let mut n = lock(&self.in_flight);
        while *n > 0 {
            if Instant::now() >= deadline || abort() {
                return false;
            }
            let (g, _) = self
                .freed
                .wait_timeout(n, Duration::from_millis(10))
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            n = g;
        }
        true
    }
}

/// Pending-retry handoff between the receiver thread (which observes
/// `BUSY` sheds) and the retrier thread (which re-submits after a
/// capped, jittered backoff). `close` lets already-queued retries drain
/// before `pop` starts answering `None`.
struct RetryQueue {
    state: Mutex<(std::collections::VecDeque<(usize, u32)>, bool)>,
    wake: Condvar,
}

impl RetryQueue {
    fn new() -> RetryQueue {
        RetryQueue {
            state: Mutex::new((std::collections::VecDeque::new(), false)),
            wake: Condvar::new(),
        }
    }

    /// Queue request `k` for its `attempt`-th re-submission.
    fn push(&self, k: usize, attempt: u32) {
        lock(&self.state).0.push_back((k, attempt));
        self.wake.notify_one();
    }

    fn close(&self) {
        lock(&self.state).1 = true;
        self.wake.notify_all();
    }

    fn pop(&self) -> Option<(usize, u32)> {
        let mut st = lock(&self.state);
        loop {
            if let Some(item) = st.0.pop_front() {
                return Some(item);
            }
            if st.1 {
                return None;
            }
            st = self.wake.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }
}

/// Run the open-loop load: `connections` parallel client connections,
/// each submitting `requests_per_conn` requests (windowed, optionally
/// paced), then draining. Returns the aggregated report.
pub fn run_load(cfg: &LoadGenConfig) -> Result<LoadGenReport> {
    if cfg.connections == 0 || cfg.requests_per_conn == 0 {
        return Err(Error::Config("load generator needs ≥1 connection and ≥1 request".into()));
    }
    if cfg.mix.is_empty() {
        return Err(Error::Config("load generator mix lists no models".into()));
    }
    let started = Instant::now();
    let pace = if cfg.rate_rps > 0.0 {
        Some(Duration::from_secs_f64(cfg.connections as f64 / cfg.rate_rps))
    } else {
        None
    };
    let mut totals = LoadGenReport::default();
    let mut rtts_ms: Vec<f64> = Vec::new();
    let conn_results: Result<Vec<ConnReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|c| s.spawn(move || run_conn(cfg, c, pace)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| Error::Serving("load connection panicked".into()))?)
            .collect()
    });
    for conn in conn_results? {
        totals.sent += conn.sent;
        totals.responses += conn.responses;
        totals.busy += conn.busy;
        totals.failed += conn.failed;
        totals.expired += conn.expired;
        totals.retries += conn.retries;
        rtts_ms.extend(conn.rtts_ms);
    }
    let wall_s = started.elapsed().as_secs_f64();
    totals.wall_ms = ms(wall_s * 1e3);
    totals.rps = totals.responses as f64 / wall_s.max(1e-9);
    rtts_ms.sort_by(f64::total_cmp);
    totals.p50_ms = ms(percentile(&rtts_ms, 0.50));
    totals.p99_ms = ms(percentile(&rtts_ms, 0.99));
    Ok(totals)
}

struct ConnReport {
    sent: u64,
    responses: u64,
    busy: u64,
    failed: u64,
    expired: u64,
    retries: u64,
    rtts_ms: Vec<f64>,
}

fn run_conn(cfg: &LoadGenConfig, conn_idx: usize, pace: Option<Duration>) -> Result<ConnReport> {
    let tx = Mutex::new(NetClient::connect(&cfg.addr)?);
    let mut rx = lock(&tx).try_clone()?;
    let window = Arc::new(Window::default());
    let cap = cfg.window.max(1);
    let retry_max = cfg.retry_max;
    // Request k on this connection gets id (conn << 32) | k; the start
    // slab is indexed by k for RTT measurement on the receive side.
    let starts: Arc<Mutex<Vec<Option<Instant>>>> =
        Arc::new(Mutex::new(vec![None; cfg.requests_per_conn]));
    // Per-request shed count (how many times k came back BUSY) and the
    // image index k was submitted with, for faithful re-submission.
    let attempts: Mutex<Vec<u32>> = Mutex::new(vec![0; cfg.requests_per_conn]);
    let picks: Mutex<Vec<u8>> = Mutex::new(vec![0; cfg.requests_per_conn]);
    let retry_q = RetryQueue::new();
    let mut rng = Rng::new(cfg.seed.wrapping_add(conn_idx as u64 * 0x9E37_79B9));
    // One pre-generated image per mixed model, reused across requests
    // (the server decodes into pooled buffers either way).
    let models: Vec<Model> = cfg.mix.iter().map(|(m, _)| *m).collect();
    let images: Vec<(Model, Vec<f32>)> = models
        .iter()
        .map(|m| {
            let px = (0..m.input_elems()).map(|_| rng.f64() as f32).collect();
            (*m, px)
        })
        .collect();

    std::thread::scope(|s| {
        let recv_window = Arc::clone(&window);
        let recv_starts = Arc::clone(&starts);
        let recv_attempts = &attempts;
        let recv_q = &retry_q;
        let receiver = s.spawn(move || -> Result<ConnReport> {
            let mut rep = ConnReport {
                sent: 0,
                responses: 0,
                busy: 0,
                failed: 0,
                expired: 0,
                retries: 0,
                rtts_ms: Vec::new(),
            };
            loop {
                match rx.recv()? {
                    NetReply::Response(r) => {
                        rep.responses += 1;
                        let k = (r.id & 0xFFFF_FFFF) as usize;
                        if let Some(t0) = lock(&recv_starts).get(k).copied().flatten() {
                            rep.rtts_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        recv_window.release();
                    }
                    NetReply::Busy { id } => {
                        let k = (id & 0xFFFF_FFFF) as usize;
                        // Count this shed against k's retry budget; an
                        // out-of-range k (a connection-level BUSY) gets
                        // no retries.
                        let attempt = match lock(recv_attempts).get_mut(k) {
                            Some(slot) => {
                                *slot += 1;
                                *slot
                            }
                            None => retry_max.saturating_add(1),
                        };
                        if attempt <= retry_max {
                            // Still in flight: the window slot stays
                            // held until the retry's terminal reply.
                            rep.retries += 1;
                            recv_q.push(k, attempt);
                        } else {
                            rep.busy += 1;
                            recv_window.release();
                        }
                    }
                    NetReply::DeadlineExceeded { .. } => {
                        rep.expired += 1;
                        recv_window.release();
                    }
                    NetReply::Failed { .. } => {
                        rep.failed += 1;
                        recv_window.release();
                    }
                    NetReply::Stats(_) => {}
                    NetReply::Fin => return Ok(rep),
                }
            }
        });

        // The retrier re-submits BUSY-shed requests after a capped,
        // jittered exponential backoff. Spawned only when retries are
        // on, so the default no-retry path keeps its exact thread
        // structure.
        let retrier = (retry_max > 0).then(|| {
            let q = &retry_q;
            let tx = &tx;
            let images = &images;
            let picks = &picks;
            let base = cfg.retry_backoff.raw().max(0.0);
            let cap_ms = cfg.retry_backoff_cap.raw().max(base);
            let variant = cfg.variant;
            let deadline_ms = cfg.deadline_ms;
            // Decorrelated from the sender's pick stream.
            let mut rng = Rng::new(
                cfg.seed
                    .wrapping_add(conn_idx as u64 * 0x9E37_79B9)
                    .wrapping_add(0xC0FF_EE),
            );
            s.spawn(move || {
                while let Some((k, attempt)) = q.pop() {
                    let doubled = base * 2f64.powi(attempt.saturating_sub(1).min(20) as i32);
                    // 50–100% jitter decorrelates colliding retriers.
                    let jitter = 0.5 + rng.f64() * 0.5;
                    std::thread::sleep(ms((doubled * jitter).min(cap_ms)).to_duration());
                    let idx = lock(picks)[k] as usize;
                    let (model, px) = &images[idx];
                    let id = ((conn_idx as u64) << 32) | k as u64;
                    if lock(tx)
                        .submit_with_deadline(id, *model, variant, px, deadline_ms)
                        .is_err()
                    {
                        // Connection dead: the sender's drain/close
                        // teardown owns the ending; queued retries
                        // can't land anyway.
                        return;
                    }
                }
            })
        });

        let mut sent = 0u64;
        let mut send_err = None;
        let anchor = Instant::now();
        for k in 0..cfg.requests_per_conn {
            if let Some(interval) = pace {
                // Open-loop schedule: request k is due at anchor + k·Δ,
                // independent of how fast the server responds.
                let due = anchor + interval * k as u32;
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
            }
            window.acquire(cap);
            let (idx, model, pixels) = {
                let pick = pick_weighted(&mut rng, &cfg.mix);
                let i = images
                    .iter()
                    .position(|(m, _)| *m == pick)
                    .expect("every mixed model has a pre-generated image");
                (i, images[i].0, images[i].1.as_slice())
            };
            let id = ((conn_idx as u64) << 32) | k as u64;
            lock(&picks)[k] = idx as u8;
            lock(&starts)[k] = Some(Instant::now());
            if let Err(e) = lock(&tx).submit_with_deadline(id, model, cfg.variant, pixels, cfg.deadline_ms) {
                send_err = Some(e);
                break;
            }
            sent += 1;
        }
        // End of quota. With retries on, wait for every window slot to
        // release first: a BUSY observed *after* the retry queue closes
        // would strand its request without a terminal outcome, and a
        // retry submitted after the Drain frame would go unanswered.
        // The wait is bounded and aborts if the receiver is already
        // gone (a dead connection releases nothing).
        if send_err.is_none() && retry_max > 0 {
            window.wait_idle(Instant::now() + Duration::from_secs(5), || {
                receiver.is_finished()
            });
        }
        retry_q.close();
        if let Some(h) = retrier {
            // Joined before Drain: every queued retry is on the wire
            // ahead of the drain request, so the server answers it
            // before Fin.
            let _ = h.join();
        }
        // Ask for a drain so every in-flight response is flushed, then
        // the receiver sees Fin and returns.
        if send_err.is_none() {
            if let Err(e) = lock(&tx).drain() {
                send_err = Some(e);
            }
        }
        if send_err.is_some() {
            // Can't drain cleanly — close our write half so the server
            // EOFs, flushes, and Fins (the receiver must not hang).
            let _ = lock(&tx).close_write();
        }
        let mut rep = receiver
            .join()
            .map_err(|_| Error::Serving("load receiver panicked".into()))??;
        rep.sent = sent;
        if let Some(e) = send_err {
            return Err(e);
        }
        Ok(rep)
    })
}

/// Nearest-rank percentile over an ascending-sorted sample (0 when
/// empty).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&s, 0.5), 3.0);
        assert_eq!(percentile(&s, 0.99), 5.0);
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn window_blocks_at_capacity_and_releases() {
        let w = Arc::new(Window::default());
        w.acquire(2);
        w.acquire(2);
        let blocked = Arc::clone(&w);
        let t = std::thread::spawn(move || {
            blocked.acquire(2); // parks until a release
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!t.is_finished(), "third acquire waits at window 2");
        w.release();
        t.join().unwrap();
    }
}
