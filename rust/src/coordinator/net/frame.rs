//! Frame encode/decode on fixed stack buffers and caller-owned scratch.
//!
//! The codec never allocates per frame (the `frame-copy` lint rule in
//! `scripts/lint_invariants.py` keeps it that way):
//!
//! - headers encode into / decode from a `[u8; HEADER_LEN]` stack
//!   buffer;
//! - f32 payloads stream through a fixed stack chunk straight into the
//!   caller's `&mut [f32]` (a pooled image buffer on the server, a
//!   reused logits scratch on the client) — there is no intermediate
//!   per-frame `Vec<u8>`;
//! - outbound payloads encode into a caller-owned `Vec<u8>` that is
//!   cleared and refilled (capacity reused), then leave in **one
//!   vectored write** over `[header-prefix, payload]`.

use std::io::{IoSlice, Read, Write};
use std::sync::Arc;

use crate::coordinator::net::protocol::{FrameHeader, FrameKind, HEADER_LEN, MAGIC, MAX_PAYLOAD};
use crate::coordinator::request::{ImageBuf, ImagePool};
use crate::error::{Error, Result};

/// Streaming chunk for f32 payload decode/discard: 1 KiB of pixels per
/// `read_exact`, decoded in place from the stack.
const CHUNK: usize = 4096;

/// Serialize a header into its fixed stack buffer.
pub fn encode_header(h: &FrameHeader, buf: &mut [u8; HEADER_LEN]) {
    buf[0..4].copy_from_slice(&MAGIC);
    buf[4] = h.kind as u8;
    buf[5] = h.model;
    buf[6] = h.variant;
    buf[7] = 0;
    buf[8..16].copy_from_slice(&h.id.to_le_bytes());
    buf[16..20].copy_from_slice(&h.payload_len.to_le_bytes());
    buf[20..24].copy_from_slice(&h.aux.to_le_bytes());
}

/// Parse and validate a header from its fixed stack buffer: magic
/// (version), kind, the reserved byte, and the payload-length bound
/// (checked *before* anything is sized from it).
pub fn decode_header(buf: &[u8; HEADER_LEN]) -> Result<FrameHeader> {
    if buf[0..4] != MAGIC {
        return Err(Error::Serving(format!(
            "bad frame magic {:02x?} (want {:02x?} — incompatible peer or desynced stream)",
            &buf[0..4],
            MAGIC
        )));
    }
    let kind = FrameKind::from_wire(buf[4])
        .ok_or_else(|| Error::Serving(format!("unknown frame kind {}", buf[4])))?;
    if buf[7] != 0 {
        return Err(Error::Serving(format!(
            "nonzero reserved header byte {}",
            buf[7]
        )));
    }
    let payload_len = u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]);
    if payload_len > MAX_PAYLOAD {
        return Err(Error::Serving(format!(
            "frame payload_len {payload_len} exceeds MAX_PAYLOAD {MAX_PAYLOAD}"
        )));
    }
    Ok(FrameHeader {
        kind,
        model: buf[5],
        variant: buf[6],
        id: u64::from_le_bytes([
            buf[8], buf[9], buf[10], buf[11], buf[12], buf[13], buf[14], buf[15],
        ]),
        payload_len,
        aux: u32::from_le_bytes([buf[20], buf[21], buf[22], buf[23]]),
    })
}

/// Fill `buf` completely from the stream. `Ok(true)` means filled;
/// `Ok(false)` means the peer closed cleanly *before the first byte* —
/// an end of stream at a frame boundary, which is a legal FIN-less
/// close. EOF mid-buffer is an error (a truncated frame).
pub fn read_full_or_eof<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "stream closed mid-frame",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// Read exactly `out.len()` little-endian f32s from the stream into the
/// caller's buffer, streaming through a stack chunk — no intermediate
/// heap buffer of any size, ever.
pub fn read_f32_payload<R: Read>(r: &mut R, out: &mut [f32]) -> std::io::Result<()> {
    let mut chunk = [0u8; CHUNK];
    for dst in out.chunks_mut(CHUNK / 4) {
        let bytes = &mut chunk[..dst.len() * 4];
        r.read_exact(bytes)?;
        for (d, b) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
            *d = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
    }
    Ok(())
}

/// Read a submit frame's pixels **directly into a pooled image buffer**:
/// take an exclusively-owned `Arc<[f32]>` from the connection's pool,
/// fill it in place from the socket, wrap it into the request's
/// [`ImageBuf`], and hand the pool its recycling clone (free again once
/// the engine retires the request). The steady-state cost is the decode
/// itself — zero allocations.
pub fn read_pooled_image<R: Read>(
    r: &mut R,
    pool: &mut ImagePool,
    elems: usize,
) -> std::io::Result<ImageBuf> {
    let mut buf = pool.take(elems);
    let dst = Arc::get_mut(&mut buf).expect("freshly taken pool buffer is unique");
    read_f32_payload(r, dst)?;
    let image = ImageBuf::from(Arc::clone(&buf));
    pool.put(buf);
    Ok(image)
}

/// Append `src` as little-endian f32 bytes to a reused scratch vector
/// (capacity persists across frames; steady state appends without
/// allocating).
pub fn extend_f32s(dst: &mut Vec<u8>, src: &[f32]) {
    dst.reserve(src.len() * 4);
    for v in src {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

/// Consume and discard `len` payload bytes through the stack chunk —
/// keeps the stream framed after a per-request rejection without
/// buffering the junk.
pub fn discard_payload<R: Read>(r: &mut R, len: usize) -> std::io::Result<()> {
    let mut chunk = [0u8; CHUNK];
    let mut left = len;
    while left > 0 {
        let n = left.min(CHUNK);
        r.read_exact(&mut chunk[..n])?;
        left -= n;
    }
    Ok(())
}

/// Write a whole frame as **one vectored write** over `[prefix,
/// payload]` (`prefix` = header, or header + metering for responses).
/// The common case is a single syscall; a short write falls back to
/// finishing each piece with `write_all`.
pub fn write_frame<W: Write>(w: &mut W, prefix: &[u8], payload: &[u8]) -> std::io::Result<()> {
    let mut written = loop {
        match w.write_vectored(&[IoSlice::new(prefix), IoSlice::new(payload)]) {
            Ok(n) => break n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    };
    for part in [prefix, payload] {
        if written >= part.len() {
            written -= part.len();
            continue;
        }
        w.write_all(&part[written..])?;
        written = 0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::net::protocol::NONE_BYTE;
    use std::io::Cursor;

    fn header() -> FrameHeader {
        FrameHeader {
            kind: FrameKind::Submit,
            model: 2,
            variant: 1,
            id: 0xDEAD_BEEF_0042,
            payload_len: 576,
            aux: 7,
        }
    }

    #[test]
    fn header_roundtrips_bit_exactly() {
        let h = header();
        let mut buf = [0u8; HEADER_LEN];
        encode_header(&h, &mut buf);
        assert_eq!(decode_header(&buf).unwrap(), h);
        let c = FrameHeader::control(FrameKind::Fin);
        encode_header(&c, &mut buf);
        let back = decode_header(&buf).unwrap();
        assert_eq!(back.kind, FrameKind::Fin);
        assert_eq!(back.model, NONE_BYTE);
        assert_eq!(back.payload_len, 0);
    }

    #[test]
    fn decode_rejects_malformed_headers() {
        let mut buf = [0u8; HEADER_LEN];
        encode_header(&header(), &mut buf);
        let mut bad_magic = buf;
        bad_magic[0] = b'X';
        assert!(decode_header(&bad_magic).is_err(), "bad magic");
        let mut bad_kind = buf;
        bad_kind[4] = 99;
        assert!(decode_header(&bad_kind).is_err(), "unknown kind");
        let mut bad_reserved = buf;
        bad_reserved[7] = 1;
        assert!(decode_header(&bad_reserved).is_err(), "reserved byte");
        let mut oversized = buf;
        oversized[16..20].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(decode_header(&oversized).is_err(), "oversized payload_len");
        oversized[16..20].copy_from_slice(&MAX_PAYLOAD.to_le_bytes());
        assert!(decode_header(&oversized).is_ok(), "bound is inclusive");
    }

    #[test]
    fn f32_payload_roundtrips_through_the_chunked_codec() {
        // Longer than one 1024-f32 chunk, and not a multiple of it.
        let src: Vec<f32> = (0..2500).map(|i| i as f32 * 0.25 - 7.0).collect();
        let mut wire = Vec::new();
        extend_f32s(&mut wire, &src);
        assert_eq!(wire.len(), src.len() * 4);
        let mut back = vec![0f32; src.len()];
        read_f32_payload(&mut Cursor::new(&wire), &mut back).unwrap();
        assert_eq!(back, src);
        // Truncated stream: the decode reports the missing bytes.
        let mut short = vec![0f32; src.len() + 1];
        assert!(read_f32_payload(&mut Cursor::new(&wire), &mut short).is_err());
    }

    #[test]
    fn pooled_image_decode_recycles_the_connection_pool() {
        let mut pool = ImagePool::new(4);
        let src: Vec<f32> = (0..144).map(|i| i as f32).collect();
        let mut wire = Vec::new();
        extend_f32s(&mut wire, &src);
        let img = read_pooled_image(&mut Cursor::new(&wire), &mut pool, 144).unwrap();
        assert_eq!(img.as_slice(), &src[..]);
        let first_ptr = img.as_slice().as_ptr();
        assert_eq!(pool.pooled(), 1, "the recycling clone is retained");
        // While the request is alive the buffer is NOT reusable...
        let img2 = read_pooled_image(&mut Cursor::new(&wire), &mut pool, 144).unwrap();
        assert_ne!(img2.as_slice().as_ptr(), first_ptr);
        // ...and once the engine drops it, the next frame reuses it.
        drop(img);
        let img3 = read_pooled_image(&mut Cursor::new(&wire), &mut pool, 144).unwrap();
        assert_eq!(img3.as_slice().as_ptr(), first_ptr, "retired buffer reused");
    }

    #[test]
    fn vectored_write_emits_prefix_then_payload() {
        let mut out = Vec::new();
        write_frame(&mut out, &[1, 2, 3], &[4, 5]).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
        let mut empty_payload = Vec::new();
        write_frame(&mut empty_payload, &[9], &[]).unwrap();
        assert_eq!(empty_payload, vec![9]);
    }

    #[test]
    fn full_read_distinguishes_clean_close_from_truncation() {
        let mut buf = [0u8; 4];
        // Clean close at the boundary: Ok(false), nothing read.
        assert!(!read_full_or_eof(&mut Cursor::new(&[][..]), &mut buf).unwrap());
        // A full frame's worth: Ok(true).
        assert!(read_full_or_eof(&mut Cursor::new(&[1u8, 2, 3, 4][..]), &mut buf).unwrap());
        assert_eq!(buf, [1, 2, 3, 4]);
        // Truncated mid-frame: an error, not a silent partial fill.
        assert!(read_full_or_eof(&mut Cursor::new(&[1u8, 2][..]), &mut buf).is_err());
    }

    #[test]
    fn discard_keeps_the_stream_framed() {
        let mut c = Cursor::new(vec![0u8; 10_000]);
        discard_payload(&mut c, 9_000).unwrap();
        assert_eq!(c.position(), 9_000);
        assert!(discard_payload(&mut c, 2_000).is_err(), "short stream");
    }
}
