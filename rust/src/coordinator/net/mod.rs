//! Zero-copy TCP wire front end: socket-to-logits with <1 allocation
//! per request.
//!
//! A dependency-free `std::net` binary protocol over length-prefixed
//! little-endian frames (versioned magic `OPW1`), designed so the
//! engine's zero-copy data plane (DESIGN.md §3.1) extends all the way
//! to the socket boundary (§3.2):
//!
//! - [`protocol`] — frame kinds, the fixed 24-byte header, the wire
//!   encodings of models and variants, and the size bounds a hostile
//!   header is checked against.
//! - [`frame`] — the codec: stack-buffer header encode/decode, f32
//!   payloads streamed through a fixed stack chunk straight into
//!   caller-owned buffers, and single-vectored-write frame emission.
//! - [`server`] — [`NetServer`]: accept loop + per-connection
//!   reader/writer threads bridged by a [`ReplyQueue`]
//!   (workers push responses before the collector sees the outcome, so
//!   drain implies replies-queued); pooled image ingest; explicit
//!   `BUSY` under backpressure; graceful `DRAIN` → flush → `FIN`.
//! - [`client`] — [`NetClient`] (reused-scratch codec peer) and the
//!   multi-connection open-loop load generator
//!   ([`run_load`]) behind `serve --listen` self-drive, the
//!   `net_inference` example and `benches/net_throughput.rs`.
//!
//! The <1-allocation and ≤1-image-copy properties are pinned by
//! `rust/tests/net_roundtrip.rs` with a counting global allocator over
//! a real loopback socket.
//!
//! [`ReplyQueue`]: crate::coordinator::request::ReplyQueue

pub mod client;
pub mod frame;
pub mod protocol;
pub mod server;

pub use client::{run_load, LoadGenConfig, LoadGenReport, NetClient, NetReply, NetResponse};
pub use protocol::{FrameHeader, FrameKind, HEADER_LEN, MAGIC, MAX_PAYLOAD, METERING_LEN};
pub use server::NetServer;
