//! The OPIMA wire protocol: frame kinds, the fixed header, and the
//! wire encodings of [`Model`] and [`Variant`].
//!
//! Every frame is a fixed 24-byte little-endian header followed by
//! `payload_len` payload bytes (DESIGN.md §3.2 has the worked layout
//! table):
//!
//! ```text
//! offset  size  field        notes
//! 0       4     magic        b"OPW1" — protocol version 1 baked in
//! 4       1     kind         FrameKind discriminant
//! 5       1     model        SERVABLE_MODELS index; 0xFF = none
//! 6       1     variant      0 fp32, 1 int8, 2 int4; 0xFF = none
//! 7       1     reserved     must be 0
//! 8       8     id           request id (echoed on replies)
//! 16      4     payload_len  bytes following the header (≤ MAX_PAYLOAD)
//! 20      4     aux          kind-specific (RESPONSE: predicted class;
//!                            SUBMIT: deadline budget in ms, 0 = none)
//! ```
//!
//! Payloads by kind:
//! - `Submit` → `model.input_elems()` pixels as f32 LE (exactly; a
//!   mismatched length is rejected per request, the connection lives).
//! - `Response` → 24-byte metering prefix (`hw_latency_ms`,
//!   `hw_contended_ms`, `hw_energy_mj` as f64 LE — bit-exact through
//!   the wire) followed by `classes` logits as f32 LE; `aux` carries
//!   the predicted class.
//! - `Error` / `Stats` → UTF-8 text.
//! - `Busy`, `StatsReq`, `Drain`, `Fin`, `DeadlineExceeded` → empty.
//!
//! `Submit`'s `aux` carries the request's deadline budget in whole
//! milliseconds from server receipt (0 = no deadline); a request still
//! queued past its budget is answered with a terminal
//! `DeadlineExceeded` frame instead of a `Response`.

use crate::cnn::models::{Model, SERVABLE_MODELS};
use crate::coordinator::request::Variant;
use crate::error::{Error, Result};

/// Versioned magic: the protocol revision is baked into the four bytes,
/// so an incompatible peer fails on the very first frame.
pub const MAGIC: [u8; 4] = *b"OPW1";

/// Fixed frame-header length — always parsed from a stack buffer.
pub const HEADER_LEN: usize = 24;

/// `Response` payload prefix: three f64 metering fields.
pub const METERING_LEN: usize = 24;

/// Upper bound on `payload_len` (16 MiB — an order of magnitude above
/// the largest legitimate payload, VGG16's 224×224×3 pixels). Anything
/// larger is a malformed or hostile frame and is rejected at header
/// parse, before any buffer is sized from it.
pub const MAX_PAYLOAD: u32 = 1 << 24;

/// Wire value for "no model" / "no variant" header slots.
pub const NONE_BYTE: u8 = 0xFF;

/// Frame discriminants. `Submit`/`StatsReq`/`Drain` travel client →
/// server; the rest travel server → client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// One inference request (pixels in the payload).
    Submit = 1,
    /// One served response (metering + logits in the payload).
    Response = 2,
    /// The engine's bounded ingress was full — explicit shed, never a
    /// silent drop. Retry later.
    Busy = 3,
    /// A per-request or per-connection failure (UTF-8 message payload).
    Error = 4,
    /// Ask the server for a stats snapshot.
    StatsReq = 5,
    /// A stats snapshot (JSON text payload).
    Stats = 6,
    /// Ask the server to drain: every in-flight request completes and
    /// its response is flushed, then the server answers `Fin` and
    /// closes the connection.
    Drain = 7,
    /// End of stream: no further frames follow.
    Fin = 8,
    /// The request's deadline expired before it reached a batch slot —
    /// a terminal per-request outcome, like `Busy` but final (the
    /// server will never serve this id; retrying needs a new deadline).
    DeadlineExceeded = 9,
}

impl FrameKind {
    pub fn from_wire(b: u8) -> Option<FrameKind> {
        match b {
            1 => Some(FrameKind::Submit),
            2 => Some(FrameKind::Response),
            3 => Some(FrameKind::Busy),
            4 => Some(FrameKind::Error),
            5 => Some(FrameKind::StatsReq),
            6 => Some(FrameKind::Stats),
            7 => Some(FrameKind::Drain),
            8 => Some(FrameKind::Fin),
            9 => Some(FrameKind::DeadlineExceeded),
            _ => None,
        }
    }
}

/// A parsed frame header (the fixed 24 bytes, minus the magic).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameHeader {
    pub kind: FrameKind,
    /// Wire model byte ([`model_from_wire`] decodes; [`NONE_BYTE`] when
    /// the kind carries no model).
    pub model: u8,
    /// Wire variant byte ([`variant_from_wire`] decodes).
    pub variant: u8,
    pub id: u64,
    pub payload_len: u32,
    pub aux: u32,
}

impl FrameHeader {
    /// A header with no model/variant/id — control frames.
    pub fn control(kind: FrameKind) -> FrameHeader {
        FrameHeader {
            kind,
            model: NONE_BYTE,
            variant: NONE_BYTE,
            id: 0,
            payload_len: 0,
            aux: 0,
        }
    }
}

/// Model → wire byte (index into [`SERVABLE_MODELS`] — declaration
/// order is the stable wire order).
pub fn model_to_wire(m: Model) -> u8 {
    SERVABLE_MODELS
        .iter()
        .position(|x| *x == m)
        .expect("every Model is servable") as u8
}

pub fn model_from_wire(b: u8) -> Option<Model> {
    SERVABLE_MODELS.get(b as usize).copied()
}

pub fn variant_to_wire(v: Variant) -> u8 {
    match v {
        Variant::Fp32 => 0,
        Variant::Int8 => 1,
        Variant::Int4 => 2,
    }
}

pub fn variant_from_wire(b: u8) -> Option<Variant> {
    match b {
        0 => Some(Variant::Fp32),
        1 => Some(Variant::Int8),
        2 => Some(Variant::Int4),
        _ => None,
    }
}

/// Decode a submit header's model byte, or a per-request protocol error.
pub fn submit_model(h: &FrameHeader) -> Result<Model> {
    model_from_wire(h.model)
        .ok_or_else(|| Error::Serving(format!("submit names unknown model byte {}", h.model)))
}

/// Decode a submit header's variant byte, or a per-request protocol
/// error.
pub fn submit_variant(h: &FrameHeader) -> Result<Variant> {
    variant_from_wire(h.variant)
        .ok_or_else(|| Error::Serving(format!("submit names unknown variant byte {}", h.variant)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_wire_mapping_roundtrips() {
        for m in SERVABLE_MODELS {
            assert_eq!(model_from_wire(model_to_wire(m)), Some(m));
        }
        assert_eq!(model_from_wire(NONE_BYTE), None);
        // The wire order is the declaration order — pinned so peers
        // built from different checkouts stay compatible.
        assert_eq!(model_to_wire(Model::LeNet), 0);
        assert_eq!(model_to_wire(Model::Vgg16), 5);
    }

    #[test]
    fn variant_wire_mapping_roundtrips() {
        for v in [Variant::Fp32, Variant::Int8, Variant::Int4] {
            assert_eq!(variant_from_wire(variant_to_wire(v)), Some(v));
        }
        assert_eq!(variant_from_wire(3), None);
        assert_eq!(variant_from_wire(NONE_BYTE), None);
    }

    #[test]
    fn frame_kind_roundtrips_and_rejects() {
        for k in [
            FrameKind::Submit,
            FrameKind::Response,
            FrameKind::Busy,
            FrameKind::Error,
            FrameKind::StatsReq,
            FrameKind::Stats,
            FrameKind::Drain,
            FrameKind::Fin,
            FrameKind::DeadlineExceeded,
        ] {
            assert_eq!(FrameKind::from_wire(k as u8), Some(k));
        }
        assert_eq!(FrameKind::from_wire(0), None);
        assert_eq!(FrameKind::from_wire(10), None);
    }
}
