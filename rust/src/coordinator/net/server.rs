//! The TCP front end: socket-to-logits on the engine's zero-copy data
//! plane.
//!
//! [`NetServer::bind`] starts an accept loop over a dependency-free
//! `std::net` listener. Each connection gets a reader thread and a
//! writer thread bridged by one [`ReplyQueue`]:
//!
//! - the **reader** parses frame headers from a fixed stack buffer and
//!   decodes submit payloads *directly into pooled image buffers* (a
//!   per-connection [`ImagePool`], refilled as the engine retires
//!   requests), then submits with a reply handle — backpressure becomes
//!   an explicit [`Reply::Busy`], never a silent drop;
//! - the engine's **workers** push each request's response (or its
//!   batch's failure) onto the queue before the outcome reaches the
//!   collector;
//! - the **writer** pops replies and emits each response as one
//!   vectored write over `[header + metering, logits bytes]`, reusing
//!   a single scratch vector for the payload encode.
//!
//! Steady state, the whole socket→engine→socket path performs no
//! per-request allocation and copies request pixels exactly once (into
//! the worker's packed batch input) — `rust/tests/net_roundtrip.rs`
//! pins both properties with a counting global allocator.
//!
//! **Drain state machine** (DESIGN.md §3.2): a `Drain` frame makes the
//! reader stop consuming, run [`Engine::drain`] (worker reply pushes
//! happen *before* collector accounting, so a completed drain implies
//! every reply is queued), and push [`Reply::Fin`]; the writer flushes
//! everything queued ahead of the `Fin` — all in-flight responses —
//! then answers `Fin` and closes. Malformed frames fail loudly: a
//! per-request rejection (unknown model, wrong payload length) keeps
//! the connection alive, an unparseable header poisons only that
//! connection — the accept loop and every other connection keep
//! serving.
//!
//! **Degraded modes** (DESIGN.md §3.3): each connection carries two
//! `[fault]`-driven mechanisms. A per-connection [`TokenBucket`] —
//! active whenever `conn_rate_rps > 0`, independent of `armed`, because
//! it is a *defense*, not an injected fault — sheds over-rate submits
//! with a terminal `BUSY` (payload consumed, stream stays framed,
//! `Engine::note_shed` counts it). And the writer owns a [`FaultPlane`]
//! salted by accept order: under `writer_delay` a response leaves as a
//! deliberately split write (header+metering, a real scheduling gap,
//! then logits), exercising client mid-frame reassembly without ever
//! corrupting the stream.

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::FaultParams;
use crate::coordinator::engine::{lock, Engine};
use crate::coordinator::net::frame::{
    decode_header, discard_payload, encode_header, extend_f32s, read_full_or_eof,
    read_pooled_image, write_frame,
};
use crate::coordinator::net::protocol::{
    model_to_wire, submit_model, submit_variant, FrameHeader, FrameKind, HEADER_LEN, METERING_LEN,
    NONE_BYTE,
};
use crate::coordinator::request::{ImagePool, InferenceRequest, Reply, ReplyQueue};
use crate::coordinator::server::ServerStats;
use crate::error::{Error, Result};
use crate::util::fault::FaultPlane;

/// Retained free-list capacity of each connection's image pool.
const POOL_CAP: usize = 64;

/// Pre-reserved reply-queue capacity (pushes within it never allocate).
const QUEUE_WARM: usize = 256;

/// Accept-loop poll period while idle (the listener is non-blocking so
/// shutdown can interrupt it).
const ACCEPT_TICK: Duration = Duration::from_millis(2);

/// Monotone accept-order counter: each connection's writer fault site
/// gets a distinct salt, so a replayed seed replays each connection's
/// socket-fault schedule by accept order.
static CONN_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-connection token-bucket rate limiter. Admission consumes one
/// token; tokens refill continuously at `conn_rate_rps` up to
/// `conn_burst`. Over-rate submits are shed with a terminal `BUSY`
/// before they ever reach the engine's ingress queue.
struct TokenBucket {
    /// Refill rate, tokens (requests) per second.
    rate_rps: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    /// `None` when `conn_rate_rps` is 0 — the limiter is off and admits
    /// cost nothing.
    fn from_params(p: &FaultParams) -> Option<TokenBucket> {
        (p.conn_rate_rps > 0.0).then(|| TokenBucket {
            rate_rps: p.conn_rate_rps,
            burst: p.conn_burst as f64,
            tokens: p.conn_burst as f64,
            last: Instant::now(),
        })
    }

    fn admit(&mut self, now: Instant) -> bool {
        let elapsed = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + elapsed * self.rate_rps).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// One live connection's handles, retained for shutdown.
struct Conn {
    queue: Arc<ReplyQueue>,
    /// A clone of the connection's stream, kept so shutdown can unblock
    /// a reader parked in `read_exact`.
    stream: TcpStream,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

/// A running TCP front end over a shared [`Engine`].
pub struct NetServer {
    local: SocketAddr,
    engine: Arc<Engine>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<Conn>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start accepting
    /// connections that serve through `engine`.
    pub fn bind(engine: Arc<Engine>, addr: &str) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            // Joined by shutdown/Drop below.
            std::thread::spawn(move || accept_loop(listener, engine, stop, conns)) // lint: allow(thread-spawn)
        };
        Ok(NetServer {
            local,
            engine,
            stop,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (resolves a `:0` ephemeral-port bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// The shared engine (live counters, stats).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Graceful shutdown: stop accepting, drain the engine (flushing
    /// every in-flight response to its connection queue), answer `Fin`
    /// on every connection, and join all connection threads. The engine
    /// itself stays up — the caller owns its `Arc` and decides when to
    /// shut it down.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let drained = self.engine.drain();
        let conns = std::mem::take(&mut *lock(&self.conns));
        for c in &conns {
            // Responses are already queued (drain completed), so the Fin
            // lands behind them; unblocking the reader's parked
            // `read_exact` ends the ingress side.
            c.queue.push(Reply::Fin);
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        for c in conns {
            let _ = c.reader.join();
            let _ = c.writer.join();
        }
        drained
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // Belt-and-braces for early-exit paths: stop the accept loop so
        // the listener thread never outlives the server handle. (The
        // graceful path is `shutdown`, which also drains and joins.)
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for c in std::mem::take(&mut *lock(&self.conns)) {
            c.queue.push(Reply::Fin);
            let _ = c.stream.shutdown(Shutdown::Both);
            let _ = c.reader.join();
            let _ = c.writer.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    engine: Arc<Engine>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if let Ok(conn) = spawn_conn(stream, Arc::clone(&engine)) {
                    lock(&conns).push(conn);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_TICK);
            }
            // Transient accept errors (e.g. a connection reset between
            // queueing and accepting) — keep serving.
            Err(_) => std::thread::sleep(ACCEPT_TICK),
        }
    }
}

fn spawn_conn(stream: TcpStream, engine: Arc<Engine>) -> std::io::Result<Conn> {
    // Frames are small relative to socket buffers; Nagle would add
    // ~40 ms stalls to the request/response pattern.
    stream.set_nodelay(true)?;
    let queue = Arc::new(ReplyQueue::with_capacity(QUEUE_WARM));
    let read_half = stream.try_clone()?;
    let write_half = stream.try_clone()?;
    // Writer-side fault site, salted by accept order. The high bit-32
    // offset keeps connection salts disjoint from the engine's worker
    // salts (0..workers), so the two site families never share a
    // schedule even under the same seed.
    let salt = (1u64 << 32) | CONN_SEQ.fetch_add(1, Ordering::Relaxed);
    let fault = FaultPlane::new(engine.config().hw.fault.clone(), salt);
    let reader = {
        let queue = Arc::clone(&queue);
        // Joined by shutdown/Drop (handle kept in Conn).
        std::thread::spawn(move || reader_loop(read_half, engine, queue)) // lint: allow(thread-spawn)
    };
    let writer = {
        let queue = Arc::clone(&queue);
        // Joined by shutdown/Drop (handle kept in Conn).
        std::thread::spawn(move || writer_loop(write_half, queue, fault)) // lint: allow(thread-spawn)
    };
    Ok(Conn {
        queue,
        stream,
        reader,
        writer,
    })
}

/// Push a connection-level failure (id 0 when no request is at fault).
fn push_failed(queue: &ReplyQueue, id: u64, message: String) {
    queue.push(Reply::Failed {
        id,
        error: Arc::from(message.as_str()),
    });
}

/// Parse frames off the socket and feed the engine. Every exit path
/// pushes [`Reply::Fin`] so the writer (and the peer) always observe a
/// deliberate end of stream.
fn reader_loop(mut stream: TcpStream, engine: Arc<Engine>, queue: Arc<ReplyQueue>) {
    let mut pool = ImagePool::new(POOL_CAP);
    let mut hdr = [0u8; HEADER_LEN];
    let mut limiter = TokenBucket::from_params(&engine.config().hw.fault);
    loop {
        match read_full_or_eof(&mut stream, &mut hdr) {
            Ok(true) => {}
            // Clean close at a frame boundary, a truncated header, or
            // the shutdown path's Shutdown::Read — end of ingress.
            Ok(false) | Err(_) => break,
        }
        let h = match decode_header(&hdr) {
            Ok(h) => h,
            Err(e) => {
                // An unparseable header means the stream is desynced;
                // only closing resynchronizes it.
                push_failed(&queue, 0, e.to_string());
                break;
            }
        };
        match h.kind {
            FrameKind::Submit => {
                if let Some(b) = limiter.as_mut() {
                    if !b.admit(Instant::now()) {
                        // Over the per-connection rate: consume the
                        // payload so the stream stays framed, answer a
                        // terminal BUSY, and count the shed — the
                        // request never reaches the ingress queue.
                        if discard_payload(&mut stream, h.payload_len as usize).is_err() {
                            break;
                        }
                        queue.push(Reply::Busy { id: h.id });
                        engine.note_shed();
                        continue;
                    }
                }
                if !handle_submit(&mut stream, &engine, &queue, &mut pool, &h) {
                    break;
                }
            }
            FrameKind::StatsReq => queue.push(Reply::Stats(render_stats(&engine.stats()))),
            FrameKind::Drain => {
                // Worker reply pushes precede collector accounting, so a
                // completed drain implies every response is queued ahead
                // of the Fin pushed below.
                let _ = engine.drain();
                break;
            }
            // Server-bound streams never carry reply kinds.
            k => {
                push_failed(&queue, h.id, format!("unexpected client frame kind {k:?}"));
                break;
            }
        }
    }
    queue.push(Reply::Fin);
}

/// Decode and submit one request. Returns `false` when the connection
/// is beyond saving (payload-level I/O error); per-request rejections
/// discard the payload, report, and keep the stream framed.
fn handle_submit(
    stream: &mut TcpStream,
    engine: &Engine,
    queue: &Arc<ReplyQueue>,
    pool: &mut ImagePool,
    h: &FrameHeader,
) -> bool {
    let (model, variant) = match submit_model(h).and_then(|m| submit_variant(h).map(|v| (m, v))) {
        Ok(pair) => pair,
        Err(e) => {
            if discard_payload(stream, h.payload_len as usize).is_err() {
                return false;
            }
            push_failed(queue, h.id, e.to_string());
            return true;
        }
    };
    let elems = engine.image_elems_for(model);
    if h.payload_len as usize != elems * 4 {
        if discard_payload(stream, h.payload_len as usize).is_err() {
            return false;
        }
        push_failed(
            queue,
            h.id,
            format!(
                "submit for {} carries {} payload bytes, want {} ({elems} f32 pixels)",
                model.name(),
                h.payload_len,
                elems * 4
            ),
        );
        return true;
    }
    let image = match read_pooled_image(stream, pool, elems) {
        Ok(img) => img,
        Err(_) => return false,
    };
    let req = InferenceRequest {
        id: h.id,
        model,
        image,
        variant,
        arrival: Instant::now(),
        // Submit's aux is the deadline budget in whole ms (0 = none),
        // measured from server receipt — the client's clock never enters
        // the comparison.
        deadline: (h.aux > 0).then(|| Instant::now() + Duration::from_millis(h.aux as u64)),
        reply: Some(Arc::clone(queue)),
    };
    match engine.submit(req) {
        Ok(()) => {}
        Err(Error::Backpressure) => queue.push(Reply::Busy { id: h.id }),
        Err(e) => push_failed(queue, h.id, e.to_string()),
    }
    true
}

/// Serialize replies onto the socket. Responses leave as one vectored
/// write over `[header + metering (stack), logits (reused scratch)]` —
/// or, under an injected `writer_delay`, as a deliberately split
/// prefix/payload pair with a real scheduling gap between them.
fn writer_loop(mut stream: TcpStream, queue: Arc<ReplyQueue>, mut fault: FaultPlane) {
    let mut payload: Vec<u8> = Vec::new();
    loop {
        let reply = queue.pop();
        let ok = match &reply {
            Reply::Response(r) => {
                let mut prefix = [0u8; HEADER_LEN + METERING_LEN];
                let logits = r.logits.as_slice();
                encode_header(
                    &FrameHeader {
                        kind: FrameKind::Response,
                        model: model_to_wire(r.model),
                        variant: NONE_BYTE,
                        id: r.id,
                        payload_len: (METERING_LEN + logits.len() * 4) as u32,
                        aux: r.predicted as u32,
                    },
                    (&mut prefix[..HEADER_LEN]).try_into().expect("header size"),
                );
                prefix[HEADER_LEN..HEADER_LEN + 8]
                    .copy_from_slice(&r.sim.hw_latency_ms.raw().to_le_bytes());
                prefix[HEADER_LEN + 8..HEADER_LEN + 16]
                    .copy_from_slice(&r.sim.hw_contended_ms.raw().to_le_bytes());
                prefix[HEADER_LEN + 16..HEADER_LEN + 24]
                    .copy_from_slice(&r.sim.hw_energy_mj.raw().to_le_bytes());
                payload.clear();
                extend_f32s(&mut payload, logits);
                if let Some(gap) = fault.writer_delay() {
                    // Injected short/delayed write: flush the prefix,
                    // yield for the configured gap, then the logits —
                    // the peer sees a mid-frame stall and a split
                    // delivery, never a corrupted stream.
                    stream.write_all(&prefix).is_ok() && {
                        std::thread::sleep(gap);
                        stream.write_all(&payload).is_ok()
                    }
                } else {
                    write_frame(&mut stream, &prefix, &payload).is_ok()
                }
            }
            Reply::Failed { id, error } => {
                write_text(&mut stream, FrameKind::Error, *id, error.as_bytes())
            }
            Reply::Busy { id } => write_control(&mut stream, FrameKind::Busy, *id),
            Reply::Expired { id } => write_control(&mut stream, FrameKind::DeadlineExceeded, *id),
            Reply::Stats(s) => write_text(&mut stream, FrameKind::Stats, 0, s.as_bytes()),
            Reply::Fin => {
                let _ = write_control(&mut stream, FrameKind::Fin, 0);
                break;
            }
        };
        if !ok {
            // Peer gone mid-write: drain to the Fin so the reader's
            // producer side never blocks, then exit.
            loop {
                if matches!(queue.pop(), Reply::Fin) {
                    break;
                }
            }
            break;
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

fn write_control(stream: &mut TcpStream, kind: FrameKind, id: u64) -> bool {
    let mut hdr = [0u8; HEADER_LEN];
    encode_header(
        &FrameHeader {
            id,
            ..FrameHeader::control(kind)
        },
        &mut hdr,
    );
    write_frame(stream, &hdr, &[]).is_ok()
}

fn write_text(stream: &mut TcpStream, kind: FrameKind, id: u64, text: &[u8]) -> bool {
    let mut hdr = [0u8; HEADER_LEN];
    encode_header(
        &FrameHeader {
            id,
            payload_len: text.len() as u32,
            ..FrameHeader::control(kind)
        },
        &mut hdr,
    );
    write_frame(stream, &hdr, text).is_ok()
}

/// Render the stats snapshot a `StatsReq` frame answers with (compact
/// JSON; a control-plane frame, not on the per-request budget).
fn render_stats(s: &ServerStats) -> String {
    format!(
        concat!(
            "{{\"served\":{},\"batches\":{},\"failed\":{},\"expired\":{},\"rejected\":{},",
            "\"shed\":{},\"respawns\":{},",
            "\"throughput_rps\":{:.3},\"p50_total_ms\":{:.6},\"p99_total_ms\":{:.6},",
            "\"sim_energy_mj\":{:.6},\"sim_makespan_ms\":{:.6}}}"
        ),
        s.served,
        s.batches,
        s.failed,
        s.expired,
        s.rejected,
        s.shed,
        s.respawns,
        s.throughput_rps,
        s.p50_total_ms.raw(),
        s.p99_total_ms.raw(),
        s.sim_energy_mj.raw(),
        s.sim_makespan_ms.raw(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_sheds_over_burst_and_refills() {
        let p = FaultParams {
            conn_rate_rps: 1000.0,
            conn_burst: 4,
            ..FaultParams::default()
        };
        let mut b = TokenBucket::from_params(&p).unwrap();
        let t0 = Instant::now();
        // The burst admits instantaneously...
        for i in 0..4 {
            assert!(b.admit(t0), "admit {i} within burst");
        }
        assert!(!b.admit(t0), "fifth instantaneous admit must shed");
        // ...then one refill interval (1 ms at 1000 rps) restores one
        // token — and exactly one.
        let t1 = t0 + Duration::from_millis(1);
        assert!(b.admit(t1));
        assert!(!b.admit(t1));
        // Rate 0 (the default) disables the limiter entirely.
        assert!(TokenBucket::from_params(&FaultParams::default()).is_none());
    }
}
