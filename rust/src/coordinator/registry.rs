//! The shared plan/cost registry: per-`(model, variant)` compiled
//! serving artifacts, built lazily and exactly once.
//!
//! Multi-model serving means a worker can be handed a batch for any of
//! the [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS) at any
//! moment. Everything a batch needs besides the executor's compile
//! cache — the model's network graph, its mapper plan on the PIM
//! substrate, the precomputed [`SimCostTable`] that meters the batch,
//! and the executor program (artifact name + shapes) it runs — is
//! deterministic per `(model, variant)` and expensive enough (a full
//! analyzer pass over e.g. VGG16) that it must never run per request,
//! and wasteful enough that it should never run per *worker* either.
//!
//! [`PlanRegistry`] is that cache: an `Arc`-shared, lazily-populated map
//! keyed by `(model, variant)`. Resolution takes a short global lock to
//! find-or-create the key's slot, then builds under the slot's own lock
//! — concurrent first requests for the *same* pair block until the one
//! build finishes (never duplicating it), while requests for *different*
//! pairs build in parallel. Build outcomes (including errors — builds
//! are deterministic) are cached, and [`PlanRegistry::builds`] counts
//! actual build executions so tests can assert the exactly-once
//! property.
//!
//! The registry also owns manifest augmentation
//! ([`augment_manifest`]): synthesized [`ArtifactInfo`] entries for
//! every servable `(model, variant)` pair the loaded manifest doesn't
//! already provide, so the sim backend can execute any model while the
//! on-disk (LeNet) artifact family keeps the manifest as its single
//! source of truth — a missing LeNet artifact still fails the batch
//! instead of being silently re-synthesized.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analyzer::contention::BatchStream;
use crate::analyzer::latency::{analyze_mapped, ModelAnalysis};
use crate::analyzer::simcost::SimCostTable;
use crate::analyzer::timeline::{simulate_analysis_makespan, TimelineSummary};
use crate::cnn::graph::Network;
use crate::cnn::models::{build_model, Model, SERVABLE_MODELS};
use crate::config::OpimaConfig;
use crate::coordinator::engine::lock;
use crate::coordinator::request::Variant;
use crate::error::{Error, Result};
use crate::mapper::plan::{map_network, CapacityWarning, MappedNetwork, Occupancy};
use crate::runtime::{ArtifactInfo, Manifest, ProgramHandle};
use crate::util::units::{Millijoules, Millis};

/// Everything the serving path needs for one `(model, variant)` pair,
/// compiled once and shared read-only behind an `Arc`.
#[derive(Debug)]
pub struct ModelPlan {
    pub model: Model,
    pub variant: Variant,
    /// The model's network graph (shape/MAC ground truth).
    pub network: Network,
    /// The mapper plan: the network mapped onto the PIM substrate at
    /// this variant's operand width.
    pub mapped: MappedNetwork,
    /// The priced analysis (per-layer stage costs plus the mapping's
    /// occupancy) the timeline cache schedules from.
    pub analysis: ModelAnalysis,
    /// Whole-batch simulated cost at the serving batch size (pipelined
    /// timeline makespans, keyed by `(bits, batch)`).
    pub costs: SimCostTable,
    /// The prepared executor program: artifact name + tensor shapes,
    /// validated and flattened exactly once at plan build — workers run
    /// batches through it with no per-batch manifest lookup,
    /// `ArtifactInfo` clone or shape re-derivation.
    pub program: ProgramHandle,
    /// Serving batch size the program and costs are built for.
    pub batch: usize,
}

impl ModelPlan {
    /// Flattened per-image element count the program's input expects.
    pub fn image_elems(&self) -> usize {
        self.program.input_len(0) / self.batch.max(1)
    }

    /// Logits per inference in the program's output.
    pub fn classes(&self) -> usize {
        self.program.output_len() / self.batch.max(1)
    }

    /// Whole-batch simulated `(latency, energy)`.
    pub fn sim_cost(&self) -> (Millis, Millijoules) {
        self.costs
            .get(self.variant.pim_bits())
            .expect("table built with this variant's width")
    }

    /// Subarray occupancy of the mapping vs. the hardware capacity —
    /// drives the router's co-residency accounting and the over-capacity
    /// warning surfaced by the serve path. (Single source of truth:
    /// the analysis pass.)
    pub fn occupancy(&self) -> Occupancy {
        self.analysis.occupancy
    }

    /// Structured over-capacity warning for this plan's mapping, `None`
    /// when it fits.
    pub fn capacity_warning(&self) -> Option<CapacityWarning> {
        self.occupancy().warning_for(&self.mapped.name)
    }

    /// The plan's priced event stream at its serving batch size — what
    /// [`Router::dispatch_batch`](crate::coordinator::router::Router::dispatch_batch)
    /// admits into the global contention timeline. Over-capacity
    /// mappings stream serialized, mirroring the isolated timeline's
    /// fallback.
    pub fn stream(&self) -> BatchStream<'_> {
        BatchStream {
            costs: &self.analysis.layer_costs,
            batch: self.batch,
            pipelined: self.occupancy().fits(),
        }
    }
}

/// A cached build outcome: the shared plan, or the deterministic build
/// error's message.
type Built = std::result::Result<Arc<ModelPlan>, String>;

/// One key's build slot. The slot mutex is the per-key build lock:
/// holding it while building makes concurrent same-key resolutions wait
/// for (and then share) the single build instead of repeating it.
#[derive(Default)]
struct Slot {
    cell: Mutex<Option<Built>>,
}

/// Lazily-built, `Arc`-shared cache of per-`(model, variant)` serving
/// plans. See the [module docs](self) for the locking discipline.
pub struct PlanRegistry {
    hw: OpimaConfig,
    manifest: Manifest,
    batch: usize,
    slots: Mutex<HashMap<(Model, Variant), Arc<Slot>>>,
    /// Scheduled batch-timeline summaries, keyed by `(model, variant,
    /// batch)` — the serving batch size is prescheduled inside each
    /// plan's cost table; this cache serves ad-hoc batch sizes (the
    /// `analyze`-style queries) without re-running the simulation. Only
    /// the scalar bounds are consumed here, so scheduling uses the
    /// makespan-only fast path (no event vec is ever materialized).
    timelines: Mutex<HashMap<(Model, Variant, usize), Arc<TimelineSummary>>>,
    builds: AtomicU64,
}

impl PlanRegistry {
    /// Create a registry over an (already augmented) manifest. Plans
    /// are built on first resolution, not here.
    pub fn new(hw: OpimaConfig, manifest: Manifest) -> Self {
        let batch = manifest.batch;
        Self {
            hw,
            manifest,
            batch,
            slots: Mutex::new(HashMap::new()),
            timelines: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
        }
    }

    /// Serving batch size every plan is built for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Number of plan builds actually executed so far. With N concurrent
    /// first-resolutions of one `(model, variant)` pair this is 1, not N.
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Acquire)
    }

    /// Number of `(model, variant)` pairs resolved (or resolving) so far.
    pub fn cached(&self) -> usize {
        lock(&self.slots).len()
    }

    /// Resolve the plan for a `(model, variant)` pair, building it if
    /// this is the first resolution. Concurrent first resolutions of the
    /// same pair serialize on the pair's slot lock and share one build;
    /// different pairs build in parallel. Deterministic build errors are
    /// cached and re-reported.
    pub fn resolve(&self, model: Model, variant: Variant) -> Result<Arc<ModelPlan>> {
        let slot = {
            let mut slots = lock(&self.slots);
            Arc::clone(slots.entry((model, variant)).or_default())
        };
        let mut cell = lock(&slot.cell);
        if cell.is_none() {
            self.builds.fetch_add(1, Ordering::AcqRel);
            *cell = Some(
                self.build(model, variant)
                    .map(Arc::new)
                    .map_err(|e| e.to_string()),
            );
        }
        match cell.as_ref().expect("filled above") {
            Ok(plan) => Ok(Arc::clone(plan)),
            Err(e) => Err(Error::Serving(format!(
                "plan for ({}, {}): {e}",
                model.name(),
                variant.tag()
            ))),
        }
    }

    /// The pipelined batch-timeline summary for `(model, variant,
    /// batch)`, scheduling (and caching) it on first request. The plan
    /// is resolved (and built if needed) *before* taking the cache lock,
    /// so the lock is never held across a plan build; the simulation
    /// itself runs under the lock, which makes each key's schedule run
    /// exactly once even under racing first requests.
    pub fn timeline(
        &self,
        model: Model,
        variant: Variant,
        batch: usize,
    ) -> Result<Arc<TimelineSummary>> {
        let plan = self.resolve(model, variant)?;
        let mut cache = lock(&self.timelines);
        if let Some(t) = cache.get(&(model, variant, batch)) {
            return Ok(Arc::clone(t));
        }
        let t = Arc::new(simulate_analysis_makespan(&self.hw, &plan.analysis, batch));
        cache.insert((model, variant, batch), Arc::clone(&t));
        Ok(t)
    }

    /// Structured over-capacity warnings across every plan resolved so
    /// far (models that map but exceed the memory's subarray capacity),
    /// sorted by model.
    pub fn capacity_warnings(&self) -> Vec<CapacityWarning> {
        let slots: Vec<Arc<Slot>> = lock(&self.slots).values().cloned().collect();
        let mut warnings: Vec<CapacityWarning> = slots
            .iter()
            .filter_map(|s| match &*lock(&s.cell) {
                Some(Ok(plan)) => plan.capacity_warning(),
                _ => None,
            })
            .collect();
        warnings.sort_by(|a, b| a.network.cmp(&b.network));
        warnings
    }

    fn build(&self, model: Model, variant: Variant) -> Result<ModelPlan> {
        let bits = variant.pim_bits();
        let network = build_model(model)?;
        // One mapping pass feeds the stored mapper plan, the analysis,
        // and the cost table (analyze_mapped prices the already-mapped
        // network instead of re-mapping it).
        let mapped = map_network(&self.hw, &network, bits)?;
        let analysis = analyze_mapped(&self.hw, &mapped, bits)?;
        let costs = SimCostTable::from_analysis(&self.hw, &analysis, self.batch);
        let name = variant.artifact_for(model, self.batch);
        // The one-and-only ArtifactInfo clone for this pair: the handle
        // shares it read-only with every worker for the engine's lifetime.
        let program = ProgramHandle::new(self.manifest.get(&name)?.clone());
        Ok(ModelPlan {
            model,
            variant,
            network,
            mapped,
            analysis,
            costs,
            program,
            batch: self.batch,
        })
    }
}

impl std::fmt::Debug for PlanRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanRegistry")
            .field("batch", &self.batch)
            .field("cached", &self.cached())
            .field("builds", &self.builds())
            .finish()
    }
}

/// Add synthesized artifact entries for every servable `(model,
/// variant)` pair the manifest doesn't already define, shaped from the
/// models' static metadata at the manifest's batch size. Existing
/// entries (notably LeNet's on-disk `cnn_*` family) are never
/// overwritten — and never re-created when absent, so a manifest that
/// genuinely lacks a LeNet artifact still fails that batch loudly.
pub fn augment_manifest(manifest: &mut Manifest) {
    let batch = manifest.batch;
    for model in SERVABLE_MODELS {
        if model == Model::LeNet {
            continue;
        }
        for variant in [Variant::Fp32, Variant::Int8, Variant::Int4] {
            let name = variant.artifact_for(model, batch);
            if manifest.artifacts.contains_key(&name) {
                continue;
            }
            let size = model.input_size();
            manifest.artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    input_shapes: vec![vec![batch, size, size, model.input_channels()]],
                    output_shape: vec![batch, model.classes()],
                    bits: match variant {
                        Variant::Fp32 => None,
                        v => Some(v.pim_bits()),
                    },
                },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> PlanRegistry {
        let mut manifest = Manifest::synthetic(8, 12);
        augment_manifest(&mut manifest);
        PlanRegistry::new(OpimaConfig::paper(), manifest)
    }

    #[test]
    fn resolves_lenet_from_manifest_artifacts() {
        let r = registry();
        let plan = r.resolve(Model::LeNet, Variant::Int4).unwrap();
        assert_eq!(plan.program.name(), "cnn_int4_b8");
        assert_eq!(plan.image_elems(), 144);
        assert_eq!(plan.classes(), 4);
        let (lat, mj) = plan.sim_cost();
        assert!(lat.raw() > 0.0 && mj.raw() > 0.0);
        assert!(!plan.mapped.works.is_empty());
        assert_eq!(r.builds(), 1);
    }

    #[test]
    fn second_resolution_hits_the_cache() {
        let r = registry();
        let a = r.resolve(Model::LeNet, Variant::Int8).unwrap();
        let b = r.resolve(Model::LeNet, Variant::Int8).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same Arc, no rebuild");
        assert_eq!(r.builds(), 1);
        assert_eq!(r.cached(), 1);
    }

    #[test]
    fn distinct_pairs_build_distinct_plans() {
        let r = registry();
        let lenet = r.resolve(Model::LeNet, Variant::Int4).unwrap();
        let mobile = r.resolve(Model::MobileNet, Variant::Int4).unwrap();
        assert_eq!(r.builds(), 2);
        assert_eq!(mobile.program.name(), "mobilenet_int4_b8");
        assert_eq!(mobile.image_elems(), 32 * 32 * 3);
        assert_eq!(mobile.classes(), 1000);
        // A bigger model costs more simulated time and energy per batch.
        assert!(mobile.sim_cost().0 > lenet.sim_cost().0);
        assert!(mobile.sim_cost().1 > lenet.sim_cost().1);
    }

    #[test]
    fn concurrent_first_resolutions_build_exactly_once() {
        let r = std::sync::Arc::new(registry());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let r = Arc::clone(&r);
                s.spawn(move || {
                    let plan = r.resolve(Model::LeNet, Variant::Int4).unwrap();
                    assert_eq!(plan.model, Model::LeNet);
                });
            }
        });
        assert_eq!(r.builds(), 1, "8 racing resolutions, one build");
    }

    #[test]
    fn timeline_cache_is_per_batch_and_reused() {
        let r = registry();
        let t16 = r.timeline(Model::LeNet, Variant::Int4, 16).unwrap();
        let again = r.timeline(Model::LeNet, Variant::Int4, 16).unwrap();
        assert!(Arc::ptr_eq(&t16, &again), "cached, not rescheduled");
        assert_eq!(r.builds(), 1, "timeline reuses the plan's analysis");
        let t1 = r.timeline(Model::LeNet, Variant::Int4, 1).unwrap();
        assert!(!Arc::ptr_eq(&t1, &t16));
        assert!(t16.makespan_ns < 16.0 * t1.makespan_ns, "pipelined");
        assert!(t16.makespan_ns > t1.makespan_ns);
    }

    #[test]
    fn plans_carry_occupancy_and_fit_the_paper_memory() {
        let r = registry();
        let plan = r.resolve(Model::Vgg16, Variant::Int8).unwrap();
        assert!(plan.occupancy().fits());
        assert!(plan.occupancy().subarrays_used > 0);
        assert!(plan.capacity_warning().is_none());
        assert!(r.capacity_warnings().is_empty());
    }

    #[test]
    fn over_capacity_plan_surfaces_a_warning() {
        let mut hw = OpimaConfig::paper();
        hw.geometry.banks = 1;
        hw.geometry.subarray_rows = 2;
        hw.geometry.subarray_cols = 2;
        hw.geometry.subarray_groups = 2;
        let mut manifest = Manifest::synthetic(8, 12);
        augment_manifest(&mut manifest);
        let r = PlanRegistry::new(hw, manifest);
        let plan = r.resolve(Model::ResNet18, Variant::Int8).unwrap();
        assert!(!plan.occupancy().fits());
        let w = plan.capacity_warning().unwrap();
        assert!(w.subarrays_used > w.capacity);
        let all = r.capacity_warnings();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0], w);
        // Over capacity ⇒ the timeline refuses to pipeline.
        let t = r.timeline(Model::ResNet18, Variant::Int8, 4).unwrap();
        assert!(!t.pipelined);
        assert!((t.makespan_ns - t.sequential_ns).abs() <= 1e-9 * t.sequential_ns);
    }

    #[test]
    fn missing_artifact_is_a_cached_error() {
        let mut manifest = Manifest::synthetic(8, 12);
        manifest.artifacts.remove("cnn_int4_b8");
        augment_manifest(&mut manifest);
        let r = PlanRegistry::new(OpimaConfig::paper(), manifest);
        assert!(r.resolve(Model::LeNet, Variant::Int4).is_err());
        assert!(r.resolve(Model::LeNet, Variant::Int4).is_err());
        assert_eq!(r.builds(), 1, "the failed build is cached, not retried");
        // Other pairs are unaffected.
        assert!(r.resolve(Model::LeNet, Variant::Int8).is_ok());
    }

    #[test]
    fn augmentation_covers_all_pairs_and_keeps_existing_entries() {
        let mut manifest = Manifest::synthetic(8, 12);
        let lenet_before = manifest.get("cnn_fp32_b8").unwrap().clone();
        augment_manifest(&mut manifest);
        assert_eq!(manifest.get("cnn_fp32_b8").unwrap(), &lenet_before);
        for model in SERVABLE_MODELS {
            for v in [Variant::Fp32, Variant::Int8, Variant::Int4] {
                let info = manifest.get(&v.artifact_for(model, 8)).unwrap();
                assert_eq!(info.input_elems(0), 8 * model.input_elems());
                assert_eq!(info.output_elems(), 8 * model.classes());
            }
        }
    }
}
