//! Request/response types for the serving path.

use std::time::Instant;

use crate::error::{Error, Result};

/// Which CNN variant serves the request (precision ↔ artifact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp32,
    Int8,
    Int4,
}

impl Variant {
    /// Artifact name for a given serving batch size.
    pub fn artifact(&self, batch: usize) -> String {
        match self {
            Variant::Fp32 => format!("cnn_fp32_b{batch}"),
            Variant::Int8 => format!("cnn_int8_b{batch}"),
            Variant::Int4 => format!("cnn_int4_b{batch}"),
        }
    }

    /// Operand width on the PIM substrate (fp32 is served as int8 after
    /// PTQ; OPIMA has no float datapath).
    pub fn pim_bits(&self) -> u32 {
        match self {
            Variant::Fp32 | Variant::Int8 => 8,
            Variant::Int4 => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "fp32" => Ok(Variant::Fp32),
            "int8" => Ok(Variant::Int8),
            "int4" => Ok(Variant::Int4),
            other => Err(Error::Serving(format!("unknown variant '{other}'"))),
        }
    }
}

/// One classification request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flattened image (image_size² × channels, NHWC).
    pub image: Vec<f32>,
    pub variant: Variant,
    pub arrival: Instant,
}

/// Architectural cost metered by the simulator for the batch that
/// carried this request.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimMetering {
    /// What the OPIMA hardware would have taken for the batch (ms).
    pub hw_latency_ms: f64,
    /// Dynamic energy of the batch (mJ).
    pub hw_energy_mj: f64,
}

/// One classification response.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Wall time spent queued before execution (ms).
    pub queue_ms: f64,
    /// Wall time of the PJRT execution, amortized over the batch (ms).
    pub exec_ms: f64,
    /// Simulated OPIMA hardware cost.
    pub sim: SimMetering,
    /// Which worker/instance served it.
    pub instance: usize,
}

impl InferenceResponse {
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.exec_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Variant::Fp32.artifact(8), "cnn_fp32_b8");
        assert_eq!(Variant::Int4.artifact(8), "cnn_int4_b8");
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("int4").unwrap(), Variant::Int4);
        assert!(Variant::parse("int2").is_err());
    }

    #[test]
    fn pim_bits() {
        assert_eq!(Variant::Int4.pim_bits(), 4);
        assert_eq!(Variant::Fp32.pim_bits(), 8);
    }
}
