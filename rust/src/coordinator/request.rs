//! Request/response types for the serving path.

use std::time::Instant;

use crate::error::{Error, Result};

/// Which CNN variant serves the request (precision ↔ artifact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp32,
    Int8,
    Int4,
}

impl Variant {
    /// Artifact name for a given serving batch size.
    pub fn artifact(&self, batch: usize) -> String {
        match self {
            Variant::Fp32 => format!("cnn_fp32_b{batch}"),
            Variant::Int8 => format!("cnn_int8_b{batch}"),
            Variant::Int4 => format!("cnn_int4_b{batch}"),
        }
    }

    /// Operand width on the PIM substrate (fp32 is served as int8 after
    /// PTQ; OPIMA has no float datapath).
    pub fn pim_bits(&self) -> u32 {
        match self {
            Variant::Fp32 | Variant::Int8 => 8,
            Variant::Int4 => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "fp32" => Ok(Variant::Fp32),
            "int8" => Ok(Variant::Int8),
            "int4" => Ok(Variant::Int4),
            other => Err(Error::Serving(format!("unknown variant '{other}'"))),
        }
    }
}

/// One classification request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flattened image (image_size² × channels, NHWC).
    pub image: Vec<f32>,
    pub variant: Variant,
    pub arrival: Instant,
}

/// Architectural cost metered by the simulator for the batch that
/// carried this request.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimMetering {
    /// What the OPIMA hardware would have taken for the batch (ms).
    pub hw_latency_ms: f64,
    /// Dynamic energy of the batch (mJ).
    pub hw_energy_mj: f64,
}

/// One classification response.
///
/// Latency accounting uses one consistent convention: `queue_ms` covers
/// arrival → start of the batch's execution, `exec_ms` covers the whole
/// batch's execution, so `total_ms() = queue_ms + exec_ms` is the wall
/// time from arrival to completion. `form_ms ≤ queue_ms` isolates the
/// dynamic-batcher share of the queueing delay.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Wall time from arrival to the start of the batch's execution
    /// (batcher wait + dispatch queueing, ms).
    pub queue_ms: f64,
    /// Wall time of the execution of the whole batch that carried this
    /// request (ms) — not an amortized per-request share.
    pub exec_ms: f64,
    /// Wall time from arrival to batch formation (dynamic-batcher
    /// latency, ms); the remainder of `queue_ms` is dispatch queueing.
    pub form_ms: f64,
    /// Simulated OPIMA hardware cost of the batch that carried this
    /// request (full-batch numbers, not per-request shares).
    pub sim: SimMetering,
    /// Simulated OPIMA instance the batch was dispatched to.
    pub instance: usize,
    /// Worker thread that executed the batch.
    pub worker: usize,
}

impl InferenceResponse {
    /// Wall time from arrival to completion (ms).
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.exec_ms
    }

    /// The `(total, queue, exec, form)` latency sample (ms) this
    /// response contributes to the engine's streaming histograms.
    pub fn latency_sample(&self) -> (f64, f64, f64, f64) {
        (self.total_ms(), self.queue_ms, self.exec_ms, self.form_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Variant::Fp32.artifact(8), "cnn_fp32_b8");
        assert_eq!(Variant::Int4.artifact(8), "cnn_int4_b8");
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("int4").unwrap(), Variant::Int4);
        assert!(Variant::parse("int2").is_err());
    }

    #[test]
    fn pim_bits() {
        assert_eq!(Variant::Int4.pim_bits(), 4);
        assert_eq!(Variant::Fp32.pim_bits(), 8);
    }

    #[test]
    fn total_is_queue_plus_exec() {
        let r = InferenceResponse {
            id: 0,
            logits: vec![0.0; 4],
            predicted: 0,
            queue_ms: 1.5,
            exec_ms: 2.0,
            form_ms: 0.5,
            sim: SimMetering::default(),
            instance: 0,
            worker: 0,
        };
        assert!((r.total_ms() - 3.5).abs() < 1e-12);
        assert!(r.form_ms <= r.queue_ms);
    }
}
