//! Request/response types for the serving path, plus the shared-buffer
//! types behind the zero-copy data plane.
//!
//! The steady-state serving path moves pixels and logits without
//! per-request heap traffic:
//!
//! - [`ImageBuf`] — an `Arc<[f32]>`-backed image payload. Cloning a
//!   request (submit, batch, requeue) bumps a reference count; the
//!   pixels are copied exactly once, by the worker packing the batch
//!   input.
//! - [`LogitsView`] — a `(buffer, offset, len)` view into a batch's
//!   shared logits buffer. Every response of a batch views one shared
//!   `Arc<[f32]>`; nothing calls `row.to_vec()` per response.
//! - [`LogitsPool`] — a bounded recycler for those shared buffers: a
//!   buffer becomes reusable once every view into it has been dropped,
//!   so steady-state batches allocate nothing for logits. The same
//!   recycler (aliased [`ImagePool`]) backs the wire front end's
//!   per-connection image free-list: socket payloads decode straight
//!   into pooled `Arc<[f32]>` buffers that are wrapped into [`ImageBuf`]s
//!   via `From<Arc<[f32]>>` — no per-frame `Vec` (DESIGN.md §3.2).
//! - [`ReplyQueue`] — a per-connection FIFO of [`Reply`] items. A request
//!   submitted with a reply handle gets its response (or its batch's
//!   failure) pushed here by the worker *before* the outcome reaches the
//!   collector, so `Engine::drain` returning implies every reply is
//!   queued. Pops block; pushes within the warmed capacity don't
//!   allocate, keeping the socket egress path on the <1-alloc budget.

use std::collections::VecDeque;
use std::ops::Deref;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use crate::cnn::models::Model;
use crate::error::{Error, Result};
use crate::util::prng::Rng;
use crate::util::units::{Millijoules, Millis};

/// A shared, immutable image payload (`Arc<[f32]>`-backed).
///
/// Cloning is a reference-count bump, so a request can be enqueued,
/// batched, requeued or replayed without ever copying pixels. Derefs to
/// `[f32]`, so existing `len()`/slice call sites read through it
/// unchanged.
#[derive(Debug, Clone)]
pub struct ImageBuf(Arc<[f32]>);

impl ImageBuf {
    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }
}

impl From<Vec<f32>> for ImageBuf {
    fn from(v: Vec<f32>) -> Self {
        Self(v.into())
    }
}

impl From<&[f32]> for ImageBuf {
    fn from(s: &[f32]) -> Self {
        Self(s.into())
    }
}

/// Wrap an already-shared buffer without copying — the wire front end's
/// zero-copy ingest path: a pooled `Arc<[f32]>` is filled in place from
/// the socket (while uniquely owned), wrapped here, and the reader's
/// clone goes back to the [`ImagePool`] for recycling once the engine
/// retires the request.
impl From<Arc<[f32]>> for ImageBuf {
    fn from(buf: Arc<[f32]>) -> Self {
        Self(buf)
    }
}

impl FromIterator<f32> for ImageBuf {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> Self {
        Self(iter.into_iter().collect())
    }
}

impl Deref for ImageBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.0
    }
}

/// A response's logits: a `(offset, len)` view into the whole batch's
/// shared logits buffer.
///
/// The worker publishes each batch's logits once as an `Arc<[f32]>`;
/// every response of the batch holds a view into it instead of its own
/// `row.to_vec()` copy. Derefs to `[f32]` (use `.to_vec()` only when an
/// owned copy is genuinely needed). Holding a view keeps the whole batch
/// buffer alive — by design: the buffer returns to its worker's
/// [`LogitsPool`] and is recycled once the batch's last view drops.
#[derive(Debug, Clone)]
pub struct LogitsView {
    buf: Arc<[f32]>,
    offset: usize,
    len: usize,
}

impl LogitsView {
    /// View `len` values of `buf` starting at `offset`.
    pub fn new(buf: Arc<[f32]>, offset: usize, len: usize) -> Self {
        assert!(
            offset + len <= buf.len(),
            "logits view [{offset}, {offset}+{len}) out of buffer bounds {}",
            buf.len()
        );
        Self { buf, offset, len }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.len]
    }
}

/// Owned-vector views for tests and ad-hoc response construction; the
/// serving path always views a shared batch buffer instead.
impl From<Vec<f32>> for LogitsView {
    fn from(v: Vec<f32>) -> Self {
        let len = v.len();
        Self {
            buf: v.into(),
            offset: 0,
            len,
        }
    }
}

impl Deref for LogitsView {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl PartialEq for LogitsView {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// A bounded recycler of shared logits buffers (one per worker, no
/// locking).
///
/// [`LogitsPool::take`] hands out an exclusively-owned `Arc<[f32]>` of
/// the requested length, reusing a retired buffer whenever one is free —
/// i.e. when every [`LogitsView`] into it has been dropped (responses
/// evicted from the engine's bounded ring, or consumed by the caller).
/// [`LogitsPool::put`] returns a buffer for recycling; beyond `cap`
/// retained buffers the incoming one is dropped instead (it frees itself
/// once its last view goes), so pool memory is bounded regardless of how
/// long responses are held.
#[derive(Debug)]
pub struct LogitsPool {
    bufs: Vec<Arc<[f32]>>,
    cap: usize,
}

impl LogitsPool {
    /// Pool retaining at most `cap` buffers (`cap ≥ 1`).
    pub fn new(cap: usize) -> Self {
        Self {
            bufs: Vec::new(),
            cap: cap.max(1),
        }
    }

    /// An exclusively-owned buffer of exactly `len` elements: a retired
    /// pooled buffer when one is free, freshly allocated otherwise.
    /// `Arc::get_mut` on the returned buffer is guaranteed to succeed
    /// until it is cloned.
    pub fn take(&mut self, len: usize) -> Arc<[f32]> {
        if let Some(i) = self
            .bufs
            .iter()
            .position(|b| b.len() == len && Arc::strong_count(b) == 1)
        {
            return self.bufs.swap_remove(i);
        }
        Arc::from(vec![0f32; len])
    }

    /// Hand a buffer back for recycling (typically still viewed by the
    /// batch's in-flight responses; it becomes reusable when they drop).
    pub fn put(&mut self, buf: Arc<[f32]>) {
        if self.bufs.len() < self.cap {
            self.bufs.push(buf);
            return;
        }
        // Full pool: the incoming buffer is the freshest evidence of
        // what lengths current traffic needs. Replace a retired buffer
        // of a *different* length (a model no longer being served)
        // rather than dropping the incoming one, so a traffic shift can
        // never pin the pool to a stale length and permanently defeat
        // recycling. If every slot is same-length or still viewed, the
        // incoming buffer is dropped (it frees once its last view goes).
        let len = buf.len();
        if let Some(i) = self
            .bufs
            .iter()
            .position(|b| b.len() != len && Arc::strong_count(b) == 1)
        {
            self.bufs[i] = buf;
        }
    }

    /// Buffers currently retained for reuse.
    pub fn pooled(&self) -> usize {
        self.bufs.len()
    }
}

/// The net reader's per-connection `ImageBuf` free-list — the same
/// bounded `Arc<[f32]>` recycler the workers use for logits, under the
/// name that matches its other job: request pixels decode from the
/// socket straight into a taken (uniquely-owned) pool buffer, the
/// request wraps a clone via `ImageBuf::from`, and the buffer becomes
/// reusable when the engine drops the batch's requests (response
/// retirement refills the list; see DESIGN.md §3.2).
pub type ImagePool = LogitsPool;

/// One item of a reply stream (see [`ReplyQueue`]).
///
/// `Response`/`Failed` are pushed by the engine's workers for requests
/// carrying a reply handle; the rest are pushed by the serving front end
/// itself (the net reader maps backpressure to `Busy`, stats snapshots
/// to `Stats`, and end-of-stream to `Fin`).
#[derive(Debug)]
pub enum Reply {
    /// A served response for a request submitted with this handle.
    Response(InferenceResponse),
    /// The batch carrying the request failed; no response exists. The
    /// error is `Arc`-shared across the batch's requests.
    Failed { id: u64, error: Arc<str> },
    /// Submission was rejected with backpressure (explicit, never a
    /// silent drop).
    Busy { id: u64 },
    /// The request's deadline expired before it reached a batch slot;
    /// the batcher swept it out and no response will exist. Terminal,
    /// like `Failed`, but distinguishable so clients can account sheds,
    /// failures and expiries separately (DESIGN.md §3.3).
    Expired { id: u64 },
    /// A pre-rendered stats snapshot to forward to the peer.
    Stats(String),
    /// End of stream: no further replies will follow.
    Fin,
}

/// A blocking MPSC FIFO of [`Reply`] items — the bridge between the
/// engine's workers and a connection's writer thread.
///
/// Pushes lock, append and wake; pops block on a condvar until an item
/// arrives. `VecDeque` capacity established during warmup is reused, so
/// steady-state pushes perform no allocation (the socket egress path
/// stays on the <1-alloc-per-request budget). The queue is unbounded by
/// design: items outstanding are bounded by what the peer has submitted
/// and not yet read, which the engine's bounded ingress already caps.
#[derive(Debug, Default)]
pub struct ReplyQueue {
    items: Mutex<VecDeque<Reply>>,
    ready: Condvar,
}

impl ReplyQueue {
    /// Queue with pre-reserved capacity (pushes within it never
    /// allocate).
    pub fn with_capacity(n: usize) -> Self {
        Self {
            items: Mutex::new(VecDeque::with_capacity(n)),
            ready: Condvar::new(),
        }
    }

    fn guard(&self) -> MutexGuard<'_, VecDeque<Reply>> {
        self.items.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Append an item and wake one waiting popper.
    pub fn push(&self, item: Reply) {
        self.guard().push_back(item);
        self.ready.notify_one();
    }

    /// Remove and return the oldest item, blocking until one exists.
    pub fn pop(&self) -> Reply {
        let mut q = self.guard();
        loop {
            if let Some(item) = q.pop_front() {
                return item;
            }
            q = self.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Items currently queued (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.guard().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parse a workload-mix spec like `lenet:4,vgg16:1` into `(model,
/// weight)` pairs — the grammar behind the CLI's and the serving
/// example's `--mix` flag. A bare model name means weight 1; weights
/// must be at least 1 and at least one model must be listed.
pub fn parse_mix(spec: &str) -> Result<Vec<(Model, u64)>> {
    let mut mix: Vec<(Model, u64)> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let weight: u64 = w.trim().parse().map_err(|_| {
                    Error::Config(format!("mix weight in '{part}' wants an integer"))
                })?;
                (n.trim(), weight)
            }
            None => (part, 1),
        };
        let model = Model::from_name(name)
            .ok_or_else(|| Error::Config(format!("mix names unknown model '{name}'")))?;
        if weight == 0 {
            return Err(Error::Config(format!(
                "mix weight for '{name}' must be at least 1"
            )));
        }
        mix.push((model, weight));
    }
    if mix.is_empty() {
        return Err(Error::Config("mix lists no models".into()));
    }
    Ok(mix)
}

/// Weighted random model pick from a parsed mix (weights are positive
/// by [`parse_mix`]'s contract).
pub fn pick_weighted(rng: &mut Rng, mix: &[(Model, u64)]) -> Model {
    let total: u64 = mix.iter().map(|(_, w)| *w).sum();
    let mut ticket = rng.bounded(total);
    for (m, w) in mix {
        if ticket < *w {
            return *m;
        }
        ticket -= w;
    }
    unreachable!("ticket is bounded by the total weight");
}

/// Which quantization variant serves the request (precision ↔ artifact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp32,
    Int8,
    Int4,
}

impl Variant {
    /// Short lowercase tag used in artifact names and CLI flags.
    pub fn tag(&self) -> &'static str {
        match self {
            Variant::Fp32 => "fp32",
            Variant::Int8 => "int8",
            Variant::Int4 => "int4",
        }
    }

    /// Artifact name for a given serving batch size — the legacy
    /// single-model naming, which is exactly [`Model::LeNet`]'s artifact
    /// family (`cnn_*` — the names python/compile emits to disk).
    pub fn artifact(&self, batch: usize) -> String {
        format!("cnn_{}_b{batch}", self.tag())
    }

    /// Artifact name for a `(model, variant)` pair at a serving batch
    /// size. LeNet keeps the on-disk `cnn_*` family; every other model
    /// is namespaced by its model name (e.g. `vgg16_int4_b8`).
    pub fn artifact_for(&self, model: Model, batch: usize) -> String {
        match model {
            Model::LeNet => self.artifact(batch),
            m => format!("{}_{}_b{batch}", m.name(), self.tag()),
        }
    }

    /// Operand width on the PIM substrate (fp32 is served as int8 after
    /// PTQ; OPIMA has no float datapath).
    pub fn pim_bits(&self) -> u32 {
        match self {
            Variant::Fp32 | Variant::Int8 => 8,
            Variant::Int4 => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "fp32" => Ok(Variant::Fp32),
            "int8" => Ok(Variant::Int8),
            "int4" => Ok(Variant::Int4),
            other => Err(Error::Serving(format!("unknown variant '{other}'"))),
        }
    }
}

/// One classification request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Which CNN serves the request (see
    /// [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS)).
    pub model: Model,
    /// Flattened image (`model.input_elems()` values, NHWC), shared —
    /// cloning the request never copies pixels.
    pub image: ImageBuf,
    pub variant: Variant,
    pub arrival: Instant,
    /// Hard completion deadline: a request still queued at the batcher
    /// past this instant is swept out with a terminal
    /// [`Reply::Expired`] instead of occupying a batch slot. `None` =
    /// wait indefinitely. A request already *in* a forming batch at
    /// expiry executes normally — the deadline bounds queueing, not
    /// execution.
    pub deadline: Option<Instant>,
    /// Where the worker should additionally push this request's
    /// [`Reply`] (response, or its batch's failure) — the wire front
    /// end's per-connection response routing. `None` (every in-process
    /// caller) keeps the classic flow: responses are observable via the
    /// sink ring only.
    pub reply: Option<Arc<ReplyQueue>>,
}

/// Architectural cost metered by the simulator for the batch that
/// carried this request.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimMetering {
    /// What the OPIMA hardware would have taken for the batch in
    /// isolation — the per-batch timeline's makespan.
    pub hw_latency_ms: Millis,
    /// The batch's simulated window on its instance under co-residency:
    /// the global contention timeline's start→end, ≥
    /// `hw_latency_ms` (equal when the batch had the instance's stage
    /// pools to itself, or with `cross_batch_contention` off).
    pub hw_contended_ms: Millis,
    /// Dynamic energy of the batch.
    pub hw_energy_mj: Millijoules,
}

/// One classification response.
///
/// Latency accounting uses one consistent convention: `queue_ms` covers
/// arrival → start of the batch's execution, `exec_ms` covers the whole
/// batch's execution, so `total_ms() = queue_ms + exec_ms` is the wall
/// time from arrival to completion. `form_ms ≤ queue_ms` isolates the
/// dynamic-batcher share of the queueing delay.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// The model that served this request (batches are single-model).
    pub model: Model,
    /// This request's logits: a view into the batch's shared buffer
    /// (derefs to `[f32]`; no per-response copy is ever made).
    pub logits: LogitsView,
    pub predicted: usize,
    /// Wall time from arrival to the start of the batch's execution
    /// (batcher wait + dispatch queueing).
    pub queue_ms: Millis,
    /// Wall time of the execution of the whole batch that carried this
    /// request — not an amortized per-request share.
    pub exec_ms: Millis,
    /// Wall time from arrival to batch formation (dynamic-batcher
    /// latency); the remainder of `queue_ms` is dispatch queueing.
    pub form_ms: Millis,
    /// Simulated OPIMA hardware cost of the batch that carried this
    /// request (full-batch numbers, not per-request shares).
    pub sim: SimMetering,
    /// Simulated OPIMA instance the batch was dispatched to.
    pub instance: usize,
    /// Worker thread that executed the batch.
    pub worker: usize,
    /// Formation sequence number of the batch that carried this request
    /// (monotonic per engine) — responses with equal `batch_seq` rode
    /// the same single-model batch.
    pub batch_seq: u64,
}

impl InferenceResponse {
    /// Wall time from arrival to completion.
    pub fn total_ms(&self) -> Millis {
        self.queue_ms + self.exec_ms
    }

    /// The `(total, queue, exec, form)` latency sample (raw ms scalars)
    /// this response contributes to the engine's streaming histograms —
    /// the histogram substrate works on bare f64 samples.
    pub fn latency_sample(&self) -> (f64, f64, f64, f64) {
        (
            self.total_ms().raw(),
            self.queue_ms.raw(),
            self.exec_ms.raw(),
            self.form_ms.raw(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Variant::Fp32.artifact(8), "cnn_fp32_b8");
        assert_eq!(Variant::Int4.artifact(8), "cnn_int4_b8");
    }

    #[test]
    fn artifact_names_per_model() {
        // LeNet keeps the on-disk legacy family; other models namespace.
        assert_eq!(Variant::Fp32.artifact_for(Model::LeNet, 8), "cnn_fp32_b8");
        assert_eq!(
            Variant::Int4.artifact_for(Model::Vgg16, 8),
            "vgg16_int4_b8"
        );
        assert_eq!(
            Variant::Int8.artifact_for(Model::ResNet18, 4),
            "resnet18_int8_b4"
        );
    }

    #[test]
    fn mix_parsing() {
        let mix = parse_mix("lenet:4,vgg16:1").unwrap();
        assert_eq!(mix, vec![(Model::LeNet, 4), (Model::Vgg16, 1)]);
        assert_eq!(parse_mix("resnet18").unwrap(), vec![(Model::ResNet18, 1)]);
        assert_eq!(
            parse_mix(" lenet : 2 , mobilenet ").unwrap(),
            vec![(Model::LeNet, 2), (Model::MobileNet, 1)]
        );
        assert!(parse_mix("nope:1").is_err(), "unknown model");
        assert!(parse_mix("lenet:0").is_err(), "zero weight");
        assert!(parse_mix("lenet:x").is_err(), "non-integer weight");
        assert!(parse_mix("").is_err(), "empty spec");
    }

    #[test]
    fn weighted_pick_follows_the_mix() {
        let mix = parse_mix("lenet:3,vgg16:1").unwrap();
        let mut rng = Rng::new(1);
        let (mut lenet, mut vgg) = (0u32, 0u32);
        for _ in 0..4000 {
            match pick_weighted(&mut rng, &mix) {
                Model::LeNet => lenet += 1,
                Model::Vgg16 => vgg += 1,
                m => panic!("model {m:?} not in the mix"),
            }
        }
        assert!(vgg > 0, "every listed model appears");
        // ~3:1 split; an enormous margin at n=4000.
        assert!(lenet > 2 * vgg, "lenet {lenet} vs vgg {vgg}");
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("int4").unwrap(), Variant::Int4);
        assert!(Variant::parse("int2").is_err());
    }

    #[test]
    fn pim_bits() {
        assert_eq!(Variant::Int4.pim_bits(), 4);
        assert_eq!(Variant::Fp32.pim_bits(), 8);
    }

    #[test]
    fn total_is_queue_plus_exec() {
        let r = InferenceResponse {
            id: 0,
            model: Model::LeNet,
            logits: vec![0.0; 4].into(),
            predicted: 0,
            queue_ms: crate::util::units::ms(1.5),
            exec_ms: crate::util::units::ms(2.0),
            form_ms: crate::util::units::ms(0.5),
            sim: SimMetering::default(),
            instance: 0,
            worker: 0,
            batch_seq: 0,
        };
        assert!((r.total_ms() - crate::util::units::ms(3.5)).abs().raw() < 1e-12);
        assert!(r.form_ms <= r.queue_ms);
    }

    #[test]
    fn image_buf_clones_share_the_pixels() {
        let img = ImageBuf::from(vec![1.0f32, 2.0, 3.0]);
        let clone = img.clone();
        // Same backing allocation — cloning a request never copies.
        assert!(std::ptr::eq(img.as_slice(), clone.as_slice()));
        assert_eq!(img.len(), 3);
        assert_eq!(&img[1..], &[2.0, 3.0]);
        let collected: ImageBuf = (0..4).map(|i| i as f32).collect();
        assert_eq!(collected.as_slice(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn logits_view_derefs_to_its_row() {
        let buf: Arc<[f32]> = vec![0.0f32, 1.0, 2.0, 3.0, 4.0, 5.0].into();
        let row0 = LogitsView::new(Arc::clone(&buf), 0, 3);
        let row1 = LogitsView::new(Arc::clone(&buf), 3, 3);
        assert_eq!(row0.as_slice(), &[0.0, 1.0, 2.0]);
        assert_eq!(&row1[..], &[3.0, 4.0, 5.0]);
        assert_eq!(row1.len(), 3);
        // Rows of one batch share the backing buffer — no copies.
        assert!(std::ptr::eq(row0.as_slice().as_ptr(), buf.as_ptr()));
        assert_eq!(row0, LogitsView::from(vec![0.0, 1.0, 2.0]));
    }

    #[test]
    #[should_panic(expected = "out of buffer bounds")]
    fn logits_view_rejects_out_of_bounds() {
        let buf: Arc<[f32]> = vec![0.0f32; 4].into();
        let _ = LogitsView::new(buf, 2, 3);
    }

    #[test]
    fn logits_pool_recycles_only_free_buffers() {
        let mut pool = LogitsPool::new(4);
        let a = pool.take(8);
        let a_ptr = a.as_ptr();
        let view = LogitsView::new(Arc::clone(&a), 0, 4);
        pool.put(a);
        // Still viewed by a live response: must not be handed out again.
        let b = pool.take(8);
        assert_ne!(b.as_ptr(), a_ptr);
        // A different length never matches either.
        let c = pool.take(4);
        assert_ne!(c.as_ptr(), a_ptr);
        drop(view);
        pool.put(b);
        // The first buffer's views are gone — it is reused in place.
        let mut again = pool.take(8);
        assert_eq!(again.as_ptr(), a_ptr);
        assert!(Arc::get_mut(&mut again).is_some(), "exclusively owned");
    }

    #[test]
    fn logits_pool_is_bounded() {
        let mut pool = LogitsPool::new(2);
        for _ in 0..5 {
            let b = pool.take(4);
            pool.put(b);
        }
        assert!(pool.pooled() <= 2);
    }

    #[test]
    fn image_buf_wraps_a_shared_arc_without_copying() {
        let arc: Arc<[f32]> = vec![1.0f32, 2.0, 3.0].into();
        let ptr = arc.as_ptr();
        let img = ImageBuf::from(Arc::clone(&arc));
        // Same backing allocation — the wire ingest path never copies.
        assert!(std::ptr::eq(img.as_slice().as_ptr(), ptr));
        assert_eq!(img.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn reply_queue_is_fifo_across_threads() {
        let q = Arc::new(ReplyQueue::with_capacity(4));
        let producer = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            for id in 0..3u64 {
                producer.push(Reply::Busy { id });
            }
            producer.push(Reply::Fin);
        });
        let mut ids = Vec::new();
        loop {
            match q.pop() {
                Reply::Busy { id } => ids.push(id),
                Reply::Fin => break,
                other => panic!("unexpected reply {other:?}"),
            }
        }
        t.join().unwrap();
        assert_eq!(ids, vec![0, 1, 2]);
        assert!(q.is_empty());
    }

    #[test]
    fn logits_pool_adapts_to_a_traffic_shift() {
        // A pool pinned full of one model's retired buffers must not
        // defeat recycling forever when traffic shifts to another
        // output length.
        let mut pool = LogitsPool::new(2);
        let a = pool.take(4);
        let b = pool.take(4);
        pool.put(a);
        pool.put(b); // full: two free len-4 buffers
        let big = pool.take(8); // fresh — no len-8 retiree yet
        let big_ptr = big.as_ptr();
        pool.put(big); // evicts one stale-length free slot
        assert_eq!(pool.pooled(), 2);
        assert_eq!(
            pool.take(8).as_ptr(),
            big_ptr,
            "the shifted length is retained and recycled"
        );
    }
}
