//! Request/response types for the serving path.

use std::time::Instant;

use crate::cnn::models::Model;
use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Parse a workload-mix spec like `lenet:4,vgg16:1` into `(model,
/// weight)` pairs — the grammar behind the CLI's and the serving
/// example's `--mix` flag. A bare model name means weight 1; weights
/// must be at least 1 and at least one model must be listed.
pub fn parse_mix(spec: &str) -> Result<Vec<(Model, u64)>> {
    let mut mix: Vec<(Model, u64)> = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, weight) = match part.split_once(':') {
            Some((n, w)) => {
                let weight: u64 = w.trim().parse().map_err(|_| {
                    Error::Config(format!("mix weight in '{part}' wants an integer"))
                })?;
                (n.trim(), weight)
            }
            None => (part, 1),
        };
        let model = Model::from_name(name)
            .ok_or_else(|| Error::Config(format!("mix names unknown model '{name}'")))?;
        if weight == 0 {
            return Err(Error::Config(format!(
                "mix weight for '{name}' must be at least 1"
            )));
        }
        mix.push((model, weight));
    }
    if mix.is_empty() {
        return Err(Error::Config("mix lists no models".into()));
    }
    Ok(mix)
}

/// Weighted random model pick from a parsed mix (weights are positive
/// by [`parse_mix`]'s contract).
pub fn pick_weighted(rng: &mut Rng, mix: &[(Model, u64)]) -> Model {
    let total: u64 = mix.iter().map(|(_, w)| *w).sum();
    let mut ticket = rng.bounded(total);
    for (m, w) in mix {
        if ticket < *w {
            return *m;
        }
        ticket -= w;
    }
    unreachable!("ticket is bounded by the total weight");
}

/// Which quantization variant serves the request (precision ↔ artifact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp32,
    Int8,
    Int4,
}

impl Variant {
    /// Short lowercase tag used in artifact names and CLI flags.
    pub fn tag(&self) -> &'static str {
        match self {
            Variant::Fp32 => "fp32",
            Variant::Int8 => "int8",
            Variant::Int4 => "int4",
        }
    }

    /// Artifact name for a given serving batch size — the legacy
    /// single-model naming, which is exactly [`Model::LeNet`]'s artifact
    /// family (`cnn_*` — the names python/compile emits to disk).
    pub fn artifact(&self, batch: usize) -> String {
        format!("cnn_{}_b{batch}", self.tag())
    }

    /// Artifact name for a `(model, variant)` pair at a serving batch
    /// size. LeNet keeps the on-disk `cnn_*` family; every other model
    /// is namespaced by its model name (e.g. `vgg16_int4_b8`).
    pub fn artifact_for(&self, model: Model, batch: usize) -> String {
        match model {
            Model::LeNet => self.artifact(batch),
            m => format!("{}_{}_b{batch}", m.name(), self.tag()),
        }
    }

    /// Operand width on the PIM substrate (fp32 is served as int8 after
    /// PTQ; OPIMA has no float datapath).
    pub fn pim_bits(&self) -> u32 {
        match self {
            Variant::Fp32 | Variant::Int8 => 8,
            Variant::Int4 => 4,
        }
    }

    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "fp32" => Ok(Variant::Fp32),
            "int8" => Ok(Variant::Int8),
            "int4" => Ok(Variant::Int4),
            other => Err(Error::Serving(format!("unknown variant '{other}'"))),
        }
    }
}

/// One classification request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Which CNN serves the request (see
    /// [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS)).
    pub model: Model,
    /// Flattened image (`model.input_elems()` values, NHWC).
    pub image: Vec<f32>,
    pub variant: Variant,
    pub arrival: Instant,
}

/// Architectural cost metered by the simulator for the batch that
/// carried this request.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimMetering {
    /// What the OPIMA hardware would have taken for the batch (ms).
    pub hw_latency_ms: f64,
    /// Dynamic energy of the batch (mJ).
    pub hw_energy_mj: f64,
}

/// One classification response.
///
/// Latency accounting uses one consistent convention: `queue_ms` covers
/// arrival → start of the batch's execution, `exec_ms` covers the whole
/// batch's execution, so `total_ms() = queue_ms + exec_ms` is the wall
/// time from arrival to completion. `form_ms ≤ queue_ms` isolates the
/// dynamic-batcher share of the queueing delay.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    /// The model that served this request (batches are single-model).
    pub model: Model,
    pub logits: Vec<f32>,
    pub predicted: usize,
    /// Wall time from arrival to the start of the batch's execution
    /// (batcher wait + dispatch queueing, ms).
    pub queue_ms: f64,
    /// Wall time of the execution of the whole batch that carried this
    /// request (ms) — not an amortized per-request share.
    pub exec_ms: f64,
    /// Wall time from arrival to batch formation (dynamic-batcher
    /// latency, ms); the remainder of `queue_ms` is dispatch queueing.
    pub form_ms: f64,
    /// Simulated OPIMA hardware cost of the batch that carried this
    /// request (full-batch numbers, not per-request shares).
    pub sim: SimMetering,
    /// Simulated OPIMA instance the batch was dispatched to.
    pub instance: usize,
    /// Worker thread that executed the batch.
    pub worker: usize,
    /// Formation sequence number of the batch that carried this request
    /// (monotonic per engine) — responses with equal `batch_seq` rode
    /// the same single-model batch.
    pub batch_seq: u64,
}

impl InferenceResponse {
    /// Wall time from arrival to completion (ms).
    pub fn total_ms(&self) -> f64 {
        self.queue_ms + self.exec_ms
    }

    /// The `(total, queue, exec, form)` latency sample (ms) this
    /// response contributes to the engine's streaming histograms.
    pub fn latency_sample(&self) -> (f64, f64, f64, f64) {
        (self.total_ms(), self.queue_ms, self.exec_ms, self.form_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Variant::Fp32.artifact(8), "cnn_fp32_b8");
        assert_eq!(Variant::Int4.artifact(8), "cnn_int4_b8");
    }

    #[test]
    fn artifact_names_per_model() {
        // LeNet keeps the on-disk legacy family; other models namespace.
        assert_eq!(Variant::Fp32.artifact_for(Model::LeNet, 8), "cnn_fp32_b8");
        assert_eq!(
            Variant::Int4.artifact_for(Model::Vgg16, 8),
            "vgg16_int4_b8"
        );
        assert_eq!(
            Variant::Int8.artifact_for(Model::ResNet18, 4),
            "resnet18_int8_b4"
        );
    }

    #[test]
    fn mix_parsing() {
        let mix = parse_mix("lenet:4,vgg16:1").unwrap();
        assert_eq!(mix, vec![(Model::LeNet, 4), (Model::Vgg16, 1)]);
        assert_eq!(parse_mix("resnet18").unwrap(), vec![(Model::ResNet18, 1)]);
        assert_eq!(
            parse_mix(" lenet : 2 , mobilenet ").unwrap(),
            vec![(Model::LeNet, 2), (Model::MobileNet, 1)]
        );
        assert!(parse_mix("nope:1").is_err(), "unknown model");
        assert!(parse_mix("lenet:0").is_err(), "zero weight");
        assert!(parse_mix("lenet:x").is_err(), "non-integer weight");
        assert!(parse_mix("").is_err(), "empty spec");
    }

    #[test]
    fn weighted_pick_follows_the_mix() {
        let mix = parse_mix("lenet:3,vgg16:1").unwrap();
        let mut rng = Rng::new(1);
        let (mut lenet, mut vgg) = (0u32, 0u32);
        for _ in 0..4000 {
            match pick_weighted(&mut rng, &mix) {
                Model::LeNet => lenet += 1,
                Model::Vgg16 => vgg += 1,
                m => panic!("model {m:?} not in the mix"),
            }
        }
        assert!(vgg > 0, "every listed model appears");
        // ~3:1 split; an enormous margin at n=4000.
        assert!(lenet > 2 * vgg, "lenet {lenet} vs vgg {vgg}");
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("int4").unwrap(), Variant::Int4);
        assert!(Variant::parse("int2").is_err());
    }

    #[test]
    fn pim_bits() {
        assert_eq!(Variant::Int4.pim_bits(), 4);
        assert_eq!(Variant::Fp32.pim_bits(), 8);
    }

    #[test]
    fn total_is_queue_plus_exec() {
        let r = InferenceResponse {
            id: 0,
            model: Model::LeNet,
            logits: vec![0.0; 4],
            predicted: 0,
            queue_ms: 1.5,
            exec_ms: 2.0,
            form_ms: 0.5,
            sim: SimMetering::default(),
            instance: 0,
            worker: 0,
            batch_seq: 0,
        };
        assert!((r.total_ms() - 3.5).abs() < 1e-12);
        assert!(r.form_ms <= r.queue_ms);
    }
}
