//! Least-outstanding-work routing across simulated OPIMA instances.
//!
//! A deployment can attach several OPIMA memory modules; the router
//! tracks the simulated busy horizon of each and sends every batch to
//! the instance that frees up first (the same policy a vLLM-style
//! router applies to replicas). Reservations can be tagged with the
//! model that booked them ([`Router::dispatch_for`]), so the simulated
//! makespan is reportable per model as well as globally.

use std::collections::HashMap;

use crate::cnn::models::Model;

/// Tracks per-instance simulated busy horizons.
#[derive(Debug, Clone)]
pub struct Router {
    /// Simulated time (ms) at which each instance becomes free.
    horizons: Vec<f64>,
    /// Batches dispatched per instance.
    dispatched: Vec<u64>,
    /// Latest reservation end (ms) per tagging model — that model's
    /// simulated makespan.
    model_end: HashMap<Model, f64>,
}

impl Router {
    pub fn new(instances: usize) -> Self {
        assert!(instances >= 1);
        Self {
            horizons: vec![0.0; instances],
            dispatched: vec![0; instances],
            model_end: HashMap::new(),
        }
    }

    pub fn instances(&self) -> usize {
        self.horizons.len()
    }

    /// Pick the least-loaded instance for a batch arriving at `now_ms`
    /// with simulated duration `dur_ms`. Returns (instance, start_ms,
    /// end_ms) and commits the reservation.
    pub fn dispatch(&mut self, now_ms: f64, dur_ms: f64) -> (usize, f64, f64) {
        let (idx, _) = self
            .horizons
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .expect("non-empty");
        let start = self.horizons[idx].max(now_ms);
        let end = start + dur_ms;
        self.horizons[idx] = end;
        self.dispatched[idx] += 1;
        (idx, start, end)
    }

    /// [`Router::dispatch`] with the reservation tagged by the model the
    /// batch serves, so [`Router::model_makespan_ms`] can report when the
    /// simulated hardware finished that model's work.
    pub fn dispatch_for(&mut self, model: Model, now_ms: f64, dur_ms: f64) -> (usize, f64, f64) {
        let r = self.dispatch(now_ms, dur_ms);
        let end = self.model_end.entry(model).or_insert(0.0);
        *end = end.max(r.2);
        r
    }

    /// Per-instance dispatched-batch counts.
    pub fn load(&self) -> &[u64] {
        &self.dispatched
    }

    /// Simulated makespan across instances.
    pub fn makespan_ms(&self) -> f64 {
        self.horizons.iter().cloned().fold(0.0, f64::max)
    }

    /// Simulated makespan of one model's tagged reservations (0 when the
    /// model never dispatched).
    pub fn model_makespan_ms(&self, model: Model) -> f64 {
        self.model_end.get(&model).copied().unwrap_or(0.0)
    }

    /// All per-model makespans recorded so far.
    pub fn model_makespans(&self) -> &HashMap<Model, f64> {
        &self.model_end
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_across_instances() {
        let mut r = Router::new(2);
        let (i0, s0, _) = r.dispatch(0.0, 10.0);
        let (i1, s1, _) = r.dispatch(0.0, 10.0);
        assert_ne!(i0, i1, "second batch goes to the idle instance");
        assert_eq!(s0, 0.0);
        assert_eq!(s1, 0.0);
        // Third batch queues behind the earlier-finishing one.
        let (_, s2, e2) = r.dispatch(0.0, 5.0);
        assert_eq!(s2, 10.0);
        assert_eq!(e2, 15.0);
    }

    #[test]
    fn load_counts() {
        let mut r = Router::new(3);
        for _ in 0..9 {
            r.dispatch(0.0, 1.0);
        }
        assert_eq!(r.load().iter().sum::<u64>(), 9);
        assert!(r.load().iter().all(|&c| c == 3), "{:?}", r.load());
        assert!((r.makespan_ms() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn respects_arrival_time() {
        let mut r = Router::new(1);
        let (_, s, e) = r.dispatch(100.0, 5.0);
        assert_eq!(s, 100.0);
        assert_eq!(e, 105.0);
    }

    #[test]
    fn tagged_reservations_report_per_model_makespan() {
        let mut r = Router::new(1);
        r.dispatch_for(Model::LeNet, 0.0, 10.0);
        r.dispatch_for(Model::Vgg16, 0.0, 30.0);
        r.dispatch_for(Model::LeNet, 0.0, 10.0);
        // Serialized on one instance: lenet [0,10], vgg [10,40],
        // lenet [40,50].
        assert_eq!(r.model_makespan_ms(Model::LeNet), 50.0);
        assert_eq!(r.model_makespan_ms(Model::Vgg16), 40.0);
        assert_eq!(r.makespan_ms(), 50.0);
        assert_eq!(r.model_makespan_ms(Model::MobileNet), 0.0);
        assert_eq!(r.model_makespans().len(), 2);
    }
}
