//! Contention-aware routing across simulated OPIMA instances.
//!
//! A deployment can attach several OPIMA memory modules. The router
//! owns the placement **policy** — earliest feasible start wins, ties
//! break toward the least-dispatched instance, reservations are tagged
//! by model so makespans are reportable per model — and prices every
//! placement against the persistent
//! [`GlobalTimeline`](crate::analyzer::contention::GlobalTimeline):
//! one event engine per instance tracking subarray occupancy *and* the
//! shared aggregation/writeback stage pools across all in-flight
//! batches.
//!
//! Two admission models coexist:
//!
//! - [`Router::dispatch`] / [`Router::dispatch_for`] commit **occupancy
//!   only** (the optimistic pre-contention model): the batch's duration
//!   is the caller's isolated estimate and co-resident batches are
//!   assumed not to contend for stage pools. These keep the historical
//!   semantics (and the historical numbers) for callers that have no
//!   layer stream to admit.
//! - [`Router::dispatch_batch`] admits the batch's priced **event
//!   stream** into the instance's persistent pools, so co-resident
//!   batches genuinely compete for aggregation units and writeback
//!   channels: the committed end is the *contended* end. With one batch
//!   in flight the admission is bit-exact with the isolated per-batch
//!   timeline, so single-tenant numbers are unchanged. Setting
//!   [`PipelineParams::cross_batch_contention`] to `false` downgrades
//!   this path to the occupancy-only model.
//!
//! Placement probes use the isolated duration as the occupancy window
//! (cheap, and available before admission); the committed reservation
//! then covers the contended window, which is never shorter — the
//! feasibility accounting stays conservative. Dispatch cost is
//! O(batch × layers × log pools) for the admission plus
//! O(instances × ledger) for the probe; ledgers are end-sorted (probes
//! allocate nothing), the retirement frontier prunes them only when the
//! dispatch clock actually advances, and the oversubscribed regime is
//! bounded by folding old reservations into a per-instance floor (see
//! [`MAX_RESERVATIONS_PER_INSTANCE`]).

use std::collections::BTreeMap;

use crate::analyzer::contention::{BatchStream, GlobalTimeline};
use crate::cnn::models::Model;
use crate::config::{OpimaConfig, PipelineParams};
use crate::util::units::{Millis, Nanos};

pub use crate::analyzer::contention::MAX_RESERVATIONS_PER_INSTANCE;

/// Routes batches onto simulated instances, priced by the global
/// contention timeline.
#[derive(Debug, Clone)]
pub struct Router {
    /// The persistent per-instance event engine.
    timeline: GlobalTimeline,
    /// Batches dispatched per instance (placement tie-break).
    dispatched: Vec<u64>,
    /// Whether [`Router::dispatch_batch`] admits into the shared stage
    /// pools (honest) or books occupancy only (optimistic).
    contended: bool,
    /// Latest reservation end per tagging model — that model's
    /// simulated makespan. `BTreeMap` so iteration is model-sorted.
    model_end: BTreeMap<Model, Millis>,
}

impl Router {
    /// Router whose instances are booked exclusively (each dispatch
    /// takes the whole module — the pre-occupancy behaviour).
    pub fn new(instances: usize) -> Self {
        Self::with_capacity(instances, 1)
    }

    /// Router over instances with `subarray_capacity` subarrays each
    /// and default pipeline pools; [`Router::dispatch_for`]
    /// co-schedules batches whose footprints fit together.
    pub fn with_capacity(instances: usize, subarray_capacity: usize) -> Self {
        Self::with_pools(instances, subarray_capacity, &PipelineParams::default())
    }

    /// Router whose per-instance stage pools are sized by `pipe` —
    /// [`Router::dispatch_batch`] admits batches into them so
    /// co-resident batches contend for aggregation units and writeback
    /// channels (unless `pipe.cross_batch_contention` is off).
    pub fn with_pools(instances: usize, subarray_capacity: usize, pipe: &PipelineParams) -> Self {
        assert!(instances >= 1);
        Self {
            timeline: GlobalTimeline::new(instances, subarray_capacity, pipe),
            dispatched: vec![0; instances],
            contended: pipe.cross_batch_contention,
            model_end: BTreeMap::new(),
        }
    }

    /// Router sized from the full hardware config: capacity and bank
    /// count from the geometry, stage pools from the pipeline params,
    /// and the writeback stage priced by `[memory] writeback_model`
    /// (flat — the default — reproduces [`Router::with_pools`]
    /// bit-exactly; naive/scheduled admit each batch's writebacks as
    /// command sequences against persistent per-bank state).
    pub fn with_hw(instances: usize, cfg: &OpimaConfig) -> Self {
        assert!(instances >= 1);
        Self {
            timeline: GlobalTimeline::with_memory(
                instances,
                cfg.geometry.total_subarrays(),
                &cfg.pipeline,
                cfg.memory.writeback_model,
                cfg.geometry.banks,
            ),
            dispatched: vec![0; instances],
            contended: cfg.pipeline.cross_batch_contention,
            model_end: BTreeMap::new(),
        }
    }

    pub fn instances(&self) -> usize {
        self.timeline.instances()
    }

    /// Subarray capacity of each instance.
    pub fn capacity(&self) -> usize {
        self.timeline.capacity()
    }

    /// The global engine pricing this router's placements (read-only —
    /// audits and tests).
    pub fn timeline(&self) -> &GlobalTimeline {
        &self.timeline
    }

    /// Book a whole instance exclusively for a batch arriving at
    /// `now_ms` with simulated duration `dur_ms`. Returns (instance,
    /// start_ms, end_ms) and commits the reservation.
    pub fn dispatch(&mut self, now_ms: Millis, dur_ms: Millis) -> (usize, Millis, Millis) {
        self.place(None, self.capacity(), now_ms, dur_ms)
    }

    /// Occupancy-aware dispatch: place a batch of `model` with the
    /// mapper footprint `subarrays` at the earliest feasible simulated
    /// time across instances. The reservation is tagged by model so
    /// [`Router::model_makespan_ms`] can report when the simulated
    /// hardware finished that model's work. Footprints larger than an
    /// instance are clamped to the full instance (the model time-shares
    /// the memory; the registry surfaces the capacity warning). This
    /// path books occupancy only — co-resident stage pools are assumed
    /// free; [`Router::dispatch_batch`] is the honest path.
    pub fn dispatch_for(
        &mut self,
        model: Model,
        subarrays: usize,
        now_ms: Millis,
        dur_ms: Millis,
    ) -> (usize, Millis, Millis) {
        self.place(Some(model), subarrays, now_ms, dur_ms)
    }

    /// Contention-aware dispatch: place the batch like
    /// [`Router::dispatch_for`] (earliest feasible occupancy window of
    /// the *isolated* duration `isolated_ms`), then admit its priced
    /// event stream into the chosen instance's persistent stage pools.
    /// The returned (and committed) end is the **contended** end —
    /// never earlier than `start + isolated_ms`, and bit-exactly equal
    /// to it when the batch has the instance's pools to itself. With
    /// `cross_batch_contention` off this is exactly `dispatch_for`.
    pub fn dispatch_batch(
        &mut self,
        model: Model,
        subarrays: usize,
        now_ms: Millis,
        stream: BatchStream<'_>,
        isolated_ms: Millis,
    ) -> (usize, Millis, Millis) {
        if !self.contended {
            return self.place(Some(model), subarrays, now_ms, isolated_ms);
        }
        let fp = subarrays.clamp(1, self.capacity());
        // The router's clock is milliseconds (serving wall clock); the
        // global engine runs in nanoseconds. Convert exactly once here,
        // at admission.
        let base_ns = self.timeline.advance(now_ms.to_nanos());
        let (idx, start_ns) = self.choose(fp, base_ns, isolated_ms.to_nanos());
        let adm = self.timeline.admit(idx, fp, start_ns, stream, None);
        self.finish(Some(model), idx, adm.start_ms(), adm.end_ms())
    }

    /// Occupancy-only placement (both legacy dispatch paths).
    fn place(
        &mut self,
        model: Option<Model>,
        subarrays: usize,
        now_ms: Millis,
        dur_ms: Millis,
    ) -> (usize, Millis, Millis) {
        let fp = subarrays.clamp(1, self.capacity());
        // Place against the frontier, not the caller's clock: workers
        // race, and a stale `now_ms` below the latest retirement point
        // would see already-retired reservations as free capacity
        // (overbooking the instance). Clamping forward keeps the
        // never-undercount invariant; a placement never starts before
        // the latest observed dispatch clock anyway.
        let base_ns = self.timeline.advance(now_ms.to_nanos());
        let dur_ns = dur_ms.to_nanos();
        let (idx, start_ns) = self.choose(fp, base_ns, dur_ns);
        let end_ns = self.timeline.occupy(idx, fp, start_ns, dur_ns);
        self.finish(model, idx, start_ns.to_millis(), end_ns.to_millis())
    }

    /// Earliest feasible start wins; ties (e.g. small footprints that
    /// fit everywhere immediately) break toward the least-dispatched
    /// instance so load still spreads across modules.
    fn choose(&self, fp: usize, base_ns: Nanos, dur_ns: Nanos) -> (usize, Nanos) {
        (0..self.instances())
            .map(|i| (i, self.timeline.earliest_start(i, fp, base_ns, dur_ns)))
            .min_by(|a, b| {
                a.1.total_cmp(&b.1)
                    .then_with(|| self.dispatched[a.0].cmp(&self.dispatched[b.0]))
            })
            .expect("non-empty")
    }

    fn finish(
        &mut self,
        model: Option<Model>,
        idx: usize,
        start_ms: Millis,
        end_ms: Millis,
    ) -> (usize, Millis, Millis) {
        self.dispatched[idx] += 1;
        if let Some(m) = model {
            let e = self.model_end.entry(m).or_insert(Millis::ZERO);
            *e = e.max(end_ms);
        }
        (idx, start_ms, end_ms)
    }

    /// Per-instance dispatched-batch counts.
    pub fn load(&self) -> &[u64] {
        &self.dispatched
    }

    /// Simulated makespan across instances.
    pub fn makespan_ms(&self) -> Millis {
        self.timeline.makespan_ns().to_millis()
    }

    /// Simulated makespan of one model's tagged reservations (0 when the
    /// model never dispatched).
    pub fn model_makespan_ms(&self, model: Model) -> Millis {
        self.model_end.get(&model).copied().unwrap_or(Millis::ZERO)
    }

    /// All per-model makespans recorded so far, sorted by model
    /// (declaration order), so reports built from this are stable.
    pub fn model_makespans(&self) -> Vec<(Model, Millis)> {
        self.model_end.iter().map(|(m, e)| (*m, *e)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::scheduler::LayerCost;
    use crate::util::units::{ms, ns};

    fn lc(mac_ns: f64, aggregation_ns: f64, writeback_ns: f64) -> LayerCost {
        LayerCost {
            processing_ns: ns(mac_ns + aggregation_ns),
            mac_ns: ns(mac_ns),
            aggregation_ns: ns(aggregation_ns),
            writeback_ns: ns(writeback_ns),
            ..LayerCost::default()
        }
    }

    #[test]
    fn balances_across_instances() {
        let mut r = Router::new(2);
        let (i0, s0, _) = r.dispatch(ms(0.0), ms(10.0));
        let (i1, s1, _) = r.dispatch(ms(0.0), ms(10.0));
        assert_ne!(i0, i1, "second batch goes to the idle instance");
        assert_eq!(s0, Millis::ZERO);
        assert_eq!(s1, Millis::ZERO);
        // Third batch queues behind the earlier-finishing one.
        let (_, s2, e2) = r.dispatch(ms(0.0), ms(5.0));
        assert_eq!(s2, ms(10.0));
        assert_eq!(e2, ms(15.0));
    }

    #[test]
    fn load_counts() {
        let mut r = Router::new(3);
        for _ in 0..9 {
            r.dispatch(ms(0.0), ms(1.0));
        }
        assert_eq!(r.load().iter().sum::<u64>(), 9);
        assert!(r.load().iter().all(|&c| c == 3), "{:?}", r.load());
        assert!((r.makespan_ms() - ms(3.0)).abs().raw() < 1e-12);
    }

    #[test]
    fn respects_arrival_time() {
        let mut r = Router::new(1);
        let (_, s, e) = r.dispatch(ms(100.0), ms(5.0));
        assert_eq!(s, ms(100.0));
        assert_eq!(e, ms(105.0));
    }

    #[test]
    fn tagged_full_capacity_reservations_serialize() {
        // Full-footprint dispatches reproduce the old scalar-horizon
        // behaviour exactly.
        let mut r = Router::with_capacity(1, 16_384);
        let cap = r.capacity();
        r.dispatch_for(Model::LeNet, cap, ms(0.0), ms(10.0));
        r.dispatch_for(Model::Vgg16, cap, ms(0.0), ms(30.0));
        r.dispatch_for(Model::LeNet, cap, ms(0.0), ms(10.0));
        // Serialized on one instance: lenet [0,10], vgg [10,40],
        // lenet [40,50].
        assert_eq!(r.model_makespan_ms(Model::LeNet), ms(50.0));
        assert_eq!(r.model_makespan_ms(Model::Vgg16), ms(40.0));
        assert_eq!(r.makespan_ms(), ms(50.0));
        assert_eq!(r.model_makespan_ms(Model::MobileNet), Millis::ZERO);
        assert_eq!(r.model_makespans().len(), 2);
    }

    #[test]
    fn small_footprints_co_reside() {
        // Two models that together fit in one instance overlap in
        // simulated time instead of serializing.
        let mut r = Router::with_capacity(1, 1000);
        let (_, s0, _) = r.dispatch_for(Model::LeNet, 100, ms(0.0), ms(10.0));
        let (_, s1, _) = r.dispatch_for(Model::MobileNet, 400, ms(0.0), ms(20.0));
        assert_eq!(s0, Millis::ZERO);
        assert_eq!(s1, Millis::ZERO, "fits alongside — co-resident");
        assert_eq!(r.makespan_ms(), ms(20.0));
        // A third model that does NOT fit (100+400+600 > 1000) queues
        // until enough occupancy frees: at t=10 lenet releases 100.
        let (_, s2, e2) = r.dispatch_for(Model::Vgg16, 600, ms(0.0), ms(5.0));
        assert_eq!(s2, ms(10.0));
        assert_eq!(e2, ms(15.0));
    }

    #[test]
    fn oversized_footprint_clamps_to_exclusive() {
        let mut r = Router::with_capacity(1, 100);
        r.dispatch_for(Model::Vgg16, 10_000, ms(0.0), ms(10.0));
        let (_, s, _) = r.dispatch_for(Model::LeNet, 1, ms(0.0), ms(1.0));
        assert_eq!(s, ms(10.0), "a clamped full-capacity batch excludes others");
    }

    #[test]
    fn model_makespans_sorted_by_model() {
        let mut r = Router::with_capacity(2, 100);
        r.dispatch_for(Model::Vgg16, 10, ms(0.0), ms(5.0));
        r.dispatch_for(Model::LeNet, 10, ms(0.0), ms(5.0));
        r.dispatch_for(Model::MobileNet, 10, ms(0.0), ms(5.0));
        let spans = r.model_makespans();
        let models: Vec<Model> = spans.iter().map(|(m, _)| *m).collect();
        assert_eq!(models, vec![Model::LeNet, Model::MobileNet, Model::Vgg16]);
    }

    #[test]
    fn stale_dispatch_clock_clamps_to_frontier() {
        // Racing workers can present now_ms below the latest prune
        // frontier; placement must clamp forward so pruned occupancy
        // can never be overbooked.
        let mut r = Router::with_capacity(1, 100);
        r.dispatch_for(Model::LeNet, 60, ms(103.0), ms(5.0));
        let (_, s, _) = r.dispatch_for(Model::Vgg16, 60, ms(100.0), ms(5.0));
        assert!(s >= ms(103.0), "stale now started before the frontier: {s}");
        assert_eq!(s, ms(108.0), "60+60 > 100: serialized behind the first");
    }

    #[test]
    fn ledger_stays_bounded_when_sim_time_outruns_the_clock() {
        // Oversubscribed regime: every dispatch arrives at now = 0 while
        // simulated reservations stretch far into the future, so nothing
        // ever expires. The ledger must compact instead of growing, and
        // placements must stay feasible and non-decreasing per instance.
        let mut r = Router::with_capacity(1, 100);
        let mut last_start = Millis::ZERO;
        for _ in 0..2000 {
            // Footprint 60: no two fit together, so every batch queues.
            let (_, s, _) = r.dispatch_for(Model::Vgg16, 60, ms(0.0), ms(5.0));
            assert!(s >= last_start, "starts must not regress");
            last_start = s;
        }
        assert!(r.timeline().live_reservations(0) <= MAX_RESERVATIONS_PER_INSTANCE);
        // Work is conserved: 2000 serialized 5 ms batches.
        assert!((r.makespan_ms() - ms(2000.0 * 5.0)).abs().raw() < 1e-6);
    }

    #[test]
    fn picks_instance_with_earliest_feasible_start() {
        let mut r = Router::with_capacity(2, 100);
        // Saturate instance 0 until t=50; instance 1 until t=10.
        r.dispatch_for(Model::Vgg16, 100, ms(0.0), ms(50.0));
        r.dispatch_for(Model::LeNet, 100, ms(0.0), ms(10.0));
        let (i, s, _) = r.dispatch_for(Model::MobileNet, 80, ms(0.0), ms(5.0));
        assert_eq!(i, 1);
        assert_eq!(s, ms(10.0));
    }

    #[test]
    fn contended_dispatch_prices_pool_sharing() {
        let costs = vec![lc(100.0, 40.0, 60.0), lc(80.0, 30.0, 50.0)];
        let stream = BatchStream {
            costs: &costs,
            batch: 8,
            pipelined: true,
        };
        let pipe = PipelineParams::default();
        // Isolated duration of that stream (drained single-instance
        // engine at t = 0).
        let iso_ms = GlobalTimeline::new(1, 100, &pipe)
            .admit(0, 10, Nanos::ZERO, stream, None)
            .makespan_ns
            .to_millis();
        let mut r = Router::with_pools(1, 100, &pipe);
        // Alone in flight: bit-exact with the isolated timeline.
        let (_, s0, e0) = r.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, iso_ms);
        assert_eq!(s0, Millis::ZERO);
        assert_eq!(e0, iso_ms);
        // Co-resident (footprints fit together): the second batch
        // shares the writeback channel, so its window must stretch
        // beyond the isolated estimate — the honest makespan.
        let (_, s1, e1) = r.dispatch_batch(Model::MobileNet, 10, ms(0.0), stream, iso_ms);
        assert_eq!(s1, Millis::ZERO, "occupancy still co-resides");
        assert!(e1 - s1 > iso_ms, "no contention priced: {} vs {iso_ms}", e1 - s1);
        // Bounded by full serialization.
        assert!(r.makespan_ms() <= 2.0 * iso_ms + ms(1e-9));
        assert!(r.model_makespan_ms(Model::MobileNet) >= r.model_makespan_ms(Model::LeNet));
    }

    /// `with_hw` at the default (flat) model is the same router
    /// `with_pools` builds; switching `[memory] writeback_model` to a
    /// command controller only ever prices co-residency higher, and
    /// scheduled never above naive.
    #[test]
    fn with_hw_flat_matches_with_pools_and_command_models_order() {
        use crate::config::WritebackModel;
        let costs = vec![lc(100.0, 40.0, 60.0), lc(80.0, 30.0, 50.0)];
        let stream = BatchStream {
            costs: &costs,
            batch: 8,
            pipelined: true,
        };
        let cfg = OpimaConfig::paper();
        let mut flat_hw = Router::with_hw(1, &cfg);
        let mut flat_pools = Router::with_pools(1, cfg.geometry.total_subarrays(), &cfg.pipeline);
        let mut ends = Vec::new();
        for model in [WritebackModel::Naive, WritebackModel::Scheduled] {
            let mut c = cfg.clone();
            c.memory.writeback_model = model;
            let mut r = Router::with_hw(1, &c);
            r.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, ms(0.001));
            let (_, _, e) = r.dispatch_batch(Model::MobileNet, 10, ms(0.0), stream, ms(0.001));
            ends.push(e);
        }
        flat_hw.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, ms(0.001));
        flat_pools.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, ms(0.001));
        let (_, _, fe) = flat_hw.dispatch_batch(Model::MobileNet, 10, ms(0.0), stream, ms(0.001));
        let (_, _, pe) =
            flat_pools.dispatch_batch(Model::MobileNet, 10, ms(0.0), stream, ms(0.001));
        assert_eq!(fe, pe, "flat with_hw must be bit-exact with with_pools");
        assert_eq!(flat_hw.makespan_ms(), flat_pools.makespan_ms());
        assert!(ends[0] >= fe, "naive must not undercut flat: {} < {fe}", ends[0]);
        assert!(
            ends[1] <= ends[0] + ms(1e-9),
            "scheduled {} must not trail naive {}",
            ends[1],
            ends[0]
        );
    }

    #[test]
    fn contention_knob_off_reproduces_occupancy_only_dispatch() {
        let costs = vec![lc(100.0, 40.0, 60.0)];
        let stream = BatchStream {
            costs: &costs,
            batch: 4,
            pipelined: true,
        };
        let pipe = PipelineParams {
            cross_batch_contention: false,
            ..PipelineParams::default()
        };
        let mut honest = Router::with_pools(1, 100, &PipelineParams::default());
        let mut optimistic = Router::with_pools(1, 100, &pipe);
        let mut legacy = Router::with_pools(1, 100, &pipe);
        for _ in 0..3 {
            optimistic.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, ms(2.5));
            legacy.dispatch_for(Model::LeNet, 10, ms(0.0), ms(2.5));
            honest.dispatch_batch(Model::LeNet, 10, ms(0.0), stream, ms(2.5));
        }
        assert_eq!(optimistic.makespan_ms(), legacy.makespan_ms());
        assert!(
            honest.makespan_ms() >= optimistic.makespan_ms(),
            "the optimistic model must never exceed the honest one"
        );
    }
}
