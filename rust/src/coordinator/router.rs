//! Occupancy-aware routing across simulated OPIMA instances.
//!
//! A deployment can attach several OPIMA memory modules. The router
//! used to reduce each instance to a single scalar busy horizon —
//! one batch at a time per module, regardless of how little of the
//! module the batch's model actually occupies. It now tracks
//! per-instance **subarray occupancy**: every reservation carries the
//! mapper footprint of the model it serves, and a batch is placed at
//! the earliest simulated time at which its footprint fits alongside
//! the reservations already running there. Two models whose footprints
//! fit together co-reside on one instance instead of serializing — the
//! decision is driven by the mapper's occupancy, not a scalar horizon.
//!
//! Reservations can be tagged with the model that booked them
//! ([`Router::dispatch_for`]), so the simulated makespan is reportable
//! per model as well as globally; per-model reports are sorted by model
//! for stable output. The footprint-free [`Router::dispatch`] books the
//! instance exclusively (the whole capacity) and keeps the old
//! serialize-per-instance semantics.
//!
//! **Modeling assumption:** co-residency is gated on the *subarray*
//! footprint only — the first-order resource that determines whether a
//! model's stationary operands can be resident at all. Co-resident
//! batches are assumed to also share the aggregation/writeback stage
//! pools without contention, even though each batch's duration was
//! priced by the timeline assuming sole use of them; co-resident
//! makespans are therefore optimistic by up to the writeback-channel
//! share. Modeling cross-batch stage contention would require one
//! global event timeline across all in-flight batches (a candidate
//! follow-up), not per-batch durations.
//!
//! The feasibility check is conservative: a candidate window is charged
//! every reservation it overlaps, so occupancy is never undercounted
//! (sequential reservations inside one window may be double-counted,
//! delaying a placement but never overbooking the memory). Expired
//! reservations are pruned against the latest dispatch clock, and the
//! per-instance ledger is **bounded**: when simulated time runs ahead
//! of real time (the oversubscribed regime this router exists to
//! model) old reservations never expire, so past
//! [`MAX_RESERVATIONS_PER_INSTANCE`] the earliest-ending half is
//! compacted into a per-instance *floor* — no new reservation may
//! start before it. Compaction is conservative (placements only move
//! later, never overbook) and keeps dispatch O(bounded) instead of
//! growing with every batch ever served.

use std::collections::BTreeMap;

use crate::cnn::models::Model;

/// Ledger bound per instance; beyond this the earliest-ending half of
/// the reservations is folded into the instance's start floor.
pub const MAX_RESERVATIONS_PER_INSTANCE: usize = 128;

/// One committed slice of simulated instance time.
#[derive(Debug, Clone, Copy)]
struct Reservation {
    start_ms: f64,
    end_ms: f64,
    subarrays: usize,
}

/// Tracks per-instance reservations and occupancy.
#[derive(Debug, Clone)]
pub struct Router {
    /// Subarray capacity of each instance.
    capacity: usize,
    /// Active (not yet pruned) reservations per instance.
    reservations: Vec<Vec<Reservation>>,
    /// Batches dispatched per instance.
    dispatched: Vec<u64>,
    /// Latest reservation end (ms) per instance.
    horizons: Vec<f64>,
    /// Per-instance compaction floor (ms): simulated time before which
    /// no new reservation may start, raised when old reservations are
    /// folded away to bound the ledger.
    floors: Vec<f64>,
    /// Latest `now` seen — the prune frontier.
    frontier: f64,
    /// Latest reservation end (ms) per tagging model — that model's
    /// simulated makespan. `BTreeMap` so iteration is model-sorted.
    model_end: BTreeMap<Model, f64>,
}

impl Router {
    /// Router whose instances are booked exclusively (each dispatch
    /// takes the whole module — the pre-occupancy behaviour).
    pub fn new(instances: usize) -> Self {
        Self::with_capacity(instances, 1)
    }

    /// Router over instances with `subarray_capacity` subarrays each;
    /// [`Router::dispatch_for`] co-schedules batches whose footprints
    /// fit together.
    pub fn with_capacity(instances: usize, subarray_capacity: usize) -> Self {
        assert!(instances >= 1);
        Self {
            capacity: subarray_capacity.max(1),
            reservations: vec![Vec::new(); instances],
            dispatched: vec![0; instances],
            horizons: vec![0.0; instances],
            floors: vec![0.0; instances],
            frontier: 0.0,
            model_end: BTreeMap::new(),
        }
    }

    pub fn instances(&self) -> usize {
        self.horizons.len()
    }

    /// Subarray capacity of each instance.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Book a whole instance exclusively for a batch arriving at
    /// `now_ms` with simulated duration `dur_ms`. Returns (instance,
    /// start_ms, end_ms) and commits the reservation.
    pub fn dispatch(&mut self, now_ms: f64, dur_ms: f64) -> (usize, f64, f64) {
        self.place(None, self.capacity, now_ms, dur_ms)
    }

    /// Occupancy-aware dispatch: place a batch of `model` with the
    /// mapper footprint `subarrays` at the earliest feasible simulated
    /// time across instances. The reservation is tagged by model so
    /// [`Router::model_makespan_ms`] can report when the simulated
    /// hardware finished that model's work. Footprints larger than an
    /// instance are clamped to the full instance (the model time-shares
    /// the memory; the registry surfaces the capacity warning).
    pub fn dispatch_for(
        &mut self,
        model: Model,
        subarrays: usize,
        now_ms: f64,
        dur_ms: f64,
    ) -> (usize, f64, f64) {
        self.place(Some(model), subarrays, now_ms, dur_ms)
    }

    fn place(
        &mut self,
        model: Option<Model>,
        subarrays: usize,
        now_ms: f64,
        dur_ms: f64,
    ) -> (usize, f64, f64) {
        let fp = subarrays.clamp(1, self.capacity);
        self.frontier = self.frontier.max(now_ms);
        // Place against the frontier, not the caller's clock: workers
        // race, and a stale `now_ms` below the latest prune point would
        // see already-pruned reservations as free capacity (overbooking
        // the instance). Clamping forward keeps the never-undercount
        // invariant; a placement never starts before the latest
        // observed dispatch clock anyway.
        let now_ms = self.frontier;
        let frontier = self.frontier;
        for (rs, floor) in self.reservations.iter_mut().zip(self.floors.iter_mut()) {
            rs.retain(|r| r.end_ms > frontier);
            // When simulated time runs ahead of the wall clock nothing
            // expires; fold the earliest-ending half into the floor so
            // memory and dispatch cost stay bounded.
            if rs.len() >= MAX_RESERVATIONS_PER_INSTANCE {
                rs.sort_by(|a, b| a.end_ms.total_cmp(&b.end_ms));
                let cut = rs.len() - MAX_RESERVATIONS_PER_INSTANCE / 2;
                *floor = floor.max(rs[cut - 1].end_ms);
                rs.drain(..cut);
            }
        }
        // Earliest feasible start wins; ties (e.g. small footprints that
        // fit everywhere immediately) break toward the least-dispatched
        // instance so load still spreads across modules.
        let (idx, start) = (0..self.instances())
            .map(|i| (i, self.earliest_start(i, fp, now_ms, dur_ms)))
            .min_by(|a, b| {
                a.1.total_cmp(&b.1)
                    .then_with(|| self.dispatched[a.0].cmp(&self.dispatched[b.0]))
            })
            .expect("non-empty");
        let end = start + dur_ms;
        self.reservations[idx].push(Reservation {
            start_ms: start,
            end_ms: end,
            subarrays: fp,
        });
        self.dispatched[idx] += 1;
        self.horizons[idx] = self.horizons[idx].max(end);
        if let Some(m) = model {
            let e = self.model_end.entry(m).or_insert(0.0);
            *e = e.max(end);
        }
        (idx, start, end)
    }

    /// Earliest `t ≥ max(now, floor)` at which `fp` subarrays are free
    /// on instance `i` for the whole window `[t, t + dur)`, by the
    /// conservative overlap count. Candidates are the base time and
    /// each reservation end.
    fn earliest_start(&self, i: usize, fp: usize, now_ms: f64, dur_ms: f64) -> f64 {
        let rs = &self.reservations[i];
        let base = now_ms.max(self.floors[i]);
        let mut candidates: Vec<f64> = std::iter::once(base)
            .chain(rs.iter().map(|r| r.end_ms).filter(|&e| e > base))
            .collect();
        candidates.sort_by(|a, b| a.total_cmp(b));
        for t in candidates {
            let used: usize = rs
                .iter()
                .filter(|r| r.start_ms < t + dur_ms && r.end_ms > t)
                .map(|r| r.subarrays)
                .sum();
            if used + fp <= self.capacity {
                return t;
            }
        }
        // Unreachable by construction: at the latest reservation end no
        // reservation overlaps the window and `fp ≤ capacity`, so the
        // loop always returns there at the latest. Kept as a defensive
        // fallback rather than a panic in the serving path.
        self.horizons[i].max(base)
    }

    /// Per-instance dispatched-batch counts.
    pub fn load(&self) -> &[u64] {
        &self.dispatched
    }

    /// Simulated makespan across instances.
    pub fn makespan_ms(&self) -> f64 {
        self.horizons.iter().cloned().fold(0.0, f64::max)
    }

    /// Simulated makespan of one model's tagged reservations (0 when the
    /// model never dispatched).
    pub fn model_makespan_ms(&self, model: Model) -> f64 {
        self.model_end.get(&model).copied().unwrap_or(0.0)
    }

    /// All per-model makespans recorded so far, sorted by model
    /// (declaration order), so reports built from this are stable.
    pub fn model_makespans(&self) -> Vec<(Model, f64)> {
        self.model_end.iter().map(|(m, e)| (*m, *e)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balances_across_instances() {
        let mut r = Router::new(2);
        let (i0, s0, _) = r.dispatch(0.0, 10.0);
        let (i1, s1, _) = r.dispatch(0.0, 10.0);
        assert_ne!(i0, i1, "second batch goes to the idle instance");
        assert_eq!(s0, 0.0);
        assert_eq!(s1, 0.0);
        // Third batch queues behind the earlier-finishing one.
        let (_, s2, e2) = r.dispatch(0.0, 5.0);
        assert_eq!(s2, 10.0);
        assert_eq!(e2, 15.0);
    }

    #[test]
    fn load_counts() {
        let mut r = Router::new(3);
        for _ in 0..9 {
            r.dispatch(0.0, 1.0);
        }
        assert_eq!(r.load().iter().sum::<u64>(), 9);
        assert!(r.load().iter().all(|&c| c == 3), "{:?}", r.load());
        assert!((r.makespan_ms() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn respects_arrival_time() {
        let mut r = Router::new(1);
        let (_, s, e) = r.dispatch(100.0, 5.0);
        assert_eq!(s, 100.0);
        assert_eq!(e, 105.0);
    }

    #[test]
    fn tagged_full_capacity_reservations_serialize() {
        // Full-footprint dispatches reproduce the old scalar-horizon
        // behaviour exactly.
        let mut r = Router::with_capacity(1, 16_384);
        let cap = r.capacity();
        r.dispatch_for(Model::LeNet, cap, 0.0, 10.0);
        r.dispatch_for(Model::Vgg16, cap, 0.0, 30.0);
        r.dispatch_for(Model::LeNet, cap, 0.0, 10.0);
        // Serialized on one instance: lenet [0,10], vgg [10,40],
        // lenet [40,50].
        assert_eq!(r.model_makespan_ms(Model::LeNet), 50.0);
        assert_eq!(r.model_makespan_ms(Model::Vgg16), 40.0);
        assert_eq!(r.makespan_ms(), 50.0);
        assert_eq!(r.model_makespan_ms(Model::MobileNet), 0.0);
        assert_eq!(r.model_makespans().len(), 2);
    }

    #[test]
    fn small_footprints_co_reside() {
        // Two models that together fit in one instance overlap in
        // simulated time instead of serializing.
        let mut r = Router::with_capacity(1, 1000);
        let (_, s0, _) = r.dispatch_for(Model::LeNet, 100, 0.0, 10.0);
        let (_, s1, _) = r.dispatch_for(Model::MobileNet, 400, 0.0, 20.0);
        assert_eq!(s0, 0.0);
        assert_eq!(s1, 0.0, "fits alongside — co-resident");
        assert_eq!(r.makespan_ms(), 20.0);
        // A third model that does NOT fit (100+400+600 > 1000) queues
        // until enough occupancy frees: at t=10 lenet releases 100.
        let (_, s2, e2) = r.dispatch_for(Model::Vgg16, 600, 0.0, 5.0);
        assert_eq!(s2, 10.0);
        assert_eq!(e2, 15.0);
    }

    #[test]
    fn oversized_footprint_clamps_to_exclusive() {
        let mut r = Router::with_capacity(1, 100);
        r.dispatch_for(Model::Vgg16, 10_000, 0.0, 10.0);
        let (_, s, _) = r.dispatch_for(Model::LeNet, 1, 0.0, 1.0);
        assert_eq!(s, 10.0, "a clamped full-capacity batch excludes others");
    }

    #[test]
    fn model_makespans_sorted_by_model() {
        let mut r = Router::with_capacity(2, 100);
        r.dispatch_for(Model::Vgg16, 10, 0.0, 5.0);
        r.dispatch_for(Model::LeNet, 10, 0.0, 5.0);
        r.dispatch_for(Model::MobileNet, 10, 0.0, 5.0);
        let spans = r.model_makespans();
        let models: Vec<Model> = spans.iter().map(|(m, _)| *m).collect();
        assert_eq!(models, vec![Model::LeNet, Model::MobileNet, Model::Vgg16]);
    }

    #[test]
    fn stale_dispatch_clock_clamps_to_frontier() {
        // Racing workers can present now_ms below the latest prune
        // frontier; placement must clamp forward so pruned occupancy
        // can never be overbooked.
        let mut r = Router::with_capacity(1, 100);
        r.dispatch_for(Model::LeNet, 60, 103.0, 5.0);
        let (_, s, _) = r.dispatch_for(Model::Vgg16, 60, 100.0, 5.0);
        assert!(s >= 103.0, "stale now started before the frontier: {s}");
        assert_eq!(s, 108.0, "60+60 > 100: serialized behind the first");
    }

    #[test]
    fn ledger_stays_bounded_when_sim_time_outruns_the_clock() {
        // Oversubscribed regime: every dispatch arrives at now = 0 while
        // simulated reservations stretch far into the future, so nothing
        // ever expires. The ledger must compact instead of growing, and
        // placements must stay feasible and non-decreasing per instance.
        let mut r = Router::with_capacity(1, 100);
        let mut last_start = 0.0f64;
        for _ in 0..2000 {
            // Footprint 60: no two fit together, so every batch queues.
            let (_, s, _) = r.dispatch_for(Model::Vgg16, 60, 0.0, 5.0);
            assert!(s >= last_start, "starts must not regress");
            last_start = s;
        }
        assert!(r.reservations[0].len() <= MAX_RESERVATIONS_PER_INSTANCE);
        // Work is conserved: 2000 serialized 5 ms batches.
        assert!((r.makespan_ms() - 2000.0 * 5.0).abs() < 1e-6);
    }

    #[test]
    fn picks_instance_with_earliest_feasible_start() {
        let mut r = Router::with_capacity(2, 100);
        // Saturate instance 0 until t=50; instance 1 until t=10.
        r.dispatch_for(Model::Vgg16, 100, 0.0, 50.0);
        r.dispatch_for(Model::LeNet, 100, 0.0, 10.0);
        let (i, s, _) = r.dispatch_for(Model::MobileNet, 80, 0.0, 5.0);
        assert_eq!(i, 1);
        assert_eq!(s, 10.0);
    }
}
