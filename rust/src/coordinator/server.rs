//! The synchronous serving facade over the pipelined [`Engine`].
//!
//! `Server` keeps the seed's call-loop API — `submit`/`flush`/
//! `responses`/`stats` from one caller thread — but every batch now forms
//! in the engine's batcher thread and executes on its worker pool.
//! `submit` blocks for queue space instead of surfacing backpressure
//! (use [`Engine`] directly for non-blocking submission and multi-
//! producer serving), and `flush` drains the pipeline and waits for all
//! outstanding responses.
//!
//! Functional answers come from the AOT HLO artifacts executed on PJRT
//! (or the deterministic sim backend, see [`crate::runtime::executor`]);
//! architectural cost per batch comes from the OPIMA simulator via the
//! engine's precomputed cost table.

use std::time::Duration;

use crate::config::OpimaConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{InferenceRequest, InferenceResponse, Variant};
use crate::error::Result;
use crate::runtime::{ExecutorSpec, Manifest};

/// Server configuration (a facade over [`EngineConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Simulated OPIMA instances behind the dispatch policy.
    pub instances: usize,
    /// Batch deadline for the dynamic batcher.
    pub max_wait: Duration,
    /// OPIMA hardware configuration for the metering simulator.
    pub hw: OpimaConfig,
    /// Worker threads in the underlying engine.
    pub workers: usize,
    /// Bounded ingress queue capacity.
    pub queue_capacity: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            instances: 1,
            max_wait: Duration::from_millis(2),
            hw: OpimaConfig::paper(),
            workers: 1,
            queue_capacity: 1024,
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: u64,
    /// Successfully executed batches.
    pub batches: u64,
    /// Requests lost to failed batch executions.
    pub failed: u64,
    /// Submissions rejected with backpressure.
    pub rejected: u64,
    pub wall_ms: f64,
    /// Mean wall time from arrival to batch-execution start (ms).
    pub mean_queue_ms: f64,
    /// Mean whole-batch execution wall time over responses (ms).
    pub mean_exec_ms: f64,
    /// Mean wall time from arrival to batch formation (ms).
    pub mean_form_ms: f64,
    pub p50_total_ms: f64,
    pub p99_total_ms: f64,
    pub throughput_rps: f64,
    /// Simulated hardware energy, summed once per executed batch (mJ) —
    /// zero-padded partial batches pay full-batch energy exactly once.
    pub sim_energy_mj: f64,
    /// Simulated hardware makespan (ms) — what the OPIMA modules spent.
    pub sim_makespan_ms: f64,
}

/// The OPIMA inference server (synchronous facade).
pub struct Server {
    pub cfg: ServerConfig,
    engine: Engine,
    responses: Vec<InferenceResponse>,
}

impl Server {
    /// Build a server over an artifact manifest (native backend: PJRT
    /// when compiled with the `pjrt` feature, sim otherwise).
    pub fn new(cfg: ServerConfig, manifest: Manifest) -> Result<Self> {
        Self::with_spec(cfg, manifest, ExecutorSpec::Native)
    }

    /// Sim-backed server — no PJRT library or artifacts on disk needed.
    pub fn new_sim(cfg: ServerConfig, manifest: Manifest) -> Result<Self> {
        Self::with_spec(cfg, manifest, ExecutorSpec::Sim { work_factor: 1 })
    }

    fn with_spec(cfg: ServerConfig, manifest: Manifest, executor: ExecutorSpec) -> Result<Self> {
        let engine = Engine::new(
            EngineConfig {
                workers: cfg.workers,
                queue_capacity: cfg.queue_capacity,
                instances: cfg.instances,
                max_wait: cfg.max_wait,
                hw: cfg.hw.clone(),
                executor,
            },
            manifest,
        )?;
        Ok(Self {
            cfg,
            engine,
            responses: Vec::new(),
        })
    }

    /// Submit one request. Blocks for queue space under load (the
    /// synchronous-caller semantics of the seed API); batching and
    /// execution happen asynchronously on the engine's threads.
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        self.engine.submit_blocking(req)
    }

    /// Flush all pending requests and wait until every response is in.
    pub fn flush(&mut self) -> Result<()> {
        let result = self.engine.drain();
        // Incremental sync: only the responses that arrived since the
        // last flush are cloned out of the sink.
        let new = self.engine.responses_since(self.responses.len());
        self.responses.extend(new);
        result
    }

    /// Responses up to the last `flush` (in completion order).
    pub fn responses(&self) -> &[InferenceResponse] {
        &self.responses
    }

    /// The underlying pipelined engine (non-blocking submission, live
    /// counters, multi-producer use).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn image_elems(&self) -> usize {
        self.engine.image_elems()
    }

    pub fn batch_size(&self) -> usize {
        self.engine.batch_size()
    }

    fn sim_cost(&self, v: Variant) -> (f64, f64) {
        self.engine
            .sim_cost(v.pim_bits())
            .expect("all variants precomputed")
    }

    /// Aggregate statistics over everything served so far.
    pub fn stats(&self) -> ServerStats {
        self.engine.stats()
    }

    /// Graceful shutdown: drain in-flight work and join the pipeline.
    pub fn shutdown(mut self) -> Result<()> {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    /// Sim-backed server over a synthetic manifest: these tests exercise
    /// coordinator semantics, not PJRT numerics, so they run everywhere.
    fn server(instances: usize) -> Server {
        let cfg = ServerConfig {
            instances,
            // Large deadline so batch counts are deterministic even on a
            // loaded machine.
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        Server::new_sim(cfg, Manifest::synthetic(8, 12)).unwrap()
    }

    fn req(id: u64, elems: usize, v: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            image: (0..elems).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect(),
            variant: v,
            arrival: Instant::now(),
        }
    }

    #[test]
    fn serves_full_batches() {
        let mut s = server(1);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(2 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.responses().len(), 2 * bsz);
        let stats = s.stats();
        assert_eq!(stats.served, 2 * bsz as u64);
        assert_eq!(stats.batches, 2);
        assert!(stats.sim_energy_mj > 0.0);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn partial_batch_flushes() {
        let mut s = server(1);
        let elems = s.image_elems();
        for i in 0..3u64 {
            s.submit(req(i, elems, Variant::Fp32)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.responses().len(), 3);
        // All responses carry finite logits and a class in range.
        for r in s.responses() {
            assert!(r.logits.iter().all(|v| v.is_finite()));
            assert!(r.predicted < r.logits.len());
        }
    }

    #[test]
    fn partial_batch_pays_full_batch_energy() {
        let mut s = server(1);
        let elems = s.image_elems();
        // 3 requests → one zero-padded batch; energy must be the full
        // per-batch cost, not 3/8 of it (the seed under-counted this).
        for i in 0..3u64 {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        let (_, batch_mj) = s.sim_cost(Variant::Int4);
        let stats = s.stats();
        assert_eq!(stats.batches, 1);
        assert!(
            (stats.sim_energy_mj - batch_mj).abs() < 1e-12 * batch_mj.max(1.0),
            "partial batch energy {} != full batch {}",
            stats.sim_energy_mj,
            batch_mj
        );
    }

    #[test]
    fn latency_accounting_is_consistent() {
        let mut s = server(1);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..bsz as u64 {
            s.submit(req(i, elems, Variant::Int8)).unwrap();
        }
        s.flush().unwrap();
        for r in s.responses() {
            assert!(r.queue_ms >= 0.0 && r.exec_ms >= 0.0 && r.form_ms >= 0.0);
            // The batch formed before it started executing.
            assert!(
                r.form_ms <= r.queue_ms + 1e-9,
                "form {} > queue {}",
                r.form_ms,
                r.queue_ms
            );
            assert!(r.total_ms() >= r.exec_ms);
        }
        let stats = s.stats();
        assert!(stats.mean_form_ms <= stats.mean_queue_ms + 1e-9);
    }

    #[test]
    fn multi_instance_routing_balances() {
        let mut s = server(2);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(4 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int8)).unwrap();
        }
        s.flush().unwrap();
        let mut seen = [0u64; 2];
        for r in s.responses() {
            seen[r.instance] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "both instances used: {seen:?}");
    }

    #[test]
    fn wrong_image_size_rejected() {
        let mut s = server(1);
        assert!(s.submit(req(0, 3, Variant::Int4)).is_err());
    }

    #[test]
    fn int4_sim_cost_below_int8() {
        let s = server(1);
        let (l4, e4) = s.sim_cost(Variant::Int4);
        let (l8, e8) = s.sim_cost(Variant::Int8);
        assert!(l4 < l8, "TDM: 8-bit costs more time");
        assert!(e4 < e8);
    }
}
