//! The synchronous serving facade over the pipelined [`Engine`].
//!
//! `Server` keeps the seed's call-loop shape — `submit`/`flush`/`stats`
//! from one caller thread — but every batch forms in the engine's
//! batcher thread and executes on its worker pool. `submit` blocks for
//! queue space instead of surfacing backpressure (use [`Engine`]
//! directly for non-blocking submission and multi-producer serving),
//! and `flush` drains the pipeline and waits for all outstanding
//! responses.
//!
//! Responses are exposed **by value** from the engine's bounded ring:
//! [`Server::recent`] snapshots the retained tail and
//! [`Server::drain_responses`] hands out everything completed since the
//! previous call. The facade keeps no copy of its own (the seed's
//! borrowed `responses()` contract forced a second full-history clone —
//! unbounded memory on an indefinitely-running server).
//!
//! Functional answers come from the AOT HLO artifacts executed on PJRT
//! (or the deterministic sim backend, see [`crate::runtime::executor`]);
//! architectural cost per batch comes from the OPIMA simulator via the
//! engine's precomputed cost table.

use std::time::Duration;

use crate::cnn::models::Model;
use crate::config::OpimaConfig;
use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::request::{InferenceRequest, InferenceResponse, Variant};
use crate::error::Result;
use crate::runtime::{ExecutorSpec, Manifest};
use crate::util::histogram::Summary;
use crate::util::units::{Millijoules, Millis};

/// Server configuration (a facade over [`EngineConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Simulated OPIMA instances behind the dispatch policy.
    pub instances: usize,
    /// Batch deadline for the dynamic batcher.
    pub max_wait: Duration,
    /// OPIMA hardware configuration for the metering simulator.
    pub hw: OpimaConfig,
    /// Worker threads in the underlying engine.
    pub workers: usize,
    /// Bounded ingress queue capacity.
    pub queue_capacity: usize,
    /// Bounded response history retained for `recent`/`drain_responses`
    /// (aggregate stats always cover everything served).
    pub history: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            instances: 1,
            max_wait: Duration::from_millis(2),
            hw: OpimaConfig::paper(),
            workers: 1,
            queue_capacity: 1024,
            history: 1024,
        }
    }
}

/// Streaming latency summaries per accounting stage (ms), computed from
/// the engine's merged per-worker histograms — p50/p90/p99/p99.9 plus
/// exact mean/min/max for each, covering every response ever served in
/// fixed memory. Percentiles carry the histogram's bounded relative
/// error ([`Histogram::MAX_REL_ERROR`](crate::util::histogram::Histogram::MAX_REL_ERROR));
/// means are exact.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyBreakdown {
    /// Arrival → completion (`queue + exec`).
    pub total: Summary,
    /// Arrival → start of batch execution.
    pub queue: Summary,
    /// Whole-batch execution wall time.
    pub exec: Summary,
    /// Arrival → batch formation (the dynamic-batcher share of `queue`).
    pub form: Summary,
}

/// One model's share of the serving statistics (multi-model engines
/// serve several models from shared capacity; batches are single-model,
/// so every row is exact, not apportioned).
#[derive(Debug, Clone, Default)]
pub struct ModelServingStats {
    pub model: Model,
    /// Responses served for this model.
    pub served: u64,
    /// Successfully executed batches carrying this model.
    pub batches: u64,
    /// Requests lost to failed batch executions of this model.
    pub failed: u64,
    /// Requests expired past their deadline before batch formation
    /// (terminal `DEADLINE_EXCEEDED` outcomes — never executed).
    pub expired: u64,
    /// Simulated hardware energy of this model's batches.
    pub sim_energy_mj: Millijoules,
    /// Simulated hardware time at which this model's last batch finished
    /// — its tagged makespan on the shared instances.
    pub sim_makespan_ms: Millis,
    /// This model's streaming latency breakdown.
    pub latency: LatencyBreakdown,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: u64,
    /// Successfully executed batches.
    pub batches: u64,
    /// Requests lost to failed batch executions.
    pub failed: u64,
    /// Requests expired past their per-request deadline before batch
    /// formation (terminal `DEADLINE_EXCEEDED` outcomes). With `served`,
    /// `failed` and the front-end sheds, these partition every submitted
    /// request into exactly one terminal bucket (DESIGN.md §3.3).
    pub expired: u64,
    /// Submissions rejected with backpressure.
    pub rejected: u64,
    /// Requests shed by front-end defenses (the wire server's
    /// per-connection rate limiter) before they reached the engine —
    /// disjoint from `rejected`, which counts ingress-queue
    /// backpressure.
    pub shed: u64,
    /// Worker executor respawns after mid-batch panics (self-healing
    /// events; zero in a healthy run).
    pub respawns: u64,
    pub wall_ms: Millis,
    /// Mean wall time from arrival to batch-execution start.
    pub mean_queue_ms: Millis,
    /// Mean whole-batch execution wall time over responses.
    pub mean_exec_ms: Millis,
    /// Mean wall time from arrival to batch formation.
    pub mean_form_ms: Millis,
    /// Convenience copy of `latency.total.p50`, kept for API
    /// compatibility (the CLI prints the `latency` table instead).
    pub p50_total_ms: Millis,
    /// Convenience copy of `latency.total.p99`, kept for API
    /// compatibility (the CLI prints the `latency` table instead).
    pub p99_total_ms: Millis,
    /// Full streaming percentile breakdown (total/queue/exec/form).
    pub latency: LatencyBreakdown,
    /// Per-model breakdown (in
    /// [`SERVABLE_MODELS`](crate::cnn::models::SERVABLE_MODELS) order,
    /// models with no activity omitted). Served counts, batches, energy
    /// and latency counts each sum to the global figures.
    pub per_model: Vec<ModelServingStats>,
    pub throughput_rps: f64,
    /// Simulated hardware energy, summed once per executed batch —
    /// zero-padded partial batches pay full-batch energy exactly once.
    pub sim_energy_mj: Millijoules,
    /// Simulated hardware makespan — what the OPIMA modules spent.
    pub sim_makespan_ms: Millis,
}

/// The OPIMA inference server (synchronous facade).
pub struct Server {
    pub cfg: ServerConfig,
    engine: Engine,
    /// Completion-sequence cursor for `drain_responses`.
    seen: u64,
}

impl Server {
    /// Build a server over an artifact manifest (native backend: PJRT
    /// when compiled with the `pjrt` feature, sim otherwise).
    pub fn new(cfg: ServerConfig, manifest: Manifest) -> Result<Self> {
        Self::with_spec(cfg, manifest, ExecutorSpec::Native)
    }

    /// Sim-backed server — no PJRT library or artifacts on disk needed.
    pub fn new_sim(cfg: ServerConfig, manifest: Manifest) -> Result<Self> {
        Self::with_spec(cfg, manifest, ExecutorSpec::Sim { work_factor: 1 })
    }

    fn with_spec(cfg: ServerConfig, manifest: Manifest, executor: ExecutorSpec) -> Result<Self> {
        let engine = Engine::new(
            EngineConfig {
                workers: cfg.workers,
                queue_capacity: cfg.queue_capacity,
                instances: cfg.instances,
                max_wait: cfg.max_wait,
                hw: cfg.hw.clone(),
                executor,
                history: cfg.history,
            },
            manifest,
        )?;
        Ok(Self {
            cfg,
            engine,
            seen: 0,
        })
    }

    /// Submit one request. Blocks for queue space under load (the
    /// synchronous-caller semantics of the seed API); batching and
    /// execution happen asynchronously on the engine's threads.
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        self.engine.submit_blocking(req)
    }

    /// Flush all pending requests and wait until every response is in.
    pub fn flush(&mut self) -> Result<()> {
        self.engine.drain()
    }

    /// By-value snapshot of the retained responses (completion order):
    /// the last [`ServerConfig::history`] at most. Older responses are
    /// evicted from the engine's bounded ring — aggregate `stats()` are
    /// unaffected. Independent of the `drain_responses` cursor.
    pub fn recent(&self) -> Vec<InferenceResponse> {
        self.engine.responses()
    }

    /// Take everything completed since the previous `drain_responses`
    /// call (completion order), by value. Call `flush` first for the
    /// synchronous submit-flush-collect loop. A caller that falls more
    /// than the ring capacity behind loses the evicted gap (the cursor
    /// still advances past it, so later calls resume at the live tail).
    pub fn drain_responses(&mut self) -> Vec<InferenceResponse> {
        let (tail, next) = self.engine.responses_since(self.seen);
        self.seen = next;
        tail
    }

    /// The underlying pipelined engine (non-blocking submission, live
    /// counters, multi-producer use).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn image_elems(&self) -> usize {
        self.engine.image_elems()
    }

    /// Flattened per-image element count a request for `model` must
    /// carry.
    pub fn image_elems_for(&self, model: Model) -> usize {
        self.engine.image_elems_for(model)
    }

    pub fn batch_size(&self) -> usize {
        self.engine.batch_size()
    }

    fn sim_cost(&self, v: Variant) -> (Millis, Millijoules) {
        self.engine
            .sim_cost(Model::LeNet, v)
            .expect("lenet plans build from the synthetic manifest")
    }

    /// Aggregate statistics over everything served so far.
    pub fn stats(&self) -> ServerStats {
        self.engine.stats()
    }

    /// Graceful shutdown: drain in-flight work and join the pipeline.
    pub fn shutdown(mut self) -> Result<()> {
        self.engine.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    /// Sim-backed server over a synthetic manifest: these tests exercise
    /// coordinator semantics, not PJRT numerics, so they run everywhere.
    fn server(instances: usize) -> Server {
        let cfg = ServerConfig {
            instances,
            // Large deadline so batch counts are deterministic even on a
            // loaded machine.
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        Server::new_sim(cfg, Manifest::synthetic(8, 12)).unwrap()
    }

    fn req(id: u64, elems: usize, v: Variant) -> InferenceRequest {
        req_for(id, Model::LeNet, elems, v)
    }

    fn req_for(id: u64, model: Model, elems: usize, v: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            model,
            image: (0..elems).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect(),
            variant: v,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        }
    }

    #[test]
    fn serves_full_batches() {
        let mut s = server(1);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(2 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.drain_responses().len(), 2 * bsz);
        let stats = s.stats();
        assert_eq!(stats.served, 2 * bsz as u64);
        assert_eq!(stats.batches, 2);
        assert!(stats.sim_energy_mj > Millijoules::ZERO);
        assert!(stats.throughput_rps > 0.0);
        // The streaming breakdown covers every response with ordered
        // percentiles.
        assert_eq!(stats.latency.total.count, 2 * bsz as u64);
        assert!(stats.latency.total.p50 <= stats.latency.total.p999 + 1e-12);
    }

    #[test]
    fn drain_responses_is_incremental_and_recent_is_bounded() {
        let cfg = ServerConfig {
            max_wait: Duration::from_secs(5),
            history: 8,
            ..Default::default()
        };
        let mut s = Server::new_sim(cfg, Manifest::synthetic(8, 12)).unwrap();
        let elems = s.image_elems();
        for i in 0..8u64 {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.drain_responses().len(), 8);
        assert_eq!(s.drain_responses().len(), 0, "cursor advanced");
        for i in 8..16u64 {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        let second = s.drain_responses();
        assert_eq!(second.len(), 8, "only the new batch");
        assert!(second.iter().all(|r| r.id >= 8));
        // recent() is capped by the ring, while stats cover all 16.
        assert_eq!(s.recent().len(), 8);
        assert_eq!(s.stats().served, 16);
    }

    #[test]
    fn partial_batch_flushes() {
        let mut s = server(1);
        let elems = s.image_elems();
        for i in 0..3u64 {
            s.submit(req(i, elems, Variant::Fp32)).unwrap();
        }
        s.flush().unwrap();
        let rs = s.drain_responses();
        assert_eq!(rs.len(), 3);
        // All responses carry finite logits and a class in range.
        for r in &rs {
            assert!(r.logits.iter().all(|v| v.is_finite()));
            assert!(r.predicted < r.logits.len());
        }
    }

    #[test]
    fn partial_batch_pays_full_batch_energy() {
        let mut s = server(1);
        let elems = s.image_elems();
        // 3 requests → one zero-padded batch; energy must be the full
        // per-batch cost, not 3/8 of it (the seed under-counted this).
        for i in 0..3u64 {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        let (_, batch_mj) = s.sim_cost(Variant::Int4);
        let stats = s.stats();
        assert_eq!(stats.batches, 1);
        assert!(
            (stats.sim_energy_mj - batch_mj).abs().raw() < 1e-12 * batch_mj.raw().max(1.0),
            "partial batch energy {} != full batch {}",
            stats.sim_energy_mj,
            batch_mj
        );
    }

    #[test]
    fn latency_accounting_is_consistent() {
        let mut s = server(1);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..bsz as u64 {
            s.submit(req(i, elems, Variant::Int8)).unwrap();
        }
        s.flush().unwrap();
        for r in &s.drain_responses() {
            assert!(
                r.queue_ms >= Millis::ZERO && r.exec_ms >= Millis::ZERO && r.form_ms >= Millis::ZERO
            );
            // The batch formed before it started executing.
            assert!(
                r.form_ms <= r.queue_ms + crate::util::units::ms(1e-9),
                "form {} > queue {}",
                r.form_ms,
                r.queue_ms
            );
            assert!(r.total_ms() >= r.exec_ms);
        }
        let stats = s.stats();
        assert!(stats.mean_form_ms <= stats.mean_queue_ms + crate::util::units::ms(1e-9));
    }

    #[test]
    fn multi_instance_routing_balances() {
        let mut s = server(2);
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(4 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int8)).unwrap();
        }
        s.flush().unwrap();
        let mut seen = [0u64; 2];
        for r in &s.drain_responses() {
            seen[r.instance] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "both instances used: {seen:?}");
    }

    #[test]
    fn wrong_image_size_rejected() {
        let mut s = server(1);
        assert!(s.submit(req(0, 3, Variant::Int4)).is_err());
    }

    #[test]
    fn serves_a_model_mix_with_per_model_stats() {
        let mut s = server(1);
        let bsz = s.batch_size() as u64;
        // One full LeNet batch interleaved with one full MobileNet batch.
        for i in 0..bsz {
            s.submit(req(i, s.image_elems(), Variant::Int4)).unwrap();
            s.submit(req_for(
                bsz + i,
                Model::MobileNet,
                s.image_elems_for(Model::MobileNet),
                Variant::Int4,
            ))
            .unwrap();
        }
        s.flush().unwrap();
        let rs = s.drain_responses();
        assert_eq!(rs.len(), 2 * bsz as usize);
        // Batches are single-model: responses sharing a batch_seq share
        // a model, and each response's logits match its model's head.
        for r in &rs {
            let width = match r.model {
                Model::LeNet => 4,
                Model::MobileNet => 1000,
                m => panic!("unexpected model {m:?}"),
            };
            assert_eq!(r.logits.len(), width);
        }
        let stats = s.stats();
        assert_eq!(stats.served, 2 * bsz);
        assert_eq!(stats.batches, 2);
        assert_eq!(stats.per_model.len(), 2);
        let served_sum: u64 = stats.per_model.iter().map(|m| m.served).sum();
        let batch_sum: u64 = stats.per_model.iter().map(|m| m.batches).sum();
        let energy_sum: Millijoules = stats.per_model.iter().map(|m| m.sim_energy_mj).sum();
        assert_eq!(served_sum, stats.served);
        assert_eq!(batch_sum, stats.batches);
        assert!(
            (energy_sum - stats.sim_energy_mj).abs().raw()
                < 1e-9 * stats.sim_energy_mj.raw().max(1.0)
        );
        // MobileNet is the heavier model on the simulated hardware.
        let find = |m: Model| stats.per_model.iter().find(|x| x.model == m).unwrap();
        assert!(find(Model::MobileNet).sim_energy_mj > find(Model::LeNet).sim_energy_mj);
        assert!(
            find(Model::MobileNet).sim_makespan_ms
                <= stats.sim_makespan_ms + crate::util::units::ms(1e-12)
        );
    }

    #[test]
    fn int4_sim_cost_below_int8() {
        let s = server(1);
        let (l4, e4) = s.sim_cost(Variant::Int4);
        let (l8, e8) = s.sim_cost(Variant::Int8);
        assert!(l4 < l8, "TDM: 8-bit costs more time");
        assert!(e4 < e8);
    }
}
