//! The serving loop: queue → batcher → router → PJRT worker.
//!
//! Functional answers come from the AOT HLO artifacts executed on PJRT;
//! architectural cost per batch comes from the OPIMA simulator (the
//! small served CNN analyzed per variant at startup). Single worker
//! thread owns the PJRT client; the router load-balances the *simulated*
//! hardware across instances.

use std::time::{Duration, Instant};

use crate::analyzer::latency::analyze_model;
use crate::cnn::graph::NetworkBuilder;
use crate::cnn::layer::TensorShape;
use crate::config::OpimaConfig;
use crate::coordinator::batcher::{Batch, DynamicBatcher};
use crate::coordinator::request::{
    InferenceRequest, InferenceResponse, SimMetering, Variant,
};
use crate::coordinator::router::Router;
use crate::error::{Error, Result};
use crate::runtime::{Executor, Manifest};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Simulated OPIMA instances behind the router.
    pub instances: usize,
    /// Batch deadline for the dynamic batcher.
    pub max_wait: Duration,
    /// OPIMA hardware configuration for the metering simulator.
    pub hw: OpimaConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            instances: 1,
            max_wait: Duration::from_millis(2),
            hw: OpimaConfig::paper(),
        }
    }
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub wall_ms: f64,
    pub mean_queue_ms: f64,
    pub mean_exec_ms: f64,
    pub p50_total_ms: f64,
    pub p99_total_ms: f64,
    pub throughput_rps: f64,
    /// Simulated hardware energy across all batches (mJ).
    pub sim_energy_mj: f64,
    /// Simulated hardware makespan (ms) — what the OPIMA modules spent.
    pub sim_makespan_ms: f64,
}

/// The OPIMA inference server.
pub struct Server {
    pub cfg: ServerConfig,
    executor: Executor,
    batcher: DynamicBatcher,
    router: Router,
    /// Per-variant simulated cost of one served batch: (latency_ms, mJ).
    sim_costs: Vec<(Variant, f64, f64)>,
    epoch: Instant,
    responses: Vec<InferenceResponse>,
}

/// The served model: must match python/compile/model.py's ARCH.
fn served_network() -> Result<crate::cnn::graph::Network> {
    let mut b = NetworkBuilder::new("served_cnn", TensorShape::new(12, 12, 1));
    b.conv(3, 3, 8, 1, 1)?
        .pool(2, 2)?
        .conv(3, 3, 16, 1, 1)?
        .pool(2, 2)?
        .fc(4)?;
    Ok(b.build())
}

impl Server {
    /// Build a server over an artifact manifest.
    pub fn new(cfg: ServerConfig, manifest: Manifest) -> Result<Self> {
        cfg.hw.validate()?;
        let batch = manifest.batch;
        let executor = Executor::new(manifest)?;
        let net = served_network()?;
        // Pre-compute the simulated per-batch cost of each variant.
        let mut sim_costs = Vec::new();
        for v in [Variant::Fp32, Variant::Int8, Variant::Int4] {
            let a = analyze_model(&cfg.hw, &net, v.pim_bits())?;
            sim_costs.push((v, a.total_ms() * batch as f64, a.dynamic_mj * batch as f64));
        }
        Ok(Self {
            batcher: DynamicBatcher::new(batch, cfg.max_wait),
            router: Router::new(cfg.instances),
            cfg,
            executor,
            sim_costs,
            epoch: Instant::now(),
            responses: Vec::new(),
        })
    }

    /// Submit one request; executes a batch when the batcher flushes.
    pub fn submit(&mut self, req: InferenceRequest) -> Result<()> {
        if req.image.len() != self.image_elems() {
            return Err(Error::Serving(format!(
                "image has {} elems, artifact wants {}",
                req.image.len(),
                self.image_elems()
            )));
        }
        if let Some(batch) = self.batcher.push(req) {
            self.execute(batch)?;
        }
        // Deadline-triggered flushes.
        for batch in self.batcher.poll(Instant::now()) {
            self.execute(batch)?;
        }
        Ok(())
    }

    /// Flush all pending requests (end of stream).
    pub fn flush(&mut self) -> Result<()> {
        for batch in self.batcher.drain() {
            self.execute(batch)?;
        }
        Ok(())
    }

    /// Responses so far (in completion order).
    pub fn responses(&self) -> &[InferenceResponse] {
        &self.responses
    }

    pub fn image_elems(&self) -> usize {
        let s = self.executor.manifest().image_size;
        s * s
    }

    pub fn batch_size(&self) -> usize {
        self.batcher.max_batch()
    }

    fn sim_cost(&self, v: Variant) -> (f64, f64) {
        self.sim_costs
            .iter()
            .find(|(sv, _, _)| *sv == v)
            .map(|(_, l, e)| (*l, *e))
            .expect("all variants precomputed")
    }

    fn execute(&mut self, batch: Batch) -> Result<()> {
        let bsz = self.batcher.max_batch();
        let elems = self.image_elems();
        // Pack (and zero-pad) the fixed-shape batch input.
        let mut input = vec![0f32; bsz * elems];
        for (i, r) in batch.requests.iter().enumerate() {
            input[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
        }
        let artifact = batch.variant.artifact(bsz);
        let t0 = Instant::now();
        let logits = self.executor.run_f32(&artifact, &[&input])?;
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let classes = logits.len() / bsz;

        // Simulated hardware cost, routed to the least-loaded instance.
        let now_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        let (sim_lat, sim_mj) = self.sim_cost(batch.variant);
        let (instance, start, end) = self.router.dispatch(now_ms, sim_lat);
        let _ = (start, end);

        let done = Instant::now();
        for (i, r) in batch.requests.iter().enumerate() {
            let row = &logits[i * classes..(i + 1) * classes];
            let predicted = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap_or(0);
            self.responses.push(InferenceResponse {
                id: r.id,
                logits: row.to_vec(),
                predicted,
                queue_ms: done
                    .duration_since(r.arrival)
                    .as_secs_f64()
                    .mul_add(1e3, -exec_ms)
                    .max(0.0),
                exec_ms: exec_ms / batch.requests.len() as f64,
                sim: SimMetering {
                    hw_latency_ms: sim_lat,
                    hw_energy_mj: sim_mj,
                },
                instance,
            });
        }
        Ok(())
    }

    /// Aggregate statistics over everything served so far.
    pub fn stats(&self) -> ServerStats {
        let n = self.responses.len();
        if n == 0 {
            return ServerStats::default();
        }
        let mut totals: Vec<f64> = self.responses.iter().map(|r| r.total_ms()).collect();
        totals.sort_by(|a, b| a.total_cmp(b));
        let wall_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        let batches: u64 = self.router.load().iter().sum();
        ServerStats {
            served: n as u64,
            batches,
            wall_ms,
            mean_queue_ms: self.responses.iter().map(|r| r.queue_ms).sum::<f64>() / n as f64,
            mean_exec_ms: self.responses.iter().map(|r| r.exec_ms).sum::<f64>() / n as f64,
            p50_total_ms: totals[n / 2],
            p99_total_ms: totals[(n * 99 / 100).min(n - 1)],
            throughput_rps: n as f64 / (wall_ms / 1e3).max(1e-9),
            sim_energy_mj: self
                .responses
                .iter()
                .map(|r| r.sim.hw_energy_mj)
                .sum::<f64>()
                / self.batch_size() as f64,
            sim_makespan_ms: self.router.makespan_ms(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn server(instances: usize) -> Option<Server> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let cfg = ServerConfig {
            instances,
            ..Default::default()
        };
        Some(Server::new(cfg, manifest).unwrap())
    }

    fn req(id: u64, elems: usize, v: Variant) -> InferenceRequest {
        InferenceRequest {
            id,
            image: (0..elems).map(|i| ((id as usize + i) % 7) as f32 * 0.1).collect(),
            variant: v,
            arrival: Instant::now(),
        }
    }

    #[test]
    fn serves_full_batches() {
        let Some(mut s) = server(1) else { return };
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(2 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int4)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.responses().len(), 2 * bsz);
        let stats = s.stats();
        assert_eq!(stats.served, 2 * bsz as u64);
        assert_eq!(stats.batches, 2);
        assert!(stats.sim_energy_mj > 0.0);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn partial_batch_flushes() {
        let Some(mut s) = server(1) else { return };
        let elems = s.image_elems();
        for i in 0..3u64 {
            s.submit(req(i, elems, Variant::Fp32)).unwrap();
        }
        s.flush().unwrap();
        assert_eq!(s.responses().len(), 3);
        // All responses carry finite logits and a class in range.
        for r in s.responses() {
            assert!(r.logits.iter().all(|v| v.is_finite()));
            assert!(r.predicted < r.logits.len());
        }
    }

    #[test]
    fn multi_instance_routing_balances() {
        let Some(mut s) = server(2) else { return };
        let elems = s.image_elems();
        let bsz = s.batch_size();
        for i in 0..(4 * bsz as u64) {
            s.submit(req(i, elems, Variant::Int8)).unwrap();
        }
        s.flush().unwrap();
        let mut seen = [0u64; 2];
        for r in s.responses() {
            seen[r.instance] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "both instances used: {seen:?}");
    }

    #[test]
    fn wrong_image_size_rejected() {
        let Some(mut s) = server(1) else { return };
        assert!(s.submit(req(0, 3, Variant::Int4)).is_err());
    }

    #[test]
    fn int4_sim_cost_below_int8() {
        let Some(s) = server(1) else { return };
        let (l4, e4) = s.sim_cost(Variant::Int4);
        let (l8, e8) = s.sim_cost(Variant::Int8);
        assert!(l4 < l8, "TDM: 8-bit costs more time");
        assert!(e4 < e8);
    }
}
