//! Worker threads of the serving engine.
//!
//! Each worker owns its own [`Executor`] (PJRT clients are not shared
//! across threads; the LeNet compile caches are warmed at engine
//! startup, other models compile on first batch), pulls formed batches
//! from the shared batch channel, resolves each batch's `(model,
//! variant)` through the shared [`PlanRegistry`] (plans build lazily,
//! exactly once, under a per-key lock), executes the plan's program,
//! maps the batch onto a simulated OPIMA instance via the shared
//! [`Router`] (reservations tagged by model), folds the batch's latency
//! samples into its own per-model streaming shard (fixed-memory
//! histograms; `Engine::stats` merges the shards), and reports
//! per-request responses plus the per-batch simulated cost back over
//! the results channel.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cnn::models::Model;
use crate::coordinator::batcher::Batch;
use crate::coordinator::engine::{lock, WorkerShard};
use crate::coordinator::registry::PlanRegistry;
use crate::coordinator::request::{InferenceResponse, SimMetering};
use crate::coordinator::router::Router;
use crate::runtime::Executor;

/// Everything one worker thread owns or shares.
pub(crate) struct WorkerCtx {
    pub id: usize,
    pub executor: Executor,
    pub batch_size: usize,
    pub registry: Arc<PlanRegistry>,
    pub router: Arc<Mutex<Router>>,
    /// Shared serving epoch (finalized by `Engine::new` after warmup, so
    /// the simulated-hardware clock and `wall_ms` share one origin).
    pub epoch: Arc<Mutex<Instant>>,
    /// This worker's per-model streaming latency histograms. Locked once
    /// per batch here; contended only by a concurrent `Engine::stats`
    /// merge.
    pub shard: Arc<Mutex<WorkerShard>>,
    pub rx: Arc<Mutex<Receiver<Batch>>>,
    pub tx: Sender<BatchOutcome>,
}

/// What one executed (or failed) batch sends to the stats sink.
pub(crate) struct BatchOutcome {
    /// The model the batch served (batches are single-model).
    pub model: Model,
    pub responses: Vec<InferenceResponse>,
    /// Requests whose batch failed to execute (no responses for them).
    pub failed: u64,
    pub error: Option<String>,
    /// Full-batch simulated energy (mJ) — counted once per executed
    /// batch, so zero-padded partial batches still pay full-batch cost.
    pub sim_energy_mj: f64,
}

/// Pull batches until the channel closes (engine shutdown).
pub(crate) fn worker_loop(mut ctx: WorkerCtx) {
    loop {
        let msg = lock(&ctx.rx).recv();
        let Ok(batch) = msg else { return };
        let out = execute_batch(&mut ctx, batch);
        if ctx.tx.send(out).is_err() {
            return;
        }
    }
}

fn fail(batch: &Batch, error: String) -> BatchOutcome {
    BatchOutcome {
        model: batch.model,
        responses: Vec::new(),
        failed: batch.requests.len() as u64,
        error: Some(error),
        sim_energy_mj: 0.0,
    }
}

fn execute_batch(ctx: &mut WorkerCtx, batch: Batch) -> BatchOutcome {
    // Resolve the batch's compiled plan (lazy, cached, built exactly
    // once across the pool). A model whose artifact or mapping is broken
    // fails its batches loudly; other models keep serving.
    let plan = match ctx.registry.resolve(batch.model, batch.variant) {
        Ok(p) => p,
        Err(e) => return fail(&batch, e.to_string()),
    };
    let bsz = ctx.batch_size;
    let elems = plan.image_elems();
    // Pack (and zero-pad) the fixed-shape batch input.
    let mut input = vec![0f32; bsz * elems];
    for (i, r) in batch.requests.iter().enumerate() {
        if r.image.len() != elems {
            return fail(
                &batch,
                format!(
                    "request {} carries {} elems, plan wants {elems}",
                    r.id,
                    r.image.len()
                ),
            );
        }
        input[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
    }
    let exec_start = Instant::now();
    let logits = match ctx.executor.run_f32(&plan.program.name, &[&input]) {
        Ok(l) => l,
        Err(e) => return fail(&batch, e.to_string()),
    };
    let exec_ms = exec_start.elapsed().as_secs_f64() * 1e3;
    let classes = logits.len() / bsz;

    // Simulated hardware metering: place this *real* batch at the
    // earliest simulated time its mapper footprint fits on an OPIMA
    // instance (models whose footprints fit together co-reside), tagged
    // with the model so makespan is reportable per model.
    let (sim_lat, sim_mj) = plan.sim_cost();
    let epoch = *lock(&ctx.epoch);
    let now_ms = exec_start.saturating_duration_since(epoch).as_secs_f64() * 1e3;
    let instance = lock(&ctx.router)
        .dispatch_for(batch.model, plan.occupancy().subarrays_used, now_ms, sim_lat)
        .0;

    let mut responses = Vec::with_capacity(batch.requests.len());
    for (i, r) in batch.requests.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let predicted = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(c, _)| c)
            .unwrap_or(0);
        responses.push(InferenceResponse {
            id: r.id,
            model: batch.model,
            logits: row.to_vec(),
            predicted,
            queue_ms: exec_start.saturating_duration_since(r.arrival).as_secs_f64() * 1e3,
            exec_ms,
            form_ms: batch
                .formed_at
                .saturating_duration_since(r.arrival)
                .as_secs_f64()
                * 1e3,
            sim: SimMetering {
                hw_latency_ms: sim_lat,
                hw_energy_mj: sim_mj,
            },
            instance,
            worker: ctx.id,
            batch_seq: batch.seq,
        });
    }
    // Record latencies into this worker's per-model shard *before*
    // handing the outcome to the collector: once `drain` observes the
    // completion, the streaming aggregates already include it.
    {
        let mut shard = lock(&ctx.shard);
        for r in &responses {
            shard.record(batch.model, r);
        }
    }
    BatchOutcome {
        model: batch.model,
        responses,
        failed: 0,
        error: None,
        sim_energy_mj: sim_mj,
    }
}
