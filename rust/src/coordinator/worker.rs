//! Worker threads of the serving engine.
//!
//! Each worker owns its own [`Executor`] (PJRT clients are not shared
//! across threads; compile caches are warmed at engine startup), pulls
//! formed batches from the shared batch channel, executes them, maps the
//! batch onto a simulated OPIMA instance via the shared [`Router`],
//! folds the batch's latency samples into its own streaming
//! [`LatencyShard`] (fixed-memory histograms; `Engine::stats` merges the
//! shards), and reports per-request responses plus the per-batch
//! simulated cost back over the results channel.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::analyzer::simcost::SimCostTable;
use crate::coordinator::batcher::Batch;
use crate::coordinator::engine::{lock, LatencyShard};
use crate::coordinator::request::{InferenceResponse, SimMetering};
use crate::coordinator::router::Router;
use crate::runtime::Executor;

/// Everything one worker thread owns or shares.
pub(crate) struct WorkerCtx {
    pub id: usize,
    pub executor: Executor,
    pub batch_size: usize,
    pub image_elems: usize,
    pub router: Arc<Mutex<Router>>,
    pub costs: Arc<SimCostTable>,
    /// Shared serving epoch (finalized by `Engine::new` after warmup, so
    /// the simulated-hardware clock and `wall_ms` share one origin).
    pub epoch: Arc<Mutex<Instant>>,
    /// This worker's streaming latency histograms. Locked once per batch
    /// here; contended only by a concurrent `Engine::stats` merge.
    pub shard: Arc<Mutex<LatencyShard>>,
    pub rx: Arc<Mutex<Receiver<Batch>>>,
    pub tx: Sender<BatchOutcome>,
}

/// What one executed (or failed) batch sends to the stats sink.
pub(crate) struct BatchOutcome {
    pub responses: Vec<InferenceResponse>,
    /// Requests whose batch failed to execute (no responses for them).
    pub failed: u64,
    pub error: Option<String>,
    /// Full-batch simulated energy (mJ) — counted once per executed
    /// batch, so zero-padded partial batches still pay full-batch cost.
    pub sim_energy_mj: f64,
}

/// Pull batches until the channel closes (engine shutdown).
pub(crate) fn worker_loop(mut ctx: WorkerCtx) {
    loop {
        let msg = lock(&ctx.rx).recv();
        let Ok(batch) = msg else { return };
        let out = execute_batch(&mut ctx, batch);
        if ctx.tx.send(out).is_err() {
            return;
        }
    }
}

fn execute_batch(ctx: &mut WorkerCtx, batch: Batch) -> BatchOutcome {
    let bsz = ctx.batch_size;
    let elems = ctx.image_elems;
    // Pack (and zero-pad) the fixed-shape batch input.
    let mut input = vec![0f32; bsz * elems];
    for (i, r) in batch.requests.iter().enumerate() {
        input[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
    }
    let artifact = batch.variant.artifact(bsz);
    let exec_start = Instant::now();
    let logits = match ctx.executor.run_f32(&artifact, &[&input]) {
        Ok(l) => l,
        Err(e) => {
            return BatchOutcome {
                responses: Vec::new(),
                failed: batch.requests.len() as u64,
                error: Some(e.to_string()),
                sim_energy_mj: 0.0,
            }
        }
    };
    let exec_ms = exec_start.elapsed().as_secs_f64() * 1e3;
    let classes = logits.len() / bsz;

    // Simulated hardware metering: dispatch this *real* batch onto the
    // least-loaded simulated OPIMA instance's busy horizon. A missing
    // cost entry is a bug (the engine precomputes every variant) — fail
    // the batch loudly rather than silently metering zero.
    let Some((sim_lat, sim_mj)) = ctx.costs.get(batch.variant.pim_bits()) else {
        return BatchOutcome {
            responses: Vec::new(),
            failed: batch.requests.len() as u64,
            error: Some(format!(
                "no precomputed sim cost for {}-bit batches",
                batch.variant.pim_bits()
            )),
            sim_energy_mj: 0.0,
        };
    };
    let epoch = *lock(&ctx.epoch);
    let now_ms = exec_start.saturating_duration_since(epoch).as_secs_f64() * 1e3;
    let instance = lock(&ctx.router).dispatch(now_ms, sim_lat).0;

    let mut responses = Vec::with_capacity(batch.requests.len());
    for (i, r) in batch.requests.iter().enumerate() {
        let row = &logits[i * classes..(i + 1) * classes];
        let predicted = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(c, _)| c)
            .unwrap_or(0);
        responses.push(InferenceResponse {
            id: r.id,
            logits: row.to_vec(),
            predicted,
            queue_ms: exec_start.saturating_duration_since(r.arrival).as_secs_f64() * 1e3,
            exec_ms,
            form_ms: batch
                .formed_at
                .saturating_duration_since(r.arrival)
                .as_secs_f64()
                * 1e3,
            sim: SimMetering {
                hw_latency_ms: sim_lat,
                hw_energy_mj: sim_mj,
            },
            instance,
            worker: ctx.id,
        });
    }
    // Record latencies into this worker's shard *before* handing the
    // outcome to the collector: once `drain` observes the completion,
    // the streaming aggregates already include it.
    {
        let mut shard = lock(&ctx.shard);
        for r in &responses {
            shard.record(r);
        }
    }
    BatchOutcome {
        responses,
        failed: 0,
        error: None,
        sim_energy_mj: sim_mj,
    }
}
