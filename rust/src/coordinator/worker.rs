//! Worker threads of the serving engine.
//!
//! Each worker owns its own [`Executor`] (PJRT clients are not shared
//! across threads; the LeNet compile caches are warmed at engine
//! startup, other models compile on first batch), pulls formed batches
//! from the shared batch channel, resolves each batch's `(model,
//! variant)` through the shared [`PlanRegistry`] (plans build lazily,
//! exactly once, under a per-key lock; resolved plans are memoized in a
//! worker-local map so the steady state takes no registry lock at all),
//! executes the plan's prepared program, admits the batch's priced
//! event stream onto a simulated OPIMA instance via the shared
//! [`Router`] (reservations tagged by model; co-resident batches
//! contend for the shared stage pools through the global contention
//! timeline), folds the batch's latency samples into its own per-model
//! streaming shard (fixed-memory histograms; `Engine::stats` merges the
//! shards), and reports per-request responses plus the per-batch
//! simulated cost back over the results channel. Requests carrying a
//! reply handle (the wire front end's per-connection
//! [`ReplyQueue`](crate::coordinator::request::ReplyQueue)s) also get
//! their response — or their batch's failure — pushed to that queue
//! first, so a completed drain implies every wire reply is queued.
//!
//! **Zero-copy steady state.** The batch data plane reuses memory end to
//! end: request pixels live in shared
//! [`ImageBuf`](crate::coordinator::request::ImageBuf)s (copied exactly
//! once, into the worker's pooled `input` buffer when the batch is packed);
//! the executor writes the batch's logits straight into a shared
//! `Arc<[f32]>` recycled through the worker's [`LogitsPool`]; and each
//! response carries a [`LogitsView`] `(offset, len)` into that buffer
//! instead of a `row.to_vec()` copy. Per batch, the only heap traffic is
//! the response vec itself (and a fresh logits buffer only while a
//! previous batch's views are still alive); per response there is none.
//!
//! **Supervision.** Batch execution runs under `catch_unwind`: a panic
//! mid-batch (a backend bug, or an injected `[fault]` schedule) costs
//! exactly its own batch — every poisoned request gets a terminal
//! `Failed` reply, the executor is rebuilt in place with warmed caches,
//! and the thread keeps pulling batches. Worker threads never exit on a
//! batch failure: `Engine::drain`'s liveness check treats a finished
//! pipeline thread as a dead pipeline, so self-healing must happen
//! *inside* the loop (DESIGN.md §3.3).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cnn::models::Model;
use crate::coordinator::batcher::Batch;
use crate::coordinator::engine::{lock, WorkerShard};
use crate::coordinator::registry::{ModelPlan, PlanRegistry};
use crate::coordinator::request::{
    InferenceResponse, LogitsPool, LogitsView, Reply, SimMetering, Variant,
};
use crate::coordinator::router::Router;
use crate::runtime::{Executor, ExecutorSpec, Manifest};
use crate::util::fault::FaultPlane;
use crate::util::units::{Millijoules, Millis};

/// Everything one worker thread owns or shares.
pub(crate) struct WorkerCtx {
    pub id: usize,
    pub executor: Executor,
    pub batch_size: usize,
    pub registry: Arc<PlanRegistry>,
    pub router: Arc<Mutex<Router>>,
    /// Shared serving epoch (finalized by `Engine::new` after warmup, so
    /// the simulated-hardware clock and `wall_ms` share one origin).
    pub epoch: Arc<Mutex<Instant>>,
    /// This worker's per-model streaming latency histograms. Locked once
    /// per batch here; contended only by a concurrent `Engine::stats`
    /// merge.
    pub shard: Arc<Mutex<WorkerShard>>,
    pub rx: Arc<Mutex<Receiver<Batch>>>,
    pub tx: Sender<BatchOutcome>,
    /// Worker-local memo of resolved registry plans: after a pair's
    /// first batch, resolution is a local map probe — no registry lock,
    /// no slot lock, no `Arc` contention with other workers.
    pub plans: HashMap<(Model, Variant), Arc<ModelPlan>>,
    /// Reusable packed batch-input buffer (resized per batch, rows
    /// overwritten in place, only a short batch's padding tail zeroed;
    /// capacity grows to the largest model served and stays).
    pub input: Vec<f32>,
    /// Recycler for the shared per-batch logits buffers the responses
    /// view into.
    pub logits_pool: LogitsPool,
    /// How the executor was built — kept so a panicked worker can
    /// rebuild it in place.
    pub spec: ExecutorSpec,
    /// Manifest clone for executor rebuilds (`Executor::from_spec`
    /// consumes one).
    pub manifest: Manifest,
    /// Artifacts to re-warm after a respawn (the same list `Engine::new`
    /// warmed at startup).
    pub warm: Vec<String>,
    /// Pool-wide count of executor respawns after mid-batch panics
    /// (surfaced as `ServerStats::respawns`).
    pub respawns: Arc<AtomicU64>,
    /// This worker's deterministic fault-injection site (disarmed in
    /// production: one branch per probe, RNG never advanced).
    pub fault: FaultPlane,
}

/// What one executed (or failed) batch sends to the stats sink.
pub(crate) struct BatchOutcome {
    /// The model the batch served (batches are single-model).
    pub model: Model,
    pub responses: Vec<InferenceResponse>,
    /// Requests whose batch failed to execute (no responses for them).
    pub failed: u64,
    /// Requests whose deadline expired before batch formation (swept by
    /// the batcher with a terminal `Expired` reply; never mixed with
    /// `failed` in one outcome).
    pub expired: u64,
    pub error: Option<String>,
    /// Full-batch simulated energy — counted once per executed batch,
    /// so zero-padded partial batches still pay full-batch cost.
    pub sim_energy_mj: Millijoules,
}

/// Pull batches until the channel closes (engine shutdown), surviving
/// panics: each batch executes under `catch_unwind`, a poisoned batch
/// fails loudly (terminal `Failed` replies + a failed outcome) and the
/// executor is respawned in place before the next pull.
pub(crate) fn worker_loop(mut ctx: WorkerCtx) {
    loop {
        let msg = lock(&ctx.rx).recv();
        let Ok(batch) = msg else { return };
        if let Some(stall) = ctx.fault.worker_stall() {
            // Injected stall: the batch is late but correct — exercises
            // drain/deadline behavior, not the failure path.
            std::thread::sleep(stall);
        }
        let out = match catch_unwind(AssertUnwindSafe(|| execute_batch(&mut ctx, &batch))) {
            Ok(out) => out,
            Err(payload) => {
                // Replies first (the drain state machine needs every
                // reply queued before the collector sees the outcome),
                // then heal, then account.
                let out = fail(
                    &batch,
                    format!(
                        "worker {} panicked mid-batch: {} (executor respawned)",
                        ctx.id,
                        panic_message(payload.as_ref())
                    ),
                );
                respawn(&mut ctx);
                out
            }
        };
        if ctx.tx.send(out).is_err() {
            return;
        }
    }
}

/// Best-effort panic payload rendering (`&str` and `String` payloads
/// cover `panic!`; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// Rebuild the panicked worker's executor in place — fresh backend
/// client, re-warmed compile caches — so the thread keeps serving. If
/// the rebuild itself fails (e.g. an artifact vanished), the structurally
/// intact old executor is kept: serving degraded beats a dead worker
/// thread, which would kill the whole pipeline's liveness check.
fn respawn(ctx: &mut WorkerCtx) {
    if let Ok(mut ex) = Executor::from_spec(ctx.spec, ctx.manifest.clone()) {
        ex.warmup(&ctx.warm);
        ctx.executor = ex;
    }
    ctx.respawns.fetch_add(1, Ordering::Relaxed);
}

fn fail(batch: &Batch, error: String) -> BatchOutcome {
    // Requests submitted over the wire must hear about the failure too
    // (no silent drops): one Arc-shared error, fanned out per request.
    if batch.requests.iter().any(|r| r.reply.is_some()) {
        let shared: Arc<str> = Arc::from(error.as_str());
        for r in &batch.requests {
            if let Some(q) = &r.reply {
                q.push(Reply::Failed {
                    id: r.id,
                    error: Arc::clone(&shared),
                });
            }
        }
    }
    BatchOutcome {
        model: batch.model,
        responses: Vec::new(),
        failed: batch.requests.len() as u64,
        expired: 0,
        error: Some(error),
        sim_energy_mj: Millijoules::ZERO,
    }
}

/// Resolve the batch's compiled plan: worker-local memo first, shared
/// registry (lazy, cached, built exactly once across the pool) on a
/// local miss. A model whose artifact or mapping is broken fails its
/// batches loudly — errors are never memoized locally, so the registry
/// keeps reporting them per batch; other models keep serving.
fn resolve_plan(ctx: &mut WorkerCtx, batch: &Batch) -> crate::error::Result<Arc<ModelPlan>> {
    let key = (batch.model, batch.variant);
    if let Some(plan) = ctx.plans.get(&key) {
        return Ok(Arc::clone(plan));
    }
    let plan = ctx.registry.resolve(batch.model, batch.variant)?;
    ctx.plans.insert(key, Arc::clone(&plan));
    Ok(plan)
}

fn execute_batch(ctx: &mut WorkerCtx, batch: &Batch) -> BatchOutcome {
    if ctx.fault.worker_panic() {
        panic!("injected fault: worker panic mid-batch (fault.worker_panic)");
    }
    let plan = match resolve_plan(ctx, batch) {
        Ok(p) => p,
        Err(e) => return fail(batch, e.to_string()),
    };
    if ctx.fault.exec_transient() {
        // A transient backend error: the batch fails loudly (terminal
        // replies, failed outcome) but the executor is healthy — no
        // respawn, the next batch proceeds normally.
        return fail(
            batch,
            "injected fault: transient executor error (fault.exec_transient)".into(),
        );
    }
    let bsz = ctx.batch_size;
    let elems = plan.image_elems();
    // Pack (and zero-pad) the fixed-shape batch input into the worker's
    // pooled buffer — each request's pixels are copied exactly once on
    // their whole serving journey, right here. Every packed row is
    // overwritten below, so only the padding tail of a short batch needs
    // zeroing (a full batch pays no memset at all).
    ctx.input.resize(bsz * elems, 0.0);
    ctx.input[batch.requests.len() * elems..].fill(0.0);
    for (i, r) in batch.requests.iter().enumerate() {
        if r.image.len() != elems {
            return fail(
                batch,
                format!(
                    "request {} carries {} elems, plan wants {elems}",
                    r.id,
                    r.image.len()
                ),
            );
        }
        ctx.input[i * elems..(i + 1) * elems].copy_from_slice(&r.image);
    }
    let exec_start = Instant::now();
    // The batch's shared logits buffer: recycled from the pool when a
    // previous batch's responses have all been dropped, written by the
    // executor in place, then viewed (never copied) by every response.
    let mut logits = ctx.logits_pool.take(plan.program.output_len());
    {
        let out = Arc::get_mut(&mut logits).expect("freshly taken pool buffer is unique");
        if let Err(e) = ctx.executor.run_prepared(&plan.program, &[&ctx.input], out) {
            return fail(batch, e.to_string());
        }
    }
    let exec_ms = Millis::from_duration(exec_start.elapsed());
    let classes = plan.classes();

    // Simulated hardware metering: place this *real* batch at the
    // earliest simulated time its mapper footprint fits on an OPIMA
    // instance (models whose footprints fit together co-reside), tagged
    // with the model so makespan is reportable per model — and admit
    // its priced event stream into the instance's persistent stage
    // pools, so co-resident batches contend for aggregation units and
    // writeback channels instead of optimistically sharing them. Under
    // `[memory] writeback_model = naive|scheduled` the writeback stage
    // prices each layer as a command sequence (GST routes, MLC program
    // trains) against the instance's persistent per-bank state.
    let (sim_lat, sim_mj) = plan.sim_cost();
    let epoch = *lock(&ctx.epoch);
    let now_ms = Millis::from_duration(exec_start.saturating_duration_since(epoch));
    let (instance, sim_start, sim_end) = lock(&ctx.router).dispatch_batch(
        batch.model,
        plan.occupancy().subarrays_used,
        now_ms,
        plan.stream(),
        sim_lat,
    );

    let mut responses = Vec::with_capacity(batch.requests.len());
    for (i, r) in batch.requests.iter().enumerate() {
        let row = LogitsView::new(Arc::clone(&logits), i * classes, classes);
        let predicted = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(c, _)| c)
            .unwrap_or(0);
        let response = InferenceResponse {
            id: r.id,
            model: batch.model,
            logits: row,
            predicted,
            queue_ms: Millis::from_duration(exec_start.saturating_duration_since(r.arrival)),
            exec_ms,
            form_ms: Millis::from_duration(batch.formed_at.saturating_duration_since(r.arrival)),
            sim: SimMetering {
                hw_latency_ms: sim_lat,
                hw_contended_ms: sim_end - sim_start,
                hw_energy_mj: sim_mj,
            },
            instance,
            worker: ctx.id,
            batch_seq: batch.seq,
        };
        // Route the reply to its connection *before* the outcome reaches
        // the collector: once `drain` observes the completion, the reply
        // is already queued (the net drain state machine relies on this
        // for its responses-before-FIN ordering). Cloning a response is
        // refcount bumps only, and a warmed queue's push doesn't
        // allocate — the wire path stays on the <1-alloc budget.
        if let Some(q) = &r.reply {
            q.push(Reply::Response(response.clone()));
        }
        responses.push(response);
    }
    // Hand the buffer back for recycling: it becomes reusable the moment
    // the batch's last response view is dropped.
    ctx.logits_pool.put(logits);
    // Record latencies into this worker's per-model shard *before*
    // handing the outcome to the collector: once `drain` observes the
    // completion, the streaming aggregates already include it.
    {
        let mut shard = lock(&ctx.shard);
        for r in &responses {
            shard.record(batch.model, r);
        }
    }
    BatchOutcome {
        model: batch.model,
        responses,
        failed: 0,
        expired: 0,
        error: None,
        sim_energy_mj: sim_mj,
    }
}
