//! Crate-wide error type.

use thiserror::Error;

/// Unified error for the OPIMA stack.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration failed validation (geometry, parameters, ...).
    #[error("invalid configuration: {0}")]
    Config(String),

    /// A physical address fell outside the memory's capacity.
    #[error("address out of range: {addr:#x} (capacity {capacity} bytes)")]
    AddressRange { addr: u64, capacity: u64 },

    /// A memory or PIM command was malformed or not executable.
    #[error("command error: {0}")]
    Command(String),

    /// CNN graph construction/validation failure.
    #[error("model error: {0}")]
    Model(String),

    /// CNN → PIM mapping failure (e.g. kernel wider than a subarray row).
    #[error("mapping error: {0}")]
    Mapping(String),

    /// PJRT runtime failure (artifact missing, compile/execute error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Serving-path failure (queue closed, request rejected, ...).
    #[error("serving error: {0}")]
    Serving(String),

    /// I/O error (artifact files, config files).
    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// JSON parse error (manifest, result export).
    #[error("json error: {0}")]
    Json(String),

    /// TOML config parse error.
    #[error("config parse error: {0}")]
    Toml(String),
}

pub type Result<T> = std::result::Result<T, Error>;
