//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the default
//! build of this crate is deliberately dependency-free so tier-1
//! `cargo build && cargo test` works in offline/sandboxed environments.

use std::fmt;

/// Unified error for the OPIMA stack.
#[derive(Debug)]
pub enum Error {
    /// Configuration failed validation (geometry, parameters, ...).
    Config(String),

    /// A physical address fell outside the memory's capacity.
    AddressRange { addr: u64, capacity: u64 },

    /// A memory or PIM command was malformed or not executable.
    Command(String),

    /// CNN graph construction/validation failure.
    Model(String),

    /// CNN → PIM mapping failure (e.g. kernel wider than a subarray row).
    Mapping(String),

    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),

    /// Serving-path failure (queue closed, request rejected, ...).
    Serving(String),

    /// The serving engine's bounded ingress queue is full; the caller
    /// should retry later or shed load.
    Backpressure,

    /// The request's deadline expired before it reached a batch slot;
    /// it was swept out of the queue with a terminal reply instead of
    /// occupying capacity (DESIGN.md §3.3).
    DeadlineExceeded,

    /// I/O error (artifact files, config files).
    Io(std::io::Error),

    /// JSON parse error (manifest, result export).
    Json(String),

    /// TOML config parse error.
    Toml(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "invalid configuration: {m}"),
            Error::AddressRange { addr, capacity } => {
                write!(f, "address out of range: {addr:#x} (capacity {capacity} bytes)")
            }
            Error::Command(m) => write!(f, "command error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Mapping(m) => write!(f, "mapping error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serving(m) => write!(f, "serving error: {m}"),
            Error::Backpressure => write!(f, "backpressure: serving ingress queue is full"),
            Error::DeadlineExceeded => {
                write!(f, "deadline exceeded: request expired before batch formation")
            }
            Error::Io(e) => write!(f, "{e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Toml(m) => write!(f, "config parse error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_formats() {
        assert_eq!(
            Error::Config("bad".into()).to_string(),
            "invalid configuration: bad"
        );
        assert_eq!(
            Error::AddressRange {
                addr: 0x10,
                capacity: 8
            }
            .to_string(),
            "address out of range: 0x10 (capacity 8 bytes)"
        );
        assert_eq!(
            Error::Backpressure.to_string(),
            "backpressure: serving ingress queue is full"
        );
        assert_eq!(
            Error::DeadlineExceeded.to_string(),
            "deadline exceeded: request expired before batch formation"
        );
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
