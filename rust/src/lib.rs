//! # OPIMA — Optical Processing-In-Memory for CNN Acceleration
//!
//! Full-system reproduction of *"OPIMA: Optical Processing-In-Memory for
//! Convolutional Neural Network Acceleration"* (Sunny et al., cs.AR 2024).
//!
//! OPIMA is a photonic PIM architecture built inside an optically-programmed
//! phase-change (OPCM) main memory. This crate implements the entire
//! evaluation stack the paper ran — the authors' own substrate was a modified
//! NVMain 2.0 plus a Python performance analyzer; ours is a cycle-approximate
//! Rust simulator with the same device parameters (paper Table I) — together
//! with a serving-style coordinator that executes the *functional* model
//! (JAX/Pallas, AOT-lowered to HLO) through PJRT on the request path.
//!
//! Layer map (see `DESIGN.md`):
//! - [`phys`] — photonic device library, GST OPCM cell surrogate physics
//!   (paper Fig. 2), inverse-designed crossing surrogate (Fig. 6), MDM
//!   analysis, link budgets.
//! - [`memory`] — the OPCM main-memory simulator (banks, subarrays, cells,
//!   command scheduling; the NVMain substitute).
//! - [`pim`] — the PIM engine: subarray groups, MDL arrays, WDM/MDM MAC
//!   scheduling, aggregation unit, TDM bit-width bridging (paper §IV.C).
//! - [`cnn`] — CNN graph IR, the five evaluation models (Table II) and
//!   the tiny served LeNet, with the static serving metadata the
//!   coordinator validates requests against.
//! - [`mapper`] — CNN → PIM mapping: input-stationary convs,
//!   weight-stationary FC, 1×1-kernel serialization (paper §IV.D),
//!   per-layer subarray footprints and occupancy-vs-capacity
//!   accounting with structured over-capacity warnings.
//! - [`analyzer`] — latency/energy/power roll-up, EPB and FPS/W metrics
//!   (Figs. 7–12), and the resource-aware pipelined simulation
//!   timeline ([`analyzer::timeline`]): whole batches scheduled as
//!   discrete events against subarray/aggregation/writeback pools, so
//!   batch latency is sublinear instead of `batch ×` the layer sum
//!   (exactly equal to it at batch 1).
//! - [`baselines`] — NP100 / E7742 / ORIN rooflines, PRIME, CrossLight,
//!   PhPIM comparison models (paper §V).
//! - [`coordinator`] — the concurrent *multi-model* serving engine:
//!   bounded ingress queue with backpressure → batcher thread (one
//!   queue per `(model, variant)` pair, size- *and* idle-safe
//!   deadline-triggered flushes, round-robin fairness across models,
//!   batches never mixed) → worker pool (one PJRT executor per worker;
//!   every batch resolves through the shared `PlanRegistry`, a lazily
//!   built per-`(model, variant)` cache of mapper plan + sim-cost table
//!   + executor program, compiled exactly once under a per-key lock) →
//!   bounded stats sink, with graceful drain/shutdown; the
//!   occupancy-aware router places each real batch at the earliest
//!   simulated time its mapper footprint fits on an OPIMA instance
//!   (co-residency instead of scalar busy horizons), with reservations
//!   tagged per model, and a synchronous `Server` facade preserves the
//!   seed call-loop API with a by-value response API.
//!   Observability is streaming and per-model: per-worker log-bucketed
//!   latency histograms merged in O(models × buckets) by `stats()`
//!   (global + per-model breakdowns), and a fixed-capacity ring of
//!   recent responses — memory stays constant over unbounded request
//!   streams. The data plane is zero-copy in steady state: shared
//!   `ImageBuf` request payloads, pooled batch-input buffers, prepared
//!   executor programs writing into pooled shared logits buffers, and
//!   `LogitsView` responses that view (never copy) their batch's row.
//!   [`coordinator::net`] extends that data plane to a TCP socket
//!   boundary: a dependency-free length-prefixed binary protocol whose
//!   request pixels decode straight into pooled buffers and whose
//!   responses leave as vectored writes — <1 allocation per request
//!   end to end (DESIGN.md §3.2).
//! - [`runtime`] — artifact loading/execution: PJRT (`xla` crate,
//!   feature `pjrt`) or a deterministic sim backend for environments
//!   without the XLA native library or AOT artifacts.
//! - [`util`] — dependency-free substrates: JSON/TOML-lite parsing, the
//!   deterministic PRNG (unbiased bounded sampling), the bench harness,
//!   the compile-time units layer ([`util::units`]: `Nanos`/`Millis`/
//!   `Millijoules`/`Milliwatts`/`Bytes` newtypes that make ns/ms/mJ
//!   confusion a type error; see DESIGN.md §4), and the shared streaming
//!   histogram + bounded ring behind both the serving stats and the
//!   offline analyzer percentiles.

// The whole stack is a software model — there is no FFI, no hand-rolled
// pointer work, and nothing here should ever need `unsafe`.
#![deny(unsafe_code)]

// modules added incrementally below
pub mod analyzer;
pub mod baselines;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod mapper;
pub mod memory;
pub mod phys;
pub mod pim;
pub mod runtime;
pub mod util;

pub use config::OpimaConfig;
pub use error::{Error, Result};
