//! OPIMA CLI — the leader entrypoint.
//!
//! Subcommands map to the paper's experiments:
//!   info                      configuration + capacity summary
//!   dse                       Fig. 2  GST cell design-space exploration
//!   crossing                  Fig. 6  waveguide-crossing C-band profile
//!   groups                    Fig. 7  subarray-group selection sweep
//!   power                     Fig. 8  power breakdown
//!   latency  [--bits 4|8] [--model NAME]   Fig. 9 latency breakdown
//!   analyze  [--batch N] [--bits 4|8] [--model NAME] [--streams S]
//!                             pipelined-vs-sequential batch timeline;
//!                             --streams ≥ 2 reports contended-vs-isolated
//!                             co-residency through the global engine
//!   compare  [--bits 4|8]     Figs. 10–12 cross-platform comparison
//!   memtest  [--ops N]        memory-mode self-test (read/write sweep)
//!   serve    [--requests N] [--variant v] [--instances K] [--workers W]
//!            [--mix lenet:4,vgg16:1]     multi-model serving demo
//!   serve --listen ADDR  [--connections C] [--rate RPS] [--window W]
//!            [--requests N] [--retries R] [--deadline-ms D]
//!            [--chaos-seed S] [...]      zero-copy TCP wire front end:
//!            bind ADDR, then (requests > 0) self-drive it over loopback
//!            with the open-loop load generator, or (requests = 0) keep
//!            serving until killed. --chaos-seed arms the deterministic
//!            fault plane (a demo schedule when `[fault]` probabilities
//!            are all zero); --retries caps BUSY re-submissions;
//!            --deadline-ms tags each request with a deadline budget
//!   config                    print the active TOML configuration
//!
//! Global flag: --config <file.toml> loads overrides over paper defaults.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use opima::analyzer::metrics::{geomean_ratio, workload_bits};
use opima::analyzer::report;
use opima::analyzer::{analyze_model, power_breakdown};
use opima::baselines::evaluate_all;
use opima::cnn::{build_model, Model, ALL_MODELS};
use opima::config::WritebackModel;
use opima::coordinator::net::{run_load, LoadGenConfig, NetServer};
use opima::coordinator::{
    parse_mix, pick_weighted, Engine, EngineConfig, InferenceRequest, Server, ServerConfig,
    Variant,
};
use opima::error::{Error, Result};
use opima::phys::{crossing, dse};
use opima::pim::group;
use opima::runtime::{ExecutorSpec, Manifest};
use opima::util::prng::Rng;
use opima::util::units::Millis;
use opima::OpimaConfig;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs after the subcommand.
struct Args {
    cmd: String,
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "info".to_string());
        let mut flags = Vec::new();
        while let Some(k) = it.next() {
            let key = k
                .strip_prefix("--")
                .ok_or_else(|| Error::Config(format!("expected --flag, got '{k}'")))?
                .to_string();
            let val = it
                .next()
                .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
            flags.push((key, val));
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} wants an integer, got '{v}'"))),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} wants a number, got '{v}'"))),
        }
    }
}

fn load_config(args: &Args) -> Result<OpimaConfig> {
    match args.get("config") {
        Some(path) => OpimaConfig::from_toml_file(&PathBuf::from(path)),
        None => Ok(OpimaConfig::paper()),
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    let cfg = load_config(&args)?;
    match args.cmd.as_str() {
        "info" => cmd_info(&cfg),
        "dse" => cmd_dse(),
        "crossing" => cmd_crossing(),
        "groups" => cmd_groups(&cfg),
        "power" => cmd_power(&cfg),
        "latency" => cmd_latency(&cfg, &args),
        "analyze" => cmd_analyze(&cfg, &args),
        "compare" => cmd_compare(&cfg, &args),
        "memtest" => cmd_memtest(&cfg, &args),
        "serve" => cmd_serve(&cfg, &args),
        "config" => {
            print!("{}", cfg.to_toml());
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command '{other}' (try: info dse crossing groups power \
             latency analyze compare memtest serve config)"
        ))),
    }
}

fn cmd_info(cfg: &OpimaConfig) -> Result<()> {
    let g = &cfg.geometry;
    println!("OPIMA configuration (paper §V defaults unless overridden)");
    println!(
        "  geometry : {} banks × {}×{} subarrays × {}×{} cells × {} b/cell",
        g.banks,
        g.subarray_rows,
        g.subarray_cols,
        g.rows_per_subarray,
        g.cols_per_subarray,
        g.bits_per_cell
    );
    println!(
        "  capacity : {:.2} GiB   groups: {}   MDM degree: {}",
        g.capacity_bytes() as f64 / (1u64 << 30) as f64,
        g.subarray_groups,
        g.mdm_degree
    );
    let p = group::evaluate(cfg, g.subarray_groups)?;
    println!(
        "  peak PIM : {} MACs/cycle = {:.2} TMAC/s @ {} GHz",
        p.macs_per_cycle,
        p.mac_throughput / 1e12,
        cfg.timing.clock_ghz
    );
    println!(
        "  power    : {:.1} W (Fig. 8 envelope)",
        power_breakdown(cfg).total_w()
    );
    Ok(())
}

fn cmd_dse() -> Result<()> {
    let r = dse::run(&dse::DseSweep::default());
    println!("GST OPCM cell design-space exploration (paper Fig. 2)");
    println!(
        "optimum: width {:.2} µm, thickness {:.0} nm  (ΔT = {:.1}%, ΔT_s cryst {:.1}%, amorph {:.1}%)\n",
        r.optimum.width_um,
        r.optimum.thickness_nm,
        100.0 * r.optimum.contrast,
        100.0 * r.optimum.dts_crystalline,
        100.0 * r.optimum.dts_amorphous
    );
    println!("ΔT (%) over thickness (rows, nm) × width (cols, µm):");
    print!("      ");
    for w in r.widths_um.iter().step_by(2) {
        print!("{w:>6.2}");
    }
    println!();
    for (ti, t) in r.thicknesses_nm.iter().enumerate() {
        print!("{t:>5.0} ");
        for p in r.grid[ti].iter().step_by(2) {
            let feasible =
                p.dts_crystalline < r.dts_threshold && p.dts_amorphous < r.dts_threshold;
            if feasible {
                print!("{:>6.1}", 100.0 * p.contrast);
            } else {
                print!("{:>6}", "·");
            }
        }
        println!();
    }
    println!("(· = infeasible: ΔT_s ≥ 5%)");
    Ok(())
}

fn cmd_crossing() -> Result<()> {
    println!("Inverse-designed waveguide crossing, C-band profile (paper Fig. 6)");
    println!("| λ (nm) | insertion loss (%) | crosstalk (dB) |");
    println!("|---|---|---|");
    for p in crossing::c_band_profile(15) {
        println!(
            "| {:.1} | {:.6} | {:.1} |",
            p.wavelength_nm,
            100.0 * p.insertion_loss,
            p.crosstalk_db
        );
    }
    Ok(())
}

fn cmd_groups(cfg: &OpimaConfig) -> Result<()> {
    println!("Subarray group selection (paper Fig. 7)");
    println!("| groups | MAC/cycle | TMAC/s | power (W) | rows free | GMAC/s/W |");
    println!("|---|---|---|---|---|---|");
    for p in group::sweep(cfg, &[1, 2, 4, 8, 16, 32, 64])? {
        println!(
            "| {} | {} | {:.2} | {:.1} | {} | {:.1} |",
            p.groups,
            p.macs_per_cycle,
            p.mac_throughput / 1e12,
            p.power_w,
            p.rows_available,
            p.macs_per_watt / 1e9
        );
    }
    let best = group::select_optimal(cfg)?;
    println!("\nMAC/W optimum: {} groups (paper: 16)", best.groups);
    Ok(())
}

fn cmd_power(cfg: &OpimaConfig) -> Result<()> {
    println!("Power breakdown (paper Fig. 8; paper total 55.9 W)\n");
    print!("{}", report::power_table(&power_breakdown(cfg)));
    Ok(())
}

fn parse_models(args: &Args) -> Result<Vec<Model>> {
    match args.get("model") {
        None => Ok(ALL_MODELS.to_vec()),
        Some(name) => Model::from_name(name)
            .map(|m| vec![m])
            .ok_or_else(|| Error::Config(format!("unknown model '{name}'"))),
    }
}

fn cmd_latency(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    let models = parse_models(args)?;
    let bits_list: Vec<u32> = match args.get("bits") {
        Some(b) => vec![b.parse().map_err(|_| Error::Config("bad --bits".into()))?],
        None => vec![4, 8],
    };
    println!("OPIMA latency breakdown (paper Fig. 9)\n");
    let mut analyses = Vec::new();
    for m in &models {
        let net = build_model(*m)?;
        for &bits in &bits_list {
            analyses.push(analyze_model(cfg, &net, bits)?);
        }
    }
    print!("{}", report::latency_table(&analyses));
    Ok(())
}

fn cmd_analyze(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    let batch = args.usize_or("batch", 8)?;
    if batch == 0 {
        return Err(Error::Config("--batch must be at least 1".into()));
    }
    let models = parse_models(args)?;
    let bits: u32 = args
        .get("bits")
        .unwrap_or("4")
        .parse()
        .map_err(|_| Error::Config("bad --bits".into()))?;
    let streams = args.usize_or("streams", 1)?;
    if streams > 1 {
        return cmd_analyze_contended(cfg, &models, bits, batch, streams);
    }
    println!(
        "Pipelined batch timeline vs the analytical batch × sum ({bits}-bit, \
         batch {batch})\n"
    );
    let mut rows = Vec::new();
    let mut wb_rows = Vec::new();
    let mut warnings = Vec::new();
    for m in &models {
        let net = build_model(*m)?;
        let a = opima::analyzer::analyze_model(cfg, &net, bits)?;
        if let Some(w) = a.occupancy.warning_for(&a.name) {
            warnings.push(w);
        }
        rows.push((m.name(), opima::analyzer::simulate_analysis(cfg, &a, batch)));
        // The same batch under each writeback model (the layer costs
        // carry their command decomposition regardless of the knob, so
        // the analysis is shared; only the timeline pass differs).
        let mut per = [Millis::ZERO; 3];
        for (i, wm) in WritebackModel::ALL.iter().enumerate() {
            let mut c = cfg.clone();
            c.memory.writeback_model = *wm;
            per[i] = opima::analyzer::simulate_analysis_makespan(&c, &a, batch).makespan_ms();
        }
        wb_rows.push(report::WritebackRow {
            name: m.name().to_string(),
            batch,
            flat_ms: per[0],
            naive_ms: per[1],
            scheduled_ms: per[2],
        });
    }
    let refs: Vec<(&str, &opima::analyzer::BatchTimeline)> =
        rows.iter().map(|(n, t)| (*n, t)).collect();
    print!("{}", report::timeline_table(&refs));
    println!(
        "\n(speedup = sequential / pipelined; efficiency = bottleneck bound / \
         pipelined — 100% means the schedule saturates its busiest resource)"
    );
    println!(
        "\nWriteback pricing models (`[memory] writeback_model`; active: {})\n",
        cfg.memory.writeback_model
    );
    print!("{}", report::writeback_table(&wb_rows));
    println!(
        "\n(flat prices each layer's writeback as one scalar; naive replays \
         its command decomposition — GST routes, MLC program trains, staging \
         drain — strictly serialized; scheduled overlaps trains across banks \
         and channels. All three agree at batch 1; they diverge once \
         writebacks queue)"
    );
    for w in &warnings {
        println!("warning: {w}");
    }
    Ok(())
}

/// `analyze --streams S`: admit S identical batch streams of each model
/// onto one simulated instance and price the co-residency three ways —
/// occupancy-only (the optimistic pre-contention model), through the
/// global contention timeline (honest), and fully serialized (the
/// no-overlap upper bound).
fn cmd_analyze_contended(
    cfg: &OpimaConfig,
    models: &[Model],
    bits: u32,
    batch: usize,
    streams: usize,
) -> Result<()> {
    use opima::analyzer::contention::BatchStream;
    use opima::coordinator::Router;

    println!(
        "Contended vs isolated co-residency ({bits}-bit, batch {batch}, \
         {streams} concurrent streams on one instance)\n"
    );
    let capacity = cfg.geometry.total_subarrays();
    // The honest router prices writebacks under the configured
    // `[memory] writeback_model`; the optimistic one books occupancy
    // only, so the memory model is irrelevant there.
    let mut honest_cfg = cfg.clone();
    honest_cfg.pipeline.cross_batch_contention = true;
    let mut optimistic_pipe = cfg.pipeline.clone();
    optimistic_pipe.cross_batch_contention = false;
    let mut rows = Vec::new();
    for m in models {
        let net = build_model(*m)?;
        let a = opima::analyzer::analyze_model(cfg, &net, bits)?;
        let iso = opima::analyzer::simulate_analysis_makespan(cfg, &a, batch);
        let stream = BatchStream {
            costs: &a.layer_costs,
            batch,
            pipelined: a.occupancy.fits(),
        };
        let fp = a.occupancy.subarrays_used;
        let mut honest = Router::with_hw(1, &honest_cfg);
        let mut optimistic = Router::with_pools(1, capacity, &optimistic_pipe);
        for _ in 0..streams {
            honest.dispatch_batch(*m, fp, Millis::ZERO, stream, iso.makespan_ms());
            optimistic.dispatch_batch(*m, fp, Millis::ZERO, stream, iso.makespan_ms());
        }
        rows.push(report::ContentionRow {
            name: m.name().to_string(),
            isolated_ms: iso.makespan_ms(),
            optimistic_ms: optimistic.makespan_ms(),
            contended_ms: honest.makespan_ms(),
            serialized_ms: iso.makespan_ms() * streams as f64,
        });
    }
    print!("{}", report::contention_table(streams, &rows));
    println!(
        "\n(optimistic books subarray occupancy only; contended admits every \
         stream into the shared aggregation/writeback pools — the honest \
         fleet makespan, bounded by the serialized sum; writebacks priced \
         by `[memory] writeback_model = {}`)",
        cfg.memory.writeback_model
    );
    Ok(())
}

fn cmd_compare(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    let bits: u32 = args
        .get("bits")
        .unwrap_or("4")
        .parse()
        .map_err(|_| Error::Config("bad --bits".into()))?;
    // Figs. 10–12 use the four CNN workloads (§V.C); VGG16 is Table-II-only.
    let models: Vec<Model> = ALL_MODELS
        .iter()
        .copied()
        .filter(|m| *m != Model::Vgg16)
        .collect();
    let mut epb = vec![Vec::new(); 6];
    let mut fpsw = vec![Vec::new(); 6];
    for m in &models {
        let net = build_model(*m)?;
        let rs = evaluate_all(cfg, &net, bits)?;
        let bits_w = workload_bits(&net, bits);
        println!("\n### {} ({}-bit)\n", m.name(), bits);
        print!("{}", report::comparison_table(&rs, bits_w));
        let o = &rs[0];
        for (i, r) in rs.iter().enumerate().skip(1) {
            epb[i - 1].push(r.epb_pj(bits_w) / o.epb_pj(bits_w));
            fpsw[i - 1].push(o.fps_per_w() / r.fps_per_w());
        }
    }
    println!("\n### Geometric-mean advantage of OPIMA (paper Fig. 11 / Fig. 12)\n");
    println!("| vs | EPB (ours) | EPB (paper) | FPS/W (ours) | FPS/W (paper) |");
    println!("|---|---|---|---|---|");
    let paper = [
        ("NP100", 78.3, 6.7),
        ("E7742", 157.5, 15.2),
        ("ORIN", 1.7, 8.2),
        ("PRIME", 4.4, 5.7),
        ("CrossLight", 2.2, 1.8),
        ("PhPIM", 137.0, 11.9),
    ];
    let ones = vec![1.0; models.len()];
    for (i, (name, p_epb, p_fpsw)) in paper.iter().enumerate() {
        println!(
            "| {} | {:.1}× | {}× | {:.1}× | {}× |",
            name,
            geomean_ratio(&epb[i], &ones),
            p_epb,
            geomean_ratio(&fpsw[i], &ones),
            p_fpsw
        );
    }
    Ok(())
}

fn cmd_memtest(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    use opima::memory::MemoryController;
    let ops = args.usize_or("ops", 2000)?;
    let mut ctl = MemoryController::new(cfg)?;
    let mut rng = Rng::new(42);
    let cap = ctl.capacity_bytes();
    let t0 = Instant::now();
    let mut verified = 0u64;
    for i in 0..ops {
        let len = 16usize << rng.index(5); // 16..256 B
        let addr = (rng.next_u64() % (cap - len as u64)) / 16 * 16;
        let data: Vec<u8> = (0..len).map(|j| ((i + j) % 251) as u8).collect();
        ctl.write(addr, &data)?;
        let back = ctl.read(addr, len as u64)?.data.unwrap();
        if back != data {
            return Err(Error::Command(format!("MISMATCH at {addr:#x}")));
        }
        verified += len as u64;
    }
    let s = ctl.stats();
    println!("memtest OK: {ops} write/read pairs, {verified} bytes verified");
    println!(
        "  simulated: {:.1} µs busy, {:.2} µJ ({:.1} pJ/B write, {:.1} pJ/B read)",
        s.busy_ns.raw() / 1e3,
        s.total_energy_pj() / 1e6, // pJ → µJ display scale // lint: allow(time-literal)
        s.write_energy_pj / s.bytes_written as f64,
        s.read_energy_pj / s.bytes_read as f64
    );
    println!("  wall time: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

fn cmd_serve(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return cmd_serve_listen(cfg, args);
    }
    let n = args.usize_or("requests", 256)?;
    let instances = args.usize_or("instances", 1)?;
    let workers = args.usize_or("workers", 1)?;
    let variant = Variant::parse(args.get("variant").unwrap_or("int4"))?;
    let mix = match args.get("mix") {
        None => vec![(Model::LeNet, 1)],
        Some(spec) => parse_mix(spec)?,
    };
    // Without an artifacts directory the PJRT backend has nothing to
    // compile — fall back to the synthetic manifest AND the sim backend
    // together, so the printed message matches what actually runs.
    let (manifest, no_artifacts) = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => (m, false),
        Err(_) => {
            println!("(artifacts not found — synthetic manifest + sim executor backend)");
            (Manifest::synthetic(8, 12), true)
        }
    };
    let server_cfg = ServerConfig {
        instances,
        workers,
        hw: cfg.clone(),
        ..Default::default()
    };
    let mut server = if no_artifacts {
        Server::new_sim(server_cfg, manifest)?
    } else {
        Server::new(server_cfg, manifest)?
    };
    let mut rng = Rng::new(7);
    if !cfg!(feature = "pjrt") {
        println!(
            "(built without --features pjrt: sim executor backend — predictions are \
             deterministic pseudo-logits, not the trained model)"
        );
    }
    let mix_desc: Vec<String> = mix.iter().map(|(m, w)| format!("{}:{w}", m.name())).collect();
    println!(
        "serving {n} requests (mix {}, variant {variant:?}, {instances} instance(s), \
         {workers} worker(s)) ...",
        mix_desc.join(",")
    );
    for id in 0..n as u64 {
        // Weighted random model pick — the mixed workload.
        let model = pick_weighted(&mut rng, &mix);
        let elems = server.image_elems_for(model);
        let image: opima::coordinator::ImageBuf =
            (0..elems).map(|_| rng.f64() as f32).collect();
        server.submit(InferenceRequest {
            id,
            model,
            image,
            variant,
            arrival: Instant::now(),
            deadline: None,
            reply: None,
        })?;
    }
    server.flush()?;
    print_serving_report(server.engine());
    server.shutdown()
}

/// The shared end-of-run serving report (`serve` in both in-process and
/// `--listen` modes).
fn print_serving_report(engine: &Engine) {
    let s = engine.stats();
    println!(
        "served {} requests in {} batches ({} (model, variant) plan(s), each compiled once)",
        s.served,
        s.batches,
        engine.registry().builds()
    );
    if s.failed + s.expired + s.rejected + s.shed + s.respawns > 0 {
        println!(
            "  degraded: {} failed, {} expired, {} rejected, {} shed, {} worker respawn(s)",
            s.failed, s.expired, s.rejected, s.shed, s.respawns
        );
    }
    println!(
        "  wall: {:.1} ms   throughput: {:.0} req/s",
        s.wall_ms.raw(),
        s.throughput_rps
    );
    print!(
        "{}",
        opima::analyzer::report::latency_summary_table(&[
            ("total", &s.latency.total),
            ("queue", &s.latency.queue),
            ("exec", &s.latency.exec),
            ("form", &s.latency.form),
        ])
    );
    println!(
        "  simulated OPIMA hardware: {:.2} ms makespan, {:.2} mJ dynamic energy",
        s.sim_makespan_ms.raw(),
        s.sim_energy_mj.raw()
    );
    println!("\nper-model breakdown:");
    println!("| model | served | batches | failed | expired | p50 ms | p99 ms | energy mJ | makespan ms |");
    println!("|---|---|---|---|---|---|---|---|---|");
    for m in &s.per_model {
        println!(
            "| {} | {} | {} | {} | {} | {:.2} | {:.2} | {:.2} | {:.2} |",
            m.model.name(),
            m.served,
            m.batches,
            m.failed,
            m.expired,
            m.latency.total.p50,
            m.latency.total.p99,
            m.sim_energy_mj.raw(),
            m.sim_makespan_ms.raw()
        );
    }
    let per_model_sum: u64 = s.per_model.iter().map(|m| m.served).sum();
    debug_assert_eq!(per_model_sum, s.served);
    // Over-capacity models still serve but time-share the simulated
    // memory; surface the mapper's structured warning instead of
    // silently mapping.
    for w in engine.capacity_warnings() {
        println!("warning: {w}");
    }
}

/// `serve --listen ADDR`: bind the zero-copy TCP wire front end over a
/// fresh engine. With `--requests N > 0` the process also drives itself
/// over loopback with the open-loop load generator and reports both
/// sides; with `--requests 0` it serves until killed.
fn cmd_serve_listen(cfg: &OpimaConfig, args: &Args) -> Result<()> {
    let addr = args.get("listen").expect("dispatched on --listen").to_string();
    let requests = args.usize_or("requests", 256)?;
    let connections = args.usize_or("connections", 4)?;
    let rate_rps = args.f64_or("rate", 0.0)?;
    let window = args.usize_or("window", 32)?;
    let instances = args.usize_or("instances", 1)?;
    let workers = args.usize_or("workers", 1)?;
    let variant = Variant::parse(args.get("variant").unwrap_or("int4"))?;
    let retry_max = args.usize_or("retries", 0)? as u32;
    let deadline_ms = args.usize_or("deadline-ms", 0)? as u32;
    let mix = match args.get("mix") {
        None => vec![(Model::LeNet, 1)],
        Some(spec) => parse_mix(spec)?,
    };
    let mut hw = cfg.clone();
    if let Some(seed) = args.get("chaos-seed") {
        let seed: u64 = seed
            .parse()
            .map_err(|_| Error::Config(format!("--chaos-seed wants an integer, got '{seed}'")))?;
        hw.fault.armed = true;
        hw.fault.seed = seed;
        let p = &mut hw.fault;
        if p.worker_panic == 0.0
            && p.worker_stall == 0.0
            && p.exec_transient == 0.0
            && p.writer_delay == 0.0
            && p.conn_rate_rps == 0.0
        {
            // No `[fault]` probabilities configured: apply the demo
            // schedule so `--chaos-seed` alone shows every degraded
            // path without a config file.
            p.worker_panic = 0.02;
            p.worker_stall = 0.02;
            p.writer_delay = 0.05;
        }
        println!(
            "(chaos armed: seed {seed}, worker_panic {} worker_stall {} exec_transient {} writer_delay {} conn_rate_rps {})",
            p.worker_panic, p.worker_stall, p.exec_transient, p.writer_delay, p.conn_rate_rps
        );
    }
    let (manifest, no_artifacts) = match Manifest::load(&Manifest::default_dir()) {
        Ok(m) => (m, false),
        Err(_) => {
            println!("(artifacts not found — synthetic manifest + sim executor backend)");
            (Manifest::synthetic(8, 12), true)
        }
    };
    let engine = Arc::new(Engine::new(
        EngineConfig {
            workers,
            instances,
            hw,
            executor: if no_artifacts {
                ExecutorSpec::Sim { work_factor: 1 }
            } else {
                ExecutorSpec::Native
            },
            ..Default::default()
        },
        manifest,
    )?);
    let server = NetServer::bind(Arc::clone(&engine), &addr)?;
    println!("listening on {}", server.local_addr());
    if requests == 0 {
        println!("(no self-drive: --requests 0 — serving until killed)");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let requests_per_conn = requests.div_ceil(connections.max(1)).max(1);
    let mix_desc: Vec<String> = mix.iter().map(|(m, w)| format!("{}:{w}", m.name())).collect();
    println!(
        "self-driving {} request(s) over {connections} connection(s) (mix {}, variant {variant:?}, rate {}, window {window}) ...",
        requests_per_conn * connections,
        mix_desc.join(","),
        if rate_rps > 0.0 {
            format!("{rate_rps} req/s")
        } else {
            "open".to_string()
        }
    );
    let report = run_load(&LoadGenConfig {
        addr: server.local_addr().to_string(),
        connections,
        requests_per_conn,
        rate_rps,
        mix,
        variant,
        window,
        seed: 7,
        retry_max,
        deadline_ms,
        ..LoadGenConfig::default()
    })?;
    println!(
        "client: sent {}  responses {}  busy {}  failed {}  expired {}  retries {}  ({:.0} req/s, p50 {:.2} ms, p99 {:.2} ms)",
        report.sent,
        report.responses,
        report.busy,
        report.failed,
        report.expired,
        report.retries,
        report.rps,
        report.p50_ms.raw(),
        report.p99_ms.raw()
    );
    server.shutdown()?;
    print_serving_report(&engine);
    match Arc::try_unwrap(engine) {
        Ok(mut e) => e.shutdown(),
        Err(_) => Ok(()),
    }
}
