//! Input-stationary convolution mapping (paper §IV.D).
//!
//! The feature map stays in its native OPCM locations; kernels stream in
//! as MDL wavelength vectors. Kernel row k_i multiplies feature row f_j
//! inside one subarray; same-λ products from `optical_accum` subarrays of
//! the group interfere in the shared bus, summing vertically adjacent
//! kernel-row contributions — the paper's worked 2×2 example.

use crate::cnn::layer::{Layer, LayerInstance};
use crate::config::Geometry;
use crate::error::{Error, Result};

/// Placement of one conv layer on the PIM substrate.
#[derive(Debug, Clone)]
pub struct ConvMapping {
    /// Feature-map rows per subarray (input-stationary shards).
    pub feature_rows_per_subarray: usize,
    /// Wavelengths occupied by one kernel-row vector tile.
    pub lambdas_per_kernel_row: usize,
    /// Input-channel tiles a kernel row is split into when wider than the
    /// WDM degree (partial sums recombine digitally in the aggregation
    /// SRAM — "the parameters can be stored within the SRAM cache ... for
    /// additional accumulation operations if needed", §IV.C.4).
    pub channel_tiles: usize,
    /// Kernel instances that fit concurrently in one subarray row's WDM
    /// budget ("we will be able to drive several kernels simultaneously").
    pub kernels_per_row: usize,
    /// Subarrays needed to hold one input feature map shard set.
    pub subarrays_for_feature_map: usize,
    /// Whether the layer is accumulation-free (1×1) and serializes.
    pub one_by_one: bool,
}

impl ConvMapping {
    /// Subarrays this layer's stationary operands occupy — the resource
    /// footprint the occupancy accounting and the simulation timeline
    /// charge for the layer (input-stationary: the feature-map shards).
    pub fn footprint(&self) -> usize {
        self.subarrays_for_feature_map
    }
}

/// Map one conv layer; errors only if a single kernel row's spatial width
/// alone exceeds the WDM degree (the paper: "if the kernel sizes do not
/// exceed the subarray row size"). Wide channel counts tile.
pub fn map_conv(geom: &Geometry, inst: &LayerInstance) -> Result<ConvMapping> {
    let Layer::Conv {
        kh,
        kw,
        groups,
        ..
    } = inst.layer
    else {
        return Err(Error::Mapping("map_conv on non-conv layer".into()));
    };
    if kw > geom.cols_per_subarray {
        return Err(Error::Mapping(format!(
            "kernel width {kw} exceeds subarray row ({} λ) — layer {}",
            geom.cols_per_subarray, inst.name
        )));
    }
    let cin_per_group = inst.in_shape.c / groups;
    let channels_per_tile = (geom.cols_per_subarray / kw).min(cin_per_group).max(1);
    let channel_tiles = cin_per_group.div_ceil(channels_per_tile);
    let lambdas_per_kernel_row = kw * channels_per_tile;
    let kernels_per_row = (geom.cols_per_subarray / lambdas_per_kernel_row).max(1);

    // Feature map rows (h × c elements per row) shard across subarrays;
    // each subarray cell row holds cols_per_subarray elements.
    let elems_per_feature_row = inst.in_shape.w * cin_per_group;
    let cell_rows_per_feature_row = elems_per_feature_row.div_ceil(geom.cols_per_subarray);
    let feature_rows_per_subarray =
        (geom.rows_per_subarray / cell_rows_per_feature_row.max(1)).max(1);
    let subarrays_for_feature_map = (inst.in_shape.h * groups)
        .div_ceil(feature_rows_per_subarray)
        .max(1);

    Ok(ConvMapping {
        feature_rows_per_subarray,
        lambdas_per_kernel_row,
        channel_tiles,
        kernels_per_row,
        subarrays_for_feature_map,
        one_by_one: kh == 1 && kw == 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layer::TensorShape;

    fn conv_inst(kh: usize, kw: usize, cin: usize, cout: usize, hw: usize) -> LayerInstance {
        let layer = Layer::Conv {
            kh,
            kw,
            cout,
            stride: 1,
            pad: kh / 2,
            groups: 1,
            bias: true,
        };
        let in_shape = TensorShape::new(hw, hw, cin);
        let out_shape = layer.out_shape(in_shape).unwrap();
        LayerInstance {
            name: "t".into(),
            layer,
            in_shape,
            out_shape,
        }
    }

    #[test]
    fn small_kernel_fits_many_per_row() {
        let geom = Geometry::default();
        let m = map_conv(&geom, &conv_inst(3, 3, 16, 32, 32)).unwrap();
        assert_eq!(m.lambdas_per_kernel_row, 48);
        assert_eq!(m.kernels_per_row, 5); // 256 / 48
        assert!(!m.one_by_one);
    }

    #[test]
    fn one_by_one_flagged() {
        let geom = Geometry::default();
        let m = map_conv(&geom, &conv_inst(1, 1, 64, 128, 16)).unwrap();
        assert!(m.one_by_one);
    }

    #[test]
    fn wide_channel_kernels_tile() {
        let geom = Geometry::default();
        // kw × cin = 3 × 512 = 1536 λ > 256 → tiles of 85 channels.
        let m = map_conv(&geom, &conv_inst(3, 3, 512, 512, 8)).unwrap();
        assert_eq!(m.channel_tiles, 512usize.div_ceil(256 / 3));
        assert!(m.lambdas_per_kernel_row <= geom.cols_per_subarray);
    }

    #[test]
    fn absurd_kernel_width_rejected() {
        let mut geom = Geometry::default();
        geom.cols_per_subarray = 4;
        assert!(map_conv(&geom, &conv_inst(5, 5, 1, 4, 16)).is_err());
    }

    #[test]
    fn feature_map_sharding_counts() {
        let geom = Geometry::default();
        // 32×32×16: one feature row = 32 × 16 = 512 elems = 2 cell rows.
        let m = map_conv(&geom, &conv_inst(3, 3, 16, 32, 32)).unwrap();
        assert_eq!(m.feature_rows_per_subarray, 256); // 512 rows / 2
        assert_eq!(m.subarrays_for_feature_map, 1);
    }
}
