//! Weight-stationary fully-connected mapping (paper §IV.D).
//!
//! The weight matrix distributes across subarrays (each cell row holds a
//! chunk of one output neuron's weight vector); the input activation
//! vector drives the MDLs. Long reductions chunk into row-vectors whose
//! same-λ partial products pair across subarrays of a group, so FC layers
//! keep the full in-waveguide accumulation parallelism.

use crate::cnn::layer::{Layer, LayerInstance};
use crate::config::Geometry;
use crate::error::{Error, Result};

/// Placement of one FC layer.
#[derive(Debug, Clone)]
pub struct FcMapping {
    /// Weight-vector chunks per output neuron (reduction tiling).
    pub chunks_per_neuron: usize,
    /// Output neurons whose weights fit in one subarray.
    pub neurons_per_subarray: usize,
    /// Subarrays needed to hold the full weight matrix.
    pub subarrays_for_weights: usize,
}

impl FcMapping {
    /// Subarrays this layer's stationary operands occupy — the resource
    /// footprint the occupancy accounting and the simulation timeline
    /// charge for the layer (weight-stationary: the weight matrix).
    pub fn footprint(&self) -> usize {
        self.subarrays_for_weights
    }
}

pub fn map_fc(geom: &Geometry, inst: &LayerInstance) -> Result<FcMapping> {
    let Layer::Fc { out, .. } = inst.layer else {
        return Err(Error::Mapping("map_fc on non-fc layer".into()));
    };
    let in_elems = inst.in_shape.elems() as usize;
    let chunks_per_neuron = in_elems.div_ceil(geom.cols_per_subarray).max(1);
    let rows_per_neuron = chunks_per_neuron; // one cell row per chunk
    let neurons_per_subarray = (geom.rows_per_subarray / rows_per_neuron).max(1);
    let subarrays_for_weights = out.div_ceil(neurons_per_subarray).max(1);
    // Capacity sanity: the whole matrix must fit in the memory.
    let total_subarrays = geom.total_subarrays();
    if subarrays_for_weights > total_subarrays {
        return Err(Error::Mapping(format!(
            "FC weight matrix needs {subarrays_for_weights} subarrays, \
             memory has {total_subarrays} — layer {}",
            inst.name
        )));
    }
    Ok(FcMapping {
        chunks_per_neuron,
        neurons_per_subarray,
        subarrays_for_weights,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layer::TensorShape;

    fn fc_inst(inf: usize, out: usize) -> LayerInstance {
        let layer = Layer::Fc { out, bias: true };
        let in_shape = TensorShape::new(1, 1, inf);
        let out_shape = layer.out_shape(in_shape).unwrap();
        LayerInstance {
            name: "t".into(),
            layer,
            in_shape,
            out_shape,
        }
    }

    #[test]
    fn small_fc_fits_one_subarray() {
        let geom = Geometry::default();
        let m = map_fc(&geom, &fc_inst(512, 100)).unwrap();
        assert_eq!(m.chunks_per_neuron, 2); // 512 / 256 λ
        assert_eq!(m.neurons_per_subarray, 256); // 512 rows / 2
        assert_eq!(m.subarrays_for_weights, 1);
    }

    #[test]
    fn vgg_fc1_spreads_subarrays() {
        let geom = Geometry::default();
        // 25088 → 4096: 98 chunks/neuron, 5 neurons/subarray.
        let m = map_fc(&geom, &fc_inst(25_088, 4_096)).unwrap();
        assert_eq!(m.chunks_per_neuron, 98);
        assert_eq!(m.neurons_per_subarray, 5);
        assert_eq!(m.subarrays_for_weights, 820);
    }

    #[test]
    fn impossible_fc_rejected() {
        let mut geom = Geometry::default();
        geom.subarray_rows = 2;
        geom.subarray_cols = 2;
        geom.subarray_groups = 2;
        geom.rows_per_subarray = 4;
        geom.cols_per_subarray = 4;
        assert!(map_fc(&geom, &fc_inst(1 << 14, 1 << 14)).is_err());
    }
}
