//! CNN → PIM mapping (paper §IV.D).
//!
//! - [`conv`] — input-stationary convolution mapping: feature-map rows
//!   shard across subarrays of a group, kernel rows become MDL wavelength
//!   vectors, stride walks reuse the stationary map.
//! - [`fc`] — weight-stationary fully-connected mapping: weight matrix
//!   rows distribute across subarrays, activations drive the MDLs.
//! - [`plan`] — turns a [`crate::cnn::Network`] into the
//!   [`crate::pim::LayerWork`] stream the PIM scheduler prices, with
//!   placement validation against the geometry.

pub mod conv;
pub mod fc;
pub mod plan;

pub use plan::{map_network, CapacityWarning, MappedNetwork, Occupancy};
