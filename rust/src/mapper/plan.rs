//! Network-level mapping: [`crate::cnn::Network`] → PIM workload stream.

use crate::cnn::graph::Network;
use crate::cnn::layer::Layer;
use crate::config::OpimaConfig;
use crate::error::Result;
use crate::mapper::{conv, fc};
use crate::pim::LayerWork;

/// A network mapped onto the PIM substrate.
#[derive(Debug, Clone)]
pub struct MappedNetwork {
    pub name: String,
    /// Per-compute-layer work items, in execution order.
    pub works: Vec<LayerWork>,
    /// Total subarrays touched by stationary operands (capacity check).
    pub subarrays_used: usize,
}

/// Map a network at a given operand bit-width (activations and weights
/// share the width in the paper's 4b/8b variants).
pub fn map_network(cfg: &OpimaConfig, net: &Network, bits: u32) -> Result<MappedNetwork> {
    let geom = &cfg.geometry;
    let mut works = Vec::new();
    let mut subarrays_used = 0usize;
    for inst in net.compute_layers() {
        match inst.layer {
            Layer::Conv { kh, .. } => {
                let m = conv::map_conv(geom, inst)?;
                subarrays_used += m.subarrays_for_feature_map;
                works.push(LayerWork {
                    name: inst.name.clone(),
                    macs: inst.macs(),
                    spatial_accum: if m.one_by_one { 1 } else { kh },
                    act_bits: bits,
                    weight_bits: bits,
                    out_elems: inst.out_shape.elems(),
                    weight_elems: inst.params(),
                });
            }
            Layer::Fc { .. } => {
                let m = fc::map_fc(geom, inst)?;
                subarrays_used += m.subarrays_for_weights;
                works.push(LayerWork {
                    name: inst.name.clone(),
                    macs: inst.macs(),
                    spatial_accum: inst.layer.spatial_accum(),
                    act_bits: bits,
                    weight_bits: bits,
                    out_elems: inst.out_shape.elems(),
                    weight_elems: inst.params(),
                });
            }
            _ => {}
        }
    }
    Ok(MappedNetwork {
        name: format!("{}_{}b", net.name, bits),
        works,
        subarrays_used,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model, ALL_MODELS};

    #[test]
    fn all_models_map_at_both_widths() {
        let cfg = OpimaConfig::paper();
        for m in ALL_MODELS {
            let net = build_model(m).unwrap();
            for bits in [4, 8] {
                let mapped = map_network(&cfg, &net, bits).unwrap();
                assert!(!mapped.works.is_empty(), "{}", m.name());
                // MACs preserved through the mapping.
                let total: u64 = mapped.works.iter().map(|w| w.macs).sum();
                assert_eq!(total, net.macs(), "{}", m.name());
            }
        }
    }

    #[test]
    fn one_by_one_layers_flagged() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::MobileNet).unwrap();
        let mapped = map_network(&cfg, &net, 4).unwrap();
        let serialized: u64 = mapped
            .works
            .iter()
            .filter(|w| w.spatial_accum == 1)
            .map(|w| w.macs)
            .sum();
        assert_eq!(serialized, net.one_by_one_macs());
    }

    #[test]
    fn capacity_fits_paper_memory() {
        // Every model's stationary operands must fit in the 16384
        // subarrays of the paper configuration.
        let cfg = OpimaConfig::paper();
        let total = cfg.geometry.banks * cfg.geometry.subarrays_per_bank();
        for m in ALL_MODELS {
            let net = build_model(m).unwrap();
            let mapped = map_network(&cfg, &net, 8).unwrap();
            assert!(
                mapped.subarrays_used <= total,
                "{} uses {} of {total}",
                m.name(),
                mapped.subarrays_used
            );
        }
    }

    #[test]
    fn bits_propagate() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let mapped = map_network(&cfg, &net, 8).unwrap();
        assert!(mapped.works.iter().all(|w| w.act_bits == 8 && w.weight_bits == 8));
        assert!(mapped.name.ends_with("_8b"));
    }
}
