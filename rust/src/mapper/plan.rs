//! Network-level mapping: [`crate::cnn::Network`] → PIM workload stream.

use std::fmt;

use crate::cnn::graph::Network;
use crate::cnn::layer::Layer;
use crate::config::{Geometry, OpimaConfig};
use crate::error::Result;
use crate::mapper::{conv, fc};
use crate::pim::LayerWork;

/// Subarray occupancy of a mapped network against a geometry's capacity.
///
/// This is the first-class form of what used to be a test-only
/// comparison: the registry and the `serve`/`analyze` CLI paths surface
/// over-capacity mappings as a structured [`CapacityWarning`] instead of
/// silently mapping, and the simulation timeline disables cross-image
/// pipelining when the footprints cannot all be resident at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Occupancy {
    /// Subarrays occupied by the network's stationary operands.
    pub subarrays_used: usize,
    /// Subarrays the geometry provides (`banks × subarrays_per_bank`).
    pub capacity: usize,
}

impl Occupancy {
    /// Whether the stationary operands fit in memory all at once.
    pub fn fits(&self) -> bool {
        self.subarrays_used <= self.capacity
    }

    /// Fraction of the memory's subarrays occupied (may exceed 1).
    pub fn utilization(&self) -> f64 {
        self.subarrays_used as f64 / self.capacity.max(1) as f64
    }

    /// Structured over-capacity warning, `None` when the mapping fits.
    pub fn warning_for(&self, network: &str) -> Option<CapacityWarning> {
        if self.fits() {
            None
        } else {
            Some(CapacityWarning {
                network: network.to_string(),
                subarrays_used: self.subarrays_used,
                capacity: self.capacity,
            })
        }
    }
}

/// A mapped network whose stationary operands exceed the memory's
/// subarray capacity: it still maps (layers time-share the memory), but
/// cross-image pipelining is unsound and serving it degrades latency.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CapacityWarning {
    pub network: String,
    pub subarrays_used: usize,
    pub capacity: usize,
}

impl fmt::Display for CapacityWarning {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: stationary operands need {} subarrays but the memory has {} \
             ({:.1}% over capacity) — layers time-share the memory and the \
             batch timeline falls back to serial execution",
            self.network,
            self.subarrays_used,
            self.capacity,
            100.0 * (self.subarrays_used as f64 / self.capacity.max(1) as f64 - 1.0)
        )
    }
}

/// A network mapped onto the PIM substrate.
#[derive(Debug, Clone)]
pub struct MappedNetwork {
    pub name: String,
    /// Per-compute-layer work items, in execution order. Each carries
    /// its own subarray footprint (`LayerWork::subarrays`).
    pub works: Vec<LayerWork>,
    /// Total subarrays touched by stationary operands (capacity check).
    pub subarrays_used: usize,
}

impl MappedNetwork {
    /// Occupancy of this mapping against a geometry's subarray capacity.
    pub fn occupancy(&self, geom: &Geometry) -> Occupancy {
        Occupancy {
            subarrays_used: self.subarrays_used,
            capacity: geom.total_subarrays(),
        }
    }
}

/// Map a network at a given operand bit-width (activations and weights
/// share the width in the paper's 4b/8b variants).
pub fn map_network(cfg: &OpimaConfig, net: &Network, bits: u32) -> Result<MappedNetwork> {
    let geom = &cfg.geometry;
    let mut works = Vec::new();
    let mut subarrays_used = 0usize;
    for inst in net.compute_layers() {
        match inst.layer {
            Layer::Conv { kh, .. } => {
                let m = conv::map_conv(geom, inst)?;
                subarrays_used += m.footprint();
                works.push(LayerWork {
                    name: inst.name.clone(),
                    macs: inst.macs(),
                    spatial_accum: if m.one_by_one { 1 } else { kh },
                    act_bits: bits,
                    weight_bits: bits,
                    out_elems: inst.out_shape.elems(),
                    weight_elems: inst.params(),
                    subarrays: m.footprint(),
                });
            }
            Layer::Fc { .. } => {
                let m = fc::map_fc(geom, inst)?;
                subarrays_used += m.footprint();
                works.push(LayerWork {
                    name: inst.name.clone(),
                    macs: inst.macs(),
                    spatial_accum: inst.layer.spatial_accum(),
                    act_bits: bits,
                    weight_bits: bits,
                    out_elems: inst.out_shape.elems(),
                    weight_elems: inst.params(),
                    subarrays: m.footprint(),
                });
            }
            _ => {}
        }
    }
    Ok(MappedNetwork {
        name: format!("{}_{}b", net.name, bits),
        works,
        subarrays_used,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::models::{build_model, Model, ALL_MODELS};

    #[test]
    fn all_models_map_at_both_widths() {
        let cfg = OpimaConfig::paper();
        for m in ALL_MODELS {
            let net = build_model(m).unwrap();
            for bits in [4, 8] {
                let mapped = map_network(&cfg, &net, bits).unwrap();
                assert!(!mapped.works.is_empty(), "{}", m.name());
                // MACs preserved through the mapping.
                let total: u64 = mapped.works.iter().map(|w| w.macs).sum();
                assert_eq!(total, net.macs(), "{}", m.name());
            }
        }
    }

    #[test]
    fn one_by_one_layers_flagged() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::MobileNet).unwrap();
        let mapped = map_network(&cfg, &net, 4).unwrap();
        let serialized: u64 = mapped
            .works
            .iter()
            .filter(|w| w.spatial_accum == 1)
            .map(|w| w.macs)
            .sum();
        assert_eq!(serialized, net.one_by_one_macs());
    }

    #[test]
    fn per_layer_footprints_sum_to_total() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let mapped = map_network(&cfg, &net, 4).unwrap();
        assert!(mapped.works.iter().all(|w| w.subarrays >= 1));
        let sum: usize = mapped.works.iter().map(|w| w.subarrays).sum();
        assert_eq!(sum, mapped.subarrays_used);
    }

    #[test]
    fn capacity_fits_paper_memory() {
        // Every model's stationary operands must fit in the 16384
        // subarrays of the paper configuration — now asserted through
        // the first-class occupancy API.
        let cfg = OpimaConfig::paper();
        for m in ALL_MODELS {
            let net = build_model(m).unwrap();
            let mapped = map_network(&cfg, &net, 8).unwrap();
            let occ = mapped.occupancy(&cfg.geometry);
            assert_eq!(occ.capacity, 16_384);
            assert!(
                occ.fits(),
                "{} uses {} of {}",
                m.name(),
                occ.subarrays_used,
                occ.capacity
            );
            assert!(occ.warning_for(&mapped.name).is_none());
            assert!(occ.utilization() <= 1.0);
        }
    }

    #[test]
    fn over_capacity_mapping_warns() {
        // A starved geometry still maps (conv footprints have no hard
        // capacity error) but reports a structured warning.
        let mut cfg = OpimaConfig::paper();
        cfg.geometry.subarray_rows = 2;
        cfg.geometry.subarray_cols = 2;
        cfg.geometry.subarray_groups = 2;
        cfg.geometry.banks = 1;
        let net = build_model(Model::ResNet18).unwrap();
        let mapped = map_network(&cfg, &net, 8).unwrap();
        let occ = mapped.occupancy(&cfg.geometry);
        assert!(!occ.fits());
        assert!(occ.utilization() > 1.0);
        let w = occ.warning_for(&mapped.name).unwrap();
        assert_eq!(w.capacity, 4);
        assert!(w.to_string().contains("resnet18_8b"));
    }

    #[test]
    fn bits_propagate() {
        let cfg = OpimaConfig::paper();
        let net = build_model(Model::ResNet18).unwrap();
        let mapped = map_network(&cfg, &net, 8).unwrap();
        assert!(mapped.works.iter().all(|w| w.act_bits == 8 && w.weight_bits == 8));
        assert!(mapped.name.ends_with("_8b"));
    }
}
