//! Physical-address → (bank, subarray, row, column) decomposition.
//!
//! OPIMA keeps a DRAM-like addressable organization (paper §II.B) so that
//! "modern memory addressing schemes and memory controllers" can interface
//! with it. We use a bank-interleaved cell-row mapping: consecutive cell
//! rows rotate across banks so sequential streams exploit MDM-parallel
//! banks, then walk subarray columns, then subarray rows.

use crate::config::Geometry;
use crate::error::{Error, Result};

/// A fully decoded cell location.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodedAddr {
    pub bank: usize,
    /// Subarray row within the bank's grid.
    pub subarray_row: usize,
    /// Subarray column within the bank's grid.
    pub subarray_col: usize,
    /// Cell row within the subarray.
    pub row: usize,
    /// First cell column of the access within the subarray.
    pub col: usize,
}

/// Maps byte addresses to cell coordinates.
#[derive(Debug, Clone)]
pub struct AddressMap {
    geom: Geometry,
    /// Cells per addressable row segment (one subarray row).
    cells_per_row: usize,
}

impl AddressMap {
    pub fn new(geom: &Geometry) -> Self {
        Self {
            geom: geom.clone(),
            cells_per_row: geom.cols_per_subarray,
        }
    }

    /// Total capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        self.geom.capacity_bytes()
    }

    /// Number of bytes stored per subarray cell row.
    pub fn bytes_per_row(&self) -> usize {
        self.cells_per_row * self.geom.bits_per_cell as usize / 8
    }

    /// Convert a byte address to (cell-row index, cell offset within row).
    fn row_of(&self, addr: u64) -> Result<(u64, usize)> {
        if addr >= self.capacity_bytes() {
            return Err(Error::AddressRange {
                addr,
                capacity: self.capacity_bytes(),
            });
        }
        let cell_index = addr * 8 / self.geom.bits_per_cell as u64;
        Ok((
            cell_index / self.cells_per_row as u64,
            (cell_index % self.cells_per_row as u64) as usize,
        ))
    }

    /// Decode a byte address to a cell location.
    ///
    /// Row-interleave order: bank → subarray_col → subarray_row → row.
    pub fn decode(&self, addr: u64) -> Result<DecodedAddr> {
        let (global_row, col) = self.row_of(addr)?;
        let g = &self.geom;
        let bank = (global_row % g.banks as u64) as usize;
        let r1 = global_row / g.banks as u64;
        let subarray_col = (r1 % g.subarray_cols as u64) as usize;
        let r2 = r1 / g.subarray_cols as u64;
        let subarray_row = (r2 % g.subarray_rows as u64) as usize;
        let row = (r2 / g.subarray_rows as u64) as usize;
        debug_assert!(row < g.rows_per_subarray);
        Ok(DecodedAddr {
            bank,
            subarray_row,
            subarray_col,
            row,
            col,
        })
    }

    /// Inverse of [`decode`] for col-0 addresses (row granularity).
    pub fn encode_row(&self, d: &DecodedAddr) -> u64 {
        let g = &self.geom;
        let global_row = ((d.row * g.subarray_rows + d.subarray_row) * g.subarray_cols
            + d.subarray_col) as u64
            * g.banks as u64
            + d.bank as u64;
        global_row * self.bytes_per_row() as u64
    }

    /// Split a byte range into per-cell-row segments: (addr, cells) pairs.
    pub fn row_segments(&self, addr: u64, len: u64) -> Result<Vec<(DecodedAddr, usize)>> {
        if len == 0 {
            return Ok(vec![]);
        }
        let end = addr
            .checked_add(len)
            .filter(|&e| e <= self.capacity_bytes())
            .ok_or(Error::AddressRange {
                addr: addr.saturating_add(len),
                capacity: self.capacity_bytes(),
            })?;
        let bits = self.geom.bits_per_cell as u64;
        let first_cell = addr * 8 / bits;
        let last_cell = (end * 8).div_ceil(bits) - 1;
        let mut segments = Vec::new();
        let mut cell = first_cell;
        while cell <= last_cell {
            let row_end = (cell / self.cells_per_row as u64 + 1) * self.cells_per_row as u64;
            let seg_end = row_end.min(last_cell + 1);
            let byte_addr = cell * bits / 8;
            segments.push((self.decode(byte_addr)?, (seg_end - cell) as usize));
            cell = seg_end;
        }
        Ok(segments)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> AddressMap {
        AddressMap::new(&Geometry::default())
    }

    #[test]
    fn decode_zero() {
        let d = map().decode(0).unwrap();
        assert_eq!(
            d,
            DecodedAddr {
                bank: 0,
                subarray_row: 0,
                subarray_col: 0,
                row: 0,
                col: 0
            }
        );
    }

    #[test]
    fn consecutive_rows_interleave_banks() {
        let m = map();
        let bpr = m.bytes_per_row() as u64;
        for i in 0..8u64 {
            let d = m.decode(i * bpr).unwrap();
            assert_eq!(d.bank, (i % 4) as usize, "row {i}");
            assert_eq!(d.col, 0);
        }
    }

    #[test]
    fn decode_encode_roundtrip() {
        let m = map();
        let bpr = m.bytes_per_row() as u64;
        for i in [0u64, 1, 5, 63, 4096, 123_456, 8_000_000] {
            let addr = i * bpr;
            if addr >= m.capacity_bytes() {
                continue;
            }
            let d = m.decode(addr).unwrap();
            assert_eq!(m.encode_row(&d), addr, "row {i}");
        }
    }

    #[test]
    fn decode_rejects_out_of_range() {
        let m = map();
        assert!(m.decode(m.capacity_bytes()).is_err());
        assert!(m.decode(u64::MAX).is_err());
    }

    #[test]
    fn all_fields_within_bounds_across_capacity() {
        let m = map();
        let g = Geometry::default();
        let step = m.capacity_bytes() / 997; // prime-ish stride
        let mut addr = 0;
        while addr < m.capacity_bytes() {
            let d = m.decode(addr).unwrap();
            assert!(d.bank < g.banks);
            assert!(d.subarray_row < g.subarray_rows);
            assert!(d.subarray_col < g.subarray_cols);
            assert!(d.row < g.rows_per_subarray);
            assert!(d.col < g.cols_per_subarray);
            addr += step;
        }
    }

    #[test]
    fn row_segments_cover_range() {
        let m = map();
        // 300 bytes starting mid-row: 4 bits/cell → 600 cells ⇒ 3+ segments
        // over 256-cell rows.
        let segs = m.row_segments(100, 300).unwrap();
        let total: usize = segs.iter().map(|(_, n)| n).sum();
        assert!(total >= 600, "cells covered = {total}");
        assert!(segs.len() >= 3);
        // Starting col of first segment reflects the offset.
        assert_eq!(segs[0].0.col, 200); // 100 B * 2 cells/B % 256
    }

    #[test]
    fn row_segments_empty_and_overflow() {
        let m = map();
        assert!(m.row_segments(0, 0).unwrap().is_empty());
        assert!(m.row_segments(m.capacity_bytes() - 4, 8).is_err());
    }
}
