//! Bank state: GST routing, busy windows, PIM row reservations.
//!
//! Each bank owns a GST-switch column that routes the external WDM signal
//! to exactly one subarray row at a time for memory traffic (paper
//! §IV.C.2); switching rows costs a reconfiguration delay. PIM work does
//! not use this path — it runs on per-subarray MDL arrays — but the
//! subarray rows lent to PIM (one per group) are unavailable to memory
//! commands while reserved.

use crate::config::Geometry;
use crate::error::{Error, Result};
use crate::memory::timing::GST_SWITCH_RECONFIG_NS;
use crate::util::units::Nanos;

/// Per-bank dynamic state.
#[derive(Debug, Clone)]
pub struct BankState {
    /// Which subarray row the GST switch column currently targets.
    pub routed_row: Option<usize>,
    /// Time until which the bank datapath is busy.
    pub busy_until_ns: Nanos,
    /// Subarray rows currently reserved by the PIM engine.
    pub pim_reserved: Vec<bool>,
    subarray_rows: usize,
}

impl BankState {
    pub fn new(geom: &Geometry) -> Self {
        Self {
            routed_row: None,
            busy_until_ns: Nanos::ZERO,
            pim_reserved: vec![false; geom.subarray_rows],
            subarray_rows: geom.subarray_rows,
        }
    }

    /// Number of subarray rows usable by memory traffic right now.
    pub fn rows_available(&self) -> usize {
        self.pim_reserved.iter().filter(|r| !**r).count()
    }

    /// Reserve a subarray row for PIM. Errors if already reserved.
    pub fn reserve(&mut self, row: usize) -> Result<()> {
        if row >= self.subarray_rows {
            return Err(Error::Command(format!(
                "subarray row {row} out of range (0..{})",
                self.subarray_rows
            )));
        }
        if self.pim_reserved[row] {
            return Err(Error::Command(format!("subarray row {row} already reserved")));
        }
        self.pim_reserved[row] = true;
        Ok(())
    }

    /// Release a PIM reservation.
    pub fn release(&mut self, row: usize) -> Result<()> {
        if row >= self.subarray_rows || !self.pim_reserved[row] {
            return Err(Error::Command(format!("subarray row {row} not reserved")));
        }
        self.pim_reserved[row] = false;
        Ok(())
    }

    /// Route the GST switch column to `row`, returning the earliest time
    /// the datapath is usable given current routing and busy window.
    pub fn route_to(&mut self, row: usize, now_ns: Nanos) -> Result<Nanos> {
        if row >= self.subarray_rows {
            return Err(Error::Command(format!("subarray row {row} out of range")));
        }
        if self.pim_reserved[row] {
            return Err(Error::Command(format!(
                "subarray row {row} is lent to the PIM engine"
            )));
        }
        let start = now_ns.max(self.busy_until_ns);
        let ready = if self.routed_row == Some(row) {
            start
        } else {
            self.routed_row = Some(row);
            start + GST_SWITCH_RECONFIG_NS
        };
        Ok(ready)
    }

    /// Mark the datapath busy until `until_ns`.
    pub fn occupy(&mut self, until_ns: Nanos) {
        self.busy_until_ns = self.busy_until_ns.max(until_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bank() -> BankState {
        BankState::new(&Geometry::default())
    }

    #[test]
    fn routing_same_row_is_free_different_row_costs() {
        let mut b = bank();
        let t0 = b.route_to(5, Nanos::ZERO).unwrap();
        assert_eq!(t0, GST_SWITCH_RECONFIG_NS);
        b.occupy(t0);
        let t1 = b.route_to(5, t0).unwrap();
        assert_eq!(t1, t0, "same-row access needs no reconfig");
        let t2 = b.route_to(6, t1).unwrap();
        assert_eq!(t2, t1 + GST_SWITCH_RECONFIG_NS);
    }

    #[test]
    fn reservations_block_memory_routing() {
        let mut b = bank();
        b.reserve(10).unwrap();
        assert!(b.route_to(10, Nanos::ZERO).is_err());
        assert_eq!(b.rows_available(), 63);
        b.release(10).unwrap();
        assert!(b.route_to(10, Nanos::ZERO).is_ok());
        assert_eq!(b.rows_available(), 64);
    }

    #[test]
    fn double_reserve_and_bad_release_rejected() {
        let mut b = bank();
        b.reserve(3).unwrap();
        assert!(b.reserve(3).is_err());
        assert!(b.release(4).is_err());
        assert!(b.reserve(999).is_err());
    }

    /// The GST reconfiguration penalty is charged exactly once per row
    /// switch: a burst of same-row accesses after the switch pays it on
    /// the first access only.
    #[test]
    fn gst_penalty_once_per_switch_never_on_bursts() {
        let mut b = bank();
        let mut now = Nanos::ZERO;
        let mut switches = 0u32;
        let mut expected = Nanos::ZERO;
        for row in [3usize, 3, 3, 7, 7, 3, 3, 3, 7] {
            let prev = b.routed_row;
            let ready = b.route_to(row, now).unwrap();
            if prev != Some(row) {
                switches += 1;
                expected = now.max(b.busy_until_ns) + GST_SWITCH_RECONFIG_NS;
            } else {
                expected = now.max(b.busy_until_ns);
            }
            assert_eq!(ready, expected, "row {row} at {now}");
            b.occupy(ready + Nanos::new(5.0));
            now = ready + Nanos::new(5.0);
        }
        assert_eq!(switches, 4, "3→(first)3, 3→7, 7→3, 3→7");
    }

    /// Same-row bursts never pay the penalty even across idle gaps —
    /// the GST switch is non-volatile (no refresh to re-route around).
    #[test]
    fn same_row_burst_across_idle_gap_is_penalty_free() {
        let mut b = bank();
        let t0 = b.route_to(9, Nanos::ZERO).unwrap();
        b.occupy(t0);
        let later = t0 + Nanos::new(1e6);
        let t1 = b.route_to(9, later).unwrap();
        assert_eq!(t1, later, "idle gap must not re-trigger reconfiguration");
    }

    #[test]
    fn busy_window_serializes() {
        let mut b = bank();
        let t0 = b.route_to(1, Nanos::ZERO).unwrap();
        b.occupy(t0 + Nanos::new(100.0));
        let t1 = b.route_to(1, Nanos::ZERO).unwrap();
        assert_eq!(t1, t0 + Nanos::new(100.0));
    }
}
