//! OPCM cell and subarray storage.
//!
//! A cell stores one of 2^bits transmission levels (16 for the paper's
//! 4-bit MLC). Storage is sparse per subarray: a fully populated paper
//! configuration holds 2³¹ cells, so subarray backing vectors are
//! allocated on first touch. Endurance is tracked per subarray (GST
//! crystallization cycles are finite; the simulator reports wear).

use std::collections::HashMap;

use crate::config::Geometry;

/// Sparse cell storage for one bank.
#[derive(Debug, Default)]
pub struct CellStore {
    /// (subarray_row, subarray_col) → cell levels, row-major.
    subarrays: HashMap<(usize, usize), Vec<u8>>,
    rows_per_subarray: usize,
    cols_per_subarray: usize,
    /// Total cell writes (endurance proxy).
    pub write_count: u64,
}

impl CellStore {
    pub fn new(geom: &Geometry) -> Self {
        Self {
            subarrays: HashMap::new(),
            rows_per_subarray: geom.rows_per_subarray,
            cols_per_subarray: geom.cols_per_subarray,
            write_count: 0,
        }
    }

    fn backing(&mut self, sr: usize, sc: usize) -> &mut Vec<u8> {
        let (r, c) = (self.rows_per_subarray, self.cols_per_subarray);
        self.subarrays
            .entry((sr, sc))
            .or_insert_with(|| vec![0u8; r * c])
    }

    /// Read `n` consecutive cell levels starting at (row, col).
    pub fn read(&self, sr: usize, sc: usize, row: usize, col: usize, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        self.read_into(sr, sc, row, col, n, &mut out);
        out
    }

    /// Allocation-free read: append `n` levels into `out`.
    pub fn read_into(
        &self,
        sr: usize,
        sc: usize,
        row: usize,
        col: usize,
        n: usize,
        out: &mut Vec<u8>,
    ) {
        debug_assert!(col + n <= self.cols_per_subarray);
        match self.subarrays.get(&(sr, sc)) {
            Some(cells) => {
                let start = row * self.cols_per_subarray + col;
                out.extend_from_slice(&cells[start..start + n]);
            }
            None => out.resize(out.len() + n, 0), // untouched reads as erased
        }
    }

    /// Write consecutive cell levels starting at (row, col).
    pub fn write(&mut self, sr: usize, sc: usize, row: usize, col: usize, levels: &[u8]) {
        debug_assert!(col + levels.len() <= self.cols_per_subarray);
        let cols = self.cols_per_subarray;
        let cells = self.backing(sr, sc);
        let start = row * cols + col;
        cells[start..start + levels.len()].copy_from_slice(levels);
        self.write_count += levels.len() as u64;
    }

    /// Number of subarrays with allocated (touched) backing.
    pub fn touched_subarrays(&self) -> usize {
        self.subarrays.len()
    }
}

/// Pack bytes into cell levels (little-endian nibble order for 4-bit cells).
pub fn bytes_to_levels(bytes: &[u8], bits_per_cell: u32) -> Vec<u8> {
    assert!(matches!(bits_per_cell, 1 | 2 | 4 | 8));
    let per_byte = (8 / bits_per_cell) as usize;
    let mask = ((1u16 << bits_per_cell) - 1) as u8;
    let mut levels = Vec::with_capacity(bytes.len() * per_byte);
    for &b in bytes {
        for i in 0..per_byte {
            levels.push((b >> (i as u32 * bits_per_cell)) & mask);
        }
    }
    levels
}

/// Inverse of [`bytes_to_levels`].
pub fn levels_to_bytes(levels: &[u8], bits_per_cell: u32) -> Vec<u8> {
    assert!(matches!(bits_per_cell, 1 | 2 | 4 | 8));
    let per_byte = (8 / bits_per_cell) as usize;
    assert_eq!(levels.len() % per_byte, 0);
    levels
        .chunks(per_byte)
        .map(|chunk| {
            chunk
                .iter()
                .enumerate()
                .fold(0u8, |acc, (i, &lv)| acc | (lv << (i as u32 * bits_per_cell)))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn untouched_reads_zero() {
        let store = CellStore::new(&Geometry::default());
        assert_eq!(store.read(3, 7, 100, 10, 4), vec![0, 0, 0, 0]);
        assert_eq!(store.touched_subarrays(), 0);
    }

    #[test]
    fn write_then_read() {
        let mut store = CellStore::new(&Geometry::default());
        store.write(1, 2, 5, 10, &[3, 15, 0, 7]);
        assert_eq!(store.read(1, 2, 5, 10, 4), vec![3, 15, 0, 7]);
        assert_eq!(store.read(1, 2, 5, 9, 1), vec![0]);
        assert_eq!(store.touched_subarrays(), 1);
        assert_eq!(store.write_count, 4);
    }

    #[test]
    fn levels_roundtrip_4bit() {
        let bytes = vec![0xAB, 0x00, 0xFF, 0x5C];
        let levels = bytes_to_levels(&bytes, 4);
        assert_eq!(levels, vec![0xB, 0xA, 0x0, 0x0, 0xF, 0xF, 0xC, 0x5]);
        assert_eq!(levels_to_bytes(&levels, 4), bytes);
    }

    #[test]
    fn levels_roundtrip_all_densities() {
        let bytes: Vec<u8> = (0..=255).collect();
        for bits in [1u32, 2, 4, 8] {
            let levels = bytes_to_levels(&bytes, bits);
            assert_eq!(levels.len(), bytes.len() * (8 / bits as usize));
            assert!(levels.iter().all(|&l| (l as u16) < (1 << bits)));
            assert_eq!(levels_to_bytes(&levels, bits), bytes);
        }
    }
}
