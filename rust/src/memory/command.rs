//! Memory command descriptors.

use crate::util::units::Nanos;

/// What a command does.
#[derive(Debug, Clone, PartialEq)]
pub enum CommandKind {
    /// Read `len` bytes starting at `addr`.
    Read { addr: u64, len: u64 },
    /// Write the payload starting at `addr`.
    Write { addr: u64, data: Vec<u8> },
}

/// A queued memory command.
#[derive(Debug, Clone)]
pub struct MemCommand {
    pub id: u64,
    pub kind: CommandKind,
    /// Issue timestamp.
    pub issued_ns: Nanos,
}

/// Completion record for a command.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// When the command finished.
    pub finished_ns: Nanos,
    /// Total latency including queueing.
    pub latency_ns: Nanos,
    /// Energy consumed (pJ).
    pub energy_pj: f64,
    /// Data returned (reads only).
    pub data: Option<Vec<u8>>,
}
