//! Memory command descriptors.

use crate::util::units::Nanos;

/// What a command does.
#[derive(Debug, Clone, PartialEq)]
pub enum CommandKind {
    /// Read `len` bytes starting at `addr`.
    Read { addr: u64, len: u64 },
    /// Write the payload starting at `addr`.
    Write { addr: u64, data: Vec<u8> },
}

/// A queued memory command.
#[derive(Debug, Clone)]
pub struct MemCommand {
    pub id: u64,
    pub kind: CommandKind,
    /// Issue timestamp.
    pub issued_ns: Nanos,
}

/// One step of a command-level writeback sequence
/// ([`crate::memory::writeback`]): a layer's activation writeback
/// decomposes into GST route reconfigurations, MLC program trains and a
/// final staging drain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WbCommandKind {
    /// Reconfigure `bank`'s GST switch column to `row` (charged only
    /// when the bank was routed elsewhere; may prefetch under the tail
    /// of the bank's previous train).
    Route { bank: usize, row: u64 },
    /// One µs-class MLC program train on `bank`, row `row`. Trains hold
    /// the bank datapath exclusively — per-bank windows never overlap.
    Write { bank: usize, row: u64 },
    /// E-O-E staging drain after the job's last train.
    Settle,
}

/// A traced writeback command with its scheduled window (absolute
/// simulated time). Controllers record these only when built with
/// tracing enabled; tests assert busy-window and capacity invariants
/// over the trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WbCommand {
    /// Id of the [`crate::memory::writeback::WbJob`] this step belongs to.
    pub job: u64,
    pub kind: WbCommandKind,
    pub start_ns: Nanos,
    pub end_ns: Nanos,
}

/// Completion record for a command.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// When the command finished.
    pub finished_ns: Nanos,
    /// Total latency including queueing.
    pub latency_ns: Nanos,
    /// Energy consumed (pJ).
    pub energy_pj: f64,
    /// Data returned (reads only).
    pub data: Option<Vec<u8>>,
}
