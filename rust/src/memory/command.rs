//! Memory command descriptors.

/// What a command does.
#[derive(Debug, Clone, PartialEq)]
pub enum CommandKind {
    /// Read `len` bytes starting at `addr`.
    Read { addr: u64, len: u64 },
    /// Write the payload starting at `addr`.
    Write { addr: u64, data: Vec<u8> },
}

/// A queued memory command.
#[derive(Debug, Clone)]
pub struct MemCommand {
    pub id: u64,
    pub kind: CommandKind,
    /// Issue timestamp (ns).
    pub issued_ns: f64,
}

/// Completion record for a command.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// When the command finished (ns).
    pub finished_ns: f64,
    /// Total latency including queueing (ns).
    pub latency_ns: f64,
    /// Energy consumed (pJ).
    pub energy_pj: f64,
    /// Data returned (reads only).
    pub data: Option<Vec<u8>>,
}
