//! The memory controller: command execution over banks + cell stores.
//!
//! Functionally correct (writes are readable) and cycle-approximate
//! (per-bank busy windows, GST routing penalties, row-segmented bursts).
//! This is the component the paper replaced NVMain 2.0 with; it also
//! exposes the PIM reservation interface used by the PIM engine.

use crate::config::OpimaConfig;
use crate::error::{Error, Result};
use crate::memory::address::AddressMap;
use crate::memory::bank::BankState;
use crate::memory::cell::{bytes_to_levels, levels_to_bytes, CellStore};
use crate::memory::command::{CommandKind, Completion, MemCommand};
use crate::memory::timing::{read_latency_ns, write_latency_ns};
use crate::util::units::Nanos;

/// Aggregate statistics.
#[derive(Debug, Clone, Default)]
pub struct MemStats {
    pub reads: u64,
    pub writes: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_energy_pj: f64,
    pub write_energy_pj: f64,
    pub busy_ns: Nanos,
}

impl MemStats {
    pub fn total_energy_pj(&self) -> f64 {
        self.read_energy_pj + self.write_energy_pj
    }
}

/// The OPCM main-memory controller.
pub struct MemoryController {
    cfg: OpimaConfig,
    map: AddressMap,
    banks: Vec<BankState>,
    stores: Vec<CellStore>,
    stats: MemStats,
    next_id: u64,
    now_ns: Nanos,
}

impl MemoryController {
    pub fn new(cfg: &OpimaConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self {
            map: AddressMap::new(&cfg.geometry),
            banks: (0..cfg.geometry.banks)
                .map(|_| BankState::new(&cfg.geometry))
                .collect(),
            stores: (0..cfg.geometry.banks)
                .map(|_| CellStore::new(&cfg.geometry))
                .collect(),
            cfg: cfg.clone(),
            stats: MemStats::default(),
            next_id: 0,
            now_ns: Nanos::ZERO,
        })
    }

    pub fn config(&self) -> &OpimaConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &MemStats {
        &self.stats
    }

    pub fn now_ns(&self) -> Nanos {
        self.now_ns
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.map.capacity_bytes()
    }

    /// Advance the wall clock (e.g. between request arrivals).
    pub fn advance_to(&mut self, t_ns: Nanos) {
        self.now_ns = self.now_ns.max(t_ns);
    }

    fn alloc_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Issue a read command and execute it to completion.
    pub fn read(&mut self, addr: u64, len: u64) -> Result<Completion> {
        let cmd = MemCommand {
            id: self.alloc_id(),
            kind: CommandKind::Read { addr, len },
            issued_ns: self.now_ns,
        };
        self.execute(cmd)
    }

    /// Issue a write command and execute it to completion.
    pub fn write(&mut self, addr: u64, data: &[u8]) -> Result<Completion> {
        let cmd = MemCommand {
            id: self.alloc_id(),
            kind: CommandKind::Write {
                addr,
                data: data.to_vec(),
            },
            issued_ns: self.now_ns,
        };
        self.execute(cmd)
    }

    /// Reserve one subarray row per group in every bank for PIM use
    /// (paper §IV.C.2: "one row of subarrays per group can be employed
    /// for PIM at a time"). Returns the reserved row indices.
    pub fn reserve_pim_rows(&mut self) -> Result<Vec<usize>> {
        let per_group = self.cfg.geometry.subarray_rows_per_group();
        let rows: Vec<usize> = (0..self.cfg.geometry.subarray_groups)
            .map(|g| g * per_group) // first row of each group
            .collect();
        for bank in &mut self.banks {
            for &r in &rows {
                bank.reserve(r)?;
            }
        }
        Ok(rows)
    }

    /// Release previously reserved PIM rows.
    pub fn release_pim_rows(&mut self, rows: &[usize]) -> Result<()> {
        for bank in &mut self.banks {
            for &r in rows {
                bank.release(r)?;
            }
        }
        Ok(())
    }

    /// Memory rows (per bank) available for ordinary traffic.
    pub fn rows_available(&self) -> usize {
        self.banks.first().map(|b| b.rows_available()).unwrap_or(0)
    }

    fn execute(&mut self, cmd: MemCommand) -> Result<Completion> {
        match cmd.kind.clone() {
            CommandKind::Read { addr, len } => self.do_read(cmd, addr, len),
            CommandKind::Write { addr, data } => self.do_write(cmd, addr, &data),
        }
    }

    fn do_read(&mut self, cmd: MemCommand, addr: u64, len: u64) -> Result<Completion> {
        if len == 0 {
            return Err(Error::Command("zero-length read".into()));
        }
        let bits = self.cfg.geometry.bits_per_cell;
        let segments = self.map.row_segments(addr, len)?;
        let mut levels: Vec<u8> = Vec::with_capacity((len as usize * 8).div_ceil(bits as usize));
        let mut finish = cmd.issued_ns;
        let mut energy = 0.0;
        for (d, cells) in &segments {
            let ready = self.banks[d.bank].route_to(d.subarray_row, cmd.issued_ns)?;
            let lat = read_latency_ns(&self.cfg.timing, *cells);
            let done = ready + lat;
            self.banks[d.bank].occupy(done);
            finish = finish.max(done);
            energy += self.cfg.energy.opcm_read_pj * *cells as f64;
            self.stores[d.bank].read_into(
                d.subarray_row,
                d.subarray_col,
                d.row,
                d.col,
                *cells,
                &mut levels,
            );
        }
        let mut bytes = levels_to_bytes(&levels, bits);
        // Trim to the requested window (segments are cell-aligned) without
        // re-allocating: aligned reads (the common case) just truncate.
        let cell_offset_bytes = (addr * 8 % bits as u64) as usize / 8; // 0 for aligned
        if cell_offset_bytes > 0 {
            bytes.drain(..cell_offset_bytes);
        }
        bytes.truncate(len as usize);
        let data = bytes;

        self.stats.reads += 1;
        self.stats.bytes_read += len;
        self.stats.read_energy_pj += energy;
        self.stats.busy_ns += finish - cmd.issued_ns;
        self.now_ns = self.now_ns.max(finish);
        Ok(Completion {
            id: cmd.id,
            finished_ns: finish,
            latency_ns: finish - cmd.issued_ns,
            energy_pj: energy,
            data: Some(data),
        })
    }

    fn do_write(&mut self, cmd: MemCommand, addr: u64, data: &[u8]) -> Result<Completion> {
        if data.is_empty() {
            return Err(Error::Command("zero-length write".into()));
        }
        let bits = self.cfg.geometry.bits_per_cell;
        if (addr * 8) % bits as u64 != 0 {
            return Err(Error::Command("write not cell-aligned".into()));
        }
        let segments = self.map.row_segments(addr, data.len() as u64)?;
        let levels = bytes_to_levels(data, bits);
        let mut offset = 0usize;
        let mut finish = cmd.issued_ns;
        let mut energy = 0.0;
        for (d, cells) in &segments {
            let ready = self.banks[d.bank].route_to(d.subarray_row, cmd.issued_ns)?;
            let lat =
                write_latency_ns(&self.cfg.timing, *cells, self.cfg.geometry.cols_per_subarray);
            let done = ready + lat;
            self.banks[d.bank].occupy(done);
            finish = finish.max(done);
            energy += self.cfg.energy.opcm_write_pj * *cells as f64;
            let chunk = &levels[offset..offset + *cells];
            self.stores[d.bank].write(d.subarray_row, d.subarray_col, d.row, d.col, chunk);
            offset += *cells;
        }
        self.stats.writes += 1;
        self.stats.bytes_written += data.len() as u64;
        self.stats.write_energy_pj += energy;
        self.stats.busy_ns += finish - cmd.issued_ns;
        self.now_ns = self.now_ns.max(finish);
        Ok(Completion {
            id: cmd.id,
            finished_ns: finish,
            latency_ns: finish - cmd.issued_ns,
            energy_pj: energy,
            data: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> MemoryController {
        MemoryController::new(&OpimaConfig::paper()).unwrap()
    }

    #[test]
    fn write_read_roundtrip() {
        let mut c = ctl();
        let data: Vec<u8> = (0..=255).collect();
        c.write(4096, &data).unwrap();
        let r = c.read(4096, 256).unwrap();
        assert!(r.finished_ns >= r.latency_ns);
        assert_eq!(r.data.unwrap(), data);
    }

    #[test]
    fn roundtrip_across_row_boundaries() {
        let mut c = ctl();
        // 1000 bytes spanning many 128-byte rows, unaligned start.
        let data: Vec<u8> = (0..1000).map(|i| (i * 7 % 256) as u8).collect();
        c.write(120, &data).unwrap();
        let r = c.read(120, 1000).unwrap();
        assert_eq!(r.data.unwrap(), data);
        // Overlapping reread of a sub-window.
        let r2 = c.read(200, 64).unwrap();
        assert_eq!(r2.data.unwrap(), data[80..144].to_vec());
    }

    #[test]
    fn unwritten_memory_reads_zero() {
        let mut c = ctl();
        let r = c.read(1 << 20, 64).unwrap();
        assert_eq!(r.data.unwrap(), vec![0u8; 64]);
    }

    #[test]
    fn writes_cost_more_time_and_energy_than_reads() {
        let mut c = ctl();
        let data = vec![0xAAu8; 128];
        let w = c.write(0, &data).unwrap();
        let r = c.read(0, 128).unwrap();
        assert!(w.latency_ns > r.latency_ns * 5.0);
        assert!(w.energy_pj > r.energy_pj * 10.0);
        // Table I: 256 cells × 5 pJ read, 250 pJ write.
        assert!((r.energy_pj - 256.0 * 5.0).abs() < 1e-9);
        assert!((w.energy_pj - 256.0 * 250.0).abs() < 1e-9);
    }

    #[test]
    fn stats_accumulate() {
        let mut c = ctl();
        c.write(0, &[1u8; 64]).unwrap();
        c.read(0, 64).unwrap();
        c.read(0, 64).unwrap();
        let s = c.stats();
        assert_eq!(s.writes, 1);
        assert_eq!(s.reads, 2);
        assert_eq!(s.bytes_written, 64);
        assert_eq!(s.bytes_read, 128);
        assert!(s.total_energy_pj() > 0.0);
    }

    #[test]
    fn pim_reservation_blocks_memory_and_releases() {
        let mut c = ctl();
        let rows = c.reserve_pim_rows().unwrap();
        assert_eq!(rows.len(), 16);
        assert_eq!(c.rows_available(), 48); // 64 − 16 groups × 1 row
        // An access decoding to a reserved subarray row errors.
        // Row 0 of subarray_row 0 is addr 0.
        assert!(c.read(0, 16).is_err());
        c.release_pim_rows(&rows).unwrap();
        assert!(c.read(0, 16).is_ok());
    }

    #[test]
    fn zero_len_commands_rejected() {
        let mut c = ctl();
        assert!(c.read(0, 0).is_err());
        assert!(c.write(0, &[]).is_err());
    }

    #[test]
    fn out_of_range_rejected() {
        let mut c = ctl();
        let cap = c.capacity_bytes();
        assert!(c.read(cap - 4, 8).is_err());
        assert!(c.write(cap, &[1]).is_err());
    }

    #[test]
    fn bank_parallel_rows_finish_together() {
        let mut c = ctl();
        // Two rows mapping to different banks can both complete at the
        // same wall-clock time (bank interleaving).
        let bpr = 128u64; // bytes per row (256 cells × 4 bits)
        let r0 = c.read(0, 64).unwrap();
        c.advance_to(Nanos::ZERO);
        let r1 = c.read(bpr, 64).unwrap(); // next row → bank 1
        assert!((r0.latency_ns - r1.latency_ns).abs().raw() < 1e-6);
    }
}
