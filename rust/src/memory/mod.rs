//! OPCM main-memory simulator — the NVMain 2.0 substitute (paper §V).
//!
//! Models OPIMA's memory organization: `banks → subarray grid → R×C OPCM
//! cells`, with GST-switch subarray routing, EO-MR row access, per-level
//! MLC write pulse trains, and read/write energy from Table I. The
//! simulator is cycle-approximate: commands carry nanosecond timestamps
//! and banks/subarrays track busy windows; functional contents are stored
//! sparsely (a fully populated memory is 2³¹ cells).
//!
//! PIM interacts with the memory through *group reservations*
//! ([`controller::MemoryController::reserve_pim_rows`]): one subarray row
//! per group is lent to the PIM engine while the remaining rows continue
//! to serve ordinary reads/writes (paper §IV.C.2).

pub mod address;
pub mod bank;
pub mod cell;
pub mod command;
pub mod controller;
pub mod timing;

pub use address::{AddressMap, DecodedAddr};
pub use command::{CommandKind, MemCommand};
pub use controller::{MemStats, MemoryController};
