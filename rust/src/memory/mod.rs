//! OPCM main-memory simulator — the NVMain 2.0 substitute (paper §V).
//!
//! Models OPIMA's memory organization: `banks → subarray grid → R×C OPCM
//! cells`, with GST-switch subarray routing, EO-MR row access, per-level
//! MLC write pulse trains, and read/write energy from Table I. The
//! simulator is cycle-approximate: commands carry nanosecond timestamps
//! and banks/subarrays track busy windows; functional contents are stored
//! sparsely (a fully populated memory is 2³¹ cells).
//!
//! PIM interacts with the memory through *group reservations*
//! ([`controller::MemoryController::reserve_pim_rows`]): one subarray row
//! per group is lent to the PIM engine while the remaining rows continue
//! to serve ordinary reads/writes (paper §IV.C.2).

//!
//! The writeback path of the serving timeline is priced against this
//! layer's command model when `[memory] writeback_model` selects one of
//! the [`writeback`] controllers (naive or scheduled); the default flat
//! model bypasses it (DESIGN.md §2.7).

pub mod address;
pub mod bank;
pub mod cell;
pub mod command;
pub mod controller;
pub mod timing;
pub mod writeback;

pub use address::{AddressMap, DecodedAddr};
pub use command::{CommandKind, MemCommand, WbCommand, WbCommandKind};
pub use controller::{MemStats, MemoryController};
pub use writeback::{
    NaiveWritebackController, ScheduledWritebackController, WbJob, WritebackController,
};
