//! Access-timing model for the OPCM memory.
//!
//! Reads are single-pass optical transits (laser settle + propagation +
//! PD/ADC); writes are multi-pulse partial-crystallization trains whose
//! duration grows with the target level distance (MLC programming).
//! Switching a bank's GST routing to a different subarray row costs a
//! reconfiguration delay (amorphous↔crystalline transition of the switch).

use crate::config::Timing;
use crate::util::units::Nanos;

/// GST waveguide-switch reconfiguration time: a partial phase
/// transition, far faster than a full MLC data write but not free.
pub const GST_SWITCH_RECONFIG_NS: Nanos = Nanos::new(10.0);

/// Latency of a row read burst of `cells` cells (they stream on WDM
/// signals in parallel; the transit is one shot, ADC conversion is
/// pipelined per cell batch).
pub fn read_latency_ns(t: &Timing, cells: usize) -> Nanos {
    // One optical transit + pipelined ADC batches (32 λ per ADC bank).
    let batches = cells.div_ceil(32) as f64;
    t.read_ns + t.cycle_ns() * batches
}

/// Cells concurrently programmable in one MLC pulse train: the optical
/// write power budget sustains a quarter of the row's wavelengths at
/// programming power (write power ≫ read power), so the budget scales
/// with the configured row width instead of a fixed lane count.
pub fn write_quarter_row(row_cells: usize) -> usize {
    (row_cells / 4).max(1)
}

/// Latency of writing `cells` cells in one row of `row_cells` columns
/// (pulse trains run concurrently across the row's wavelengths;
/// duration is set by the worst-case level transition, i.e. the full
/// write_ns figure).
pub fn write_latency_ns(t: &Timing, cells: usize, row_cells: usize) -> Nanos {
    if cells == 0 {
        return Nanos::ZERO;
    }
    let quarter = write_quarter_row(row_cells);
    let waves = cells.div_ceil(quarter) as f64;
    waves * t.write_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Timing;

    /// Paper row width, matching `Geometry::default().cols_per_subarray`.
    const ROW: usize = 256;

    #[test]
    fn read_much_faster_than_write() {
        let t = Timing::default();
        assert!(read_latency_ns(&t, 256) * 10.0 < write_latency_ns(&t, 256, ROW));
    }

    #[test]
    fn read_scales_sublinearly() {
        let t = Timing::default();
        let r1 = read_latency_ns(&t, 32);
        let r8 = read_latency_ns(&t, 256);
        assert!(r8 < 8.0 * r1, "WDM parallel read: {r1} vs {r8}");
    }

    #[test]
    fn write_zero_cells_is_free() {
        let t = Timing::default();
        assert_eq!(write_latency_ns(&t, 0, ROW), Nanos::ZERO);
    }

    #[test]
    fn write_scales_with_row_quarters() {
        let t = Timing::default();
        assert_eq!(write_latency_ns(&t, 64, ROW), t.write_ns);
        assert_eq!(write_latency_ns(&t, 65, ROW), 2.0 * t.write_ns);
        assert_eq!(write_latency_ns(&t, 256, ROW), 4.0 * t.write_ns);
    }

    /// Regression pin: the quarter-row power budget used to be a
    /// hardcoded `64usize`. For the paper's 256-column rows the derived
    /// budget must reproduce that value (and every latency above)
    /// bit-identically; other row widths scale with the geometry.
    #[test]
    fn quarter_row_budget_derived_from_geometry() {
        assert_eq!(write_quarter_row(ROW), 64, "paper row pins the old budget");
        let t = Timing::default();
        assert_eq!(write_quarter_row(512), 128);
        assert_eq!(write_latency_ns(&t, 128, 512), t.write_ns);
        assert_eq!(write_latency_ns(&t, 129, 512), 2.0 * t.write_ns);
        // Degenerate narrow rows still admit one cell per train.
        assert_eq!(write_quarter_row(2), 1);
        assert_eq!(write_latency_ns(&t, 2, 2), 2.0 * t.write_ns);
    }
}
