//! Access-timing model for the OPCM memory.
//!
//! Reads are single-pass optical transits (laser settle + propagation +
//! PD/ADC); writes are multi-pulse partial-crystallization trains whose
//! duration grows with the target level distance (MLC programming).
//! Switching a bank's GST routing to a different subarray row costs a
//! reconfiguration delay (amorphous↔crystalline transition of the switch).

use crate::config::Timing;
use crate::util::units::Nanos;

/// GST waveguide-switch reconfiguration time: a partial phase
/// transition, far faster than a full MLC data write but not free.
pub const GST_SWITCH_RECONFIG_NS: Nanos = Nanos::new(10.0);

/// Latency of a row read burst of `cells` cells (they stream on WDM
/// signals in parallel; the transit is one shot, ADC conversion is
/// pipelined per cell batch).
pub fn read_latency_ns(t: &Timing, cells: usize) -> Nanos {
    // One optical transit + pipelined ADC batches (32 λ per ADC bank).
    let batches = cells.div_ceil(32) as f64;
    t.read_ns + t.cycle_ns() * batches
}

/// Latency of writing `cells` cells in one row (pulse trains run
/// concurrently across the row's wavelengths; duration is set by the
/// worst-case level transition, i.e. the full write_ns figure).
pub fn write_latency_ns(t: &Timing, cells: usize) -> Nanos {
    if cells == 0 {
        return Nanos::ZERO;
    }
    // The optical power budget limits concurrent MLC programming to a
    // quarter-row per pulse train (write power ≫ read power).
    let quarter = 64usize;
    let waves = cells.div_ceil(quarter) as f64;
    waves * t.write_ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Timing;

    #[test]
    fn read_much_faster_than_write() {
        let t = Timing::default();
        assert!(read_latency_ns(&t, 256) * 10.0 < write_latency_ns(&t, 256));
    }

    #[test]
    fn read_scales_sublinearly() {
        let t = Timing::default();
        let r1 = read_latency_ns(&t, 32);
        let r8 = read_latency_ns(&t, 256);
        assert!(r8 < 8.0 * r1, "WDM parallel read: {r1} vs {r8}");
    }

    #[test]
    fn write_zero_cells_is_free() {
        let t = Timing::default();
        assert_eq!(write_latency_ns(&t, 0), Nanos::ZERO);
    }

    #[test]
    fn write_scales_with_row_quarters() {
        let t = Timing::default();
        assert_eq!(write_latency_ns(&t, 64), t.write_ns);
        assert_eq!(write_latency_ns(&t, 65), 2.0 * t.write_ns);
        assert_eq!(write_latency_ns(&t, 256), 4.0 * t.write_ns);
    }
}
