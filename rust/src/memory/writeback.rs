//! Command-level writeback controllers: the naive/scheduled pair.
//!
//! The serving timeline historically priced a layer's activation
//! writeback as one flat scalar (`LayerCost::writeback_ns`). This module
//! decomposes that scalar into the command sequence the OPCM controller
//! actually issues — GST route reconfigurations, µs-class MLC program
//! trains (one per optical write-power quantum), and a final E-O-E
//! staging drain — and replays it against per-bank busy windows
//! (DESIGN.md §2.7).
//!
//! Two controllers implement one trait, in the SDRAM-controller idiom of
//! keeping a trivially-correct reference next to the optimized design:
//!
//! * [`NaiveWritebackController`] serializes whole jobs strictly behind
//!   one another — obviously correct, pessimal under contention.
//! * [`ScheduledWritebackController`] runs trains bank-parallel across
//!   the configured writeback channels, coalesces same-row bursts (no
//!   repeated GST reconfiguration), and hides row switches under other
//!   banks' tails.
//!
//! The differential contract, property-tested in
//! `rust/tests/memory_command.rs`:
//!
//! * On any single-image stream (one writeback in flight at a time,
//!   one channel) the two controllers produce identical schedules.
//! * On any stream, naive ≥ scheduled ≥ the bank-bottleneck lower bound.
//! * Uncontended jobs that run as a gapless serial chain return exactly
//!   `ready + flat_ns` — the analytical figure, bit-for-bit — so the
//!   batch-1 limit of the timeline is unchanged by the command model.
//!
//! There is no refresh (the optical twist: OPCM cells are non-volatile);
//! the conflicts that matter are wavelength-group (channel) capacity and
//! bank/row collisions between co-resident batches.
//!
//! Admission is **relative-frame**: `admit(origin, ready, job)` takes
//! `ready` relative to `origin` and converts every absolute state
//! constraint with `rel(abs) = max(0, abs − origin)`. A drained
//! controller therefore prices a stream identically at any origin —
//! the same trick `analyzer::contention::RelPool` uses to keep
//! single-batch admission bit-exact.

use crate::memory::command::{WbCommand, WbCommandKind};
use crate::memory::timing::GST_SWITCH_RECONFIG_NS;
use crate::util::units::Nanos;

/// Row-route sentinel: "this bank's GST column has never been routed".
const UNROUTED: u64 = u64::MAX;

/// One layer writeback, decomposed for command-level replay.
///
/// Built from a [`crate::pim::scheduler::LayerCost`] by the timeline;
/// the invariant `flat_ns == trains × train_ns + settle_ns` (same
/// rounding order as `cost_layer`) is what makes the uncontended limit
/// recover the analytical figure bit-exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WbJob {
    /// Monotone id, for traces.
    pub id: u64,
    /// Target subarray row. Trains stripe round-robin over banks
    /// starting at `row % banks`; distinct co-resident batches write
    /// distinct rows, so their bursts cannot coalesce.
    pub row: u64,
    /// Number of MLC program trains.
    pub trains: u64,
    /// Duration of one train (bank-exclusive).
    pub train_ns: Nanos,
    /// E-O-E staging drain after the last train (not bank-exclusive).
    pub settle_ns: Nanos,
    /// The analytical flat figure this job decomposes.
    pub flat_ns: Nanos,
}

/// A command-level writeback controller: prices one job at a time,
/// carrying bank/channel state between admissions.
pub trait WritebackController {
    /// Admit one job whose inputs become ready at `ready` (relative to
    /// `origin`); returns the job's `(start, end)` in the same relative
    /// frame. `end` is when the written activations are readable.
    fn admit(&mut self, origin: Nanos, ready: Nanos, job: &WbJob) -> (Nanos, Nanos);

    /// Drain the recorded command trace (empty unless tracing was
    /// enabled at construction). Times are absolute.
    fn take_trace(&mut self) -> Vec<WbCommand>;
}

/// Per-bank state shared by both controllers.
#[derive(Debug, Clone, Copy)]
struct WbBank {
    /// Absolute end of the last train that held this bank.
    busy_until: Nanos,
    /// Row the bank's GST switch column currently targets.
    routed_row: u64,
}

impl WbBank {
    fn fresh() -> Self {
        Self {
            busy_until: Nanos::ZERO,
            routed_row: UNROUTED,
        }
    }
}

/// Convert an absolute state timestamp into the `origin`-relative frame.
fn rel(abs: Nanos, origin: Nanos) -> Nanos {
    if abs <= origin {
        Nanos::ZERO
    } else {
        abs - origin
    }
}

/// Bank targeted by train `i` of a job: round-robin from the job's row.
fn bank_of(row: u64, i: u64, banks: u64) -> usize {
    ((row + i) % banks) as usize
}

fn push_trace(
    trace: &mut Option<Vec<WbCommand>>,
    origin: Nanos,
    job: u64,
    kind: WbCommandKind,
    start: Nanos,
    end: Nanos,
) {
    if let Some(t) = trace {
        t.push(WbCommand {
            job,
            kind,
            start_ns: origin + start,
            end_ns: origin + end,
        });
    }
}

/// Reference controller: whole jobs run strictly one after another —
/// every train of job *k+1* waits for job *k*'s settle to drain, on top
/// of the per-bank busy/route constraints. Obviously correct; the
/// scheduled controller must never price a stream above it.
#[derive(Debug, Clone)]
pub struct NaiveWritebackController {
    banks: Vec<WbBank>,
    /// Absolute end (incl. settle) of the last admitted job.
    last_end: Nanos,
    trace: Option<Vec<WbCommand>>,
}

impl NaiveWritebackController {
    pub fn new(banks: usize) -> Self {
        Self {
            banks: vec![WbBank::fresh(); banks.max(1)],
            last_end: Nanos::ZERO,
            trace: None,
        }
    }

    /// Like [`Self::new`], recording every issued command.
    pub fn with_trace(banks: usize) -> Self {
        Self {
            trace: Some(Vec::new()),
            ..Self::new(banks)
        }
    }
}

impl WritebackController for NaiveWritebackController {
    fn admit(&mut self, origin: Nanos, ready: Nanos, job: &WbJob) -> (Nanos, Nanos) {
        let nb = self.banks.len() as u64;
        let t0 = ready.max(rel(self.last_end, origin));
        // A job that runs as a gapless serial chain from `ready` prices
        // as the analytical flat figure, with its exact rounding order
        // (chained per-train addition would drift by ulps).
        let mut serial = t0 == ready;
        let mut t = t0;
        let mut first_start = t0;
        for i in 0..job.trains {
            let b = bank_of(job.row, i, nb);
            let switched = self.banks[b].routed_row != job.row;
            let route_ready = if switched {
                rel(self.banks[b].busy_until, origin) + GST_SWITCH_RECONFIG_NS
            } else {
                Nanos::ZERO
            };
            let start = t.max(rel(self.banks[b].busy_until, origin)).max(route_ready);
            if start != t {
                serial = false;
            }
            if i == 0 {
                first_start = start;
            }
            let end = start + job.train_ns;
            if switched {
                push_trace(
                    &mut self.trace,
                    origin,
                    job.id,
                    WbCommandKind::Route { bank: b, row: job.row },
                    start - GST_SWITCH_RECONFIG_NS,
                    start,
                );
            }
            push_trace(
                &mut self.trace,
                origin,
                job.id,
                WbCommandKind::Write { bank: b, row: job.row },
                start,
                end,
            );
            self.banks[b].busy_until = origin + end;
            self.banks[b].routed_row = job.row;
            t = end;
        }
        let (start, end) = if job.trains == 0 {
            (t0, t0 + job.settle_ns)
        } else if serial {
            (first_start, first_start + job.flat_ns)
        } else {
            (first_start, t + job.settle_ns)
        };
        if job.settle_ns > Nanos::ZERO {
            push_trace(
                &mut self.trace,
                origin,
                job.id,
                WbCommandKind::Settle,
                end - job.settle_ns,
                end,
            );
        }
        self.last_end = origin + end;
        (start, end)
    }

    fn take_trace(&mut self) -> Vec<WbCommand> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }
}

/// Scheduled controller: trains from any in-flight job occupy the
/// earliest-free writeback channel (the optical write-power quanta,
/// `[pipeline] writeback_channels`) and their target bank concurrently;
/// same-row bursts keep the GST route (no reconfiguration), row
/// switches prefetch under the bank's previous tail. Settle drains
/// off-channel, so back-to-back jobs overlap their tails.
#[derive(Debug, Clone)]
pub struct ScheduledWritebackController {
    banks: Vec<WbBank>,
    /// Absolute free time per writeback channel.
    channels: Vec<Nanos>,
    trace: Option<Vec<WbCommand>>,
}

impl ScheduledWritebackController {
    pub fn new(banks: usize, channels: usize) -> Self {
        Self {
            banks: vec![WbBank::fresh(); banks.max(1)],
            channels: vec![Nanos::ZERO; channels.max(1)],
            trace: None,
        }
    }

    /// Like [`Self::new`], recording every issued command.
    pub fn with_trace(banks: usize, channels: usize) -> Self {
        Self {
            trace: Some(Vec::new()),
            ..Self::new(banks, channels)
        }
    }
}

impl WritebackController for ScheduledWritebackController {
    fn admit(&mut self, origin: Nanos, ready: Nanos, job: &WbJob) -> (Nanos, Nanos) {
        let nb = self.banks.len() as u64;
        let mut serial = true;
        let mut chain = ready;
        let mut last_end = ready;
        let mut first_start = ready;
        for i in 0..job.trains {
            let b = bank_of(job.row, i, nb);
            // Earliest-free channel (argmin scan; the pool is tiny).
            let mut ch = 0usize;
            for (k, free) in self.channels.iter().enumerate() {
                if *free < self.channels[ch] {
                    ch = k;
                }
            }
            let ch_free = rel(self.channels[ch], origin);
            let switched = self.banks[b].routed_row != job.row;
            let route_ready = if switched {
                rel(self.banks[b].busy_until, origin) + GST_SWITCH_RECONFIG_NS
            } else {
                Nanos::ZERO
            };
            let start = ready
                .max(ch_free)
                .max(rel(self.banks[b].busy_until, origin))
                .max(route_ready);
            if start != chain {
                serial = false;
            }
            if i == 0 {
                first_start = start;
            }
            let end = start + job.train_ns;
            if switched {
                push_trace(
                    &mut self.trace,
                    origin,
                    job.id,
                    WbCommandKind::Route { bank: b, row: job.row },
                    start - GST_SWITCH_RECONFIG_NS,
                    start,
                );
            }
            push_trace(
                &mut self.trace,
                origin,
                job.id,
                WbCommandKind::Write { bank: b, row: job.row },
                start,
                end,
            );
            self.channels[ch] = origin + end;
            self.banks[b].busy_until = origin + end;
            self.banks[b].routed_row = job.row;
            chain = end;
            last_end = last_end.max(end);
        }
        let (start, end) = if job.trains == 0 {
            (ready, ready + job.settle_ns)
        } else if serial {
            (first_start, first_start + job.flat_ns)
        } else {
            (first_start, last_end + job.settle_ns)
        };
        if job.settle_ns > Nanos::ZERO {
            push_trace(
                &mut self.trace,
                origin,
                job.id,
                WbCommandKind::Settle,
                end - job.settle_ns,
                end,
            );
        }
        (start, end)
    }

    fn take_trace(&mut self) -> Vec<WbCommand> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::ns;

    fn job(id: u64, row: u64, trains: u64, train: f64, settle: f64) -> WbJob {
        WbJob {
            id,
            row,
            trains,
            train_ns: ns(train),
            settle_ns: ns(settle),
            flat_ns: ns(trains as f64 * train + settle),
        }
    }

    #[test]
    fn uncontended_job_prices_flat_exactly() {
        let j = job(0, 0, 7, 1000.0, 4.5);
        let mut naive = NaiveWritebackController::new(4);
        let mut sched = ScheduledWritebackController::new(4, 1);
        let ready = ns(123.25);
        assert_eq!(naive.admit(Nanos::ZERO, ready, &j), (ready, ready + j.flat_ns));
        assert_eq!(sched.admit(Nanos::ZERO, ready, &j), (ready, ready + j.flat_ns));
    }

    #[test]
    fn rel_frame_admission_is_origin_invariant() {
        // A drained controller must price a stream identically at any
        // origin — the contention timeline's bit-exactness depends on it.
        let jobs = [job(0, 0, 3, 1000.0, 4.0), job(1, 1, 5, 1000.0, 2.0)];
        let mut at_zero = ScheduledWritebackController::new(4, 2);
        let mut shifted = ScheduledWritebackController::new(4, 2);
        let origin = ns(777_777.5);
        for (i, j) in jobs.iter().enumerate() {
            let ready = ns(i as f64 * 1500.0);
            assert_eq!(
                at_zero.admit(Nanos::ZERO, ready, j),
                shifted.admit(origin, ready, j),
                "job {i} priced differently under a shifted origin"
            );
        }
    }

    #[test]
    fn naive_serializes_whole_jobs() {
        let mut naive = NaiveWritebackController::new(4);
        let a = job(0, 0, 2, 1000.0, 4.0);
        let b = job(1, 1, 2, 1000.0, 4.0);
        let (_, a_end) = naive.admit(Nanos::ZERO, Nanos::ZERO, &a);
        // b is ready immediately but must queue behind a (and pay the
        // row switch: its banks were last routed to a's row).
        let (b_start, b_end) = naive.admit(Nanos::ZERO, Nanos::ZERO, &b);
        assert!(b_start >= a_end);
        assert!(b_end >= b_start + ns(2.0 * 1000.0));
    }

    #[test]
    fn scheduled_overlaps_conflict_free_jobs() {
        // Two ready-at-zero jobs on disjoint banks, two channels: the
        // scheduled controller overlaps them; naive cannot.
        let a = job(0, 0, 2, 1000.0, 0.0); // banks 0, 1
        let b = job(1, 2, 2, 1000.0, 0.0); // banks 2, 3
        let mut naive = NaiveWritebackController::new(4);
        let mut sched = ScheduledWritebackController::new(4, 2);
        naive.admit(Nanos::ZERO, Nanos::ZERO, &a);
        sched.admit(Nanos::ZERO, Nanos::ZERO, &a);
        let (_, n_end) = naive.admit(Nanos::ZERO, Nanos::ZERO, &b);
        let (_, s_end) = sched.admit(Nanos::ZERO, Nanos::ZERO, &b);
        assert!(s_end < n_end, "scheduled {s_end} !< naive {n_end}");
    }

    #[test]
    fn trace_records_route_once_per_switch() {
        let mut sched = ScheduledWritebackController::with_trace(4, 1);
        // 8 trains on 4 banks: each bank is visited twice for the same
        // row — one Route per bank, not per train.
        let j = job(0, 0, 8, 1000.0, 0.0);
        sched.admit(Nanos::ZERO, GST_SWITCH_RECONFIG_NS, &j);
        let trace = sched.take_trace();
        let routes = trace
            .iter()
            .filter(|c| matches!(c.kind, WbCommandKind::Route { .. }))
            .count();
        let writes = trace
            .iter()
            .filter(|c| matches!(c.kind, WbCommandKind::Write { .. }))
            .count();
        assert_eq!(routes, 4);
        assert_eq!(writes, 8);
        assert!(sched.take_trace().is_empty(), "trace drains");
    }
}
