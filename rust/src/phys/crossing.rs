//! Inverse-designed waveguide crossing surrogate (paper §IV.C.3, Fig. 6).
//!
//! The paper optimized the crossing geometry with Lumerical FDTD + LumOpt,
//! reporting <0.001% insertion loss at C-band center and ≤ −40 dB crosstalk
//! across the C-band. The computation waveguides cross the data-out
//! waveguides many times (Fig. 5(b)), so these two figures gate how many
//! MAC results can cross the array without corrupting memory readouts.
//!
//! Surrogate: a broadband Lorentzian response centered at 1550 nm whose
//! floor values are the published ones.



/// C-band limits (nm).
pub const C_BAND_MIN_NM: f64 = 1530.0;
pub const C_BAND_MAX_NM: f64 = 1565.0;
/// Design center of the inverse-designed crossing (nm).
pub const CENTER_NM: f64 = 1550.0;

/// Fractional insertion loss floor at band center: <0.001% (Fig. 6).
const LOSS_FLOOR: f64 = 8.0e-6;
/// Loss growth half-width (nm): the response stays flat across C-band.
const LOSS_HALF_WIDTH_NM: f64 = 60.0;
/// Crosstalk floor at band center (dB).
const XTALK_FLOOR_DB: f64 = -41.5;
/// Crosstalk degradation rate away from center (dB/nm²).
const XTALK_CURVE_DB_PER_NM2: f64 = 3.0e-4;

/// One sampled point of the crossing response.
#[derive(Debug, Clone, Copy)]
pub struct CrossingPoint {
    pub wavelength_nm: f64,
    /// Power transmission of the through path (fraction of input).
    pub transmission: f64,
    /// Fractional insertion loss (1 − transmission).
    pub insertion_loss: f64,
    /// Crosstalk into the orthogonal waveguide (dB, negative).
    pub crosstalk_db: f64,
}

/// Fractional insertion loss at a wavelength (Lorentzian broadening).
pub fn insertion_loss(wavelength_nm: f64) -> f64 {
    let d = (wavelength_nm - CENTER_NM) / LOSS_HALF_WIDTH_NM;
    LOSS_FLOOR * (1.0 + d * d)
}

/// Through-path power transmission.
pub fn transmission(wavelength_nm: f64) -> f64 {
    1.0 - insertion_loss(wavelength_nm)
}

/// Crosstalk (dB) into the crossing waveguide.
pub fn crosstalk_db(wavelength_nm: f64) -> f64 {
    let d = wavelength_nm - CENTER_NM;
    XTALK_FLOOR_DB + XTALK_CURVE_DB_PER_NM2 * d * d
}

/// Sample the full C-band response (Fig. 6, right).
pub fn c_band_profile(n_points: usize) -> Vec<CrossingPoint> {
    assert!(n_points >= 2);
    (0..n_points)
        .map(|i| {
            let wl = C_BAND_MIN_NM
                + (C_BAND_MAX_NM - C_BAND_MIN_NM) * i as f64 / (n_points - 1) as f64;
            CrossingPoint {
                wavelength_nm: wl,
                transmission: transmission(wl),
                insertion_loss: insertion_loss(wl),
                crosstalk_db: crosstalk_db(wl),
            }
        })
        .collect()
}

/// Accumulated loss (dB) of a signal traversing `n` crossings.
pub fn chain_loss_db(n: usize, wavelength_nm: f64) -> f64 {
    -10.0 * (transmission(wavelength_nm).powi(n as i32)).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insertion_loss_below_paper_figure_across_c_band() {
        // Fig. 6: "less than 0.001% of the input optical signal being lost".
        for p in c_band_profile(64) {
            assert!(
                p.insertion_loss < 1.0e-5,
                "{} nm: loss {}",
                p.wavelength_nm,
                p.insertion_loss
            );
        }
    }

    #[test]
    fn crosstalk_at_most_minus_40db_across_c_band() {
        for p in c_band_profile(64) {
            assert!(
                p.crosstalk_db <= -40.0,
                "{} nm: {} dB",
                p.wavelength_nm,
                p.crosstalk_db
            );
        }
    }

    #[test]
    fn maximum_transmission_at_band_center() {
        let t_center = transmission(CENTER_NM);
        for wl in [1530.0, 1540.0, 1560.0, 1565.0] {
            assert!(t_center >= transmission(wl));
        }
    }

    #[test]
    fn chain_loss_is_additive_in_db() {
        let one = chain_loss_db(1, CENTER_NM);
        let hundred = chain_loss_db(100, CENTER_NM);
        assert!((hundred - 100.0 * one).abs() < 1e-9);
        // Even 512 crossings (a full subarray column) stay below 0.05 dB.
        assert!(chain_loss_db(512, CENTER_NM) < 0.05);
    }
}
