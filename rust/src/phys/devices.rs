//! Photonic device primitives and their loss contributions.
//!
//! Each device on an optical path contributes an insertion loss (or gain,
//! for SOAs) drawn from the paper's Table I. Paths are composed as ordered
//! device lists and reduced to a total dB figure by [`path_loss_db`].



use super::params::LossParams;
use crate::util::units::Milliwatts;

/// A photonic element along an optical path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Device {
    /// Directional coupler (e.g. MDL → subarray input coupling).
    DirectionalCoupler,
    /// Passive MR, drop port (wavelength filtered onto a branch).
    MrDrop,
    /// Passive MR, through port (wavelength passes a non-resonant ring).
    MrThrough,
    /// EO-tuned MR, drop port (access-control rings of the OPCM cell).
    EoMrDrop,
    /// EO-tuned MR, through port.
    EoMrThrough,
    /// Straight waveguide propagation over a length in µm.
    Waveguide { length_um: f64 },
    /// A 90° bend.
    Bend,
    /// GST waveguide switch (subarray access routing, §IV.C.2).
    GstSwitch,
    /// Inverse-designed waveguide crossing (computation waveguides).
    Crossing,
    /// Mode converter (MDM group aggregation).
    ModeConverter,
    /// Semiconductor optical amplifier (gain element).
    Soa,
    /// The OPCM memory cell itself at a given stored transmission.
    OpcmCell { transmission: f64 },
}

impl Device {
    /// Signed loss contribution in dB (positive = loss, negative = gain).
    pub fn loss_db(&self, p: &LossParams) -> f64 {
        match *self {
            Device::DirectionalCoupler => p.directional_coupler_db,
            Device::MrDrop => p.mr_drop_db,
            Device::MrThrough => p.mr_through_db,
            Device::EoMrDrop => p.eo_mr_drop_db,
            Device::EoMrThrough => p.eo_mr_through_db,
            Device::Waveguide { length_um } => p.propagation_db_per_cm * length_um / 1e4,
            Device::Bend => p.bend_db_per_90,
            Device::GstSwitch => p.gst_switch_db,
            Device::Crossing => p.crossing_db,
            Device::ModeConverter => p.mode_converter_db,
            Device::Soa => -p.soa_gain_db,
            Device::OpcmCell { transmission } => {
                debug_assert!((0.0..=1.0).contains(&transmission));
                -10.0 * transmission.max(1e-12).log10()
            }
        }
    }
}

/// Total loss of an ordered device path in dB (gains subtract).
pub fn path_loss_db(path: &[Device], p: &LossParams) -> f64 {
    path.iter().map(|d| d.loss_db(p)).sum()
}

/// Remaining optical power after a path, given launch power.
pub fn output_power_mw(launch_mw: Milliwatts, path: &[Device], p: &LossParams) -> Milliwatts {
    launch_mw * 10f64.powf(-path_loss_db(path, p) / 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_losses_flow_through() {
        let p = LossParams::default();
        assert_eq!(Device::DirectionalCoupler.loss_db(&p), 0.02);
        assert_eq!(Device::MrDrop.loss_db(&p), 0.5);
        assert_eq!(Device::EoMrDrop.loss_db(&p), 1.6);
        assert_eq!(Device::Soa.loss_db(&p), -20.0);
        // 1 cm of waveguide = 0.1 dB.
        assert!((Device::Waveguide { length_um: 10_000.0 }.loss_db(&p) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn opcm_cell_loss_reflects_transmission() {
        let p = LossParams::default();
        let dark = Device::OpcmCell { transmission: 0.02 }.loss_db(&p);
        let bright = Device::OpcmCell { transmission: 0.97 }.loss_db(&p);
        assert!(dark > 16.0 && dark < 18.0); // ~17 dB
        assert!(bright < 0.2);
    }

    #[test]
    fn path_composition() {
        let p = LossParams::default();
        let path = [
            Device::DirectionalCoupler,
            Device::GstSwitch,
            Device::Waveguide { length_um: 500.0 },
            Device::EoMrDrop,
            Device::OpcmCell { transmission: 0.5 },
            Device::EoMrDrop,
            Device::Soa,
        ];
        let total = path_loss_db(&path, &p);
        // 0.02 + 0.05 + 0.005 + 1.6 + 3.01 + 1.6 − 20 ≈ −13.7 dB (net gain).
        assert!(total < 0.0, "SOA should more than recover losses: {total}");
        let out = output_power_mw(crate::util::units::mw(1.0), &path, &p);
        assert!(out.raw() > 1.0);
    }
}
