//! Design-space exploration of the GST OPCM cell (paper Fig. 2).
//!
//! Sweeps GST width × thickness, evaluating the scattering change ΔT_s in
//! both phases and the controlled contrast ΔT, and selects the optimum the
//! way the paper does: maximize ΔT subject to ΔT_s < 5% in both states.



use super::gst::{contrast, delta_t_scatter, GstGeometry, GstState};

/// One evaluated design point.
#[derive(Debug, Clone, Copy)]
pub struct DsePoint {
    pub width_um: f64,
    pub thickness_nm: f64,
    /// ΔT_s in the crystalline state (Fig. 2(a)).
    pub dts_crystalline: f64,
    /// ΔT_s in the amorphous state (Fig. 2(b)).
    pub dts_amorphous: f64,
    /// Controlled contrast ΔT = T_a − T_c (Fig. 2(c)).
    pub contrast: f64,
}

/// Full sweep result.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub widths_um: Vec<f64>,
    pub thicknesses_nm: Vec<f64>,
    /// Row-major [thickness][width] grid of evaluated points.
    pub grid: Vec<Vec<DsePoint>>,
    /// The selected optimum (max ΔT subject to ΔT_s < threshold).
    pub optimum: DsePoint,
    /// The ΔT_s feasibility threshold (0.05 in the paper).
    pub dts_threshold: f64,
}

/// Sweep parameters matching the paper's Fig. 2 axes.
#[derive(Debug, Clone)]
pub struct DseSweep {
    pub width_min_um: f64,
    pub width_max_um: f64,
    pub width_step_um: f64,
    pub thickness_min_nm: f64,
    pub thickness_max_nm: f64,
    pub thickness_step_nm: f64,
    pub dts_threshold: f64,
}

impl Default for DseSweep {
    fn default() -> Self {
        Self {
            width_min_um: 0.30,
            width_max_um: 0.70,
            width_step_um: 0.02,
            thickness_min_nm: 5.0,
            thickness_max_nm: 50.0,
            thickness_step_nm: 5.0,
            dts_threshold: 0.05,
        }
    }
}

fn frange(min: f64, max: f64, step: f64) -> Vec<f64> {
    let n = ((max - min) / step).round() as usize + 1;
    (0..n).map(|i| min + i as f64 * step).collect()
}

/// Evaluate a single geometry.
pub fn evaluate(width_um: f64, thickness_nm: f64) -> DsePoint {
    let g = GstGeometry::new(width_um, thickness_nm);
    DsePoint {
        width_um,
        thickness_nm,
        dts_crystalline: delta_t_scatter(&g, GstState::Crystalline),
        dts_amorphous: delta_t_scatter(&g, GstState::Amorphous),
        contrast: contrast(&g),
    }
}

/// Run the full design-space exploration (Fig. 2).
pub fn run(sweep: &DseSweep) -> DseResult {
    let widths = frange(sweep.width_min_um, sweep.width_max_um, sweep.width_step_um);
    let thicknesses = frange(
        sweep.thickness_min_nm,
        sweep.thickness_max_nm,
        sweep.thickness_step_nm,
    );
    let grid: Vec<Vec<DsePoint>> = thicknesses
        .iter()
        .map(|&t| widths.iter().map(|&w| evaluate(w, t)).collect())
        .collect();

    let optimum = grid
        .iter()
        .flatten()
        .filter(|p| {
            p.dts_crystalline < sweep.dts_threshold && p.dts_amorphous < sweep.dts_threshold
        })
        .max_by(|a, b| a.contrast.total_cmp(&b.contrast))
        .copied()
        .unwrap_or_else(|| evaluate(0.48, 20.0));

    DseResult {
        widths_um: widths,
        thicknesses_nm: thicknesses,
        grid,
        optimum,
        dts_threshold: sweep.dts_threshold,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimum_matches_paper_design_point() {
        let r = run(&DseSweep::default());
        // Paper Fig. 2(c): optimum at width 0.48 µm, thickness 20 nm.
        assert!(
            (r.optimum.width_um - 0.48).abs() < 1e-9,
            "width = {}",
            r.optimum.width_um
        );
        assert!(
            (r.optimum.thickness_nm - 20.0).abs() < 1e-9,
            "thickness = {}",
            r.optimum.thickness_nm
        );
        assert!(r.optimum.contrast > 0.92, "ΔT = {}", r.optimum.contrast);
        assert!(r.optimum.dts_crystalline < 0.05);
        assert!(r.optimum.dts_amorphous < 0.05);
    }

    #[test]
    fn grid_dimensions_consistent() {
        let r = run(&DseSweep::default());
        assert_eq!(r.grid.len(), r.thicknesses_nm.len());
        assert!(r.grid.iter().all(|row| row.len() == r.widths_um.len()));
        // 0.30..0.70 step 0.02 → 21 widths; 5..50 step 5 → 10 thicknesses.
        assert_eq!(r.widths_um.len(), 21);
        assert_eq!(r.thicknesses_nm.len(), 10);
    }

    #[test]
    fn infeasible_region_exists() {
        // Thick films must violate the ΔT_s constraint — otherwise the
        // constraint is vacuous and the sweep proves nothing.
        let r = run(&DseSweep::default());
        let infeasible = r
            .grid
            .iter()
            .flatten()
            .filter(|p| p.dts_crystalline >= 0.05 || p.dts_amorphous >= 0.05)
            .count();
        assert!(infeasible > 0);
    }
}
