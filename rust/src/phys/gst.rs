//! GST OPCM cell surrogate physics (paper §IV.A, Fig. 2).
//!
//! The paper ran an FDTD design-space exploration of a 2-µm-long GST patch
//! on a silicon waveguide, sweeping GST width and thickness, and selected
//! the geometry that (a) keeps the *scattering/back-reflection* transmission
//! change ΔT_s below 5% in both phases and (b) maximizes the *controlled*
//! amorphous↔crystalline transmission contrast ΔT (96% at w = 0.48 µm,
//! t = 20 nm), which supports 16 transmission levels → 4 bits/cell.
//!
//! Surrogate model (Eq. 2 of the paper: T_out = T_in − ΔT_s − P_abs):
//!
//! * **Absorption** follows Beer–Lambert with a confinement factor
//!   Γ(w, t): the guided mode's overlap with the GST film grows with film
//!   thickness (saturating) and peaks at the mode-matched width.
//!   P_abs = 1 − exp(−α_state · Γ · L) with α_c ≫ α_a (crystalline GST is
//!   strongly absorbing at 1550 nm, amorphous is nearly transparent).
//! * **Scattering/back-reflection** at the waveguide/GST index
//!   discontinuity grows quadratically with film thickness (Fresnel-like
//!   step reflection ∝ interface area) and is minimized at the
//!   mode-matched width; the crystalline state scatters more (larger Δn).
//!
//! Constants are calibrated so the published design point is reproduced:
//! at (0.48 µm, 20 nm): ΔT_s < 5% in both states and ΔT ≈ 96%.



/// GST phase state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GstState {
    /// Melt-quenched, high-transmission state (binary 1 ↔ low absorption).
    Amorphous,
    /// Annealed, low-transmission state (strong absorption at 1550 nm).
    Crystalline,
}

/// Geometry of the GST patch on the waveguide.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GstGeometry {
    /// GST width in µm (across the waveguide).
    pub width_um: f64,
    /// GST film thickness in nm.
    pub thickness_nm: f64,
    /// GST length along the waveguide in µm (2 µm in the paper).
    pub length_um: f64,
}

impl GstGeometry {
    pub fn new(width_um: f64, thickness_nm: f64) -> Self {
        Self {
            width_um,
            thickness_nm,
            length_um: 2.0,
        }
    }

    /// The paper's chosen design point (Fig. 2(c), marked '×').
    pub fn paper_optimum() -> Self {
        Self::new(0.48, 20.0)
    }
}

/// Calibrated surrogate constants (see module docs).
mod cal {
    /// Mode-matched GST width (µm): scattering minimum & confinement peak.
    pub const W_OPT_UM: f64 = 0.48;
    /// Width tolerance of the confinement peak (µm).
    pub const W_SIGMA_UM: f64 = 0.20;
    /// Thickness half-saturation constant for the confinement factor (nm).
    pub const T_HALF_NM: f64 = 10.0;
    /// Crystalline absorption rate (µm⁻¹, per unit confinement).
    pub const ALPHA_C: f64 = 3.0;
    /// Amorphous absorption rate (µm⁻¹, per unit confinement).
    pub const ALPHA_A: f64 = 0.010;
    /// Crystalline scattering coefficient at reference geometry.
    pub const R_C: f64 = 0.040;
    /// Amorphous scattering coefficient at reference geometry (smaller Δn).
    pub const R_A: f64 = 0.015;
    /// Reference thickness for scattering normalization (nm).
    pub const T_REF_NM: f64 = 20.0;
    /// Width sensitivity of the scattering mismatch term (µm⁻¹).
    pub const W_SCATTER_SENS: f64 = 10.0;
}

/// Confinement factor Γ(w, t) ∈ (0, 1): modal overlap with the GST film.
pub fn confinement(geom: &GstGeometry) -> f64 {
    let dw = (geom.width_um - cal::W_OPT_UM) / cal::W_SIGMA_UM;
    let width_term = (-dw * dw).exp();
    let thick_term = geom.thickness_nm / (geom.thickness_nm + cal::T_HALF_NM);
    width_term * thick_term
}

/// Transmission change due to scattering and back-reflections, ΔT_s
/// (fraction of input power, paper Fig. 2(a)/(b)).
pub fn delta_t_scatter(geom: &GstGeometry, state: GstState) -> f64 {
    let r0 = match state {
        GstState::Crystalline => cal::R_C,
        GstState::Amorphous => cal::R_A,
    };
    let thick = (geom.thickness_nm / cal::T_REF_NM).powi(2);
    let dw = geom.width_um - cal::W_OPT_UM;
    let width = 1.0 + (cal::W_SCATTER_SENS * dw).powi(2);
    (r0 * thick * width).min(1.0)
}

/// Fraction of power absorbed in the GST patch (P_abs of Eq. 2).
pub fn absorbed_fraction(geom: &GstGeometry, state: GstState) -> f64 {
    let alpha = match state {
        GstState::Crystalline => cal::ALPHA_C,
        GstState::Amorphous => cal::ALPHA_A,
    };
    1.0 - (-alpha * confinement(geom) * geom.length_um).exp()
}

/// Output transmission T_out = T_in − ΔT_s − P_abs (T_in = 1), clamped.
pub fn transmission(geom: &GstGeometry, state: GstState) -> f64 {
    let t = (1.0 - delta_t_scatter(geom, state)) * (1.0 - absorbed_fraction(geom, state));
    t.clamp(0.0, 1.0)
}

/// Controlled optical transmission contrast ΔT = T_a − T_c (Fig. 2(c)).
pub fn contrast(geom: &GstGeometry) -> f64 {
    transmission(geom, GstState::Amorphous) - transmission(geom, GstState::Crystalline)
}

/// Transmission of a partially crystallized cell storing `level` out of
/// `n_levels` (multi-level cell): linear interpolation between the two
/// phase extremes, which is how MLC programming targets are set.
pub fn mlc_transmission(geom: &GstGeometry, level: u32, n_levels: u32) -> f64 {
    assert!(n_levels >= 2 && level < n_levels);
    let t_c = transmission(geom, GstState::Crystalline);
    let t_a = transmission(geom, GstState::Amorphous);
    let frac = level as f64 / (n_levels - 1) as f64;
    t_c + frac * (t_a - t_c)
}

/// Maximum bit density supported by a geometry: levels must be separated
/// by more than the scattering-induced uncertainty (the paper's read-error
/// argument for why ΔT_s must be small).
pub fn max_bits_per_cell(geom: &GstGeometry) -> u32 {
    let dt = contrast(geom);
    let noise = delta_t_scatter(geom, GstState::Amorphous)
        .max(delta_t_scatter(geom, GstState::Crystalline));
    if dt <= 0.0 || noise <= 0.0 {
        return 0;
    }
    // Need 2^b levels with spacing dt/(2^b - 1) > 2*noise-margin heuristic.
    let mut bits = 0u32;
    while bits < 8 {
        let levels = 1u64 << (bits + 1);
        let spacing = dt / (levels - 1) as f64;
        if spacing <= noise * 0.5 {
            break;
        }
        bits += 1;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;

    const OPT: GstGeometry = GstGeometry {
        width_um: 0.48,
        thickness_nm: 20.0,
        length_um: 2.0,
    };

    #[test]
    fn paper_design_point_scattering_below_5pct() {
        // Fig. 2(a)/(b): ΔT_s < 5% in both states at the chosen point.
        assert!(delta_t_scatter(&OPT, GstState::Crystalline) < 0.05);
        assert!(delta_t_scatter(&OPT, GstState::Amorphous) < 0.05);
    }

    #[test]
    fn paper_design_point_contrast_near_96pct() {
        // Fig. 2(c): ΔT ≈ 96% at (0.48 µm, 20 nm).
        let dt = contrast(&OPT);
        assert!((0.92..=0.99).contains(&dt), "ΔT = {dt}");
    }

    #[test]
    fn supports_16_levels_at_optimum() {
        assert!(max_bits_per_cell(&OPT) >= 4, "paper stores 4 bits/cell");
    }

    #[test]
    fn crystalline_darker_than_amorphous() {
        for w in [0.3, 0.4, 0.5, 0.6, 0.7] {
            for t in [5.0, 15.0, 25.0, 40.0] {
                let g = GstGeometry::new(w, t);
                assert!(
                    transmission(&g, GstState::Amorphous)
                        > transmission(&g, GstState::Crystalline),
                    "at ({w}, {t})"
                );
            }
        }
    }

    #[test]
    fn scattering_grows_with_thickness() {
        let thin = GstGeometry::new(0.48, 10.0);
        let thick = GstGeometry::new(0.48, 40.0);
        assert!(
            delta_t_scatter(&thick, GstState::Crystalline)
                > delta_t_scatter(&thin, GstState::Crystalline)
        );
    }

    #[test]
    fn mlc_levels_monotone() {
        let mut prev = -1.0;
        for lv in 0..16 {
            let t = mlc_transmission(&OPT, lv, 16);
            assert!(t > prev, "levels must be strictly increasing");
            prev = t;
        }
    }

    #[test]
    fn transmission_bounded() {
        for w in [0.30, 0.48, 0.70] {
            for t in [5.0, 20.0, 50.0] {
                let g = GstGeometry::new(w, t);
                for s in [GstState::Amorphous, GstState::Crystalline] {
                    let tr = transmission(&g, s);
                    assert!((0.0..=1.0).contains(&tr));
                }
            }
        }
    }
}
