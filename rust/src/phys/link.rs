//! Optical link budgets for OPIMA's read/compute paths (paper §IV.B–C).
//!
//! Builds the canonical device paths (MDL → subarray → OPCM cell →
//! computation waveguide → aggregation unit) from the geometry, computes
//! their worst-case losses, determines where SOAs must be inserted
//! ("row-wise loss-aware signal amplification", §IV.B), and solves the
//! minimum per-wavelength laser power for a photodetector sensitivity
//! target at a given cell bit density.



use super::devices::{path_loss_db, Device};
use super::params::LossParams;
use crate::config::Geometry;
use crate::util::units::Milliwatts;

/// Photodetector sensitivity (dBm) for reliable level discrimination at
/// baseline (1-bit) readout. Each extra bit of cell density halves the
/// level spacing, costing ~3 dB of required SNR.
pub const PD_SENSITIVITY_DBM: f64 = -26.0;
pub const SNR_PER_BIT_DB: f64 = 3.0;

/// Physical pitch assumptions for path-length estimation (µm).
const CELL_PITCH_UM: f64 = 12.0;
const SUBARRAY_SPACING_UM: f64 = 150.0;

/// A fully characterized optical path.
#[derive(Debug, Clone)]
pub struct LinkBudget {
    /// Raw path loss before amplification (dB).
    pub raw_loss_db: f64,
    /// Number of SOAs inserted to keep the signal above sensitivity.
    pub soa_count: usize,
    /// Residual loss after amplification (dB; can be negative = net gain).
    pub net_loss_db: f64,
    /// Minimum launch power per wavelength for `bits_per_cell` readout.
    pub min_launch_mw: Milliwatts,
}

/// Worst-case PIM read path inside one subarray: MDL launch, coupler, row
/// access through the EO-MR pair, the OPCM cell, the full column of cells
/// passed at through-ports, the coupling MR onto the computation
/// waveguide, crossings across the subarray grid, and the mode converter
/// into the aggregation bus.
pub fn pim_read_path(geom: &Geometry) -> Vec<Device> {
    let mut path = vec![
        Device::DirectionalCoupler, // MDL → input waveguide
        Device::GstSwitch,          // subarray select
        Device::EoMrDrop,           // access-control ring (in)
        Device::OpcmCell { transmission: 0.5 }, // mid-level cell (average)
        Device::EoMrDrop,           // access-control ring (out)
    ];
    // Propagate along the subarray row; other columns' rings at through.
    for _ in 0..(geom.cols_per_subarray - 1) {
        path.push(Device::MrThrough);
    }
    path.push(Device::Waveguide {
        length_um: geom.cols_per_subarray as f64 * CELL_PITCH_UM,
    });
    // Reroute onto the computation waveguide (coupling MR, §IV.C.3).
    path.push(Device::MrDrop);
    // Cross the data-out waveguides of the subarrays between here and the
    // bank edge (worst case: a full subarray-column traversal).
    for _ in 0..geom.subarray_rows {
        path.push(Device::Crossing);
    }
    path.push(Device::Waveguide {
        length_um: geom.subarray_rows as f64 * SUBARRAY_SPACING_UM,
    });
    // Group mode conversion before the aggregation demux.
    path.push(Device::ModeConverter);
    path.push(Device::Bend);
    path.push(Device::Bend);
    path
}

/// Main-memory read path: external laser through bank/subarray routing.
/// The external comb laser couples on-chip, is mode-filtered to the bank,
/// then rides the GST-switch column to the target subarray row (§IV.C.2:
/// "GST-based waveguide switching, rather than splitting the WDM signal").
pub fn memory_read_path(geom: &Geometry) -> Vec<Device> {
    let mut path = vec![
        Device::DirectionalCoupler, // laser → chip
        Device::DirectionalCoupler, // chip → bank bus
        Device::MrDrop,             // bank mode filter
        Device::ModeConverter,
    ];
    // The signal passes every subarray row's GST switch on the way to the
    // selected one (all-but-one at through state).
    for _ in 0..geom.subarray_rows {
        path.push(Device::GstSwitch);
    }
    path.push(Device::Waveguide {
        length_um: geom.subarray_rows as f64 * SUBARRAY_SPACING_UM,
    });
    path.push(Device::EoMrDrop);
    path.push(Device::OpcmCell { transmission: 0.5 });
    path.push(Device::EoMrDrop);
    for _ in 0..(geom.cols_per_subarray - 1) {
        path.push(Device::MrThrough);
    }
    path.push(Device::Waveguide {
        length_um: geom.cols_per_subarray as f64 * CELL_PITCH_UM,
    });
    path
}

/// Solve the link budget: insert SOAs until the arriving power at the PD
/// exceeds the sensitivity needed for `bits_per_cell` discrimination.
pub fn solve(
    path: &[Device],
    losses: &LossParams,
    bits_per_cell: u32,
    launch_mw: Milliwatts,
) -> LinkBudget {
    let raw_loss_db = path_loss_db(path, losses);
    let required_dbm = PD_SENSITIVITY_DBM + SNR_PER_BIT_DB * bits_per_cell as f64;
    let launch_dbm = 10.0 * launch_mw.raw().log10();

    let mut soa_count = 0;
    let mut net_loss_db = raw_loss_db;
    while launch_dbm - net_loss_db < required_dbm && soa_count < 16 {
        soa_count += 1;
        net_loss_db -= losses.soa_gain_db;
    }

    // Minimum launch power with that many SOAs.
    let min_launch_dbm = required_dbm + net_loss_db;
    LinkBudget {
        raw_loss_db,
        soa_count,
        net_loss_db,
        min_launch_mw: Milliwatts::new(10f64.powf(min_launch_dbm / 10.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pim_path_closes_with_mdl_class_power() {
        let geom = Geometry::default();
        let losses = LossParams::default();
        let path = pim_read_path(&geom);
        let budget = solve(&path, &losses, geom.bits_per_cell, crate::util::units::mw(1.0));
        // The per-λ launch power must be in the MDL range (≲ a few mW),
        // otherwise the local-laser design of §IV.C.2 would not work.
        assert!(
            budget.min_launch_mw.raw() < 5.0,
            "PIM link needs {}",
            budget.min_launch_mw
        );
    }

    #[test]
    fn memory_path_closes_with_soas() {
        let geom = Geometry::default();
        let losses = LossParams::default();
        let path = memory_read_path(&geom);
        // Per-wavelength launch power is ~1 mW: the external comb's output
        // is divided across the WDM degree.
        let budget = solve(&path, &losses, geom.bits_per_cell, crate::util::units::mw(1.0));
        assert!(budget.soa_count >= 1, "bank paths need SOA stages (§IV.B)");
        assert!(budget.soa_count <= 4, "SOA chains must stay short");
    }

    #[test]
    fn higher_bit_density_needs_more_power() {
        let geom = Geometry::default();
        let losses = LossParams::default();
        let path = pim_read_path(&geom);
        let b2 = solve(&path, &losses, 2, crate::util::units::mw(1.0));
        let b4 = solve(&path, &losses, 4, crate::util::units::mw(1.0));
        assert!(b4.min_launch_mw > b2.min_launch_mw);
    }

    #[test]
    fn raw_loss_is_dominated_by_through_ports() {
        // 255 through-port passes × 0.02 dB ≈ 5.1 dB — the dominant term,
        // which is why the paper isolates cells and amplifies row-wise.
        let geom = Geometry::default();
        let losses = LossParams::default();
        let raw = path_loss_db(&pim_read_path(&geom), &losses);
        assert!(raw > 5.0 && raw < 20.0, "raw loss = {raw} dB");
    }
}
