//! Photonic physics layer: device models, GST OPCM cell surrogate physics,
//! inverse-designed crossing surrogate, MDM analysis and link budgets.
//!
//! The paper obtained these numbers from Lumerical FDTD + LumOpt inverse
//! design and fabricated-device characterization; this module provides
//! calibrated analytical surrogates that reproduce the published design
//! points and qualitative landscapes (see `DESIGN.md` §2 for the
//! substitution argument).

pub mod crossing;
pub mod devices;
pub mod dse;
pub mod gst;
pub mod link;
pub mod mode;
pub mod params;

/// Convert a dB value to a linear power ratio.
#[inline]
pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Convert a linear power ratio to dB.
#[inline]
pub fn linear_to_db(ratio: f64) -> f64 {
    10.0 * ratio.log10()
}

/// Convert dBm to milliwatts.
#[inline]
pub fn dbm_to_mw(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0)
}

/// Convert milliwatts to dBm.
#[inline]
pub fn mw_to_dbm(mw: f64) -> f64 {
    10.0 * mw.log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_roundtrip() {
        for &db in &[-40.0, -3.0, 0.0, 3.0, 20.0] {
            assert!((linear_to_db(db_to_linear(db)) - db).abs() < 1e-9);
        }
        assert!((db_to_linear(3.0) - 1.9953).abs() < 1e-3);
        assert!((dbm_to_mw(0.0) - 1.0).abs() < 1e-12);
        assert!((mw_to_dbm(100.0) - 20.0).abs() < 1e-12);
    }
}
