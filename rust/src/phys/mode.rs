//! Mode-division multiplexing (MDM) analysis (paper §IV.C.1).
//!
//! OPIMA excites the first four TE modes of a multimode bus to parallelize
//! across banks (and reuses them for the 16 subarray groups' aggregation
//! paths). More modes need wider waveguides and suffer intermodal
//! crosstalk from modal overlap — the paper's propagation analysis capped
//! the MDM degree at 4. This module reproduces that trade-off.



/// Minimum intermodal crosstalk suppression (dB) for reliable multi-level
/// readout; below this the analog sums corrupt adjacent-mode channels.
pub const XTALK_LIMIT_DB: f64 = -20.0;

/// Single-mode silicon waveguide width at 1550 nm (µm).
const BASE_WIDTH_UM: f64 = 0.45;
/// Extra width needed per additional guided TE mode (µm).
const WIDTH_PER_MODE_UM: f64 = 0.40;
/// Crosstalk of a 2-mode bus (dB) and degradation per extra mode (dB).
const XTALK_2MODE_DB: f64 = -32.0;
const XTALK_SLOPE_DB_PER_MODE: f64 = 4.5;

/// Characterization of an `n`-mode MDM bus.
#[derive(Debug, Clone, Copy)]
pub struct MdmBus {
    pub modes: usize,
    /// Required waveguide width (µm) to guide all modes.
    pub width_um: f64,
    /// Worst-pair intermodal crosstalk (dB; more negative = better).
    pub crosstalk_db: f64,
    /// Mode-converter insertion loss per conversion (dB).
    pub converter_loss_db: f64,
}

/// Evaluate an MDM bus with `modes` concurrently excited TE modes.
pub fn evaluate(modes: usize) -> MdmBus {
    assert!(modes >= 1);
    let width_um = BASE_WIDTH_UM + WIDTH_PER_MODE_UM * (modes as f64 - 1.0);
    let crosstalk_db = if modes == 1 {
        -60.0 // no intermodal partner; limited by fabrication disorder
    } else {
        XTALK_2MODE_DB + XTALK_SLOPE_DB_PER_MODE * (modes as f64 - 2.0)
    };
    MdmBus {
        modes,
        width_um,
        crosstalk_db,
        // Inverse-designed converters (ref [34]): compact, low, mildly
        // increasing loss with mode order.
        converter_loss_db: 0.08 + 0.015 * (modes as f64 - 1.0),
    }
}

/// Does an `n`-mode bus keep crosstalk within the readout budget?
pub fn is_reliable(modes: usize) -> bool {
    evaluate(modes).crosstalk_db <= XTALK_LIMIT_DB
}

/// Largest reliable MDM degree — the paper's analysis yields 4.
pub fn max_reliable_modes() -> usize {
    let mut m = 1;
    while is_reliable(m + 1) {
        m += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_mdm_degree_is_four() {
        assert_eq!(max_reliable_modes(), 4);
        assert!(is_reliable(4));
        assert!(!is_reliable(5));
    }

    #[test]
    fn width_grows_with_modes() {
        let w4 = evaluate(4).width_um;
        let w1 = evaluate(1).width_um;
        assert!(w4 > 2.0 * w1, "4-mode buses are much wider: {w4} vs {w1}");
    }

    #[test]
    fn crosstalk_monotonically_degrades() {
        let mut prev = evaluate(2).crosstalk_db;
        for m in 3..8 {
            let x = evaluate(m).crosstalk_db;
            assert!(x > prev, "mode {m} must be worse");
            prev = x;
        }
    }
}
