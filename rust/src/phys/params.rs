//! Device loss and energy parameters — paper Table I, verbatim.
//!
//! Every value carries the unit in its name. These are the inputs the
//! paper's own performance analyzer consumed; all downstream latency,
//! energy and power numbers derive from them plus the geometry.



use crate::error::{Error, Result};

/// Optical loss parameters (Table I, left column).
#[derive(Debug, Clone, PartialEq)]

pub struct LossParams {
    /// Directional coupler loss (dB). [42]
    pub directional_coupler_db: f64,
    /// Microring resonator drop-port loss (dB). [43]
    pub mr_drop_db: f64,
    /// Microring resonator through-port loss (dB). [44]
    pub mr_through_db: f64,
    /// Waveguide propagation loss (dB/cm). [45]
    pub propagation_db_per_cm: f64,
    /// Bending loss (dB per 90° bend). [46]
    pub bend_db_per_90: f64,
    /// EO-tuned MR drop-port loss (dB). [47]
    pub eo_mr_drop_db: f64,
    /// EO-tuned MR through-port loss (dB). [47]
    pub eo_mr_through_db: f64,
    /// Semiconductor optical amplifier gain (dB).
    pub soa_gain_db: f64,
    /// GST waveguide-switch insertion loss (dB) — "minimal losses"
    /// (§IV.C.2); modeled like a directional-coupler-class element.
    pub gst_switch_db: f64,
    /// Mode converter insertion loss (dB) — inverse-designed, compact,
    /// minimal loss (§IV.C.1).
    pub mode_converter_db: f64,
    /// Waveguide-crossing insertion loss (dB) — inverse-designed (Fig. 6,
    /// <0.001% ⇒ ~4.3e-5 dB).
    pub crossing_db: f64,
    /// Crossing crosstalk floor (dB, negative) — Fig. 6 reports −40 dB.
    pub crossing_crosstalk_db: f64,
}

impl Default for LossParams {
    fn default() -> Self {
        Self {
            directional_coupler_db: 0.02,
            mr_drop_db: 0.5,
            mr_through_db: 0.02,
            propagation_db_per_cm: 0.1,
            bend_db_per_90: 0.01,
            eo_mr_drop_db: 1.6,
            eo_mr_through_db: 0.33,
            soa_gain_db: 20.0,
            gst_switch_db: 0.05,
            mode_converter_db: 0.1,
            crossing_db: 4.3e-5,
            crossing_crosstalk_db: -40.0,
        }
    }
}

impl LossParams {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("directional_coupler_db", self.directional_coupler_db),
            ("mr_drop_db", self.mr_drop_db),
            ("mr_through_db", self.mr_through_db),
            ("propagation_db_per_cm", self.propagation_db_per_cm),
            ("bend_db_per_90", self.bend_db_per_90),
            ("eo_mr_drop_db", self.eo_mr_drop_db),
            ("eo_mr_through_db", self.eo_mr_through_db),
            ("gst_switch_db", self.gst_switch_db),
            ("mode_converter_db", self.mode_converter_db),
            ("crossing_db", self.crossing_db),
        ] {
            if v < 0.0 {
                return Err(Error::Config(format!("{name} must be non-negative")));
            }
        }
        if self.soa_gain_db <= 0.0 {
            return Err(Error::Config("soa_gain_db must be positive".into()));
        }
        if self.crossing_crosstalk_db >= 0.0 {
            return Err(Error::Config(
                "crossing_crosstalk_db is a suppression figure and must be negative".into(),
            ));
        }
        Ok(())
    }
}

/// Energy parameters (Table I, right column).
#[derive(Debug, Clone, PartialEq)]

pub struct EnergyParams {
    /// OPCM cell read energy (pJ). [23]
    pub opcm_read_pj: f64,
    /// OPCM cell write energy (pJ). [23]
    pub opcm_write_pj: f64,
    /// EPCM (electrically programmed PCM) write energy (nJ). [48] — used by
    /// the PhPIM baseline's reprogramming path.
    pub epcm_write_nj: f64,
    /// DRAM access energy (pJ/bit). [49] — used by baselines with DDR5.
    pub dram_access_pj_per_bit: f64,
    /// ADC conversion energy (fJ/step). [50]
    pub adc_fj_per_step: f64,
    /// DAC conversion energy (pJ/bit). [51]
    pub dac_pj_per_bit: f64,
    /// SRAM access in the aggregation unit (pJ/bit) — CACTI-class figure.
    pub sram_pj_per_bit: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        Self {
            opcm_read_pj: 5.0,
            opcm_write_pj: 250.0,
            epcm_write_nj: 860.0,
            dram_access_pj_per_bit: 20.0,
            adc_fj_per_step: 24.4,
            dac_pj_per_bit: 2.0,
            sram_pj_per_bit: 0.05,
        }
    }
}

impl EnergyParams {
    pub fn validate(&self) -> Result<()> {
        for (name, v) in [
            ("opcm_read_pj", self.opcm_read_pj),
            ("opcm_write_pj", self.opcm_write_pj),
            ("epcm_write_nj", self.epcm_write_nj),
            ("dram_access_pj_per_bit", self.dram_access_pj_per_bit),
            ("adc_fj_per_step", self.adc_fj_per_step),
            ("dac_pj_per_bit", self.dac_pj_per_bit),
            ("sram_pj_per_bit", self.sram_pj_per_bit),
        ] {
            if v <= 0.0 {
                return Err(Error::Config(format!("{name} must be positive")));
            }
        }
        if self.opcm_write_pj <= self.opcm_read_pj {
            return Err(Error::Config(
                "OPCM writes (phase transitions) must cost more than reads".into(),
            ));
        }
        Ok(())
    }

    /// Energy of one n-bit ADC conversion in pJ (fJ/step × 2^bits steps).
    pub fn adc_conversion_pj(&self, bits: u32) -> f64 {
        self.adc_fj_per_step * (1u64 << bits) as f64 / 1000.0
    }

    /// Energy of one n-bit DAC conversion in pJ.
    pub fn dac_conversion_pj(&self, bits: u32) -> f64 {
        self.dac_pj_per_bit * bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults_match_paper() {
        let l = LossParams::default();
        assert_eq!(l.directional_coupler_db, 0.02);
        assert_eq!(l.mr_drop_db, 0.5);
        assert_eq!(l.mr_through_db, 0.02);
        assert_eq!(l.propagation_db_per_cm, 0.1);
        assert_eq!(l.bend_db_per_90, 0.01);
        assert_eq!(l.eo_mr_drop_db, 1.6);
        assert_eq!(l.eo_mr_through_db, 0.33);
        assert_eq!(l.soa_gain_db, 20.0);
        let e = EnergyParams::default();
        assert_eq!(e.opcm_read_pj, 5.0);
        assert_eq!(e.opcm_write_pj, 250.0);
        assert_eq!(e.epcm_write_nj, 860.0);
        assert_eq!(e.dram_access_pj_per_bit, 20.0);
        assert_eq!(e.adc_fj_per_step, 24.4);
        assert_eq!(e.dac_pj_per_bit, 2.0);
        l.validate().unwrap();
        e.validate().unwrap();
    }

    #[test]
    fn adc_energy_scales_with_steps() {
        let e = EnergyParams::default();
        // 5-bit: 24.4 fJ × 32 steps = 780.8 fJ = 0.7808 pJ.
        assert!((e.adc_conversion_pj(5) - 0.7808).abs() < 1e-9);
        assert!(e.adc_conversion_pj(6) > e.adc_conversion_pj(5));
    }

    #[test]
    fn epcm_vs_opcm_write_gap() {
        // The 137× EPB story vs PhPIM hinges on nJ-vs-pJ write energies.
        let e = EnergyParams::default();
        let ratio = e.epcm_write_nj * 1000.0 / e.opcm_write_pj;
        assert!(ratio > 3000.0, "EPCM/OPCM write ratio = {ratio}");
    }

    #[test]
    fn invalid_params_rejected() {
        let mut l = LossParams::default();
        l.soa_gain_db = -1.0;
        assert!(l.validate().is_err());
        let mut e = EnergyParams::default();
        e.opcm_write_pj = 1.0;
        assert!(e.validate().is_err());
    }
}
