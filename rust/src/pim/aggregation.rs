//! The aggregation unit (paper §IV.C.3–4, Fig. 5(b)).
//!
//! Per bank: mode-demux → wavelength-selective photodetectors → 5-bit
//! ADCs → shift-and-add logic → SRAM accumulation cache → DAC + VCSEL
//! regeneration toward the E-O-E controller. The PD conversion also acts
//! as a noise filter (wavelength-specific PDs disentangle crosstalk).
//!
//! This module prices aggregation events (energy) and models the
//! pipeline latency contribution per MAC burst.

use crate::config::OpimaConfig;
use crate::util::units::Nanos;

/// Energy/latency cost of aggregating one burst of MAC results.
#[derive(Debug, Clone, Copy, Default)]
pub struct AggregationCost {
    pub adc_pj: f64,
    pub sram_pj: f64,
    pub shift_add_pj: f64,
    pub dac_pj: f64,
    pub latency_ns: Nanos,
}

impl AggregationCost {
    pub fn total_pj(&self) -> f64 {
        self.adc_pj + self.sram_pj + self.shift_add_pj + self.dac_pj
    }
}

/// Digital add energy (pJ) per shift-and-add op — standard-cell adder at
/// the aggregation unit's word width (Horowitz-class figure).
pub const SHIFT_ADD_PJ: f64 = 0.03;

/// Accumulator word width (bits) held in the aggregation SRAM: wide
/// enough for worst-case 32-bit recombinations with carries.
pub const ACCUM_BITS: u32 = 40;

/// Price one aggregation burst.
///
/// * `results` — number of analog MAC readouts digitized (ADC firings).
/// * `shift_adds` — digital recombination ops (from the TDM plan).
/// * `sram_accum_ops` — partial-sum read-modify-writes in the SRAM cache.
/// * `regenerated` — output channels re-emitted via DAC + VCSEL for the
///   trip to the E-O-E controller.
pub fn cost(
    cfg: &OpimaConfig,
    results: u64,
    shift_adds: u64,
    sram_accum_ops: u64,
    regenerated: u64,
) -> AggregationCost {
    let e = &cfg.energy;
    let adc_pj = results as f64 * e.adc_conversion_pj(cfg.pim.adc_bits);
    let sram_pj = sram_accum_ops as f64 * ACCUM_BITS as f64 * 2.0 * e.sram_pj_per_bit; // R+W
    let shift_add_pj = shift_adds as f64 * SHIFT_ADD_PJ;
    let dac_pj = regenerated as f64 * e.dac_conversion_pj(cfg.geometry.bits_per_cell);
    // Pipelined: one aggregation pipeline latency per burst, plus a cycle
    // per ADC batch beyond the first. The ADC array matches the λ-lane
    // count (one converter per wavelength per group per bank), so batches
    // are full-width.
    let adc_channels = (cfg.geometry.banks
        * cfg.geometry.subarray_groups
        * cfg.geometry.cols_per_subarray) as u64;
    let batches = results.div_ceil(adc_channels).max(1) as f64;
    let latency_ns = cfg.timing.aggregation_ns + (batches - 1.0) * cfg.timing.cycle_ns();
    AggregationCost {
        adc_pj,
        sram_pj,
        shift_add_pj,
        dac_pj,
        latency_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adc_dominates_small_bursts() {
        let cfg = OpimaConfig::paper();
        let c = cost(&cfg, 256, 0, 0, 0);
        // 256 × 0.7808 pJ ≈ 200 pJ.
        assert!((c.adc_pj - 256.0 * 0.7808).abs() < 1e-6);
        assert_eq!(c.total_pj(), c.adc_pj);
    }

    #[test]
    fn costs_compose() {
        let cfg = OpimaConfig::paper();
        let c = cost(&cfg, 512, 384, 512, 64);
        assert!(c.adc_pj > 0.0 && c.sram_pj > 0.0 && c.shift_add_pj > 0.0 && c.dac_pj > 0.0);
        assert!(
            (c.total_pj() - (c.adc_pj + c.sram_pj + c.shift_add_pj + c.dac_pj)).abs() < 1e-12
        );
    }

    #[test]
    fn latency_grows_with_batches() {
        let cfg = OpimaConfig::paper();
        // ADC channels = 4 banks × 16 groups × 256 λ = 16 384.
        let small = cost(&cfg, 16_384, 0, 0, 0);
        let large = cost(&cfg, 10 * 16_384, 0, 0, 0);
        assert!(large.latency_ns > small.latency_ns);
        assert!((small.latency_ns - cfg.timing.aggregation_ns).abs().raw() < 1e-12);
    }

    #[test]
    fn zero_burst_costs_pipeline_only() {
        let cfg = OpimaConfig::paper();
        let c = cost(&cfg, 0, 0, 0, 0);
        assert_eq!(c.total_pj(), 0.0);
        assert!(c.latency_ns > Nanos::ZERO);
    }
}
