//! Subarray grouping (paper §IV.C.2 and Fig. 7).
//!
//! A bank's 64 subarray rows are divided into `G` groups. At any time one
//! subarray row per group is lent to the PIM engine; the remaining rows
//! keep serving main-memory traffic. More groups ⇒ more parallel MAC
//! lanes but more laser/aggregation power and fewer memory-available
//! rows. Fig. 7 sweeps G and picks 16 as the MAC/W optimum.

use crate::config::{Geometry, OpimaConfig};
use crate::error::{Error, Result};

/// Static characterization of a grouping choice (one Fig. 7 x-axis point).
#[derive(Debug, Clone, Copy)]
pub struct GroupingPoint {
    pub groups: usize,
    /// Peak MAC operations per cycle across the whole memory.
    pub macs_per_cycle: u64,
    /// Peak MAC throughput (MAC/s).
    pub mac_throughput: f64,
    /// Total PIM-mode power (W): MDL + aggregation + interface.
    pub power_w: f64,
    /// Subarray rows per bank still available to memory traffic.
    pub rows_available: usize,
    /// Throughput efficiency (MAC/s per W) — Fig. 7's selection metric.
    pub macs_per_watt: f64,
}

/// Multimode waveguides feeding each bank's aggregation demux (§V.A:
/// "each of the four modes is assigned a separate multimode waveguide").
pub const AGG_WAVEGUIDES: usize = 4;

/// Groups whose results reach the aggregation unit concurrently: four
/// modes × four multimode waveguides = 16 clean channels per bank. More
/// groups than that must share channels and serialize their readouts, so
/// effective throughput saturates — this is why Fig. 7's MAC/W peaks at
/// 16 rather than growing monotonically.
pub fn effective_groups(geom: &Geometry, groups: usize) -> usize {
    groups.min(geom.mdm_degree * AGG_WAVEGUIDES)
}

/// Peak concurrent MAC lanes for a grouping: per bank, each *effective*
/// group drives `optical_accum` subarrays of its active row concurrently,
/// each contributing `cols_per_subarray` wavelength lanes whose products
/// merge in the shared readout bus (the paper's in-waveguide
/// accumulation).
pub fn macs_per_cycle(geom: &Geometry, groups: usize, optical_accum: usize) -> u64 {
    (geom.banks * effective_groups(geom, groups) * geom.cols_per_subarray * optical_accum)
        as u64
}

/// Number of MDLs lit concurrently for a grouping.
pub fn active_mdls(geom: &Geometry, groups: usize, optical_accum: usize) -> u64 {
    (geom.banks * groups * optical_accum * geom.cols_per_subarray) as u64
}

/// Evaluate one grouping choice.
pub fn evaluate(cfg: &OpimaConfig, groups: usize) -> Result<GroupingPoint> {
    let geom = &cfg.geometry;
    if groups == 0 || groups > geom.subarray_rows {
        return Err(Error::Config(format!(
            "groups must be 1..={}, got {groups}",
            geom.subarray_rows
        )));
    }
    let accum = cfg.pim.optical_accum;
    let mpc = macs_per_cycle(geom, groups, accum);
    let f_hz = cfg.timing.clock_ghz * 1e9;
    let mac_throughput = mpc as f64 * f_hz;

    // PIM power: lit MDLs + per-group aggregation interfaces + controller.
    let mdl_w = active_mdls(geom, groups, accum) as f64 * cfg.power.mdl_wallplug_mw.raw() / 1e3;
    // ADC/DAC interface energy at the achieved conversion rate: one ADC
    // conversion per λ-lane result per cycle, one DAC regeneration per
    // group output channel.
    let adc_w = (geom.banks * groups * geom.cols_per_subarray) as f64
        * cfg.energy.adc_conversion_pj(cfg.pim.adc_bits)
        * 1e-12
        * f_hz
        * ADC_ACTIVITY;
    // DAC/VCSEL regeneration runs per group output channel (16 per
    // group), not per λ lane.
    let dac_w = (geom.banks * groups * 16) as f64
        * cfg.energy.dac_conversion_pj(cfg.geometry.bits_per_cell)
        * 1e-12
        * f_hz
        * DAC_ACTIVITY;
    let vcsel_w = (geom.banks * groups) as f64 * 16.0 * cfg.power.vcsel_mw.raw() / 1e3;
    let agg_logic_w = cfg.power.aggregation_logic_w * (groups as f64 / 16.0).max(0.25)
        * geom.banks as f64;
    let power_w = mdl_w + adc_w + dac_w + vcsel_w + agg_logic_w + cfg.power.controller_w;

    let rows_available = geom.subarray_rows - groups;
    Ok(GroupingPoint {
        groups,
        macs_per_cycle: mpc,
        mac_throughput,
        power_w,
        rows_available,
        macs_per_watt: mac_throughput / power_w,
    })
}

/// ADC/DAC duty factors: conversions fire on result-carrying cycles only
/// (the TDM nibble loop and stride walks leave idle cycles); calibrated
/// so the full-system power matches Fig. 8's 55.9 W envelope.
pub const ADC_ACTIVITY: f64 = 0.15;
pub const DAC_ACTIVITY: f64 = 0.15;

/// Sweep groupings (Fig. 7's x-axis) and return the evaluated points.
pub fn sweep(cfg: &OpimaConfig, choices: &[usize]) -> Result<Vec<GroupingPoint>> {
    choices.iter().map(|&g| evaluate(cfg, g)).collect()
}

/// The MAC/W-optimal grouping among divisors of the subarray-row count,
/// excluding the degenerate extremes (1 and all-rows), as the paper does.
pub fn select_optimal(cfg: &OpimaConfig) -> Result<GroupingPoint> {
    let rows = cfg.geometry.subarray_rows;
    let candidates: Vec<usize> = (2..rows)
        .filter(|g| rows % g == 0)
        .collect();
    let pts = sweep(cfg, &candidates)?;
    pts.into_iter()
        .max_by(|a, b| a.macs_per_watt.total_cmp(&b.macs_per_watt))
        .ok_or_else(|| Error::Config("no grouping candidates".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configuration_peaks_at_16_groups() {
        // Fig. 7: "16 subarray groups enable the maximum throughput
        // efficiency (MAC/Watt)".
        let cfg = OpimaConfig::paper();
        let best = select_optimal(&cfg).unwrap();
        assert_eq!(best.groups, 16, "MAC/W optimum must be 16 groups");
    }

    #[test]
    fn throughput_grows_then_saturates_power_grows_rows_shrink() {
        let cfg = OpimaConfig::paper();
        let pts = sweep(&cfg, &[1, 2, 4, 8, 16, 32, 64]).unwrap();
        for w in pts.windows(2) {
            if w[1].groups <= 16 {
                assert!(w[1].mac_throughput > w[0].mac_throughput);
            } else {
                // Beyond 16 groups the aggregation channels (4 modes × 4
                // waveguides) are exhausted; readouts serialize.
                assert_eq!(w[1].mac_throughput, w[0].mac_throughput);
            }
            assert!(w[1].power_w > w[0].power_w);
            assert!(w[1].rows_available < w[0].rows_available);
        }
    }

    #[test]
    fn sixty_four_groups_starve_memory() {
        let cfg = OpimaConfig::paper();
        let p = evaluate(&cfg, 64).unwrap();
        assert_eq!(p.rows_available, 0, "64 groups leave no memory rows");
    }

    #[test]
    fn paper_grouping_peak_throughput() {
        let cfg = OpimaConfig::paper();
        let p = evaluate(&cfg, 16).unwrap();
        // 4 banks × 16 groups × 256 λ × 2-way optical accumulation
        assert_eq!(p.macs_per_cycle, 32_768);
        // × 5 GHz = 163.84 TMAC/s peak.
        assert!((p.mac_throughput - 163.84e12).abs() < 1e6);
    }

    #[test]
    fn invalid_grouping_rejected() {
        let cfg = OpimaConfig::paper();
        assert!(evaluate(&cfg, 0).is_err());
        assert!(evaluate(&cfg, 65).is_err());
    }
}
