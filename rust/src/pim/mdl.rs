//! Microdisk-laser (MDL) arrays (paper §IV.C.2).
//!
//! Each subarray carries `C` MDLs (one per column/wavelength) coupled
//! onto its input waveguide by directional couplers. They let the PIM
//! engine read any row without the external main-memory laser, and since
//! the arrays are independent, many subarrays can be read concurrently.
//! Kernel vectors are encoded as per-λ amplitudes via MDL drive DACs.

use crate::config::OpimaConfig;
use crate::error::{Error, Result};

/// One subarray's MDL array state.
#[derive(Debug, Clone)]
pub struct MdlArray {
    /// Number of lasers (= columns per subarray).
    pub lanes: usize,
    /// Current per-λ drive levels (quantized amplitudes), if lit.
    levels: Option<Vec<u8>>,
}

impl MdlArray {
    pub fn new(lanes: usize) -> Self {
        Self { lanes, levels: None }
    }

    /// Program a kernel vector onto the array: one level per wavelength.
    /// Values must fit the drive DAC resolution (= cell bit density, so a
    /// one-shot multiply aligns operand precisions).
    pub fn program(&mut self, levels: &[u8], bits: u32) -> Result<()> {
        if levels.len() > self.lanes {
            return Err(Error::Command(format!(
                "kernel vector of {} exceeds {} MDL lanes",
                levels.len(),
                self.lanes
            )));
        }
        let max = (1u16 << bits) as u8;
        if let Some(&bad) = levels.iter().find(|&&l| l as u16 >= max as u16) {
            return Err(Error::Command(format!(
                "level {bad} exceeds {bits}-bit drive range"
            )));
        }
        let mut v = levels.to_vec();
        v.resize(self.lanes, 0); // unused lanes dark
        self.levels = Some(v);
        Ok(())
    }

    /// Lit lanes (nonzero drive).
    pub fn lit_lanes(&self) -> usize {
        self.levels
            .as_ref()
            .map(|v| v.iter().filter(|&&l| l > 0).count())
            .unwrap_or(0)
    }

    /// Turn the array off (between PIM bursts).
    pub fn dark(&mut self) {
        self.levels = None;
    }

    pub fn is_lit(&self) -> bool {
        self.levels.is_some()
    }

    /// Energy to (re)program the array: one DAC conversion per lane.
    pub fn program_energy_pj(&self, cfg: &OpimaConfig, lanes: usize) -> f64 {
        lanes as f64 * cfg.energy.dac_conversion_pj(cfg.geometry.bits_per_cell)
    }

    /// Wall-plug power while lit.
    pub fn power_mw(&self, cfg: &OpimaConfig) -> crate::util::units::Milliwatts {
        if self.is_lit() {
            self.lanes as f64 * cfg.power.mdl_wallplug_mw
        } else {
            crate::util::units::Milliwatts::ZERO
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_and_query() {
        let mut a = MdlArray::new(256);
        a.program(&[1, 0, 15, 7], 4).unwrap();
        assert!(a.is_lit());
        assert_eq!(a.lit_lanes(), 3);
        a.dark();
        assert_eq!(a.lit_lanes(), 0);
    }

    #[test]
    fn rejects_out_of_range() {
        let mut a = MdlArray::new(4);
        assert!(a.program(&[16], 4).is_err(), "level 16 needs 5 bits");
        assert!(a.program(&[1; 5], 4).is_err(), "too many lanes");
        a.program(&[15], 4).unwrap();
    }

    #[test]
    fn power_only_when_lit() {
        let cfg = OpimaConfig::paper();
        let mut a = MdlArray::new(256);
        assert_eq!(a.power_mw(&cfg), crate::util::units::Milliwatts::ZERO);
        a.program(&[1; 256], 4).unwrap();
        assert!((a.power_mw(&cfg) - 256.0 * cfg.power.mdl_wallplug_mw).abs().raw() < 1e-12);
    }

    #[test]
    fn program_energy_uses_dac_figure() {
        let cfg = OpimaConfig::paper();
        let a = MdlArray::new(256);
        // 2 pJ/bit × 4 bits × 256 lanes = 2048 pJ.
        assert!((a.program_energy_pj(&cfg, 256) - 2048.0).abs() < 1e-9);
    }
}
