//! The PIM engine — OPIMA's compute contribution (paper §IV.C).
//!
//! Submodules mirror the paper's four challenges:
//! - [`group`] — subarray grouping: one subarray row per group does PIM
//!   while the rest serve memory traffic (challenges 1 & 2).
//! - [`mdl`] — per-subarray microdisk-laser arrays: memory-independent
//!   PIM reads (challenge 2).
//! - [`wdm`] — wavelength scheduling: in-waveguide accumulation pairing
//!   and the 1×1-kernel serialization rule (challenge 3).
//! - [`tdm`] — time-division nibble decomposition bridging parameter
//!   bit-widths to the 4-bit cells (challenge 4).
//! - [`aggregation`] — the per-bank aggregation unit: PD + 5-bit ADC +
//!   shift-and-add + SRAM + DAC/VCSEL regeneration (challenges 3 & 4).
//! - [`scheduler`] — composes all of the above into per-layer cycle and
//!   energy costs; the quantity the analyzer rolls up into Figs. 7–12.

pub mod aggregation;
pub mod group;
pub mod mdl;
pub mod scheduler;
pub mod tdm;
pub mod wdm;

pub use scheduler::{LayerCost, LayerWork, PimScheduler};
