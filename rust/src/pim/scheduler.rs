//! PIM cost scheduler: per-layer cycle and energy accounting.
//!
//! This composes the grouping (parallel lanes), WDM accumulation rules
//! (1×1 serialization), TDM bit-width bridging, aggregation-unit pricing
//! and the OPCM writeback path into the per-layer numbers the analyzer
//! rolls up into the paper's Figs. 7–12.

use crate::config::OpimaConfig;
use crate::error::Result;
use crate::memory::timing::{write_latency_ns, write_quarter_row};
use crate::pim::{aggregation, tdm, wdm};
use crate::util::units::Nanos;

/// A unit of CNN work as emitted by the mapper (one layer, one inference).
#[derive(Debug, Clone)]
pub struct LayerWork {
    pub name: String,
    /// MAC operations at full operand precision.
    pub macs: u64,
    /// Spatial accumulation depth: kernel rows that pair across subarrays
    /// in a group (kh). 1 for 1×1 kernels and FC row-chunks that cannot
    /// pair (the paper's serialization hazard).
    pub spatial_accum: usize,
    /// Activation operand width (bits).
    pub act_bits: u32,
    /// Weight operand width (bits).
    pub weight_bits: u32,
    /// Output feature elements produced.
    pub out_elems: u64,
    /// Weight parameters involved (for MDL programming counts).
    pub weight_elems: u64,
    /// Subarrays occupied by this layer's stationary operands (the
    /// mapper's placement footprint) — the resource the simulation
    /// timeline and the router's co-residency accounting charge.
    pub subarrays: usize,
}

/// Cost of one layer on the PIM substrate.
#[derive(Debug, Clone, Default)]
pub struct LayerCost {
    pub name: String,
    /// In-memory MAC + aggregation time (the paper's "processing").
    /// Always equal to `mac_ns + aggregation_ns`.
    pub processing_ns: Nanos,
    /// In-waveguide MAC time alone (MDL cycles) — the stage the timeline
    /// schedules against the layer's subarray/MDL resources.
    pub mac_ns: Nanos,
    /// Aggregation-unit pipeline time alone (PD + ADC + shift-add) — the
    /// stage the timeline schedules against the shared aggregation units.
    pub aggregation_ns: Nanos,
    /// Non-linearity application + OPCM write of output maps ("writeback").
    pub writeback_ns: Nanos,
    /// Command decomposition of `writeback_ns` for the command-level
    /// controllers ([`crate::memory::writeback`]): number of µs-class MLC
    /// program trains (the optical write-power budget caps each train at
    /// a quarter-row of wavelengths).
    pub wb_trains: u64,
    /// Duration of one MLC program train.
    pub wb_train_ns: Nanos,
    /// E-O-E staging drain appended after the last train. Invariant:
    /// `writeback_ns == wb_trains × wb_train_ns + wb_settle_ns`.
    pub wb_settle_ns: Nanos,
    /// OPCM cell read energy (pJ).
    pub read_pj: f64,
    /// MDL laser energy: wall-plug power × lit time + programming DACs (pJ).
    pub mdl_pj: f64,
    /// Aggregation-unit energy (ADC+SRAM+shift-add+DAC regen) (pJ).
    pub aggregation_pj: f64,
    /// Writeback OPCM write energy (pJ).
    pub writeback_pj: f64,
    /// Number of PIM cycles consumed.
    pub cycles: u64,
    /// Effective MAC lanes used.
    pub lanes: u64,
    /// Subarray footprint inherited from the [`LayerWork`].
    pub subarrays: usize,
}

impl LayerCost {
    pub fn total_ns(&self) -> Nanos {
        self.processing_ns + self.writeback_ns
    }

    pub fn dynamic_pj(&self) -> f64 {
        self.read_pj + self.mdl_pj + self.aggregation_pj + self.writeback_pj
    }
}

/// The scheduler: holds the configuration and prices layer work.
#[derive(Debug, Clone)]
pub struct PimScheduler {
    cfg: OpimaConfig,
}

impl PimScheduler {
    pub fn new(cfg: &OpimaConfig) -> Result<Self> {
        cfg.validate()?;
        Ok(Self { cfg: cfg.clone() })
    }

    pub fn config(&self) -> &OpimaConfig {
        &self.cfg
    }

    /// Effective parallel MAC lanes for a layer.
    pub fn lanes_for(&self, spatial_accum: usize) -> u64 {
        let g = &self.cfg.geometry;
        if spatial_accum >= 2 {
            (g.banks
                * g.subarray_groups
                * wdm::effective_lanes(
                    g.cols_per_subarray,
                    self.cfg.pim.optical_accum,
                    spatial_accum,
                )) as u64
        } else {
            // Accumulation-free products: a few guarded lanes per bank
            // (λ sharing would corrupt the lone products).
            (g.banks * self.cfg.pim.one_by_one_lanes_per_bank) as u64
        }
    }

    /// Price one layer.
    pub fn cost_layer(&self, work: &LayerWork) -> Result<LayerCost> {
        let cfg = &self.cfg;
        let plan = tdm::plan(work.act_bits, work.weight_bits, cfg.geometry.bits_per_cell)?;
        let lanes = self.lanes_for(work.spatial_accum);
        let nibble_macs = work.macs * plan.steps as u64;
        let cycles = nibble_macs.div_ceil(lanes);
        // MDL kernel-vector programming: each distinct weight digit vector
        // is loaded once per TDM step; a program covers a full MDL array.
        let programs = (work.weight_elems * plan.steps as u64)
            .div_ceil(cfg.geometry.cols_per_subarray as u64);

        // --- processing time -------------------------------------------
        let agg = aggregation::cost(
            cfg,
            nibble_macs / cfg.pim.optical_accum.max(1) as u64,
            work.out_elems * plan.shift_adds as u64,
            work.out_elems * plan.steps as u64,
            work.out_elems,
        );
        let mac_ns = cycles as f64 * cfg.timing.cycle_ns();
        let processing_ns = mac_ns + agg.latency_ns;

        // --- energies ----------------------------------------------------
        // One OPCM cell read per nibble MAC (input-stationary operand).
        let read_pj = nibble_macs as f64 * cfg.energy.opcm_read_pj;
        // MDL wall-plug while processing (lit lanes only) + program DACs.
        let mdl_power_mw = lanes as f64 * cfg.power.mdl_wallplug_mw;
        // Cross-unit energy = power × time chain, priced with the explicit
        // mW→W and ns→s factor trail (1e-3/1e-9 are not time conversions).
        let mdl_pj = mdl_power_mw.raw() * 1e-3 * processing_ns.raw() * 1e-9 * 1e12
            + programs as f64
                * cfg.geometry.cols_per_subarray as f64
                * cfg.energy.dac_conversion_pj(cfg.geometry.bits_per_cell);

        // --- writeback: quantize outputs, write OPCM cells ---------------
        let out_bits = work.out_elems * work.act_bits as u64;
        let out_cells = out_bits.div_ceil(cfg.geometry.bits_per_cell as u64);
        let lanes_wb = cfg.pim.writeback_lanes as u64;
        let trains = out_cells.div_ceil(lanes_wb);
        // One train programs a power-budget quantum (a quarter-row of
        // wavelengths) at the worst-case MLC pulse duration; the E-O-E
        // staging drain is the tail the commands settle into.
        let quarter = write_quarter_row(cfg.geometry.cols_per_subarray);
        let wb_train_ns =
            write_latency_ns(&cfg.timing, quarter, cfg.geometry.cols_per_subarray);
        let wb_settle_ns = cfg.timing.writeback_overhead_ns * work.out_elems as f64
            / lanes_wb.max(1) as f64;
        let writeback_ns = trains as f64 * wb_train_ns + wb_settle_ns;
        let writeback_pj = out_cells as f64 * cfg.energy.opcm_write_pj;

        Ok(LayerCost {
            name: work.name.clone(),
            processing_ns,
            mac_ns,
            aggregation_ns: agg.latency_ns,
            writeback_ns,
            wb_trains: trains,
            wb_train_ns,
            wb_settle_ns,
            read_pj,
            mdl_pj,
            aggregation_pj: agg.total_pj(),
            writeback_pj,
            cycles,
            lanes,
            subarrays: work.subarrays,
        })
    }

    /// Price a whole network (sum of layers; layers execute sequentially
    /// because each consumes its predecessor's written-back maps).
    pub fn cost_network(&self, layers: &[LayerWork]) -> Result<Vec<LayerCost>> {
        layers.iter().map(|w| self.cost_layer(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> PimScheduler {
        PimScheduler::new(&OpimaConfig::paper()).unwrap()
    }

    fn conv_work(macs: u64, kh: usize, out_elems: u64) -> LayerWork {
        LayerWork {
            name: "conv".into(),
            macs,
            spatial_accum: kh,
            act_bits: 4,
            weight_bits: 4,
            out_elems,
            weight_elems: 1_000,
            subarrays: 4,
        }
    }

    #[test]
    fn stage_costs_partition_processing() {
        // The timeline composes mac/aggregation/writeback stages; they
        // must partition the analytical totals exactly.
        let s = sched();
        let c = s.cost_layer(&conv_work(1_000_000, 3, 10_000)).unwrap();
        assert!(c.mac_ns > Nanos::ZERO && c.aggregation_ns > Nanos::ZERO);
        assert!((c.processing_ns - (c.mac_ns + c.aggregation_ns)).abs().raw() < 1e-9);
        assert!(
            (c.total_ns() - (c.mac_ns + c.aggregation_ns + c.writeback_ns)).abs().raw() < 1e-9
        );
        assert_eq!(c.subarrays, 4, "footprint carried through pricing");
    }

    #[test]
    fn four_bit_conv_uses_full_lanes() {
        let s = sched();
        let c = s.cost_layer(&conv_work(1_000_000, 3, 10_000)).unwrap();
        assert_eq!(c.lanes, 32_768);
        assert_eq!(c.cycles, 1_000_000u64.div_ceil(32_768));
    }

    #[test]
    fn one_by_one_kernels_serialize() {
        let s = sched();
        let full = s.cost_layer(&conv_work(1_000_000, 3, 10_000)).unwrap();
        let lone = s.cost_layer(&conv_work(1_000_000, 1, 10_000)).unwrap();
        assert_eq!(lone.lanes, 8);
        assert!(
            lone.processing_ns > 100.0 * full.processing_ns,
            "1×1: {} vs {}",
            lone.processing_ns,
            full.processing_ns
        );
    }

    #[test]
    fn eight_bit_quadruples_processing() {
        let s = sched();
        let mut w = conv_work(1_000_000, 3, 10_000);
        let c4 = s.cost_layer(&w).unwrap();
        w.act_bits = 8;
        w.weight_bits = 8;
        let c8 = s.cost_layer(&w).unwrap();
        let ratio = c8.cycles as f64 / c4.cycles as f64;
        assert!((3.9..=4.1).contains(&ratio), "TDM ratio = {ratio}");
        // Writeback also doubles (8-bit activations).
        assert!(c8.writeback_pj > 1.9 * c4.writeback_pj);
    }

    #[test]
    fn writeback_decomposition_partitions_flat_figure() {
        // The command-level controllers replay wb_trains × wb_train_ns
        // + wb_settle_ns; the sum must reproduce the flat scalar with
        // the exact rounding order used to compute it.
        let s = sched();
        for out_elems in [1_000u64, 10_000, 100_000] {
            let c = s.cost_layer(&conv_work(1_000_000, 3, out_elems)).unwrap();
            assert!(c.wb_trains > 0);
            assert!(c.wb_train_ns > Nanos::ZERO);
            assert_eq!(
                c.writeback_ns,
                c.wb_trains as f64 * c.wb_train_ns + c.wb_settle_ns,
                "decomposition must be bit-identical for {out_elems} elems"
            );
        }
    }

    #[test]
    fn writeback_dominates_typical_conv() {
        // The Fig. 9 shape: for multi-row kernels, OPCM writeback latency
        // far exceeds in-memory processing.
        let s = sched();
        let c = s.cost_layer(&conv_work(10_000_000, 3, 100_000)).unwrap();
        assert!(c.writeback_ns > 5.0 * c.processing_ns);
    }

    #[test]
    fn energy_breakdown_positive_and_consistent() {
        let s = sched();
        let c = s.cost_layer(&conv_work(500_000, 3, 5_000)).unwrap();
        assert!(c.read_pj > 0.0);
        assert!(c.mdl_pj > 0.0);
        assert!(c.aggregation_pj > 0.0);
        assert!(c.writeback_pj > 0.0);
        // Table I: one 5 pJ read per nibble MAC.
        assert!((c.read_pj - 500_000.0 * 5.0).abs() < 1e-6);
        assert!((c.dynamic_pj()
            - (c.read_pj + c.mdl_pj + c.aggregation_pj + c.writeback_pj))
            .abs()
            < 1e-9);
    }

    #[test]
    fn network_costs_sum_layers() {
        let s = sched();
        let layers = vec![
            conv_work(100_000, 3, 1_000),
            conv_work(200_000, 1, 2_000),
        ];
        let costs = s.cost_network(&layers).unwrap();
        assert_eq!(costs.len(), 2);
        assert!(costs[1].processing_ns > costs[0].processing_ns);
    }

    #[test]
    fn rejects_unsupported_bitwidths() {
        let s = sched();
        let mut w = conv_work(1000, 3, 100);
        w.act_bits = 6;
        assert!(s.cost_layer(&w).is_err());
    }
}
