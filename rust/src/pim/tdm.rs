//! TDM nibble decomposition (paper §IV.C.4, challenge 4).
//!
//! OPCM cells hold 4-bit levels; CNN parameters may be 4/8/16/32-bit.
//! Wider operands are split into 4-bit nibbles and every nibble of one
//! operand multiplies every nibble of the other across TDM steps, with
//! shift-and-add recombination in the aggregation unit. This trades
//! throughput for bit-width flexibility — the paper's 8-bit variants run
//! 4× more MAC steps than the 4-bit ones.

use crate::error::{Error, Result};

/// Decomposition plan for one (activation bits × weight bits) pairing on
/// cells of a given density.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TdmPlan {
    /// Nibbles (cell-width digits) per activation operand.
    pub act_digits: u32,
    /// Nibbles per weight operand.
    pub weight_digits: u32,
    /// TDM steps = act_digits × weight_digits (MAC-op multiplier).
    pub steps: u32,
    /// Digital shift-and-add operations per output element.
    pub shift_adds: u32,
}

/// Build a TDM plan. Operand widths must be multiples of the cell width.
pub fn plan(act_bits: u32, weight_bits: u32, cell_bits: u32) -> Result<TdmPlan> {
    if cell_bits == 0 {
        return Err(Error::Config("cell_bits must be positive".into()));
    }
    for (name, bits) in [("activation", act_bits), ("weight", weight_bits)] {
        if bits == 0 || bits % cell_bits != 0 {
            return Err(Error::Mapping(format!(
                "{name} width {bits} is not a positive multiple of the \
                 {cell_bits}-bit cell density"
            )));
        }
    }
    let act_digits = act_bits / cell_bits;
    let weight_digits = weight_bits / cell_bits;
    let steps = act_digits * weight_digits;
    Ok(TdmPlan {
        act_digits,
        weight_digits,
        steps,
        // Recombining S partial products needs S−1 adds (each with a shift).
        shift_adds: steps.saturating_sub(1),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_bit_is_one_shot() {
        let p = plan(4, 4, 4).unwrap();
        assert_eq!(p.steps, 1);
        assert_eq!(p.shift_adds, 0);
    }

    #[test]
    fn eight_bit_quadruples_work() {
        let p = plan(8, 8, 4).unwrap();
        assert_eq!(p.steps, 4);
        assert_eq!(p.shift_adds, 3);
    }

    #[test]
    fn mixed_widths() {
        let p = plan(8, 4, 4).unwrap();
        assert_eq!(p.steps, 2);
        let p = plan(16, 8, 4).unwrap();
        assert_eq!(p.steps, 8);
        let p = plan(32, 32, 4).unwrap();
        assert_eq!(p.steps, 64);
    }

    #[test]
    fn non_multiple_widths_rejected() {
        assert!(plan(6, 4, 4).is_err());
        assert!(plan(4, 10, 4).is_err());
        assert!(plan(0, 4, 4).is_err());
    }

    #[test]
    fn two_bit_cells() {
        let p = plan(8, 8, 2).unwrap();
        assert_eq!(p.steps, 16);
    }
}
