//! WDM scheduling and the in-waveguide accumulation rule (paper §IV.C.3,
//! §IV.D).
//!
//! Products travelling on the *same wavelength* in a shared readout bus
//! interfere and sum — that is the accumulate of the MAC. The scheduler
//! must therefore ensure every λ in a bus carries only products that are
//! *meant* to be summed. Kernels with spatial extent (K ≥ 2 rows)
//! naturally pair rows across subarrays of a group; 1×1 kernels produce
//! lone products with no accumulation partner, so their λ lanes cannot be
//! shared — OPIMA loses most of its parallelism on such layers (the
//! paper's InceptionV2/MobileNet observation).

use crate::error::{Error, Result};

/// Conflict-checked plan for one wavelength batch in one readout bus.
#[derive(Debug, Clone)]
pub struct WdmAssignment {
    /// λ index → accumulation-group tag (products with equal tag sum).
    pub lanes: Vec<Option<u32>>,
}

impl WdmAssignment {
    pub fn new(wdm_degree: usize) -> Self {
        Self {
            lanes: vec![None; wdm_degree],
        }
    }

    /// Assign a contiguous span of wavelengths to an accumulation group.
    /// Errors if any lane is already carrying a different group's product
    /// (that interference would corrupt both results).
    pub fn assign(&mut self, start: usize, len: usize, tag: u32) -> Result<()> {
        if start + len > self.lanes.len() {
            return Err(Error::Mapping(format!(
                "λ span {start}+{len} exceeds WDM degree {}",
                self.lanes.len()
            )));
        }
        for lane in &self.lanes[start..start + len] {
            if let Some(existing) = lane {
                if *existing != tag {
                    return Err(Error::Mapping(format!(
                        "λ conflict: lane already carries group {existing}"
                    )));
                }
            }
        }
        for lane in &mut self.lanes[start..start + len] {
            *lane = Some(tag);
        }
        Ok(())
    }

    pub fn used_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }
}

/// Effective parallel MAC lanes for a layer, given the kernel's
/// accumulation depth.
///
/// * `wdm_degree` — λ lanes per subarray (= columns).
/// * `optical_accum` — subarrays whose same-λ products merge in the bus.
/// * `accum_len` — the layer's reduction length per output element.
///
/// Layers with `accum_len == 1` (1×1 convolutions) cannot share λ lanes:
/// each product must travel alone, and concurrent unrelated products
/// on the bus would corrupt it, so only one subarray of the group can
/// drive each λ *and* adjacent λ reuse is restricted to keep the bus
/// clean — an effective `ONE_BY_ONE_PENALTY`× serialization.
pub fn effective_lanes(wdm_degree: usize, optical_accum: usize, accum_len: usize) -> usize {
    if accum_len >= 2 {
        wdm_degree * optical_accum
    } else {
        (wdm_degree / ONE_BY_ONE_PENALTY).max(1)
    }
}

/// Serialization factor for accumulation-free (1×1) workloads; calibrated
/// against the paper's Fig. 9 (MobileNet's processing latency exceeding
/// ResNet18's despite 2.75× fewer parameters).
pub const ONE_BY_ONE_PENALTY: usize = 16;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_conflicts_detected() {
        let mut a = WdmAssignment::new(8);
        a.assign(0, 4, 1).unwrap();
        a.assign(4, 4, 2).unwrap();
        // Same tag overlapping is fine (accumulation partners).
        a.assign(0, 2, 1).unwrap();
        // Different tag overlapping is interference.
        assert!(a.assign(3, 2, 9).is_err());
        assert_eq!(a.used_lanes(), 8);
    }

    #[test]
    fn span_bounds_checked() {
        let mut a = WdmAssignment::new(4);
        assert!(a.assign(2, 3, 0).is_err());
    }

    #[test]
    fn one_by_one_kernels_lose_parallelism() {
        let full = effective_lanes(256, 2, 9); // 3×3 kernel
        let lone = effective_lanes(256, 2, 1); // 1×1 kernel
        assert_eq!(full, 512);
        assert_eq!(lone, 16);
        assert!(full / lone >= 32, "paper: 1×1 layers forfeit parallelism");
    }

    #[test]
    fn minimum_one_lane() {
        assert_eq!(effective_lanes(4, 2, 1), 1);
    }
}
