//! AOT artifact manifest (`artifacts/manifest.json`) and the prepared
//! [`ProgramHandle`] the serving path executes batches through.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape/dtype description of one artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    /// Input tensor shapes.
    pub input_shapes: Vec<Vec<usize>>,
    /// Output tensor shape (single output per artifact).
    pub output_shape: Vec<usize>,
    /// Operand bit-width for quantized artifacts (None for fp32).
    pub bits: Option<u32>,
}

impl ArtifactInfo {
    /// Element count of input `i`.
    pub fn input_elems(&self, i: usize) -> usize {
        self.input_shapes[i].iter().product()
    }

    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product()
    }
}

/// A prepared executor program: one artifact's shapes validated and
/// flattened exactly once, shared read-only behind an `Arc`.
///
/// The per-batch hot path used to re-fetch the [`ArtifactInfo`] from the
/// manifest by name (a string hash lookup plus a deep clone) and
/// re-derive every shape product on every `run_f32` call. A handle is
/// built once — by [`Executor::prepare`](crate::runtime::Executor::prepare)
/// or directly by the serving plan registry — and
/// [`run_prepared`](crate::runtime::Executor::run_prepared) then only
/// compares precomputed element counts: no string lookup, no
/// `ArtifactInfo` clone, no re-validation per batch.
#[derive(Debug, Clone)]
pub struct ProgramHandle {
    info: Arc<ArtifactInfo>,
    /// Flattened element count per input, in input order.
    input_lens: Vec<usize>,
    /// Flattened element count of the single output.
    output_len: usize,
}

impl ProgramHandle {
    /// Flatten `info`'s shapes into the handle's precomputed counts.
    pub fn new(info: ArtifactInfo) -> Self {
        let input_lens = (0..info.input_shapes.len())
            .map(|i| info.input_elems(i))
            .collect();
        let output_len = info.output_elems();
        Self {
            info: Arc::new(info),
            input_lens,
            output_len,
        }
    }

    /// Artifact name the handle executes.
    pub fn name(&self) -> &str {
        &self.info.name
    }

    /// The full shape/dtype description behind the handle.
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    /// Flattened element counts per input.
    pub fn input_lens(&self) -> &[usize] {
        &self.input_lens
    }

    /// Flattened element count of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.input_lens[i]
    }

    /// Flattened element count of the output.
    pub fn output_len(&self) -> usize {
        self.output_len
    }
}

/// The parsed manifest plus the directory it lives in.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    pub batch: usize,
    pub image_size: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = Json::parse(&text)?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Json("manifest missing 'artifacts'".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, info) in arts {
            let input_shapes = info
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Json(format!("{name}: missing inputs")))?
                .iter()
                .map(|inp| {
                    inp.get("shape")
                        .and_then(Json::as_arr)
                        .map(|s| s.iter().filter_map(|d| d.as_f64()).map(|d| d as usize).collect())
                        .ok_or_else(|| Error::Json(format!("{name}: bad input shape")))
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            let output_shape = info
                .get("output_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Json(format!("{name}: missing output_shape")))?
                .iter()
                .filter_map(|d| d.as_f64())
                .map(|d| d as usize)
                .collect();
            let bits = info.get("bits").and_then(Json::as_f64).map(|b| b as u32);
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    input_shapes,
                    output_shape,
                    bits,
                },
            );
        }
        let batch = v.get("batch").and_then(Json::as_f64).unwrap_or(8.0) as usize;
        let image_size = v.get("image_size").and_then(Json::as_f64).unwrap_or(12.0) as usize;
        Ok(Self {
            dir: dir.to_path_buf(),
            artifacts,
            batch,
            image_size,
        })
    }

    /// Path of the HLO text file for an artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in manifest")))
    }

    /// In-memory manifest for the sim executor backend: the three served
    /// CNN variants at the given batch/image size, no files on disk.
    ///
    /// Lets the serving engine, its concurrency tests and its benches run
    /// in environments where `make artifacts` has never been executed.
    pub fn synthetic(batch: usize, image_size: usize) -> Self {
        let mut artifacts = BTreeMap::new();
        for (name, bits) in [
            (format!("cnn_fp32_b{batch}"), None),
            (format!("cnn_int8_b{batch}"), Some(8)),
            (format!("cnn_int4_b{batch}"), Some(4)),
        ] {
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    input_shapes: vec![vec![batch, image_size, image_size, 1]],
                    output_shape: vec![batch, 4],
                    bits,
                },
            );
        }
        Self {
            dir: PathBuf::from("<synthetic>"),
            artifacts,
            batch,
            image_size,
        }
    }

    /// Default artifacts directory: `$OPIMA_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("OPIMA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.contains_key("photonic_mac_4b"));
        assert!(m.artifacts.contains_key("cnn_fp32_b8"));
        let mac = m.get("photonic_mac_4b").unwrap();
        assert_eq!(mac.input_shapes.len(), 2);
        assert_eq!(mac.bits, Some(4));
        assert!(m.hlo_path("photonic_mac_4b").exists());
        let cnn = m.get("cnn_fp32_b8").unwrap();
        assert_eq!(cnn.input_shapes[0], vec![8, 12, 12, 1]);
        assert_eq!(cnn.output_shape, vec![8, 4]);
        assert_eq!(cnn.output_elems(), 32);
    }

    #[test]
    fn synthetic_manifest_covers_served_variants() {
        let m = Manifest::synthetic(8, 12);
        assert_eq!(m.batch, 8);
        assert_eq!(m.image_size, 12);
        for name in ["cnn_fp32_b8", "cnn_int8_b8", "cnn_int4_b8"] {
            let a = m.get(name).unwrap();
            assert_eq!(a.input_shapes[0], vec![8, 12, 12, 1]);
            assert_eq!(a.output_shape, vec![8, 4]);
        }
        assert_eq!(m.get("cnn_int4_b8").unwrap().bits, Some(4));
        assert_eq!(m.get("cnn_fp32_b8").unwrap().bits, None);
    }

    #[test]
    fn program_handle_precomputes_flat_lens() {
        let m = Manifest::synthetic(8, 12);
        let h = ProgramHandle::new(m.get("cnn_int4_b8").unwrap().clone());
        assert_eq!(h.name(), "cnn_int4_b8");
        assert_eq!(h.input_lens(), &[8 * 12 * 12]);
        assert_eq!(h.input_len(0), 1152);
        assert_eq!(h.output_len(), 32);
        assert_eq!(h.info().bits, Some(4));
        // Clones share the Arc'd info — no deep copy per worker/batch.
        let c = h.clone();
        assert!(std::ptr::eq(h.info(), c.info()));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("nonexistent").is_err());
    }
}
