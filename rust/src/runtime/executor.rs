//! Execution backends: compile-once, execute-many over HLO artifacts.
//!
//! Two backends sit behind the same `Executor` API:
//!
//! - **PJRT** (feature `pjrt`): the real path. Follows the verified
//!   /opt/xla-example/load_hlo pattern: HLO *text* is the interchange
//!   format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids), and artifacts are lowered
//!   with `return_tuple=True`, so results unwrap with `to_tuple1`.
//! - **Sim** (always available): a deterministic stand-in that validates
//!   shapes against the manifest and produces input-dependent pseudo
//!   logits. It lets the serving engine, its tests and its benches run
//!   in environments without the XLA native library or AOT artifacts.
//!
//! Serving worker threads each own an `Executor` (PJRT clients are not
//! shared across threads), and [`Executor::warmup`] pre-compiles the
//! serving artifacts at engine startup so the first request never pays
//! compile latency.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactInfo, Manifest};

/// How to construct a worker's executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorSpec {
    /// PJRT when the `pjrt` feature is enabled, otherwise the sim backend.
    #[default]
    Native,
    /// Deterministic sim backend; `work_factor` repeats the arithmetic to
    /// emulate heavier models in scheduling/scaling benchmarks.
    Sim { work_factor: u32 },
}

/// Compile-cached executor over an artifact manifest.
pub struct Executor {
    manifest: Manifest,
    backend: Backend,
}

enum Backend {
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::Pjrt),
    Sim(SimBackend),
}

impl Executor {
    /// Create an executor with the native backend (PJRT when the `pjrt`
    /// feature is enabled, the sim backend otherwise).
    pub fn new(manifest: Manifest) -> Result<Self> {
        Self::from_spec(ExecutorSpec::Native, manifest)
    }

    /// Create a sim-backed executor (no PJRT, no HLO files needed).
    pub fn new_sim(manifest: Manifest) -> Result<Self> {
        Self::from_spec(ExecutorSpec::Sim { work_factor: 1 }, manifest)
    }

    /// Create an executor from an explicit backend spec.
    pub fn from_spec(spec: ExecutorSpec, manifest: Manifest) -> Result<Self> {
        let backend = match spec {
            ExecutorSpec::Native => native_backend()?,
            ExecutorSpec::Sim { work_factor } => Backend::Sim(SimBackend::new(work_factor)),
        };
        Ok(Self { manifest, backend })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.platform(),
            Backend::Sim(_) => "sim".to_string(),
        }
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        self.manifest.get(name)?;
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.compile(&self.manifest, name),
            Backend::Sim(s) => {
                s.compiled.insert(name.to_string());
                Ok(())
            }
        }
    }

    /// Pre-compile artifacts at startup (the engine's warm path).
    ///
    /// Names missing from the manifest, or whose HLO file is absent on
    /// the PJRT backend, are skipped — serving them later surfaces the
    /// error on the request path instead. Returns how many compiled.
    pub fn warmup(&mut self, names: &[String]) -> usize {
        let mut warmed = 0;
        for name in names {
            if self.manifest.get(name).is_err() {
                continue;
            }
            #[cfg(feature = "pjrt")]
            if matches!(self.backend, Backend::Pjrt(_)) && !self.manifest.hlo_path(name).exists() {
                continue;
            }
            if self.compile(name).is_ok() {
                warmed += 1;
            }
        }
        warmed
    }

    /// Execute an artifact with f32 inputs; returns the flat f32 output.
    ///
    /// Input lengths are validated against the manifest shapes.
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let info = self.manifest.get(name)?.clone();
        if inputs.len() != info.input_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                info.input_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (buf, shape)) in inputs.iter().zip(&info.input_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has {} elems, shape {:?} wants {want}",
                    buf.len(),
                    shape
                )));
            }
        }
        self.compile(name)?;
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.run(name, &info, inputs),
            Backend::Sim(s) => Ok(s.run(&info, inputs)),
        }
    }

    /// Number of compiled executables held in the cache.
    pub fn cached(&self) -> usize {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.cached(),
            Backend::Sim(s) => s.compiled.len(),
        }
    }
}

#[cfg(feature = "pjrt")]
fn native_backend() -> Result<Backend> {
    Ok(Backend::Pjrt(pjrt::Pjrt::new()?))
}

#[cfg(not(feature = "pjrt"))]
fn native_backend() -> Result<Backend> {
    Ok(Backend::Sim(SimBackend::new(1)))
}

/// Deterministic pseudo-execution: for batched artifacts (output shape
/// `[rows, cols]`) each output is a fixed integer-patterned linear
/// functional of the corresponding input row — finite, input-dependent,
/// and identical across runs, workers and platforms.
struct SimBackend {
    work_factor: u32,
    compiled: std::collections::HashSet<String>,
}

impl SimBackend {
    fn new(work_factor: u32) -> Self {
        Self {
            work_factor: work_factor.max(1),
            compiled: std::collections::HashSet::new(),
        }
    }

    fn run(&self, info: &ArtifactInfo, inputs: &[&[f32]]) -> Vec<f32> {
        let x = inputs[0];
        let (rows, cols) = match info.output_shape.as_slice() {
            [r, c] => (*r, *c),
            _ => (1, info.output_elems()),
        };
        let per = if rows > 0 { x.len() / rows } else { 0 };
        let mut out = vec![0f32; rows * cols];
        for _ in 0..self.work_factor {
            for (b, out_row) in out.chunks_mut(cols).enumerate() {
                let row = &x[b * per..(b + 1) * per];
                for (c, o) in out_row.iter_mut().enumerate() {
                    // Seed with the previous pass so repeated passes are
                    // not hoisted out as loop-invariant work.
                    let mut acc = f64::from(*o) * 1e-9;
                    for (i, v) in row.iter().enumerate() {
                        let w = ((i * 31 + c * 17 + 7) % 13) as f64 - 6.0;
                        acc += f64::from(*v) * (w / 13.0);
                    }
                    *o = acc as f32;
                }
            }
        }
        out
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;

    use crate::error::{Error, Result};
    use crate::runtime::artifact::{ArtifactInfo, Manifest};

    /// The real PJRT CPU backend (`xla` crate).
    pub(super) struct Pjrt {
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Pjrt {
        pub(super) fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
            Ok(Self {
                client,
                cache: HashMap::new(),
            })
        }

        pub(super) fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub(super) fn cached(&self) -> usize {
            self.cache.len()
        }

        pub(super) fn compile(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let path = manifest.hlo_path(name);
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "HLO artifact missing: {} (run `make artifacts`)",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        pub(super) fn run(
            &mut self,
            name: &str,
            info: &ArtifactInfo,
            inputs: &[&[f32]],
        ) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, shape) in inputs.iter().zip(&info.input_shapes) {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
                literals.push(lit);
            }
            let exe = self.cache.get(name).expect("compiled above");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
            // Artifacts are lowered with return_tuple=True → 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
            out.to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// Real-artifact executor for the functional (PJRT) tests; the
    /// accuracy bounds below only hold on the real backend.
    fn executor() -> Option<Executor> {
        if !cfg!(feature = "pjrt") {
            eprintln!("skipping: functional PJRT tests need --features pjrt");
            return None;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Executor::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn mac_artifact_matches_integer_matmul() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("photonic_mac_4b").unwrap().clone();
        let (m, k) = (info.input_shapes[0][0], info.input_shapes[0][1]);
        let n = info.input_shapes[1][1];
        // Deterministic small levels; ADC is exact when per-pair group
        // sums stay on the step grid — use levels {0,1} scaled to land
        // on exact grid points? Simpler: compare against the kernel's
        // own documented bound: |photonic - exact| ≤ bound.
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 16) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 5) % 16) as f32).collect();
        let out = ex.run_f32("photonic_mac_4b", &[&a, &w]).unwrap();
        assert_eq!(out.len(), m * n);
        // Exact integer matmul reference.
        let mut exact = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                exact[i * n + j] = s;
            }
        }
        // ADC bound: ceil(K/G) segments × step/2 (4-bit: one nibble pair).
        let step = 2.0 * 225.0 / 32.0;
        let bound = (k as f64 / 2.0).ceil() * step / 2.0 + 1e-3;
        let max_err = out
            .iter()
            .zip(&exact)
            .map(|(o, e)| (*o as f64 - e).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err <= bound, "max_err {max_err} > bound {bound}");
        // And the result must be nontrivially correlated with the exact
        // product (sanity that we ran the right computation).
        let rel: f64 = max_err / exact.iter().cloned().fold(0.0f64, f64::max);
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn cnn_artifact_runs_and_caches() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("cnn_fp32_b8").unwrap().clone();
        let n: usize = info.input_shapes[0].iter().product();
        let x = vec![0.5f32; n];
        let out = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out.len(), info.output_elems());
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ex.cached(), 1);
        // Second run hits the compile cache.
        let out2 = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out, out2);
        assert_eq!(ex.cached(), 1);
    }

    #[test]
    fn sim_backend_runs_without_artifacts() {
        let m = Manifest::synthetic(8, 12);
        let mut ex = Executor::new_sim(m).unwrap();
        assert_eq!(ex.platform(), "sim");
        let x = vec![0.25f32; 8 * 12 * 12];
        let out = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ex.cached(), 1);
        // Deterministic: same input, same output.
        let out2 = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out, out2);
        // Input-dependent: a different image changes the logits.
        let y: Vec<f32> = (0..8 * 12 * 12).map(|i| (i % 5) as f32 * 0.1).collect();
        assert_ne!(out, ex.run_f32("cnn_fp32_b8", &[&y]).unwrap());
    }

    #[test]
    fn warmup_precompiles_serving_artifacts() {
        let m = Manifest::synthetic(8, 12);
        let mut ex = Executor::new_sim(m).unwrap();
        let names: Vec<String> = ["cnn_fp32_b8", "cnn_int8_b8", "cnn_int4_b8", "nope"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(ex.warmup(&names), 3, "unknown names are skipped");
        assert_eq!(ex.cached(), 3);
    }

    #[test]
    fn shape_validation() {
        let mut ex = Executor::new_sim(Manifest::synthetic(8, 12)).unwrap();
        let bad = vec![0f32; 3];
        assert!(ex.run_f32("cnn_fp32_b8", &[&bad]).is_err());
        assert!(ex.run_f32("cnn_fp32_b8", &[]).is_err());
        assert!(ex.run_f32("no_such_artifact", &[&bad]).is_err());
    }
}
