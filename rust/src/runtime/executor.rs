//! PJRT executor: compile-once, execute-many over HLO text artifacts.
//!
//! Follows the verified /opt/xla-example/load_hlo pattern: HLO *text* is
//! the interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids), and
//! artifacts are lowered with `return_tuple=True`, so results unwrap
//! with `to_tuple1`.

use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::runtime::artifact::Manifest;

/// Compile-cached PJRT CPU executor.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU-backed executor over an artifact manifest.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
        Ok(Self {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name);
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "HLO artifact missing: {} (run `make artifacts`)",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with f32 inputs; returns the flat f32 output.
    ///
    /// Input lengths are validated against the manifest shapes.
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let info = self.manifest.get(name)?.clone();
        if inputs.len() != info.input_shapes.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                info.input_shapes.len(),
                inputs.len()
            )));
        }
        for (i, (buf, shape)) in inputs.iter().zip(&info.input_shapes).enumerate() {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has {} elems, shape {:?} wants {want}",
                    buf.len(),
                    shape
                )));
            }
        }
        self.compile(name)?;

        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&info.input_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
            literals.push(lit);
        }
        let exe = self.cache.get(name).expect("compiled above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
        // Artifacts are lowered with return_tuple=True → 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
        out.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
    }

    /// Number of compiled executables held in the cache.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn executor() -> Option<Executor> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Executor::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn mac_artifact_matches_integer_matmul() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("photonic_mac_4b").unwrap().clone();
        let (m, k) = (info.input_shapes[0][0], info.input_shapes[0][1]);
        let n = info.input_shapes[1][1];
        // Deterministic small levels; ADC is exact when per-pair group
        // sums stay on the step grid — use levels {0,1} scaled to land
        // on exact grid points? Simpler: compare against the kernel's
        // own documented bound: |photonic - exact| ≤ bound.
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 16) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 5) % 16) as f32).collect();
        let out = ex.run_f32("photonic_mac_4b", &[&a, &w]).unwrap();
        assert_eq!(out.len(), m * n);
        // Exact integer matmul reference.
        let mut exact = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                exact[i * n + j] = s;
            }
        }
        // ADC bound: ceil(K/G) segments × step/2 (4-bit: one nibble pair).
        let step = 2.0 * 225.0 / 32.0;
        let bound = (k as f64 / 2.0).ceil() * step / 2.0 + 1e-3;
        let max_err = out
            .iter()
            .zip(&exact)
            .map(|(o, e)| (*o as f64 - e).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err <= bound, "max_err {max_err} > bound {bound}");
        // And the result must be nontrivially correlated with the exact
        // product (sanity that we ran the right computation).
        let rel: f64 = max_err / exact.iter().cloned().fold(0.0f64, f64::max);
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn cnn_artifact_runs_and_caches() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("cnn_fp32_b8").unwrap().clone();
        let n: usize = info.input_shapes[0].iter().product();
        let x = vec![0.5f32; n];
        let out = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out.len(), info.output_elems());
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ex.cached(), 1);
        // Second run hits the compile cache.
        let out2 = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out, out2);
        assert_eq!(ex.cached(), 1);
    }

    #[test]
    fn shape_validation() {
        let Some(mut ex) = executor() else { return };
        let bad = vec![0f32; 3];
        assert!(ex.run_f32("cnn_fp32_b8", &[&bad]).is_err());
        assert!(ex.run_f32("cnn_fp32_b8", &[]).is_err());
        assert!(ex.run_f32("no_such_artifact", &[&bad]).is_err());
    }
}
