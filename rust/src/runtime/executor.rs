//! Execution backends: compile-once, execute-many over HLO artifacts.
//!
//! Two backends sit behind the same `Executor` API:
//!
//! - **PJRT** (feature `pjrt`): the real path. Follows the verified
//!   /opt/xla-example/load_hlo pattern: HLO *text* is the interchange
//!   format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//!   rejects; the text parser reassigns ids), and artifacts are lowered
//!   with `return_tuple=True`, so results unwrap with `to_tuple1`.
//! - **Sim** (always available): a deterministic stand-in that validates
//!   shapes against the manifest and produces input-dependent pseudo
//!   logits. It lets the serving engine, its tests and its benches run
//!   in environments without the XLA native library or AOT artifacts.
//!
//! Serving worker threads each own an `Executor` (PJRT clients are not
//! shared across threads), and [`Executor::warmup`] pre-compiles the
//! serving artifacts at engine startup so the first request never pays
//! compile latency.
//!
//! Two execution paths sit on top: [`Executor::run_f32`] (convenience —
//! one manifest lookup plus a fresh output `Vec` per call) and the
//! serving hot path [`Executor::prepare`] → [`Executor::run_prepared`],
//! which validates shapes once into a
//! [`ProgramHandle`](crate::runtime::ProgramHandle) and then writes
//! logits into a caller-pooled buffer with no per-batch lookup, clone or
//! allocation.

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactInfo, Manifest, ProgramHandle};

/// How to construct a worker's executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecutorSpec {
    /// PJRT when the `pjrt` feature is enabled, otherwise the sim backend.
    #[default]
    Native,
    /// Deterministic sim backend; `work_factor` repeats the arithmetic to
    /// emulate heavier models in scheduling/scaling benchmarks.
    Sim { work_factor: u32 },
}

/// Compile-cached executor over an artifact manifest.
pub struct Executor {
    manifest: Manifest,
    backend: Backend,
}

enum Backend {
    #[cfg(feature = "pjrt")]
    Pjrt(pjrt::Pjrt),
    Sim(SimBackend),
}

impl Executor {
    /// Create an executor with the native backend (PJRT when the `pjrt`
    /// feature is enabled, the sim backend otherwise).
    pub fn new(manifest: Manifest) -> Result<Self> {
        Self::from_spec(ExecutorSpec::Native, manifest)
    }

    /// Create a sim-backed executor (no PJRT, no HLO files needed).
    pub fn new_sim(manifest: Manifest) -> Result<Self> {
        Self::from_spec(ExecutorSpec::Sim { work_factor: 1 }, manifest)
    }

    /// Create an executor from an explicit backend spec.
    pub fn from_spec(spec: ExecutorSpec, manifest: Manifest) -> Result<Self> {
        let backend = match spec {
            ExecutorSpec::Native => native_backend()?,
            ExecutorSpec::Sim { work_factor } => Backend::Sim(SimBackend::new(work_factor)),
        };
        Ok(Self { manifest, backend })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.platform(),
            Backend::Sim(_) => "sim".to_string(),
        }
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        self.manifest.get(name)?;
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.compile(&self.manifest, name),
            Backend::Sim(s) => {
                s.compiled.insert(name.to_string());
                Ok(())
            }
        }
    }

    /// Pre-compile artifacts at startup (the engine's warm path).
    ///
    /// Names missing from the manifest, or whose HLO file is absent on
    /// the PJRT backend, are skipped — serving them later surfaces the
    /// error on the request path instead. Returns how many compiled.
    pub fn warmup(&mut self, names: &[String]) -> usize {
        let mut warmed = 0;
        for name in names {
            if self.manifest.get(name).is_err() {
                continue;
            }
            #[cfg(feature = "pjrt")]
            if matches!(self.backend, Backend::Pjrt(_)) && !self.manifest.hlo_path(name).exists() {
                continue;
            }
            if self.compile(name).is_ok() {
                warmed += 1;
            }
        }
        warmed
    }

    /// Prepare an artifact for repeated execution: fetch its manifest
    /// entry, compile it, and flatten its shapes into a [`ProgramHandle`]
    /// — the one-time cost the per-batch [`Executor::run_prepared`] path
    /// never pays again.
    pub fn prepare(&mut self, name: &str) -> Result<ProgramHandle> {
        let info = self.manifest.get(name)?.clone();
        self.compile(name)?;
        Ok(ProgramHandle::new(info))
    }

    /// Execute an artifact with f32 inputs; returns the flat f32 output.
    ///
    /// Input lengths are validated against the manifest shapes. This is
    /// the convenience path (one manifest lookup + output allocation per
    /// call); the serving hot loop uses [`Executor::run_prepared`] with a
    /// caller-pooled output buffer instead.
    pub fn run_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let handle = self.prepare(name)?;
        let mut out = vec![0f32; handle.output_len()];
        self.run_prepared(&handle, inputs, &mut out)?;
        Ok(out)
    }

    /// Execute a prepared program, writing the logits into the
    /// caller-provided buffer (`out.len()` must equal the handle's
    /// output length).
    ///
    /// The steady-state serving path: validation is precomputed element
    /// counts only, no manifest string lookup, no `ArtifactInfo` clone,
    /// and no output `Vec` allocation — the worker hands in a pooled
    /// buffer. (On the PJRT backend the compile cache is still keyed by
    /// name — one hash probe per batch on the real hardware path; the
    /// sim backend executes the handle directly.)
    pub fn run_prepared(
        &mut self,
        handle: &ProgramHandle,
        inputs: &[&[f32]],
        out: &mut [f32],
    ) -> Result<()> {
        let name = handle.name();
        if inputs.len() != handle.input_lens().len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                handle.input_lens().len(),
                inputs.len()
            )));
        }
        for (i, (buf, &want)) in inputs.iter().zip(handle.input_lens()).enumerate() {
            if buf.len() != want {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} has {} elems, program wants {want}",
                    buf.len()
                )));
            }
        }
        if out.len() != handle.output_len() {
            return Err(Error::Runtime(format!(
                "{name}: output buffer has {} elems, program wants {}",
                out.len(),
                handle.output_len()
            )));
        }
        match &mut self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => {
                p.compile(&self.manifest, name)?;
                let v = p.run(name, handle.info(), inputs)?;
                // A manifest whose output_shape disagrees with the
                // compiled executable must fail the batch, not panic
                // the worker thread via copy_from_slice.
                if v.len() != out.len() {
                    return Err(Error::Runtime(format!(
                        "{name}: executable produced {} values, manifest shape wants {}",
                        v.len(),
                        out.len()
                    )));
                }
                out.copy_from_slice(&v);
                Ok(())
            }
            Backend::Sim(s) => {
                s.run_into(handle.info(), inputs, out);
                Ok(())
            }
        }
    }

    /// Number of compiled executables held in the cache.
    pub fn cached(&self) -> usize {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(p) => p.cached(),
            Backend::Sim(s) => s.compiled.len(),
        }
    }
}

#[cfg(feature = "pjrt")]
fn native_backend() -> Result<Backend> {
    Ok(Backend::Pjrt(pjrt::Pjrt::new()?))
}

#[cfg(not(feature = "pjrt"))]
fn native_backend() -> Result<Backend> {
    Ok(Backend::Sim(SimBackend::new(1)))
}

/// Deterministic pseudo-execution: for batched artifacts (output shape
/// `[rows, cols]`) each output is a fixed integer-patterned linear
/// functional of the corresponding input row — finite, input-dependent,
/// and identical across runs, workers and platforms.
///
/// The weight pattern `((i*31 + c*17 + 7) % 13)` has period 13 in the
/// input index `i` (31 ≡ 5 mod 13 hits every residue), so each output
/// column precomputes its 13-entry weight cycle once per pass and the
/// inner loop is pure f32 multiply-adds — no per-element integer modulo
/// or f64 converts dominating the stand-in backend's bench noise.
struct SimBackend {
    work_factor: u32,
    compiled: std::collections::HashSet<String>,
}

impl SimBackend {
    fn new(work_factor: u32) -> Self {
        Self {
            work_factor: work_factor.max(1),
            compiled: std::collections::HashSet::new(),
        }
    }

    /// Execute into a caller-provided buffer (`out.len()` must be the
    /// artifact's output element count) — no allocation.
    fn run_into(&self, info: &ArtifactInfo, inputs: &[&[f32]], out: &mut [f32]) {
        let x = inputs[0];
        let (rows, cols) = match info.output_shape.as_slice() {
            [r, c] => (*r, *c),
            _ => (1, info.output_elems()),
        };
        debug_assert_eq!(out.len(), rows * cols);
        let per = if rows > 0 { x.len() / rows } else { 0 };
        // Pooled buffers carry a previous batch's values: reset so the
        // result only depends on this call's input.
        out.fill(0.0);
        for _ in 0..self.work_factor {
            // Column-outer so each column's weight cycle really is
            // computed once per pass, not once per output element.
            for c in 0..cols {
                // This column's 13-entry weight cycle (i*31 mod 13 has
                // period 13, so w(i) == wcol[i % 13]).
                let mut wcol = [0f32; 13];
                for (r, w) in wcol.iter_mut().enumerate() {
                    *w = (((r * 31 + c * 17 + 7) % 13) as f32 - 6.0) / 13.0;
                }
                for b in 0..rows {
                    let row = &x[b * per..(b + 1) * per];
                    // Seed with the previous pass so repeated passes are
                    // not hoisted out as loop-invariant work.
                    let mut acc = out[b * cols + c] * 1e-9;
                    for chunk in row.chunks(13) {
                        for (v, w) in chunk.iter().zip(&wcol) {
                            acc += *v * *w;
                        }
                    }
                    out[b * cols + c] = acc;
                }
            }
        }
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::collections::HashMap;

    use crate::error::{Error, Result};
    use crate::runtime::artifact::{ArtifactInfo, Manifest};

    /// The real PJRT CPU backend (`xla` crate).
    pub(super) struct Pjrt {
        client: xla::PjRtClient,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Pjrt {
        pub(super) fn new() -> Result<Self> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::Runtime(format!("PJRT client: {e}")))?;
            Ok(Self {
                client,
                cache: HashMap::new(),
            })
        }

        pub(super) fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub(super) fn cached(&self) -> usize {
            self.cache.len()
        }

        pub(super) fn compile(&mut self, manifest: &Manifest, name: &str) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let path = manifest.hlo_path(name);
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "HLO artifact missing: {} (run `make artifacts`)",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        pub(super) fn run(
            &mut self,
            name: &str,
            info: &ArtifactInfo,
            inputs: &[&[f32]],
        ) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, shape) in inputs.iter().zip(&info.input_shapes) {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(buf)
                    .reshape(&dims)
                    .map_err(|e| Error::Runtime(format!("reshape input: {e}")))?;
                literals.push(lit);
            }
            let exe = self.cache.get(name).expect("compiled above");
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::Runtime(format!("fetch result: {e}")))?;
            // Artifacts are lowered with return_tuple=True → 1-tuple.
            let out = result
                .to_tuple1()
                .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
            out.to_vec::<f32>()
                .map_err(|e| Error::Runtime(format!("to_vec: {e}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    /// Real-artifact executor for the functional (PJRT) tests; the
    /// accuracy bounds below only hold on the real backend.
    fn executor() -> Option<Executor> {
        if !cfg!(feature = "pjrt") {
            eprintln!("skipping: functional PJRT tests need --features pjrt");
            return None;
        }
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Executor::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn mac_artifact_matches_integer_matmul() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("photonic_mac_4b").unwrap().clone();
        let (m, k) = (info.input_shapes[0][0], info.input_shapes[0][1]);
        let n = info.input_shapes[1][1];
        // Deterministic small levels; ADC is exact when per-pair group
        // sums stay on the step grid — use levels {0,1} scaled to land
        // on exact grid points? Simpler: compare against the kernel's
        // own documented bound: |photonic - exact| ≤ bound.
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 16) as f32).collect();
        let w: Vec<f32> = (0..k * n).map(|i| ((i * 5) % 16) as f32).collect();
        let out = ex.run_f32("photonic_mac_4b", &[&a, &w]).unwrap();
        assert_eq!(out.len(), m * n);
        // Exact integer matmul reference.
        let mut exact = vec![0f64; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += a[i * k + kk] as f64 * w[kk * n + j] as f64;
                }
                exact[i * n + j] = s;
            }
        }
        // ADC bound: ceil(K/G) segments × step/2 (4-bit: one nibble pair).
        let step = 2.0 * 225.0 / 32.0;
        let bound = (k as f64 / 2.0).ceil() * step / 2.0 + 1e-3;
        let max_err = out
            .iter()
            .zip(&exact)
            .map(|(o, e)| (*o as f64 - e).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err <= bound, "max_err {max_err} > bound {bound}");
        // And the result must be nontrivially correlated with the exact
        // product (sanity that we ran the right computation).
        let rel: f64 = max_err / exact.iter().cloned().fold(0.0f64, f64::max);
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn cnn_artifact_runs_and_caches() {
        let Some(mut ex) = executor() else { return };
        let info = ex.manifest().get("cnn_fp32_b8").unwrap().clone();
        let n: usize = info.input_shapes[0].iter().product();
        let x = vec![0.5f32; n];
        let out = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out.len(), info.output_elems());
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ex.cached(), 1);
        // Second run hits the compile cache.
        let out2 = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out, out2);
        assert_eq!(ex.cached(), 1);
    }

    #[test]
    fn sim_backend_runs_without_artifacts() {
        let m = Manifest::synthetic(8, 12);
        let mut ex = Executor::new_sim(m).unwrap();
        assert_eq!(ex.platform(), "sim");
        let x = vec![0.25f32; 8 * 12 * 12];
        let out = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out.len(), 8 * 4);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(ex.cached(), 1);
        // Deterministic: same input, same output.
        let out2 = ex.run_f32("cnn_fp32_b8", &[&x]).unwrap();
        assert_eq!(out, out2);
        // Input-dependent: a different image changes the logits.
        let y: Vec<f32> = (0..8 * 12 * 12).map(|i| (i % 5) as f32 * 0.1).collect();
        assert_ne!(out, ex.run_f32("cnn_fp32_b8", &[&y]).unwrap());
    }

    #[test]
    fn warmup_precompiles_serving_artifacts() {
        let m = Manifest::synthetic(8, 12);
        let mut ex = Executor::new_sim(m).unwrap();
        let names: Vec<String> = ["cnn_fp32_b8", "cnn_int8_b8", "cnn_int4_b8", "nope"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(ex.warmup(&names), 3, "unknown names are skipped");
        assert_eq!(ex.cached(), 3);
    }

    #[test]
    fn shape_validation() {
        let mut ex = Executor::new_sim(Manifest::synthetic(8, 12)).unwrap();
        let bad = vec![0f32; 3];
        assert!(ex.run_f32("cnn_fp32_b8", &[&bad]).is_err());
        assert!(ex.run_f32("cnn_fp32_b8", &[]).is_err());
        assert!(ex.run_f32("no_such_artifact", &[&bad]).is_err());
    }

    #[test]
    fn run_prepared_matches_run_f32_without_allocating_output() {
        let mut ex = Executor::new_sim(Manifest::synthetic(8, 12)).unwrap();
        let handle = ex.prepare("cnn_int8_b8").unwrap();
        assert_eq!(handle.output_len(), 32);
        let x: Vec<f32> = (0..handle.input_len(0)).map(|i| (i % 9) as f32 * 0.2).collect();
        let reference = ex.run_f32("cnn_int8_b8", &[&x]).unwrap();
        // A pooled buffer carrying stale garbage must be fully rewritten.
        let mut out = vec![f32::NAN; handle.output_len()];
        ex.run_prepared(&handle, &[&x], &mut out).unwrap();
        assert_eq!(out, reference);
        // Reuse the same buffer for a second batch: same answer.
        ex.run_prepared(&handle, &[&x], &mut out).unwrap();
        assert_eq!(out, reference);
    }

    #[test]
    fn run_prepared_validates_against_the_handle() {
        let mut ex = Executor::new_sim(Manifest::synthetic(8, 12)).unwrap();
        let handle = ex.prepare("cnn_int4_b8").unwrap();
        let x = vec![0f32; handle.input_len(0)];
        let mut out = vec![0f32; handle.output_len()];
        let mut short = vec![0f32; handle.output_len() - 1];
        assert!(ex.run_prepared(&handle, &[&x], &mut short).is_err());
        assert!(ex.run_prepared(&handle, &[], &mut out).is_err());
        let bad = vec![0f32; 3];
        assert!(ex.run_prepared(&handle, &[&bad], &mut out).is_err());
        assert!(ex.prepare("no_such_artifact").is_err());
    }

    #[test]
    fn sim_weight_cycle_matches_the_naive_pattern() {
        // The hoisted 13-entry weight cycle must reproduce the naive
        // per-element `((i*31 + c*17 + 7) % 13)` functional exactly
        // (same f32 accumulation order ⇒ bit-identical).
        let m = Manifest::synthetic(4, 5);
        let mut ex = Executor::new_sim(m.clone()).unwrap();
        let info = m.get("cnn_fp32_b4").unwrap();
        let x: Vec<f32> = (0..4 * 5 * 5).map(|i| ((i * 3) % 17) as f32 * 0.3).collect();
        let out = ex.run_f32("cnn_fp32_b4", &[&x]).unwrap();
        let (rows, cols) = (info.output_shape[0], info.output_shape[1]);
        let per = x.len() / rows;
        for b in 0..rows {
            for c in 0..cols {
                let mut acc = 0f32;
                for (i, v) in x[b * per..(b + 1) * per].iter().enumerate() {
                    let w = (((i * 31 + c * 17 + 7) % 13) as f32 - 6.0) / 13.0;
                    acc += *v * w;
                }
                assert_eq!(out[b * cols + c], acc, "row {b} col {c}");
            }
        }
    }
}
