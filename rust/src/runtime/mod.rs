//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from
//! the Rust request path (Python never runs at serve time).
//!
//! - [`artifact`] — `artifacts/manifest.json` parsing and path
//!   resolution for the HLO text files emitted by `python/compile/aot.py`.
//! - [`executor`] — `xla` crate wrapper: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile (cached) → execute with
//!   f32 buffers.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactInfo, Manifest};
pub use executor::Executor;
