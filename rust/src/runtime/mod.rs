//! Runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust request path (Python never runs at serve time).
//!
//! - [`artifact`] — `artifacts/manifest.json` parsing and path
//!   resolution for the HLO text files emitted by `python/compile/aot.py`,
//!   plus [`Manifest::synthetic`] for artifact-free sim runs and the
//!   prepared [`ProgramHandle`] (shapes validated once, no per-batch
//!   manifest lookup or clone).
//! - [`executor`] — the execution backends behind one `Executor` API:
//!   PJRT (`xla` crate, feature `pjrt`): `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → compile (cached) → execute with
//!   f32 buffers; and a deterministic sim backend that needs neither the
//!   XLA native library nor artifacts on disk. Serving workers each own
//!   an `Executor`, warmed via `Executor::warmup` at engine startup.

pub mod artifact;
pub mod executor;

pub use artifact::{ArtifactInfo, Manifest, ProgramHandle};
pub use executor::{Executor, ExecutorSpec};
