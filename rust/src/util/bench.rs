//! Criterion-style measurement harness for `cargo bench` targets.
//!
//! criterion is unavailable offline, so bench binaries (harness = false)
//! use this: warmup, fixed sample count, mean/median/std reporting, and a
//! `black_box` to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::histogram::nearest_rank;
use crate::util::json::Json;
use crate::util::units::{Millis, Nanos};

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: Nanos,
    pub median_ns: Nanos,
    pub std_ns: Nanos,
    pub min_ns: Nanos,
    pub max_ns: Nanos,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns.raw() / 1e3
    }

    pub fn mean_ms(&self) -> Millis {
        self.mean_ns.to_millis()
    }
}

/// Measure `f` with `warmup` unmeasured runs then `samples` timed runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Stats {
    assert!(samples >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let mean = times.iter().sum::<f64>() / samples as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / samples as f64;
    let stats = Stats {
        name: name.to_string(),
        samples,
        mean_ns: Nanos::new(mean),
        // Nearest-rank (ceil(p·n) - 1): `times[samples / 2]` overshoots
        // for even n (at n=2 it reports the max as the median).
        median_ns: Nanos::new(nearest_rank(&times, 0.5)),
        std_ns: Nanos::new(var.sqrt()),
        min_ns: Nanos::new(times[0]),
        max_ns: Nanos::new(times[samples - 1]),
    };
    println!(
        "bench {:<44} mean {:>12}  median {:>12}  σ {:>10}  ({} samples)",
        stats.name,
        stats.mean_ns.human(),
        stats.median_ns.human(),
        stats.std_ns.human(),
        samples
    );
    stats
}

/// Whether benches run in smoke mode (`OPIMA_BENCH_SMOKE=1`): one
/// sample per measurement, tiny workloads — CI uses this to exercise
/// the JSON emitters without paying full bench time.
pub fn smoke() -> bool {
    std::env::var("OPIMA_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// `samples` normally, 1 in smoke mode.
pub fn scaled(samples: usize) -> usize {
    if smoke() {
        1
    } else {
        samples
    }
}

/// Machine-readable bench summary, written as `BENCH_<name>.json` so
/// bench trajectories can be collected instead of scraped from stdout.
///
/// Schema: `{"bench": <name>, "smoke": <bool>, "results": [<row>...]}`
/// where each row is an object with at least a `"name"` field;
/// [`JsonReport::add_stats`] rows carry `samples`/`mean_ns`/`median_ns`/
/// `std_ns`/`min_ns`/`max_ns`.
#[derive(Debug, Clone)]
pub struct JsonReport {
    bench: String,
    rows: Vec<Json>,
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        Self {
            bench: bench.to_string(),
            rows: Vec::new(),
        }
    }

    /// Append one result row: a named object of numeric/string fields.
    pub fn add(&mut self, name: &str, fields: &[(&str, Json)]) {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::Str(name.to_string()));
        for (k, v) in fields {
            obj.insert((*k).to_string(), v.clone());
        }
        self.rows.push(Json::Obj(obj));
    }

    /// Append one [`measure`] result.
    pub fn add_stats(&mut self, s: &Stats) {
        self.add(
            &s.name,
            &[
                ("samples", Json::Num(s.samples as f64)),
                ("mean_ns", Json::Num(s.mean_ns.raw())),
                ("median_ns", Json::Num(s.median_ns.raw())),
                ("std_ns", Json::Num(s.std_ns.raw())),
                ("min_ns", Json::Num(s.min_ns.raw())),
                ("max_ns", Json::Num(s.max_ns.raw())),
            ],
        );
    }

    /// The full document this report serializes to.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("bench".to_string(), Json::Str(self.bench.clone()));
        obj.insert("smoke".to_string(), Json::Bool(smoke()));
        obj.insert("results".to_string(), Json::Arr(self.rows.clone()));
        Json::Obj(obj)
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into the current directory.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(Path::new("."))
    }
}

/// Print a markdown-style table header for paper-figure benches.
pub fn table_header(title: &str, columns: &[&str]) {
    println!("\n## {title}\n");
    println!("| {} |", columns.join(" | "));
    println!("|{}|", columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

/// Print one table row.
pub fn table_row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_stats() {
        let mut acc = 0u64;
        let s = measure("noop-ish", 2, 20, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(s.samples, 20);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.mean_ns > Nanos::ZERO);
    }

    #[test]
    fn json_report_round_trips_schema() {
        let mut r = JsonReport::new("unit_test");
        let s = measure("probe", 0, 3, || {
            black_box(1 + 1);
        });
        r.add_stats(&s);
        r.add("custom", &[("req_per_s", Json::Num(123.5))]);
        let path = r.write_to(&std::env::temp_dir()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("unit_test"));
        let rows = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("probe"));
        assert_eq!(rows[0].get("samples").unwrap().as_f64(), Some(3.0));
        assert_eq!(rows[1].get("req_per_s").unwrap().as_f64(), Some(123.5));
        std::fs::remove_file(path).unwrap();
    }
}
