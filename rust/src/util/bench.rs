//! Criterion-style measurement harness for `cargo bench` targets.
//!
//! criterion is unavailable offline, so bench binaries (harness = false)
//! use this: warmup, fixed sample count, mean/median/std reporting, and a
//! `black_box` to defeat constant folding.

use std::hint::black_box as std_black_box;
use std::time::Instant;

use crate::util::histogram::nearest_rank;

/// Re-exported black box.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics of one measured benchmark.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

fn fmt_time(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f` with `warmup` unmeasured runs then `samples` timed runs.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> Stats {
    assert!(samples >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let mean = times.iter().sum::<f64>() / samples as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / samples as f64;
    let stats = Stats {
        name: name.to_string(),
        samples,
        mean_ns: mean,
        // Nearest-rank (ceil(p·n) - 1): `times[samples / 2]` overshoots
        // for even n (at n=2 it reports the max as the median).
        median_ns: nearest_rank(&times, 0.5),
        std_ns: var.sqrt(),
        min_ns: times[0],
        max_ns: times[samples - 1],
    };
    println!(
        "bench {:<44} mean {:>12}  median {:>12}  σ {:>10}  ({} samples)",
        stats.name,
        fmt_time(stats.mean_ns),
        fmt_time(stats.median_ns),
        fmt_time(stats.std_ns),
        samples
    );
    stats
}

/// Print a markdown-style table header for paper-figure benches.
pub fn table_header(title: &str, columns: &[&str]) {
    println!("\n## {title}\n");
    println!("| {} |", columns.join(" | "));
    println!("|{}|", columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

/// Print one table row.
pub fn table_row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_stats() {
        let mut acc = 0u64;
        let s = measure("noop-ish", 2, 20, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(s.samples, 20);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
        assert!(s.mean_ns > 0.0);
    }
}
