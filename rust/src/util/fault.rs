//! Deterministic, seeded fault-injection plane.
//!
//! Chaos testing only pays off when a failing schedule can be replayed:
//! every injection site in the serving stack owns a [`FaultPlane`]
//! derived from the one `[fault]` config seed plus a site-specific salt,
//! so the *sequence of injection decisions at each site* is a pure
//! function of `(seed, salt)` — independent of thread interleaving at
//! every other site. The sites (DESIGN.md §3.3):
//!
//! - each engine worker (panic mid-batch, stall, transient executor
//!   error), salted by worker id;
//! - each connection's writer thread (delayed/short frame writes),
//!   salted by accept order.
//!
//! **Disarmed is free.** Every probe routes through [`FaultPlane::roll`],
//! whose first check is the `armed` flag — a disarmed plane costs one
//! predictable branch and never touches its RNG, so the production hot
//! path stays bit-identical with the plane compiled in
//! (`benches/hotpath.rs` pins `serving/submit_fault_plane_{off,armed}`).

use std::time::Duration;

use crate::config::FaultParams;
use crate::util::prng::Rng;

/// One injection site's deterministic fault source. Sites never share a
/// plane (no locking, no cross-site coupling): clone the params and
/// derive per-site with a distinct salt.
#[derive(Debug, Clone)]
pub struct FaultPlane {
    params: FaultParams,
    rng: Rng,
}

impl FaultPlane {
    /// Large odd stride decorrelating per-site streams (the SplitMix64
    /// increment): adjacent salts land in unrelated seed regions.
    const SALT_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

    /// A plane for one injection site. `salt` identifies the site
    /// (worker id, connection index, ...) so replaying a seed replays
    /// every site's decision sequence.
    pub fn new(params: FaultParams, salt: u64) -> FaultPlane {
        let seed = params.seed.wrapping_add(salt.wrapping_mul(Self::SALT_STRIDE));
        FaultPlane {
            params,
            rng: Rng::new(seed),
        }
    }

    /// The no-fault plane (default params are disarmed): every probe
    /// answers "no" after one branch.
    pub fn disarmed() -> FaultPlane {
        FaultPlane::new(FaultParams::default(), 0)
    }

    /// Whether injection is armed at all (callers may skip whole fault
    /// blocks — e.g. an injected stall's sleep — on a disarmed plane).
    #[inline]
    pub fn armed(&self) -> bool {
        self.params.armed
    }

    /// One Bernoulli decision. The armed check comes first so a
    /// disarmed plane never advances its RNG — decisive for both the
    /// zero-cost bar and bit-identical disarmed behavior.
    #[inline]
    fn roll(&mut self, p: f64) -> bool {
        self.params.armed && p > 0.0 && self.rng.f64() < p
    }

    /// Should this batch execution panic mid-flight?
    pub fn worker_panic(&mut self) -> bool {
        self.roll(self.params.worker_panic)
    }

    /// Should the executor report an injected transient error for this
    /// batch (the non-panic failure path)?
    pub fn exec_transient(&mut self) -> bool {
        self.roll(self.params.exec_transient)
    }

    /// Should this worker stall before executing, and for how long?
    pub fn worker_stall(&mut self) -> Option<Duration> {
        self.roll(self.params.worker_stall)
            .then(|| self.params.stall_ms.to_duration())
    }

    /// Should this reply frame go out as a delayed two-part (short)
    /// write, and with what gap?
    pub fn writer_delay(&mut self) -> Option<Duration> {
        self.roll(self.params.writer_delay)
            .then(|| self.params.writer_delay_ms.to_duration())
    }
}

/// Install (once, process-wide) a panic hook that suppresses the default
/// backtrace noise for injected-fault panics — recognizable by their
/// `"injected fault"` payload prefix — while forwarding every real panic
/// to the previous hook untouched. Chaos tests call this so a soak with
/// dozens of injected worker panics doesn't flood stderr; injected
/// panics are *expected* output there, not diagnostics.
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let injected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    info.payload()
                        .downcast_ref::<String>()
                        .map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if !injected {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::ms;

    fn armed_params() -> FaultParams {
        FaultParams {
            armed: true,
            seed: 42,
            worker_panic: 0.5,
            worker_stall: 0.5,
            stall_ms: ms(3.0),
            exec_transient: 0.5,
            writer_delay: 0.5,
            writer_delay_ms: ms(1.5),
            ..FaultParams::default()
        }
    }

    #[test]
    fn disarmed_never_fires_even_at_probability_one() {
        let mut p = FaultPlane::new(
            FaultParams {
                armed: false,
                worker_panic: 1.0,
                worker_stall: 1.0,
                exec_transient: 1.0,
                writer_delay: 1.0,
                ..FaultParams::default()
            },
            7,
        );
        for _ in 0..64 {
            assert!(!p.worker_panic());
            assert!(!p.exec_transient());
            assert!(p.worker_stall().is_none());
            assert!(p.writer_delay().is_none());
        }
        assert!(!p.armed());
    }

    #[test]
    fn armed_zero_probability_never_fires() {
        let mut p = FaultPlane::new(
            FaultParams {
                armed: true,
                ..FaultParams::default()
            },
            3,
        );
        for _ in 0..64 {
            assert!(!p.worker_panic());
            assert!(p.worker_stall().is_none());
        }
    }

    #[test]
    fn same_seed_and_salt_replay_the_same_schedule() {
        let mut a = FaultPlane::new(armed_params(), 11);
        let mut b = FaultPlane::new(armed_params(), 11);
        for _ in 0..256 {
            assert_eq!(a.worker_panic(), b.worker_panic());
            assert_eq!(a.worker_stall(), b.worker_stall());
        }
    }

    #[test]
    fn distinct_salts_decorrelate_sites() {
        let mut a = FaultPlane::new(armed_params(), 1);
        let mut b = FaultPlane::new(armed_params(), 2);
        let seq_a: Vec<bool> = (0..256).map(|_| a.worker_panic()).collect();
        let seq_b: Vec<bool> = (0..256).map(|_| b.worker_panic()).collect();
        assert_ne!(seq_a, seq_b, "salted sites must not share a schedule");
    }

    #[test]
    fn injected_durations_carry_the_configured_knobs() {
        let mut p = FaultPlane::new(
            FaultParams {
                armed: true,
                worker_stall: 1.0,
                stall_ms: ms(2.0),
                writer_delay: 1.0,
                writer_delay_ms: ms(0.5),
                ..FaultParams::default()
            },
            0,
        );
        assert_eq!(p.worker_stall(), Some(Duration::from_millis(2)));
        assert_eq!(p.writer_delay(), Some(Duration::from_micros(500)));
    }
}
