//! Log-bucketed streaming histogram (HDR-style): fixed memory, mergeable,
//! O(buckets) percentile queries.
//!
//! Values (latencies in ms) are bucketed log-linearly: each power-of-two
//! octave is split into `2^SUB_BITS` linear sub-buckets, extracted
//! directly from the IEEE-754 exponent and top mantissa bits — no `log`
//! calls on the record path. A bucket's midpoint is reported for
//! percentiles, so the relative error is bounded by
//! [`Histogram::MAX_REL_ERROR`] (half a sub-bucket width). `count`,
//! `sum`, `min` and `max` are tracked exactly alongside the buckets, so
//! means are not quantized and percentile estimates clamp into the true
//! observed range (an n=1 histogram reports the exact value).
//!
//! Percentiles use the nearest-rank definition `rank = ceil(p·n)` (the
//! smallest value with at least `p·n` observations at or below it) — the
//! same oracle [`nearest_rank`] applies to an exact sorted slice. The
//! seed engine's `totals[n / 2]` read the *max* at n=2; rank `ceil(p·n)`
//! reads the min there, as p50 should.
//!
//! Built for the serving engine's per-worker latency shards (see
//! `coordinator::engine`): workers `record` into their own shard and the
//! stats path `merge`s shards into one histogram per latency kind, so
//! observing the system costs O(buckets), independent of how long the
//! engine has been serving.

/// Linear sub-buckets per octave = `2^SUB_BITS`.
const SUB_BITS: u32 = 6;
const SUBS: usize = 1 << SUB_BITS;
/// Smallest trackable exponent: values below `2^EXP_MIN` (≈ 1 ns in ms
/// units), zero, negatives and NaN land in the underflow bucket.
const EXP_MIN: i32 = -20;
/// One past the largest trackable exponent: values at or above
/// `2^EXP_MAX` ms (≈ 17.5 min) clamp into the top bucket.
const EXP_MAX: i32 = 20;
const OCTAVES: usize = (EXP_MAX - EXP_MIN) as usize;
/// Bucket 0 is the underflow bucket; the rest are log-linear.
const BUCKETS: usize = 1 + OCTAVES * SUBS;

/// Smallest value the log-linear buckets resolve (ms); below this the
/// underflow bucket absorbs the sample and percentile estimates fall
/// back to the exact `min`.
pub const MIN_TRACKABLE_MS: f64 = 9.5367431640625e-7; // 2^-20

/// Streaming latency histogram: fixed `BUCKETS`-sized memory regardless
/// of how many samples are recorded.
///
/// ```
/// use opima::util::histogram::Histogram;
///
/// let mut h = Histogram::new();
/// for v in [1.0, 2.0, 4.0] {
///     h.record(v);
/// }
/// assert_eq!(h.count(), 3);
/// assert!((h.mean() - 7.0 / 3.0).abs() < 1e-12); // mean is exact
/// assert_eq!(h.min(), 1.0);
/// assert_eq!(h.max(), 4.0);
/// // Nearest-rank p50 of {1, 2, 4} is 2, within the bucketing error.
/// assert!((h.percentile(0.5) - 2.0).abs() <= 2.0 * Histogram::MAX_REL_ERROR);
///
/// // Shards merge in O(buckets) — the serving engine's stats path.
/// let mut other = Histogram::new();
/// other.record(8.0);
/// h.merge(&other);
/// assert_eq!(h.summary().count, 4);
/// assert_eq!(h.max(), 8.0);
/// ```
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Worst-case relative error of a percentile estimate vs the exact
    /// nearest-rank value, for samples the log-linear buckets resolve:
    /// half a sub-bucket width, `2^-(SUB_BITS+1)` (< 0.79%).
    pub const MAX_REL_ERROR: f64 = 1.0 / (2u64 << SUB_BITS) as f64;

    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a value (callers sanitize NaN first). Total:
    /// negative/tiny values go to the underflow bucket, huge values
    /// clamp to the top bucket.
    fn index(v: f64) -> usize {
        if v < MIN_TRACKABLE_MS {
            return 0;
        }
        if v >= (1u64 << EXP_MAX) as f64 {
            return BUCKETS - 1;
        }
        let bits = v.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
        let sub = ((bits >> (52 - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        1 + (exp - EXP_MIN) as usize * SUBS + sub
    }

    /// Representative (midpoint) value of a bucket.
    fn bucket_mid(idx: usize) -> f64 {
        if idx == 0 {
            return 0.0;
        }
        let i = idx - 1;
        let scale = f64::powi(2.0, EXP_MIN + (i / SUBS) as i32);
        let lo = scale * (1.0 + (i % SUBS) as f64 / SUBS as f64);
        lo + scale / (2 * SUBS) as f64
    }

    /// Record one sample. O(1), no allocation. NaN counts as 0 (the
    /// underflow bucket) so min/max stay ordered and `clamp` stays safe.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        self.counts[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram into this one. O(buckets).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact streaming mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile estimate for `p` in (0, 1]: the midpoint
    /// of the bucket holding the rank-`ceil(p·n)` sample, clamped into
    /// the exact observed `[min, max]`. Within
    /// [`Histogram::MAX_REL_ERROR`] of the exact sorted-slice answer; 0
    /// when empty. O(buckets).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (idx, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_mid(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Snapshot of the standard summary quantities.
    pub fn summary(&self) -> Summary {
        Summary {
            count: self.count,
            mean: self.mean(),
            min: self.min(),
            max: self.max(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p99: self.percentile(0.99),
            p999: self.percentile(0.999),
        }
    }
}

/// Point-in-time summary of one latency distribution (ms).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Summary {
    pub count: u64,
    /// Exact streaming mean.
    pub mean: f64,
    /// Exact minimum.
    pub min: f64,
    /// Exact maximum.
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    /// p99.9.
    pub p999: f64,
}

/// Exact nearest-rank percentile of an ascending-sorted non-empty slice:
/// `sorted[ceil(p·n) - 1]` with the rank clamped into `[1, n]`. The
/// oracle the histogram approximates — and the correct form of the
/// seed's `totals[n / 2]` (which read the max at n=2 for p50).
pub fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty slice");
    let n = sorted.len();
    let rank = ((p * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_all_zero() {
        let h = Histogram::new();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 0.0);
        assert_eq!(s.p50, 0.0);
    }

    #[test]
    fn single_value_is_exact() {
        let mut h = Histogram::new();
        h.record(3.7);
        let s = h.summary();
        assert_eq!(s.count, 1);
        assert_eq!(s.mean, 3.7);
        // min/max clamping makes every percentile of n=1 exact.
        assert_eq!(s.p50, 3.7);
        assert_eq!(s.p999, 3.7);
    }

    #[test]
    fn n2_p50_reads_the_lower_sample() {
        // The off-by-one this subsystem fixes: the seed's `totals[n/2]`
        // reported the max of two samples as p50.
        let mut h = Histogram::new();
        h.record(1.0);
        h.record(100.0);
        assert!(h.percentile(0.5) < 1.01, "p50 of {{1, 100}} is 1");
        assert!(h.percentile(0.99) > 99.0, "p99 of {{1, 100}} is 100");
    }

    #[test]
    fn percentiles_within_relative_error_bound() {
        let mut h = Histogram::new();
        let vals: Vec<f64> = (1..=1000).map(|i| i as f64 * 0.13).collect();
        for &v in &vals {
            h.record(v);
        }
        for &p in &[0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let exact = nearest_rank(&vals, p);
            let est = h.percentile(p);
            assert!(
                (est - exact).abs() <= exact * Histogram::MAX_REL_ERROR,
                "p{p}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_equals_single_histogram() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 0..500 {
            let v = 0.01 * (i as f64 + 1.0);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            whole.record(v);
        }
        a.merge(&b);
        let (m, w) = (a.summary(), whole.summary());
        // Bucket counts, min and max are order-insensitive, so the
        // percentiles match exactly; the mean's summation order differs
        // (evens+odds vs interleaved), so it only matches to rounding.
        assert_eq!(m.count, w.count);
        assert_eq!(m.min, w.min);
        assert_eq!(m.max, w.max);
        assert_eq!(m.p50, w.p50);
        assert_eq!(m.p90, w.p90);
        assert_eq!(m.p99, w.p99);
        assert_eq!(m.p999, w.p999);
        assert!((m.mean - w.mean).abs() <= w.mean * 1e-12);
    }

    #[test]
    fn extreme_values_are_total() {
        let mut h = Histogram::new();
        for v in [0.0, -5.0, f64::NAN, 1e-12, 1e9, f64::INFINITY, 2.5] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        // Every sample landed in some bucket; percentiles stay finite
        // and ordered (max is +inf by exact tracking, p50 is bucketed).
        assert!(h.percentile(0.5).is_finite());
    }

    #[test]
    fn memory_is_fixed() {
        let mut h = Histogram::new();
        let before = h.counts.len();
        for i in 0..100_000 {
            h.record((i % 977) as f64 * 0.003);
        }
        assert_eq!(h.counts.len(), before, "no growth with sample count");
        assert_eq!(h.count(), 100_000);
    }

    #[test]
    fn nearest_rank_oracle() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(nearest_rank(&v, 0.5), 2.0);
        assert_eq!(nearest_rank(&v, 0.25), 1.0);
        assert_eq!(nearest_rank(&v, 0.75), 3.0);
        assert_eq!(nearest_rank(&v, 1.0), 4.0);
        assert_eq!(nearest_rank(&[1.0, 100.0], 0.5), 1.0, "n=2 p50 is the min");
        assert_eq!(nearest_rank(&[7.0], 0.999), 7.0);
    }
}
