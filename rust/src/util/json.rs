//! Minimal JSON parser and writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for the AOT artifact manifest and for
//! exporting experiment results. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}",
                b as char, self.pos
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(Error::Json(format!("expected , or }} at {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(Error::Json(format!("expected , or ] at {}", self.pos))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?,
                                16,
                            )
                            .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
                None => return Err(Error::Json("unterminated string".into())),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}'")))
    }
}

/// Escape and quote a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write!(f, "{}", escape(s)),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let text = r#"{
            "artifacts": {
                "cnn_fp32_b8": {"inputs": [{"shape": [8, 12, 12, 1], "dtype": "float32"}],
                                 "output_shape": [8, 4]},
                "photonic_mac_4b": {"bits": 4}
            },
            "batch": 8, "ok": true, "nothing": null
        }"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap();
        let shape = arts
            .get("cnn_fp32_b8")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 4);
        assert_eq!(shape[0].as_f64(), Some(8.0));
        assert_eq!(v.get("batch").unwrap().as_f64(), Some(8.0));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(v.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3e2],"b":"x\"y\n","c":{"d":false}}"#;
        let v = Json::parse(text).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
