//! Self-contained utility substrates.
//!
//! This build environment is fully offline with a small vendored crate set
//! (the `xla` closure + `anyhow`/`thiserror`), so the usual ecosystem
//! crates (serde/serde_json/toml/rand/criterion/proptest) are unavailable.
//! Per the reproduction ground rules we build the substrates we need:
//!
//! - [`json`] — minimal JSON parser/writer (artifact manifests, result
//!   export).
//! - [`tomlite`] — a TOML subset parser (flat `[section]` tables with
//!   scalar values) for experiment configs.
//! - [`prng`] — SplitMix64/Xoshiro256** deterministic PRNG (workloads,
//!   property tests) with unbiased Lemire bounded sampling.
//! - [`fault`] — the deterministic, seeded fault-injection plane behind
//!   the `[fault]` config section: per-site Bernoulli schedules derived
//!   from one seed + a site salt, free (one branch) when disarmed.
//! - [`bench`] — a criterion-style measurement harness for `cargo bench`
//!   targets (warmup, N samples, mean/median/stddev reporting), plus
//!   machine-readable `BENCH_<name>.json` summaries and the
//!   `OPIMA_BENCH_SMOKE` one-sample mode CI uses to gate the schema.
//! - [`histogram`] — log-bucketed streaming histogram (HDR-style): fixed
//!   memory, mergeable shards, O(buckets) nearest-rank percentiles. The
//!   one percentile implementation shared by the serving engine's
//!   streaming stats and the offline analyzer.
//! - [`ring`] — fixed-capacity ring buffer with monotonic sequence
//!   numbers (the engine's bounded response history).
//! - [`units`] — zero-cost units-of-measure newtypes (`Nanos`, `Millis`,
//!   `Millijoules`, `Milliwatts`, `Bytes`) and the only sanctioned
//!   ns↔ms conversion sites in the crate.

pub mod bench;
pub mod fault;
pub mod histogram;
pub mod json;
pub mod prng;
pub mod ring;
pub mod tomlite;
pub mod units;
