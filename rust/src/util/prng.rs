//! Deterministic PRNG: SplitMix64 seeding + Xoshiro256** core.
//!
//! Used by workload generators, the serving driver, and the in-repo
//! property-test harness. Deterministic across platforms and runs.

/// Xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exactly uniform integer in [0, n) via Lemire's multiply-shift
    /// reduction with rejection. A plain `next_u64() % n` carries modulo
    /// bias: low residues receive ⌈2^64/n⌉ of the 2^64 equally-likely
    /// draws while high residues receive only ⌊2^64/n⌋ — a skew that
    /// load-generator arrival sampling inherits. The rejection loop
    /// removes the bias and almost never iterates (reject probability
    /// < n/2^64, exactly 0 for powers of two).
    pub fn bounded(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut m = self.next_u64() as u128 * n as u128;
        if (m as u64) < n {
            let t = n.wrapping_neg() % n; // (2^64 - n) mod n
            while (m as u64) < t {
                m = self.next_u64() as u128 * n as u128;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi) — hi exclusive, hi > lo. Unbiased.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.bounded(hi - lo)
    }

    /// Uniform usize in [0, n). Unbiased.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        self.bounded(n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponentially distributed with the given rate (for Poisson arrivals).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.index(i + 1);
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn bounded_in_range_and_deterministic() {
        let mut a = Rng::new(17);
        let mut b = Rng::new(17);
        for n in [1u64, 2, 3, 7, 1 << 32, u64::MAX] {
            for _ in 0..200 {
                let x = a.bounded(n);
                assert!(x < n);
                assert_eq!(x, b.bounded(n));
            }
        }
    }

    #[test]
    fn bounded_is_unbiased_mod3() {
        // With `% 3` the residues of 2^64 draws split 1-extra/1-extra/
        // 0-extra; Lemire+rejection must be exactly uniform. 30k draws,
        // expected 10k each, σ ≈ 82 → a 500 tolerance is > 6σ.
        let mut r = Rng::new(23);
        let mut counts = [0u64; 3];
        for _ in 0..30_000 {
            counts[r.bounded(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 500, "counts {counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
