//! Fixed-capacity ring buffer with monotonic sequence numbers.
//!
//! Backs the serving engine's bounded response history: the ring retains
//! only the last `capacity` items, but every item ever pushed gets a
//! monotonically increasing sequence number (its push index), so tailing
//! consumers can express "everything since my high-water mark" with
//! [`Ring::since`] and detect eviction gaps by comparing cursors.

use std::collections::VecDeque;

/// A bounded FIFO: pushing beyond capacity evicts the oldest item.
///
/// ```
/// use opima::util::ring::Ring;
///
/// let mut r = Ring::new(2);
/// r.push("a");
/// r.push("b");
/// r.push("c"); // evicts "a"
/// assert_eq!(r.to_vec(), vec!["b", "c"]);
/// assert_eq!(r.len(), 2);
/// assert_eq!(r.pushed(), 3);    // sequence numbers keep counting
/// assert_eq!(r.first_seq(), 1); // "a" (seq 0) was evicted
/// // Tail everything at or after sequence 2:
/// assert_eq!(r.since(2), vec!["c"]);
/// ```
#[derive(Debug, Clone)]
pub struct Ring<T> {
    buf: VecDeque<T>,
    cap: usize,
    pushed: u64,
}

impl<T> Ring<T> {
    /// Create a ring retaining at most `cap` items (`cap ≥ 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "ring capacity must be at least 1");
        Self {
            buf: VecDeque::with_capacity(cap),
            cap,
            pushed: 0,
        }
    }

    /// Append an item, evicting the oldest when full. O(1) amortized.
    pub fn push(&mut self, item: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(item);
        self.pushed += 1;
    }

    /// Items currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Maximum number of retained items.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total items ever pushed — also the sequence number the *next*
    /// push will get, i.e. the cursor one past the newest retained item.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Sequence number of the oldest retained item (= `pushed` when
    /// empty). Items below this have been evicted.
    pub fn first_seq(&self) -> u64 {
        self.pushed - self.buf.len() as u64
    }

    /// Iterate the retained items, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }
}

impl<T: Clone> Ring<T> {
    /// Clone out all retained items, oldest first.
    pub fn to_vec(&self) -> Vec<T> {
        self.buf.iter().cloned().collect()
    }

    /// Clone out the retained items with sequence number ≥ `seq`, oldest
    /// first. Items older than `seq` that were already evicted are — by
    /// design — not reconstructible; a consumer whose cursor fell behind
    /// `first_seq()` has lost the gap.
    pub fn since(&self, seq: u64) -> Vec<T> {
        let skip = seq.saturating_sub(self.first_seq()) as usize;
        self.buf.iter().skip(skip).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_within_capacity_keeps_all() {
        let mut r = Ring::new(4);
        for i in 0..3 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.pushed(), 3);
        assert_eq!(r.first_seq(), 0);
        assert_eq!(r.to_vec(), vec![0, 1, 2]);
    }

    #[test]
    fn overflow_evicts_oldest() {
        let mut r = Ring::new(3);
        for i in 0..10 {
            r.push(i);
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.pushed(), 10);
        assert_eq!(r.first_seq(), 7);
        assert_eq!(r.to_vec(), vec![7, 8, 9]);
    }

    #[test]
    fn since_respects_cursor_and_eviction() {
        let mut r = Ring::new(4);
        for i in 0..6 {
            r.push(i);
        }
        // Retained: seqs 2..6.
        assert_eq!(r.since(0), vec![2, 3, 4, 5], "evicted gap is gone");
        assert_eq!(r.since(3), vec![3, 4, 5]);
        assert_eq!(r.since(6), Vec::<i32>::new(), "cursor at head: empty");
        assert_eq!(r.since(99), Vec::<i32>::new());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = Ring::<u8>::new(0);
    }
}
