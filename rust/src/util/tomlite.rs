//! A TOML-subset parser ("tomlite") for experiment configs.
//!
//! Supports what our configs need: `[section]` and `[section.sub]`
//! headers, `key = value` pairs with string / float / integer / boolean
//! values, comments (`#`), and blank lines. No arrays-of-tables, no
//! multi-line strings, no dotted keys.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parsed document: `section.key` → value (root keys have no dot).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Toml(format!("line {}: bad section", lineno + 1)))?
                    .trim();
                if name.is_empty() {
                    return Err(Error::Toml(format!("line {}: empty section", lineno + 1)));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| Error::Toml(format!("line {}: expected key = value", lineno + 1)))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::Toml(format!("line {}: empty key", lineno + 1)));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, parse_value(val.trim(), lineno + 1)?);
        }
        Ok(Doc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// Float lookup with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// Usize lookup with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    /// Keys not consumed by the caller can be detected for strictness.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value> {
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| Error::Toml(format!("line {lineno}: unterminated string")))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    text.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| Error::Toml(format!("line {lineno}: bad value '{text}'")))
}

/// Serialize section→(key→value) maps in deterministic order.
pub fn to_string(sections: &BTreeMap<String, BTreeMap<String, Value>>) -> String {
    let mut out = String::new();
    for (section, kv) in sections {
        if !section.is_empty() {
            out.push_str(&format!("[{section}]\n"));
        }
        for (k, v) in kv {
            let vs = match v {
                Value::Str(s) => format!("\"{s}\""),
                Value::Float(f) => {
                    if f.fract() == 0.0 {
                        format!("{f:.1}")
                    } else {
                        format!("{f}")
                    }
                }
                Value::Int(i) => format!("{i}"),
                Value::Bool(b) => format!("{b}"),
            };
            out.push_str(&format!("{k} = {vs}\n"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
# top comment
title = "opima"

[geometry]
banks = 4            # inline comment
bits_per_cell = 4

[timing]
clock_ghz = 5.0
write_ns = 5e1
fast = false
"#,
        )
        .unwrap();
        assert_eq!(doc.get("title").unwrap().as_str(), Some("opima"));
        assert_eq!(doc.usize_or("geometry.banks", 0), 4);
        assert_eq!(doc.f64_or("timing.clock_ghz", 0.0), 5.0);
        assert_eq!(doc.f64_or("timing.write_ns", 0.0), 50.0);
        assert_eq!(doc.get("timing.fast").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = \"unterminated").is_err());
        assert!(Doc::parse("k = 1.2.3").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn roundtrip_via_to_string() {
        let mut sections = BTreeMap::new();
        let mut kv = BTreeMap::new();
        kv.insert("banks".into(), Value::Int(4));
        kv.insert("clock_ghz".into(), Value::Float(5.0));
        sections.insert("geometry".into(), kv);
        let text = to_string(&sections);
        let doc = Doc::parse(&text).unwrap();
        assert_eq!(doc.usize_or("geometry.banks", 0), 4);
        assert_eq!(doc.f64_or("geometry.clock_ghz", 0.0), 5.0);
    }
}
